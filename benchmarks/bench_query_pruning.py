"""Region pruning efficacy — pruned prefix scan vs naive full-table scan.

The paper's rowkey scheme (criterion 3) exists so a subset query touches
only the bytes its predicate can match.  PR 1 pushed predicates into the
*gather*; the GridQuery planner now pushes rowkey ranges into the *scan*:
a prefix plan resolves against region start keys and never visits the
regions outside its range.  This bench measures that win both ways:

- **measured**: wall time of executing the same per-site query as a pruned
  prefix plan vs an unpruned full-scan predicate plan (identical selected
  rows, warm executables, cold layout caches), on this host;
- **simulated**: the distributed scan phase under the paper's hardware
  constants (ClusterSim), where scan cost follows bytes a region server
  must touch.

Artifact: ``BENCH_query_pruning.json`` via benchmarks/run.py.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.balancer import greedy_allocation
from repro.core.grid import GridSession
from repro.core.simulator import ClusterSim, SimTask, paper_cluster
from repro.core.stats import MeanProgram
from repro.core.table import ColumnSpec, make_mip_table

N_SITES = 8
ROWS_PER_SITE = 160
PAYLOAD = (16, 16, 16)
REPS = 15
# the simulator projects the scan phase at archive scale (paper: ~5k images
# per study, multi-study archives): logical rows per region-server scan
LOGICAL_ROWS_PER_REGION = 1_000_000


def build_table(seed=0):
    """Multi-site layout: per-site rowkey prefixes, presplit per site, plus
    a redundant ``idx:site`` column so the unpruned baseline can select the
    same rows without a rowkey range."""
    rng = np.random.default_rng(seed)
    sites = [f"site{s}/" for s in range(N_SITES)]
    t = make_mip_table(
        payload_shape=PAYLOAD,
        extra_index_columns=[ColumnSpec("site", (), np.int16)],
        presplit_keys=sites[1:])
    n = N_SITES * ROWS_PER_SITE
    keys = [f"{sites[s]}img{i:05d}"
            for s in range(N_SITES) for i in range(ROWS_PER_SITE)]
    site_col = np.repeat(np.arange(N_SITES, dtype=np.int16), ROWS_PER_SITE)
    t.upload(keys, {
        "img": {"data": rng.normal(size=(n,) + PAYLOAD).astype(np.float32)},
        "idx": {"size": rng.integers(6_000_000, 20_000_001, n),
                "site": site_col}})
    return t, sites


def site_predicate(s):
    return lambda cols: cols["site"] == s


def _time_plans(session, reps=REPS, **make_plans):
    """Median wall times of cache-cold plan executions, warm executables.
    Variants run interleaved so drift hits them evenly."""
    for make_plan in make_plans.values():
        make_plan().collect()                   # warm up the XLA executables
    samples = {name: [] for name in make_plans}
    for _ in range(reps):
        for name, make_plan in make_plans.items():
            session._results.clear()            # cold results + partials,
            session.blocks.clear_partials()     # warm engine executables
            t0 = time.perf_counter()
            make_plan().collect()
            samples[name].append(time.perf_counter() - t0)
    return {name: float(np.median(s)) for name, s in samples.items()}


def simulate_scan(sim, nodes, alloc, scanned_regions, bytes_per_region):
    """Distributed scan phase: one task per region actually visited.
    Returns ``(wall_time, resource_time)`` — pruning's wall win is bounded
    by scan parallelism, but its resource win is the full region ratio."""
    tasks = [SimTask(i, input_bytes=bytes_per_region, output_bytes=0,
                     work=0.0, home_node=alloc[i % len(alloc)])
             for i in range(scanned_regions)]
    r = sim.run(tasks, "hadoop")
    return r.wall_time, r.resource_time


def run(verbose: bool = True):
    t, sites = build_table()
    session = GridSession(t, default_eta=32)
    index_row_nbytes = (t.column_spec("idx", "site").row_nbytes
                        + t.column_spec("idx", "size").row_nbytes)

    # identical selections, two plans: pruned prefix vs unpruned predicate
    sid = N_SITES // 2
    pruned_plan = lambda: session.scan(prefix=sites[sid]).map(MeanProgram())
    pred = site_predicate(sid)
    naive_plan = lambda: (session.scan()
                          .where(pred, ["site"]).map(MeanProgram()))

    # pre-PR1 mask path: gather EVERY region's payload, fold a masked subset
    # — what a scan without rowkey pruning physically does
    import jax

    from repro.core.placement import Placement
    from repro.core.query import mask_to_device_layout

    eta = session.default_eta
    sh = Placement.data_sharding(session.mesh, session.data_axis)
    site_mask = np.asarray(t.column("idx", "site")) == sid

    def mask_path():
        values, valid = session.placement.gather_column(
            "img", "data", chunk_size=eta)
        row_ids, lvalid = session.placement.device_layout(chunk_size=eta)
        rm = mask_to_device_layout(site_mask, row_ids, lvalid)
        res, _ = session.engine.run(
            MeanProgram(), jax.device_put(values, sh),
            jax.device_put(valid, sh), eta,
            row_mask=jax.device_put(rm, sh))
        return res

    rep_p = pruned_plan().stats()
    rep_n = naive_plan().stats()
    assert rep_p.query.rows_selected == rep_n.query.rows_selected \
        == ROWS_PER_SITE
    assert rep_p.query.regions_scanned == 1
    assert rep_p.query.regions_pruned == N_SITES - 1
    assert rep_n.query.regions_pruned == 0
    ref = np.asarray(pruned_plan().collect()[0])
    np.testing.assert_allclose(np.asarray(mask_path()), ref, atol=1e-5)

    walls = _time_plans(session, pruned=pruned_plan, naive=naive_plan)
    wall_pruned, wall_naive = walls["pruned"], walls["naive"]
    mask_samples = []
    for _ in range(REPS):
        t0 = time.perf_counter()
        mask_path()
        mask_samples.append(time.perf_counter() - t0)
    wall_mask = float(np.median(mask_samples))

    # simulator: scan cost under paper constants follows regions visited,
    # projected to archive-scale regions (index bytes only — the §2.3 scheme)
    nodes = paper_cluster()
    region_bytes = {i: ROWS_PER_SITE * 13_000_000 for i in range(N_SITES)}
    alloc = greedy_allocation(region_bytes, nodes)
    sim = ClusterSim(nodes, bandwidth=70e6)
    idx_bytes_per_region = LOGICAL_ROWS_PER_REGION * index_row_nbytes
    sim_pruned, rt_pruned = simulate_scan(sim, nodes, alloc, 1,
                                          idx_bytes_per_region)
    sim_naive, rt_naive = simulate_scan(sim, nodes, alloc, N_SITES,
                                        idx_bytes_per_region)

    out = {
        "n_sites": N_SITES,
        "rows_per_site": ROWS_PER_SITE,
        "regions_scanned_pruned": rep_p.query.regions_scanned,
        "regions_pruned": rep_p.query.regions_pruned,
        "payload_bytes_moved": rep_p.query.payload_bytes_moved,
        "index_bytes_pruned": rep_p.query.index_bytes_scanned,
        "index_bytes_naive": rep_n.query.index_bytes_scanned,
        "wall_pruned_s": wall_pruned,
        "wall_naive_s": wall_naive,
        "wall_mask_path_s": wall_mask,
        "wall_speedup_vs_indexed": wall_naive / max(wall_pruned, 1e-12),
        "wall_speedup_vs_mask_path": wall_mask / max(wall_pruned, 1e-12),
        "sim_scan_pruned_s": sim_pruned,
        "sim_scan_naive_s": sim_naive,
        "sim_scan_speedup": sim_naive / max(sim_pruned, 1e-12),
        "sim_rt_pruned_s": rt_pruned,
        "sim_rt_naive_s": rt_naive,
        "sim_rt_speedup": rt_naive / max(rt_pruned, 1e-12),
    }
    if verbose:
        print(f"prefix scan: {out['regions_scanned_pruned']} region scanned, "
              f"{out['regions_pruned']} pruned "
              f"({out['payload_bytes_moved']:,} payload B moved)")
        print(f"measured wall: pruned {wall_pruned*1e3:.1f} ms, "
              f"indexed-unpruned {wall_naive*1e3:.1f} ms "
              f"({out['wall_speedup_vs_indexed']:.1f}x), "
              f"gather-all mask path {wall_mask*1e3:.1f} ms "
              f"({out['wall_speedup_vs_mask_path']:.1f}x)")
        print(f"simulated scan phase: wall pruned {sim_pruned:.3f} s vs "
              f"naive {sim_naive:.3f} s -> {out['sim_scan_speedup']:.1f}x; "
              f"resource {rt_pruned:.3f} s vs {rt_naive:.3f} s -> "
              f"{out['sim_rt_speedup']:.1f}x")
    return out


if __name__ == "__main__":
    run()
