"""Fault-tolerance overhead bench: what does the safety net cost when
nothing goes wrong — and how fast is recovery when something does?

Two interleaved arms run the SAME spill-pressure query workload:

- **plain** — no injector: the zero-cost fast path (``faults is None``
  guards every instrumented site).
- **armed** — an injector with a never-firing rule plus the retry
  policy: every ``fire()`` call, retry wrapper, and spill CRC
  write/verify is live, but no fault ever triggers.

``fault_overhead_ratio`` (gated, lower is better, ≤ 1.05) is the
median of per-round paired armed/plain wall ratios: each round times
both arms back to back, so machine drift cancels inside the pair
instead of letting one arm's lucky minimum skew an unpaired min/min.

Recovery walls (informational, absolute seconds): re-deriving every
payload block after a full spill-tier corruption, and re-homing after a
permanent owner loss (single-device: host-degraded serving).
"""

from __future__ import annotations

import os
import shutil
import tempfile
import time

import numpy as np

import jax

from repro.core.faults import FaultInjector, FaultRule, RetryPolicy
from repro.core.grid import GridSession
from repro.core.stats import MeanProgram, VarianceProgram
from repro.core.table import make_mip_table

N_REGIONS = 12
PER_REGION = 8
PAYLOAD = (32, 32)                      # 4 KB float32 rows
ROW_BYTES = int(np.prod(PAYLOAD)) * 4


def _make_table(seed=0):
    rng = np.random.default_rng(seed)
    groups = [f"g{i:02d}" for i in range(N_REGIONS)]
    t = make_mip_table(payload_shape=PAYLOAD, presplit_keys=groups[1:])
    keys = [f"{g}x{i:04d}" for g in groups for i in range(PER_REGION)]
    n = len(keys)
    t.upload(keys, {
        "img": {"data": rng.normal(size=(n,) + PAYLOAD).astype(np.float32)},
        "idx": {"size": rng.integers(6_000_000, 20_000_001, n)}})
    return t


def _session(t, spill_root, armed: bool):
    total = N_REGIONS * PER_REGION * ROW_BYTES
    kw = dict(default_eta=PER_REGION,
              device_budget=total // 8, host_budget=total // 4,
              spill_dir=tempfile.mkdtemp(dir=spill_root), prefetch=False)
    if armed:
        # a rule that can never fire: the full instrumentation path runs
        # (site counters, rule scan, retry wrappers, spill CRC), but the
        # workload itself is fault-free
        kw["fault_injector"] = FaultInjector(rules=(
            FaultRule(site="gather", kind="transient", after=10 ** 9),))
        kw["retry_policy"] = RetryPolicy()
    return GridSession(t, **kw)


def _one_pass(t, spill_root, armed: bool, expect) -> float:
    """Cold query + partial-less repeat: gathers, folds, demotes, spills,
    then re-reads spill files — the whole checksummed surface."""
    s = _session(t, spill_root, armed)
    try:
        t0 = time.perf_counter()
        res, _ = s.run(MeanProgram())
        jax.block_until_ready(res)
        s.blocks.clear_partials()
        s._results.clear()
        res, _ = s.run(MeanProgram())
        jax.block_until_ready(res)
        wall = time.perf_counter() - t0
        np.testing.assert_allclose(np.asarray(res), expect, atol=1e-4)
        if armed:
            assert s.blocks.stats.faults_injected == 0
    finally:
        s.close()
    return wall


def _corrupt_recovery(t, spill_root, expect) -> float:
    """Mangle EVERY spilled payload, then time the lossless re-derive."""
    s = _session(t, spill_root, armed=True)
    try:
        s.run(MeanProgram())
        spill = s.blocks.spill_dir
        payloads = [f for f in os.listdir(spill) if f.endswith(".npy")]
        for f in payloads:
            p = os.path.join(spill, f)
            with open(p, "r+b") as fh:
                fh.seek(os.path.getsize(p) // 2)
                fh.write(b"\xff\xff\xff\xff")
        t0 = time.perf_counter()
        res, _ = s.run(VarianceProgram())
        jax.block_until_ready(res["var"])
        wall = time.perf_counter() - t0
        np.testing.assert_allclose(
            np.asarray(res["var"]),
            t.column("img", "data").astype(np.float64).var(0), atol=1e-3)
        assert s.blocks.stats.spill_corruptions >= len(payloads) > 0
    finally:
        s.close()
    return wall


def _quarantine_recovery(t, spill_root, expect) -> float:
    """Kill the (only local) device after warmup; time the degraded
    re-fold that the quarantine path serves from host copies."""
    s = _session(t, spill_root, armed=True)
    try:
        s.run(MeanProgram())
        s.faults.lost_devices.add(0)
        t0 = time.perf_counter()
        res, _ = s.run(VarianceProgram())
        jax.block_until_ready(res["var"])
        wall = time.perf_counter() - t0
        assert s.blocks.stats.quarantines == 1
        np.testing.assert_allclose(
            np.asarray(res["var"]),
            t.column("img", "data").astype(np.float64).var(0), atol=1e-3)
    finally:
        s.close()
    return wall


def run(smoke: bool = False, verbose: bool = True):
    t = _make_table()
    expect = t.column("img", "data").astype(np.float64).mean(0)
    # paired rounds: each round times plain then armed back to back and
    # contributes ONE ratio — the ±20% run-to-run wall noise is shared
    # drift that divides out, so the median ratio is tight enough for a
    # ±5% gate where an unpaired min/min is not
    rounds = 5 if smoke else 7
    spill_root = tempfile.mkdtemp(prefix="bench-faults-")
    try:
        # one throwaway pass per arm absorbs jit compilation
        _one_pass(t, spill_root, armed=False, expect=expect)
        _one_pass(t, spill_root, armed=True, expect=expect)
        plain, armed = [], []
        for _ in range(rounds):          # interleaved: drift hits both arms
            plain.append(_one_pass(t, spill_root, False, expect))
            armed.append(_one_pass(t, spill_root, True, expect))
        corrupt_s = _corrupt_recovery(t, spill_root, expect)
        quarantine_s = _quarantine_recovery(t, spill_root, expect)
    finally:
        shutil.rmtree(spill_root, ignore_errors=True)

    ratios = sorted(a / p for a, p in zip(armed, plain))
    b = {
        "rounds": rounds,
        "plain_wall_s": min(plain),
        "armed_wall_s": min(armed),
        "fault_overhead_ratio": ratios[len(ratios) // 2],
        "corrupt_recovery_wall_s": corrupt_s,
        "corrupt_recovery_over_plain": corrupt_s / min(plain),
        "quarantine_recovery_wall_s": quarantine_s,
    }
    if verbose:
        for k, v in b.items():
            print(f"  {k}: {v}")
    return b


if __name__ == "__main__":
    run()
