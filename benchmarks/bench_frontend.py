"""GridFrontend serving bench — the PR-7 wall-clock acceptance.

Closed-loop client threads drive one :class:`GridFrontend` through three
mixes, each run twice — coalescing ON vs OFF (the no-coalesce control
executes every query independently, like clients sharing a bare session
behind a lock-free thread pool):

1. **repeat-heavy**   — every client re-asks the same warm statistic; the
   single-flight registry should collapse the stream to ~zero executions
   (the gated ``coalesce_speedup_repeat`` ratio).
2. **group-by-heavy** — clients cycle distinct programs over one grouped
   scan; the tick scheduler merges them into shared fused passes.
3. **mutation-interleaved** — the repeat mix with periodic uploads
   draining in-flight queries; measures serving under epoch churn.

Reported per arm: sustained queries/sec, p50/p99 service latency,
coalesce ratio (hits / submissions).  Artifact: ``BENCH_frontend.json``
via benchmarks/run.py (also in ``--smoke``; CI gates
``coalesce_speedup_repeat`` via perf_baselines.json).
"""

from __future__ import annotations

import threading
import time

import numpy as np

from repro.core.frontend import GridFrontend
from repro.core.grid import GridSession
from repro.core.regions import HierarchicalSplitPolicy
from repro.core.stats import CountProgram, MeanProgram, VarianceProgram
from repro.core.table import ColumnSpec, make_mip_table

N_ROWS = 256
PAYLOAD = (8, 8)
ETA = 8
CLIENTS = 8
QUERIES_SMOKE = 30           # per client per arm
QUERIES_FULL = 120
TICK_MS = 1.0
MUTATION_ROUNDS = 4


def _make_table(seed=0):
    rng = np.random.default_rng(seed)
    t = make_mip_table(
        payload_shape=PAYLOAD,
        extra_index_columns=[ColumnSpec("age", (), np.float32),
                             ColumnSpec("sex", (), np.int8)],
        split_policy=HierarchicalSplitPolicy(max_region_bytes=4096),
    )
    n = N_ROWS
    t.upload(
        [f"img{i:05d}" for i in range(n)],
        {"img": {"data": rng.normal(size=(n,) + PAYLOAD)
                 .astype(np.float32)},
         "idx": {"size": rng.integers(6_000_000, 20_000_001, n),
                 "age": rng.uniform(4, 80, n).astype(np.float32),
                 "sex": rng.integers(0, 2, n).astype(np.int8)}},
    )
    return t


def _mutation_batch(r, seed):
    rng = np.random.default_rng(seed)
    keys = [f"mut{r}_{j}" for j in range(2)]
    n = len(keys)
    return keys, {
        "img": {"data": rng.normal(size=(n,) + PAYLOAD)
                .astype(np.float32)},
        "idx": {"size": rng.integers(6_000_000, 20_000_001, n),
                "age": rng.uniform(4, 80, n).astype(np.float32),
                "sex": rng.integers(0, 2, n).astype(np.int8)}}


def _drive(fe: GridFrontend, plans, queries_per_client: int,
           mutate: bool = False) -> dict:
    """Closed loop: CLIENTS threads each issue ``queries_per_client``
    queries round-robin over ``plans``; optionally a mutator thread
    uploads between rounds.  Returns qps/latency/coalesce numbers."""
    errors = []
    served0 = fe.stats.snapshot().served       # warm-up queries
    fe.stats.reset_latencies()                 # steady-state percentiles
    barrier = threading.Barrier(CLIENTS + 1)

    def client(i):
        try:
            barrier.wait()
            for q in range(queries_per_client):
                plan = plans[(i + q) % len(plans)]
                fe.query(plan, timeout=300)
        except BaseException as e:   # noqa: BLE001 — surfaced below
            errors.append(e)

    threads = [threading.Thread(target=client, args=(i,))
               for i in range(CLIENTS)]
    for t in threads:
        t.start()
    barrier.wait()
    t0 = time.perf_counter()
    if mutate:
        for r in range(MUTATION_ROUNDS):
            time.sleep(0.02)
            keys, data = _mutation_batch(r, seed=r + 100)
            fe.upload(keys, data, on_duplicate="overwrite")
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    if errors:
        raise errors[0]
    stats = fe.stats.snapshot()
    p50, p99 = fe.stats.latency_percentiles()
    total = CLIENTS * queries_per_client
    assert stats.served - served0 == total, (stats.served, served0, total)
    return {
        "queries": total,
        "wall_s": wall,
        "qps": total / max(wall, 1e-9),
        "p50_ms": p50 * 1e3,
        "p99_ms": p99 * 1e3,
        "coalesce_ratio": stats.coalesce_hits / max(stats.submitted, 1),
        "batch_merges": stats.batch_merges,
        "ticks": stats.ticks,
        "mutations": stats.mutations,
    }


def _arm(make_plans, queries: int, coalesce: bool,
         mutate: bool = False) -> dict:
    """One (mix, mode) measurement on a fresh session — cold caches for
    both modes, one warm-up pass so the gated ratio compares steady-state
    serving, not first-touch compilation."""
    s = GridSession(_make_table(), default_eta=ETA)
    plans = make_plans(s)
    with GridFrontend(s, workers=CLIENTS, tick_ms=TICK_MS,
                      max_pending=4 * CLIENTS * len(plans),
                      coalesce=coalesce) as fe:
        for plan in plans:                       # warm: compile + caches
            fe.query(plan, timeout=300)
        return _drive(fe, plans, queries, mutate=mutate)


def run(verbose: bool = True, smoke: bool = True) -> dict:
    queries = QUERIES_SMOKE if smoke else QUERIES_FULL

    def repeat_plans(s):
        return [s.scan().map(MeanProgram()).reduce()]

    def grouped_plans(s):
        base = s.scan().group_by("idx:sex")
        return [base.map(MeanProgram()).reduce(),
                base.map(VarianceProgram()).reduce(),
                base.map(CountProgram()).reduce()]

    arms = {}
    # the mutation mix drives the grouped plans: each upload clears the
    # flight registry, so the post-mutation burst arrives cold with three
    # distinct programs — the tick scheduler's merge path under churn
    for mix, make_plans, mutate in (
            ("repeat", repeat_plans, False),
            ("grouped", grouped_plans, False),
            ("mutation", grouped_plans, True)):
        arms[f"{mix}_coalesced"] = _arm(make_plans, queries,
                                        coalesce=True, mutate=mutate)
        arms[f"{mix}_baseline"] = _arm(make_plans, queries,
                                       coalesce=False, mutate=mutate)

    def speedup(mix):
        return (arms[f"{mix}_coalesced"]["qps"]
                / max(arms[f"{mix}_baseline"]["qps"], 1e-9))

    out = {
        "n_rows": N_ROWS,
        "clients": CLIENTS,
        "queries_per_client": queries,
        "tick_ms": TICK_MS,
        "coalesce_speedup_repeat": speedup("repeat"),
        "coalesce_speedup_grouped": speedup("grouped"),
        "coalesce_speedup_mutation": speedup("mutation"),
        **{f"{arm}_{k}": v for arm, d in arms.items()
           for k, v in d.items()},
    }
    # acceptance: coalesced serving at least doubles repeat throughput
    assert out["coalesce_speedup_repeat"] >= 2.0, (
        arms["repeat_coalesced"], arms["repeat_baseline"])
    if verbose:
        for mix in ("repeat", "grouped", "mutation"):
            c, b = arms[f"{mix}_coalesced"], arms[f"{mix}_baseline"]
            print(f"{mix:>9}: {c['qps']:8.0f} qps coalesced "
                  f"(p50={c['p50_ms']:.2f}ms p99={c['p99_ms']:.2f}ms, "
                  f"coalesce={c['coalesce_ratio']:.2f}, "
                  f"merges={c['batch_merges']}) vs "
                  f"{b['qps']:8.0f} qps baseline -> "
                  f"{speedup(mix):.1f}x")
    return out


if __name__ == "__main__":
    import argparse
    import json

    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--smoke", action="store_true",
                        help="CI-fast query counts")
    args = parser.parse_args()
    out = run(smoke=args.smoke)
    with open("BENCH_frontend.json", "w") as f:
        json.dump({"bench": "frontend", **out}, f, indent=2, sort_keys=True)
    print("wrote BENCH_frontend.json")
