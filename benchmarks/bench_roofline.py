"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads ``artifacts/dryrun/*.json`` (written by ``repro.launch.dryrun``) and
prints, per single-pod (arch × shape) cell: the three roofline terms, the
dominant one, per-device memory, and MODEL_FLOPS/HLO_FLOPS.
"""

from __future__ import annotations

import glob
import json
import os

ART = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "artifacts", "dryrun")


def load_cells(mesh="single"):
    cells = []
    for path in sorted(glob.glob(os.path.join(ART, f"*__{mesh}.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def run(verbose: bool = True, mesh: str = "single"):
    cells = load_cells(mesh)
    rows = []
    header = (f"{'arch':18s} {'shape':12s} {'st':4s} {'dom':10s} "
              f"{'comp_s':>9s} {'mem_s':>9s} {'coll_s':>9s} "
              f"{'frac':>6s} {'useful':>6s} {'GB/dev':>7s} fits")
    if verbose:
        print(header)
        print("-" * len(header))
    for c in cells:
        if c["status"] == "skipped":
            if verbose:
                print(f"{c['arch']:18s} {c['shape']:12s} skip   "
                      f"({c['reason'][:60]})")
            continue
        if c["status"] == "error":
            if verbose:
                print(f"{c['arch']:18s} {c['shape']:12s} ERR    "
                      f"{c['error'][:70]}")
            continue
        r = c.get("roofline")
        gb = c["per_device_bytes"] / 1e9
        if r is None:
            if verbose:
                print(f"{c['arch']:18s} {c['shape']:12s} ok     (no probes)"
                      f"{'':40s}{gb:7.1f} {c['fits_v5e']}")
            continue
        rows.append({**{k: c[k] for k in ("arch", "shape")}, **r,
                     "gb_per_dev": gb, "fits": c["fits_v5e"]})
        if verbose:
            print(f"{c['arch']:18s} {c['shape']:12s} ok   {r['dominant']:10s} "
                  f"{r['compute_s']:9.2e} {r['memory_s']:9.2e} "
                  f"{r['collective_s']:9.2e} {r['compute_fraction']:6.3f} "
                  f"{r['useful_flops_ratio']:6.2f} {gb:7.1f} {c['fits_v5e']}")
    return {"rows": rows}


if __name__ == "__main__":
    run()
