"""Block-granular fold engine microbench — the PR's wall-clock acceptance.

Three query regimes at the 16-region smoke size, against the PR-3 baseline
(full re-fold of the assembled ``[D, C, ...]`` layout, which is what a warm
plan-cache hit used to execute):

1. **cold**  — first ``.stats()``: gather + fold every block, compile;
2. **warm**  — repeat on an unchanged epoch: result-cache hit, ZERO rows
   folded (``QueryStats.rows_folded == 0``);
3. **one-dirty-region** — overwrite one row, repeat: only that region's
   block re-folds and re-merges.

Plus the fused-program CSE comparison: FLOPs (XLA ``cost_analysis`` of the
per-block fold executable) and wall time of a CSE'd vs naive fused
mean+variance+moments fold over the same chunk stream.

Artifact: ``BENCH_fold_engine.json`` via benchmarks/run.py (also in
``--smoke``; CI uploads it).
"""

from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.grid import GridSession
from repro.core.mapreduce import MapReduceEngine
from repro.core.placement import Placement
from repro.core.stats import (
    FusedProgram,
    MeanProgram,
    MomentsProgram,
    VarianceProgram,
)
from repro.core.table import make_mip_table
from repro.utils import make_mesh

N_ROWS = 512
N_REGIONS = 16
PAYLOAD = (32, 32)
ETA = 8
REPS = 15


def _make_table(seed=0):
    rng = np.random.default_rng(seed)
    groups = [f"g{i:02d}" for i in range(N_REGIONS)]
    t = make_mip_table(payload_shape=PAYLOAD, presplit_keys=groups[1:])
    per = N_ROWS // N_REGIONS
    keys = [f"{g}x{i:04d}" for g in groups for i in range(per)]
    n = len(keys)
    t.upload(keys, {
        "img": {"data": rng.normal(size=(n,) + PAYLOAD).astype(np.float32)},
        "idx": {"size": rng.integers(6_000_000, 20_000_001, n)}})
    return t


def _timed(fn, reps=REPS):
    samples = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        samples.append(time.perf_counter() - t0)
    return float(np.median(samples))


def run(verbose: bool = True):
    program = MeanProgram()
    rng = np.random.default_rng(1)
    t = _make_table()
    s = GridSession(t, default_eta=ETA)

    # --- cold: gather + fold + compile everything ----------------------
    t0 = time.perf_counter()
    res, rep_cold = s.run(program)
    jax.block_until_ready(res)
    cold_s = time.perf_counter() - t0
    assert rep_cold.query.rows_folded == N_ROWS

    # --- warm: repeat .stats() on the unchanged epoch -------------------
    def warm():
        r, rep = s.run(program)
        assert rep.query.rows_folded == 0, rep.query          # acceptance
        assert rep.query.partials_reused == rep.query.partials_total
        return r
    warm_s = _timed(warm)
    _, rep_warm = s.run(program)

    # --- PR-3 baseline: full re-fold of the assembled layout ------------
    # (what a warm plan-cache hit executed before this PR: the layout and
    # executable are cached, but every row re-folds every call)
    vals, valid = s.placement.put_column(s.mesh, "img", "data",
                                         chunk_size=ETA)
    sh = Placement.data_sharding(s.mesh, s.data_axis)
    vals = jax.device_put(vals, sh)
    dvalid = jax.device_put(valid, sh)
    baseline_eng = MapReduceEngine(s.mesh)
    baseline_eng.run(program, vals, dvalid, ETA)              # compile
    refold_s = _timed(
        lambda: baseline_eng.run(program, vals, dvalid, ETA)[0])

    # --- one dirty region: overwrite a row, re-fold only its block ------
    group_keys = [f"g07x{i:04d}" for i in range(N_ROWS // N_REGIONS)]
    dirty_samples, dirty_rows, dirty_reused = [], 0, 0
    for i in range(REPS):
        key = group_keys[i % len(group_keys)]
        s.upload([key], {
            "img": {"data": rng.normal(size=(1,) + PAYLOAD)
                    .astype(np.float32)},
            "idx": {"size": rng.integers(6_000_000, 20_000_001, 1)}},
            on_duplicate="overwrite")
        t0 = time.perf_counter()
        r, rep = s.run(program)
        jax.block_until_ready(r)
        dirty_samples.append(time.perf_counter() - t0)
        q = rep.query
        assert q.partials_reused == q.partials_total - 1, q   # acceptance
        dirty_rows, dirty_reused = q.rows_folded, q.partials_reused
    dirty_s = float(np.median(dirty_samples))

    warm_speedup = refold_s / max(warm_s, 1e-9)
    assert warm_speedup >= 3.0, (warm_s, refold_s)            # acceptance

    # --- fused CSE vs naive fusion: FLOPs + wall ------------------------
    members = (MeanProgram(), VarianceProgram(), MomentsProgram())
    cse, naive = FusedProgram(members), FusedProgram(members, cse=False)
    eng = MapReduceEngine(make_mesh((1,), ("data",)))
    block_rows = N_ROWS // N_REGIONS
    cost_cse = eng.fold_cost(cse, block_rows, PAYLOAD, jnp.float32, ETA)
    cost_naive = eng.fold_cost(naive, block_rows, PAYLOAD, jnp.float32, ETA)
    big = jnp.asarray(rng.normal(size=(256,) + PAYLOAD).astype(np.float32))
    for p in (cse, naive):
        eng.fold_block(p, big, None, ETA, PAYLOAD, np.float32)  # compile
    cse_fold_s = _timed(
        lambda: eng.fold_block(cse, big, None, ETA, PAYLOAD, np.float32))
    naive_fold_s = _timed(
        lambda: eng.fold_block(naive, big, None, ETA, PAYLOAD, np.float32))

    out = {
        "n_rows": N_ROWS,
        "n_regions": len(t.regions),
        "payload_bytes_per_row": int(np.prod(PAYLOAD)) * 4,
        "eta": ETA,
        "cold_s": cold_s,
        "warm_s": warm_s,
        "pr3_full_refold_s": refold_s,
        "warm_speedup_vs_refold": warm_speedup,
        "warm_rows_folded": rep_warm.query.rows_folded,
        "warm_partials_reused": rep_warm.query.partials_reused,
        "warm_partials_total": rep_warm.query.partials_total,
        "one_dirty_region_s": dirty_s,
        "dirty_rows_folded": dirty_rows,
        "dirty_partials_reused": dirty_reused,
        "dirty_speedup_vs_refold": refold_s / max(dirty_s, 1e-9),
        "cse_fold_flops": cost_cse["flops"],
        "naive_fold_flops": cost_naive["flops"],
        "cse_flop_ratio": cost_cse["flops"] / max(cost_naive["flops"], 1e-9),
        "cse_fold_s": cse_fold_s,
        "naive_fold_s": naive_fold_s,
        "cse_wall_speedup": naive_fold_s / max(cse_fold_s, 1e-9),
    }
    if verbose:
        print(f"cold={cold_s*1e3:.1f}ms warm={warm_s*1e3:.2f}ms "
              f"pr3-refold={refold_s*1e3:.2f}ms "
              f"({warm_speedup:.0f}x warm win, rows_folded=0)")
        print(f"one-dirty-region={dirty_s*1e3:.2f}ms "
              f"(refolds {dirty_rows} rows, reuses "
              f"{dirty_reused}/{rep_warm.query.partials_total} partials)")
        print(f"fused CSE: {cost_cse['flops']:.0f} vs "
              f"{cost_naive['flops']:.0f} flops/block "
              f"({out['cse_flop_ratio']:.2f}x), wall "
              f"{cse_fold_s*1e3:.2f} vs {naive_fold_s*1e3:.2f} ms "
              f"({out['cse_wall_speedup']:.2f}x)")
    return out


if __name__ == "__main__":
    run()
