"""Grouped analytics microbench — one-pass group_by vs a per-group loop,
and tree-reduce vs funnel merge at high region counts.

Two comparisons back the PR's perf claims:

1. **grouped vs per-group loop** — ``scan().group_by("idx:site")
   .map(mean).map(variance)`` computes all G strata in ONE block pass
   (group-keyed partials, segment-summed CSE pool) against the workload it
   replaces: G separate predicate queries, each re-scanning the index and
   re-folding its subset.  Cold (fresh session) and warm (repeat on the
   same session) walls for both.
2. **tree vs funnel merge** — ``merge_finalize`` over many per-block
   partials on an 8-device mesh (subprocess with
   ``--xla_force_host_platform_device_count=8``), psum-tree against the
   forced single-device funnel.  Skipped gracefully (reported as 0) where
   the subprocess is unavailable.

Artifact: ``BENCH_group_by.json`` via benchmarks/run.py (also in
``--smoke``; CI uploads it and the perf gate checks the headline
``grouped_speedup_vs_loop``).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import numpy as np

import jax

from repro.core.grid import GridSession
from repro.core.regions import HierarchicalSplitPolicy
from repro.core.stats import MeanProgram, VarianceProgram
from repro.core.table import ColumnSpec, make_mip_table

N_REGIONS = 16
ROWS_PER_REGION = 32
PAYLOAD = (16, 16)
N_SITES = 8
ETA = 8
REPS = 10

MERGE_SNIPPET = """
import json, time
import numpy as np
import jax, jax.numpy as jnp
from repro.core.mapreduce import MapReduceEngine
from repro.core.stats import MeanProgram
from repro.utils import make_mesh

D, P, PAYLOAD, REPS = 8, %(n_partials)d, %(payload)s, %(reps)d
assert jax.device_count() == D
mesh = make_mesh((D,), ("data",))
devices = list(np.asarray(mesh.devices).flat)
program = MeanProgram()
rng = np.random.default_rng(0)
partials = []
owners = []
for i in range(P):
    owner = i %% D
    p = {"sum": jnp.asarray(rng.normal(size=PAYLOAD).astype(np.float32)),
         "count": jnp.asarray(np.float32(4.0))}
    partials.append(jax.device_put(p, devices[owner]))
    owners.append(owner)

def timed(eng, **kw):
    eng.merge_finalize(program, partials, PAYLOAD, np.float32, **kw)  # compile
    samples = []
    for _ in range(REPS):
        t0 = time.perf_counter()
        jax.block_until_ready(
            eng.merge_finalize(program, partials, PAYLOAD, np.float32, **kw))
        samples.append(time.perf_counter() - t0)
    return float(np.median(samples))

tree_eng = MapReduceEngine(mesh)
funnel_eng = MapReduceEngine(mesh, merge_strategy="funnel")
tree_s = timed(tree_eng, owners=owners)
funnel_s = timed(funnel_eng, owners=owners)
assert tree_eng.merge_path_counts["tree"] > 0
assert funnel_eng.merge_path_counts["funnel"] > 0
print("MERGE_JSON " + json.dumps({"tree_s": tree_s, "funnel_s": funnel_s}))
"""


def _make_table(seed=0):
    rng = np.random.default_rng(seed)
    groups = [f"g{i:02d}" for i in range(N_REGIONS)]
    t = make_mip_table(
        payload_shape=PAYLOAD,
        extra_index_columns=[ColumnSpec("site", (), np.int32)],
        split_policy=HierarchicalSplitPolicy(max_region_bytes=10**18),
        presplit_keys=groups[1:])
    keys = [f"{g}x{i:04d}" for g in groups for i in range(ROWS_PER_REGION)]
    n = len(keys)
    t.upload(keys, {
        "img": {"data": rng.normal(size=(n,) + PAYLOAD).astype(np.float32)},
        "idx": {"size": rng.integers(6_000_000, 20_000_001, n),
                "site": rng.integers(0, N_SITES, n).astype(np.int32)}})
    return t


def _timed(fn, reps=REPS):
    samples = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        samples.append(time.perf_counter() - t0)
    return float(np.median(samples))


def _clear_data_caches(s):
    """Forget results, partials, and resident blocks (compiled executables
    stay): the next query pays the full gather+fold, not the compile —
    the steady-state "cold data" regime a long-lived service sees."""
    s._results.clear()
    s.blocks.clear()


def _timed_cold_data(s, fn, reps=REPS):
    samples = []
    for _ in range(reps):
        _clear_data_caches(s)
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        samples.append(time.perf_counter() - t0)
    return float(np.median(samples))


def _site_predicate(site):
    return lambda cols: cols["site"] == site


def _grouped_query(s):
    return (s.scan().select("img:data").group_by("idx:site")
            .map(MeanProgram()).map(VarianceProgram()).reduce())


def _loop_queries(s, sites):
    """The workload group_by replaces: one fused mean+variance query per
    stratum — each pass re-scans the index and re-folds its subset."""
    out = []
    for k in sites:
        (mean, var), _ = (s.scan().select("img:data")
                          .where(_site_predicate(int(k)), ["site"])
                          .map(MeanProgram()).map(VarianceProgram())
                          .reduce().collect())
        out.append((mean, var))
    return out


def _merge_bench():
    """tree vs funnel merge on 8 forced host devices (subprocess)."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(repo, "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    snippet = MERGE_SNIPPET % {
        "n_partials": 256, "payload": repr(PAYLOAD), "reps": 20}
    try:
        proc = subprocess.run([sys.executable, "-c", snippet],
                              capture_output=True, text=True, env=env,
                              timeout=300)
    except (subprocess.SubprocessError, OSError):
        return {}
    if proc.returncode != 0:
        return {}
    for line in proc.stdout.splitlines():
        if line.startswith("MERGE_JSON "):
            return json.loads(line[len("MERGE_JSON "):])
    return {}


def run(verbose: bool = True):
    t = _make_table()
    sites = np.unique(t.column("idx", "site"))
    data = t.column("img", "data")
    site_col = t.column("idx", "site")

    # --- grouped one-pass: cold then warm -------------------------------
    s = GridSession(t, default_eta=ETA, compact_gather_threshold=0.0)
    t0 = time.perf_counter()
    res, rep_cold = _grouped_query(s).collect()
    jax.block_until_ready(res.values)
    grouped_cold_s = time.perf_counter() - t0
    assert rep_cold.query.num_groups == len(sites)
    assert rep_cold.query.gather_count == N_REGIONS   # ONE gather per block
    # correctness vs the groupby oracle
    mean, var = res.values
    for g, k in enumerate(res.keys):
        sel = data[site_col == k]
        np.testing.assert_allclose(np.asarray(mean)[g], sel.mean(0),
                                   atol=1e-3)
        np.testing.assert_allclose(np.asarray(var["var"])[g], sel.var(0),
                                   rtol=1e-3, atol=1e-3)

    def warm():
        r, rep = _grouped_query(s).collect()
        assert rep.query.rows_folded == 0, rep.query    # acceptance
        return r.values
    grouped_warm_s = _timed(warm)
    # cold DATA, warm jit: caches cleared each rep, executables kept —
    # the steady-state fold+gather cost the one-pass claim is about
    grouped_data_s = _timed_cold_data(
        s, lambda: _grouped_query(s).collect()[0].values)

    # --- per-group-loop baseline: G predicate queries -------------------
    s_loop = GridSession(t, default_eta=ETA, compact_gather_threshold=0.0)
    t0 = time.perf_counter()
    loop_res = _loop_queries(s_loop, sites)
    jax.block_until_ready(loop_res[-1][0])
    loop_cold_s = time.perf_counter() - t0
    loop_warm_s = _timed(lambda: _loop_queries(s_loop, sites)[-1][0])
    loop_data_s = _timed_cold_data(
        s_loop, lambda: _loop_queries(s_loop, sites)[-1][0])
    # the loop answers must agree with the grouped ones (same statistics)
    for g, k in enumerate(res.keys):
        np.testing.assert_allclose(np.asarray(loop_res[g][0]),
                                   np.asarray(mean)[g], atol=1e-3)

    # headline: cold-data regime (per-rep cleared caches, jit warm) — the
    # loop re-scans the index and re-folds every block once PER STRATUM,
    # the grouped pass folds each block once for all strata.  No hard
    # assert here: the committed baseline in perf_baselines.json is the
    # single regression mechanism (check_regression.py reports properly
    # instead of crashing the artifact write on a noisy runner).
    grouped_speedup = loop_data_s / max(grouped_data_s, 1e-9)
    warm_speedup = loop_warm_s / max(grouped_warm_s, 1e-9)

    # --- merge phase: tree reduce vs funnel at high region count --------
    merge = _merge_bench()
    tree_s = float(merge.get("tree_s", 0.0))
    funnel_s = float(merge.get("funnel_s", 0.0))

    out = {
        "n_rows": t.num_rows,
        "n_regions": N_REGIONS,
        "n_sites": int(len(sites)),
        "eta": ETA,
        "grouped_cold_s": grouped_cold_s,
        "grouped_cold_data_s": grouped_data_s,
        "grouped_warm_s": grouped_warm_s,
        "loop_cold_s": loop_cold_s,
        "loop_cold_data_s": loop_data_s,
        "loop_warm_s": loop_warm_s,
        "grouped_speedup_vs_loop": grouped_speedup,
        "grouped_warm_speedup_vs_loop": warm_speedup,
        "warm_rows_folded": 0,
        "merge_tree_s": tree_s,
        "merge_funnel_s": funnel_s,
        "merge_tree_speedup": (funnel_s / tree_s) if tree_s > 0 else 0.0,
        "merge_partials": 256 if merge else 0,
    }
    if verbose:
        print(f"grouped one-pass: cold={grouped_cold_s*1e3:.1f}ms "
              f"cold-data={grouped_data_s*1e3:.1f}ms "
              f"warm={grouped_warm_s*1e3:.2f}ms over {len(sites)} sites")
        print(f"per-group loop : cold={loop_cold_s*1e3:.1f}ms "
              f"cold-data={loop_data_s*1e3:.1f}ms "
              f"warm={loop_warm_s*1e3:.2f}ms "
              f"({grouped_speedup:.1f}x cold-data win, "
              f"{warm_speedup:.1f}x warm)")
        if merge:
            print(f"merge @256 partials x 8 dev: tree={tree_s*1e3:.2f}ms "
                  f"funnel={funnel_s*1e3:.2f}ms "
                  f"({out['merge_tree_speedup']:.2f}x)")
        else:
            print("merge bench skipped (8-device subprocess unavailable)")
    return out


if __name__ == "__main__":
    run()
