"""BlockStore mutation-reuse microbench.

Measures the two copy-on-write reuse paths the block layer exists for:

1. **Epoch reuse** — after a single-row ``remove``, a repeat full-table
   ``.stats()`` re-gathers ONE region's block instead of rebuilding the
   world.  Reported against the cold-session build of the same layout
   (what every mutation used to cost).
2. **Plan overlap** — two pruned scans over overlapping region subsets:
   the second plan's ``gather_count`` covers only the regions the first
   didn't touch.
"""

from __future__ import annotations

import time

import numpy as np

import jax

from repro.core.grid import GridSession
from repro.core.stats import MeanProgram
from repro.core.table import make_mip_table

N_ROWS = 512
N_REGIONS = 16
PAYLOAD = (32, 32)


def _make_table(seed=0):
    rng = np.random.default_rng(seed)
    groups = [f"g{i:02d}" for i in range(N_REGIONS)]
    t = make_mip_table(payload_shape=PAYLOAD,
                       presplit_keys=groups[1:])
    per = N_ROWS // N_REGIONS
    keys = [f"{g}x{i:04d}" for g in groups for i in range(per)]
    n = len(keys)
    t.upload(keys, {
        "img": {"data": rng.normal(size=(n,) + PAYLOAD).astype(np.float32)},
        "idx": {"size": rng.integers(6_000_000, 20_000_001, n)}})
    return t


def _timed_stats(session, warm_program):
    t0 = time.perf_counter()
    res, rep = session.run(warm_program)
    jax.block_until_ready(res)
    return (time.perf_counter() - t0), rep


def run(verbose: bool = True):
    program = MeanProgram()
    rng = np.random.default_rng(1)

    # --- 1. epoch reuse: overwrite one row, repeat the full stats ------
    # (overwrite keeps every block's row count, so refresh and rebuild
    # compare pure gather/transfer work at identical array shapes)
    t = _make_table()
    s = GridSession(t, default_eta=8)
    cold_s, _ = _timed_stats(s, program)             # build + compile
    _timed_stats(s, program)                         # warm the executable
    key = bytes(t.keys[0])
    s.upload([key], {
        "img": {"data": rng.normal(size=(1,) + PAYLOAD).astype(np.float32)},
        "idx": {"size": rng.integers(6_000_000, 20_000_001, 1)}},
        on_duplicate="overwrite")
    refresh_s, rep_refresh = _timed_stats(s, program)
    q = rep_refresh.query
    assert q.blocks_reused == q.blocks_total - 1, q  # the microbench's point

    # cold-session baseline at the SAME epoch/executable state: a fresh
    # session re-gathers and re-ships every block (pre-BlockStore behavior)
    s2 = GridSession(t, default_eta=8)
    s2.engine = s.engine                             # share compiled fns
    rebuild_s, _ = _timed_stats(s2, program)

    # remove exercises the other mutation verb; assert (don't time — the
    # shrunken block changes concat shapes) that reuse holds there too
    s.remove(rowkey=key)
    _, rep_remove = _timed_stats(s, program)
    qr = rep_remove.query
    assert qr.blocks_reused == qr.blocks_total - 1, qr
    assert qr.gather_count == 1, qr

    # --- 2. plan overlap: two pruned scans sharing half their regions --
    s3 = GridSession(t, default_eta=8)
    g = N_REGIONS
    stop_a = f"g{3 * g // 4:02d}".encode()
    start_b = f"g{g // 4:02d}".encode()
    t0 = time.perf_counter()
    ra = s3.scan(stop=stop_a).map(program).stats()
    jax.block_until_ready(ra)
    first_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    rb = s3.scan(start=start_b).map(program).stats()
    jax.block_until_ready(rb)
    second_s = time.perf_counter() - t0

    out = {
        "n_rows": N_ROWS,
        "n_regions": len(t.regions),
        "payload_bytes_per_row": int(np.prod(PAYLOAD)) * 4,
        "cold_build_s": cold_s,
        "rebuild_everything_s": rebuild_s,
        "incremental_refresh_s": refresh_s,
        "refresh_speedup_vs_rebuild": rebuild_s / max(refresh_s, 1e-9),
        "refresh_blocks_reused": q.blocks_reused,
        "refresh_blocks_transferred": q.blocks_transferred,
        "refresh_gather_count": q.gather_count,
        "overlap_first_gathers": ra.query.gather_count,
        "overlap_second_gathers": rb.query.gather_count,
        "overlap_second_reused": rb.query.blocks_reused,
        "overlap_first_s": first_s,
        "overlap_second_s": second_s,
    }
    if verbose:
        print(f"epoch reuse: rebuild={rebuild_s*1e3:.1f}ms "
              f"refresh={refresh_s*1e3:.1f}ms "
              f"({out['refresh_speedup_vs_rebuild']:.1f}x; "
              f"{q.blocks_reused}/{q.blocks_total} blocks reused)")
        print(f"plan overlap: first gathers={ra.query.gather_count} "
              f"second gathers={rb.query.gather_count} "
              f"reused={rb.query.blocks_reused} "
              f"({first_s*1e3:.1f}ms -> {second_s*1e3:.1f}ms)")
    return out


if __name__ == "__main__":
    run()
