"""Use case 2 / Fig. 4 A–D — large-dataset averaging, chunk-size model.

Sweeps the map-task chunk size η ∈ [30, 160] step 5 (the paper's §2.4.3
protocol) over:

    theory      — eq. (1)-(8) wall/resource model (Fig. 4C/D lines)
    simulated   — the discrete-event cluster on the same job set
                  (stands in for the paper's empirical curves)
    sge         — same jobs with central storage (Fig. 4A/B comparison)

Validated claims: optimal η in [50, 60]; resource-time flattens past η≈80;
Hadoop ≈5-8× wall and ≈14-20× resource better than SGE.
"""

from __future__ import annotations

import numpy as np

from repro.core.balancer import greedy_allocation
from repro.core.chunk_model import PAPER_PARAMS, ChunkModel
from repro.core.simulator import ClusterSim, SimTask, paper_cluster

SIZE_IN = 13e6          # registered image average size (6..20 MB)
SIZE_GEN = 21e6
N_IMG = 5153
AVG = PAPER_PARAMS.avg_fn


def job_tasks(eta: int, alloc, n_regions: int):
    # deterministic round-robin chunk->region placement: the eta sweep then
    # reflects model structure, not placement noise
    n_maps = N_IMG // eta
    maps = [
        SimTask(i, input_bytes=eta * SIZE_IN, output_bytes=SIZE_GEN,
                work=AVG(eta), home_node=alloc[(i * 7) % n_regions])
        for i in range(n_maps)
    ]
    reduce_t = SimTask(n_maps, input_bytes=n_maps * SIZE_GEN,
                       output_bytes=SIZE_GEN, work=AVG(n_maps),
                       home_node=None)
    return maps + [reduce_t]


def run(verbose: bool = True):
    nodes = paper_cluster()
    rng = np.random.default_rng(0)
    n_regions = 416
    region_bytes = {i: int(b) for i, b in
                    enumerate(rng.integers(150e6, 220e6, n_regions))}
    alloc = greedy_allocation(region_bytes, nodes)
    sim = ClusterSim(nodes, bandwidth=70e6)
    cm = ChunkModel(PAPER_PARAMS)

    rows = []
    for eta in range(30, 161, 5):
        th_w = cm.wall_time(eta)["total"]
        th_r = cm.resource_time(eta)["total"]
        tasks = job_tasks(eta, alloc, n_regions)
        h = sim.run(tasks, "hadoop")
        rows.append({"eta": eta, "theory_wall": th_w, "theory_rt": th_r,
                     "sim_wall": h.wall_time, "sim_rt": h.resource_time})
        if verbose:
            print(f"eta={eta:4d}  theory wall={th_w:7.1f}s rt={th_r:8.0f}s | "
                  f"sim wall={h.wall_time:7.1f}s rt={h.resource_time:8.0f}s")

    # optimum + SGE comparison at the model optimum
    eta_star, _ = cm.optimal_eta()
    sim_star = min(rows, key=lambda r: r["sim_wall"])
    tasks = job_tasks(eta_star, alloc, n_regions)
    h = sim.run(tasks, "hadoop")
    s = sim.run(tasks, "sge")
    wall_x = s.wall_time / h.wall_time
    rt_x = s.resource_time / h.resource_time

    # resource flatness past 80 (paper Fig. 4D)
    rts = {r["eta"]: r["theory_rt"] for r in rows}
    flat = abs(rts[160] - rts[80]) / rts[80]

    if verbose:
        print(f"\nmodel optimum eta*={eta_star} (paper: 50-60); "
              f"simulated optimum eta={sim_star['eta']}")
        print(f"SGE/Hadoop at eta*: wall {wall_x:.1f}x (paper ~5-8x), "
              f"resource {rt_x:.1f}x (paper 14-20x)")
        print(f"resource-time change 80->160: {flat*100:.1f}% (paper: flat)")
    return {
        "rows": rows,
        "eta_star_model": eta_star,
        "eta_star_sim": sim_star["eta"],
        "sge_wall_x": wall_x,
        "sge_rt_x": rt_x,
        "rt_flatness_80_160": flat,
    }


if __name__ == "__main__":
    run()
