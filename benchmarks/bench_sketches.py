"""Sketch-statistics microbench — fused sketch fold overhead vs the plain
moments fold, warm repeat cost, and measured accuracy vs the exact oracles.

Three numbers back the sketch PR's claims:

1. **fold overhead** — each sketch program folds a scalar metadata
   column (the sketch use case: distinct patients, site cardinality,
   intensity/age quantiles — one item per row) and its cold-data wall is
   gated against the plain :class:`MomentsProgram` fold over the same
   column.  The gated metric ``sketch_fold_overhead_vs_moments`` is the
   WORST of the three per-program ratios (≤ 1.5×, the committed
   baseline): approximating a statistic must not cost materially more
   than the exact power sums it complements.  The combined
   ``.map(cm).map(hll).map(qs)`` pipeline — one gather, three
   per-program folds, three cache entries by design — is reported
   unguarded as ``sketch_pipeline_cold_data_s``, as is element-level
   sketching of a full (16, 16) payload block (256 items/row,
   ``payload_sketch_cold_data_s``); both scale with work by design.
2. **warm repeat** — a repeat sketch query on a clean epoch folds ZERO
   rows (block-partial cache; asserted, and exported as
   ``warm_rows_folded``) and serves from merged partials.
3. **accuracy** — the same run reports measured error vs the float64
   oracles in :mod:`repro.core.ref` as fractions of each documented bound
   (count-min overcount / ε·n, HLL relative error / standard error, rank
   error / the dyadic bound); CI's sketch-accuracy leg asserts the
   bounds, this artifact tracks the margin.

Artifact: ``BENCH_sketches.json`` via benchmarks/run.py (also in
``--smoke``; the perf gate checks ``sketch_fold_overhead_vs_moments`` and
``warm_rows_folded``).
"""

from __future__ import annotations

import time

import numpy as np

import jax

from repro.core import ref
from repro.core.grid import GridSession
from repro.core.regions import HierarchicalSplitPolicy
from repro.core.stats import (
    CountMinProgram,
    HyperLogLogProgram,
    MomentsProgram,
    QuantileSketchProgram,
)
from repro.core.table import ColumnSpec, make_mip_table

N_REGIONS = 16
ROWS_PER_REGION = 256
PAYLOAD = (16, 16)
ETA = 64
REPS = 10

CM = CountMinProgram(depth=4, width=1024, seed=71)
HLL = HyperLogLogProgram(p=12, seed=72)
QS = QuantileSketchProgram(lo=-5.0, hi=5.0, log2_universe=12, depth=4,
                           width=2048, probes=(0.5, 0.9, 0.99), seed=73)


def _make_table(seed=0):
    rng = np.random.default_rng(seed)
    groups = [f"g{i:02d}" for i in range(N_REGIONS)]
    t = make_mip_table(
        payload_shape=PAYLOAD,
        extra_index_columns=[ColumnSpec("site", (), np.int32),
                             ColumnSpec("val", (), np.float32)],
        split_policy=HierarchicalSplitPolicy(max_region_bytes=10**18),
        presplit_keys=groups[1:])
    keys = [f"{g}x{i:04d}" for g in groups for i in range(ROWS_PER_REGION)]
    n = len(keys)
    t.upload(keys, {
        "img": {"data": rng.normal(size=(n,) + PAYLOAD).astype(np.float32)},
        "idx": {"size": rng.integers(6_000_000, 20_000_001, n),
                "site": rng.integers(0, 8, n).astype(np.int32),
                "val": rng.normal(size=n).astype(np.float32).clip(-4.9, 4.9)}})
    return t


def _timed(fn, reps=REPS):
    samples = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        samples.append(time.perf_counter() - t0)
    return float(np.median(samples))


def _clear_data_caches(s):
    """Forget results, partials, and resident blocks (compiled executables
    stay): per-rep full gather+fold cost, no compile — the steady-state
    regime the overhead ratio is about."""
    s._results.clear()
    s.blocks.clear()


def _timed_cold_data(s, fn, reps=REPS):
    samples = []
    for _ in range(reps):
        _clear_data_caches(s)
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        samples.append(time.perf_counter() - t0)
    return float(np.median(samples))


def _sketch_query(s, column="idx:val"):
    return (s.scan().select(column).map(CM).map(HLL).map(QS).reduce())


def _moments_query(s, column="idx:val"):
    return s.scan().select(column).map(MomentsProgram()).reduce()


def run(verbose: bool = True):
    t = _make_table()
    vals = t.column("idx", "val")
    n_items = vals.size

    # --- scalar-column sketch fold: cold, warm, cold-data ---------------
    s = GridSession(t, default_eta=ETA, compact_gather_threshold=0.0)
    t0 = time.perf_counter()
    (cm_res, hll_res, q_res), rep_cold = _sketch_query(s).collect()
    jax.block_until_ready(q_res["quantiles"])
    sketch_cold_s = time.perf_counter() - t0
    assert rep_cold.query.rows_folded == t.num_rows

    def warm():
        res, rep = _sketch_query(s).collect()
        assert rep.query.rows_folded == 0, rep.query    # acceptance
        return res[2]["quantiles"]
    sketch_warm_s = _timed(warm)
    pipeline_data_s = _timed_cold_data(
        s, lambda: _sketch_query(s).collect()[0][2]["quantiles"])

    # --- plain moments fold over the same column (overhead baseline) ----
    s_m = GridSession(t, default_eta=ETA, compact_gather_threshold=0.0)
    _moments_query(s_m).collect()                       # compile
    moments_data_s = _timed_cold_data(
        s_m, lambda: _moments_query(s_m).collect()[0])

    # --- per-program fold cost: the gated ratio is the worst sketch -----
    per_program = {}
    for name, prog in [("cm", CM), ("hll", HLL), ("qs", QS)]:
        s_1 = GridSession(t, default_eta=ETA, compact_gather_threshold=0.0)
        def one():
            return s_1.scan().select("idx:val").map(prog).reduce().collect()[0]
        one()                                           # compile
        per_program[name] = _timed_cold_data(s_1, one)
    overhead = max(per_program.values()) / max(moments_data_s, 1e-9)

    # --- element-level payload sketching: unguarded trajectory metric ---
    s_p = GridSession(t, default_eta=ETA, compact_gather_threshold=0.0)
    _sketch_query(s_p, "img:data").collect()            # compile
    payload_data_s = _timed_cold_data(
        s_p, lambda: _sketch_query(s_p, "img:data").collect()[0][1],
        reps=3)

    # --- measured accuracy as a fraction of each documented bound -------
    cm_np = jax.tree.map(np.asarray, cm_res)
    uniq, counts = ref.exact_frequencies(vals)
    est = CM.estimate(cm_np, uniq)
    eps_n, _ = CM.error_bound(n_items)
    cm_overcount_frac = float((est - counts).max() / eps_n)

    true_d = ref.exact_distinct(vals)
    hll_rel_err = abs(float(np.asarray(hll_res["estimate"])) - true_d) / true_d
    hll_err_frac = hll_rel_err / HLL.std_error()

    v = np.asarray(q_res["quantiles"])
    below, _ = ref.rank_interval(vals, v - QS.value_resolution())
    _, at_or_below = ref.rank_interval(vals, v + QS.value_resolution())
    targets = np.ceil(np.asarray(QS.probes) * n_items)
    rank_err = ref.interval_distance(targets, below, at_or_below)
    rank_err_frac = float(rank_err.max() / (QS.rank_error_bound(n_items) + 1))

    out = {
        "n_rows": t.num_rows,
        "n_items": int(n_items),
        "n_regions": N_REGIONS,
        "eta": ETA,
        "sketch_cold_s": sketch_cold_s,
        "sketch_pipeline_cold_data_s": pipeline_data_s,
        "sketch_warm_s": sketch_warm_s,
        "moments_cold_data_s": moments_data_s,
        "cm_cold_data_s": per_program["cm"],
        "hll_cold_data_s": per_program["hll"],
        "qs_cold_data_s": per_program["qs"],
        "sketch_fold_overhead_vs_moments": overhead,
        "payload_sketch_cold_data_s": payload_data_s,
        "warm_rows_folded": 0,
        "cm_overcount_frac_of_bound": cm_overcount_frac,
        "hll_rel_err": hll_rel_err,
        "hll_err_frac_of_se": hll_err_frac,
        "quantile_rank_err_frac_of_bound": rank_err_frac,
    }
    if verbose:
        print(f"sketch pipeline (cm+hll+quantile over {n_items} scalar "
              f"items): cold={sketch_cold_s*1e3:.1f}ms "
              f"cold-data={pipeline_data_s*1e3:.1f}ms "
              f"warm={sketch_warm_s*1e3:.2f}ms")
        print(f"per-program cold-data: "
              f"cm={per_program['cm']*1e3:.1f}ms "
              f"hll={per_program['hll']*1e3:.1f}ms "
              f"qs={per_program['qs']*1e3:.1f}ms "
              f"vs moments={moments_data_s*1e3:.1f}ms "
              f"-> worst overhead {overhead:.2f}x (gate <= 1.5x); "
              f"payload-element sketch {payload_data_s*1e3:.1f}ms "
              f"(unguarded)")
        print(f"accuracy: cm_overcount={cm_overcount_frac:.3f} of eps*n, "
              f"hll={hll_err_frac:.2f} se, "
              f"rank={rank_err_frac:.3f} of bound")
    return out


if __name__ == "__main__":
    run()
