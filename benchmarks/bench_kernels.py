"""Kernel microbench: allclose vs oracle + interpret-mode op accounting.

Wall-clock on CPU interpret mode is NOT a TPU perf signal; what this bench
certifies is (1) numeric agreement on production-shaped tiles, (2) the
analytic FLOPs/bytes per call that the roofline model uses for the kernels'
VMEM tiling story.

The ``fused_fold`` section gates the tentpole's one-HBM-pass contract with
modeled ratios (stable across machines, unlike interpret wall clock):

- ``fused_fold_speedup_grouped`` / ``_ungrouped`` — bytes XLA's own
  ``cost_analysis`` measures for the reference chunk-scan fold of the CSE
  pool, over the kernel's analytic one-pass HBM bytes for the same block.
  > 1 means the kernel genuinely reduces chunk bytes-read per fold;
- ``fused_fold_roofline_bw_frac`` — ``memory_s / bound_s`` from
  ``launch/roofline.py`` on the kernel's analytic FLOPs/bytes: 1.0 says
  the kernel is bandwidth-bound (intensity far below the ridge), i.e. a
  perfectly streaming kernel runs at peak HBM bandwidth.
"""

from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssm_scan.ops import ssd_scan
from repro.kernels.streaming_stats.ops import streaming_stats
from repro.kernels.streaming_stats.ref import streaming_stats_ref


def _time(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6  # us


def _fused_fold_section(rng, rows):
    """Fused fold kernel: oracle agreement + one-HBM-pass ratio metrics."""
    from repro.core.mapreduce import MapReduceEngine
    from repro.core.stats import (
        FusedProgram, GroupedProgram, MeanProgram, MomentsProgram,
        VarianceProgram)
    from repro.kernels.fused_fold import (
        fused_fold, fused_fold_numpy, kernel_flops, kernel_hbm_bytes)
    from repro.launch.roofline import derive_terms
    from repro.utils import make_mesh

    R, shape, eta, G = 256, (64, 48), 64, 7
    F = int(np.prod(shape))
    names = ("count", "s1", "s2", "s3", "s4")

    x = rng.normal(size=(R,) + shape).astype(np.float32)
    m = rng.random(R) > 0.2
    g = rng.integers(0, G, R).astype(np.int32)
    got = fused_fold(jnp.asarray(x), jnp.asarray(m), jnp.asarray(g),
                     num_groups=G)
    want = fused_fold_numpy(x, m, g, num_groups=G)
    err = max(float(np.abs(np.asarray(got[n], np.float64)
                           - want[n]).max()) for n in names)
    us = _time(lambda a, b, c: fused_fold(a, b, c, num_groups=G),
               jnp.asarray(x), jnp.asarray(m), jnp.asarray(g))

    # measured XLA fold bytes (cost_analysis of the reference chunk scan)
    # vs the kernel's analytic one-pass bytes, grouped and ungrouped
    eng = MapReduceEngine(make_mesh((1,), ("data",)))
    cse = (MeanProgram(), VarianceProgram(), MomentsProgram())
    kernel_bytes = kernel_hbm_bytes(R, F, 4, names, num_groups=G)
    xla_g = eng.fold_cost(GroupedProgram(FusedProgram(cse), num_groups=G),
                          R, shape, jnp.float32, eta, masked=True, groups=G)
    xla_u = eng.fold_cost(FusedProgram(cse), R, shape, jnp.float32, eta,
                          masked=True)
    speedup_g = (xla_g["bytes"] / kernel_bytes
                 if xla_g["bytes"] and kernel_bytes else 0.0)
    speedup_u = (xla_u["bytes"] / kernel_hbm_bytes(R, F, 4, names)
                 if xla_u["bytes"] else 0.0)

    terms = derive_terms(kernel_flops(R, F, names, num_groups=G),
                         kernel_bytes, 0.0)
    bw_frac = terms.memory_s / terms.bound_s if terms.bound_s else 0.0

    rows.append((f"fused_fold_g{G}_256x64x48", us,
                 f"maxerr={err:.1e};xla_bytes={xla_g['bytes']:.2e};"
                 f"kernel_bytes={kernel_bytes:.2e};"
                 f"bytes_ratio={speedup_g:.2f};"
                 f"roofline={terms.dominant}"))
    return {
        "fused_fold_speedup_grouped": speedup_g,
        "fused_fold_speedup_ungrouped": speedup_u,
        "fused_fold_roofline_bw_frac": bw_frac,
    }


def run(verbose: bool = True):
    rng = np.random.default_rng(0)
    rows = []

    # streaming stats: one map-task chunk (eta=50 rows of 1MB fp32)
    R, F = 50, 262_144
    x = jnp.asarray(rng.normal(size=(R, F)).astype(np.float32))
    m = jnp.ones((R,), bool)
    s, _, c = streaming_stats(x, m)
    rs, _, rc = streaming_stats_ref(x, m)
    err = float(jnp.abs(s - rs).max())
    us = _time(lambda a, b: streaming_stats(a, b, impl="ref"), x, m)
    rows.append(("streaming_stats_eta50_1MBrows", us,
                 f"maxerr={err:.1e};bytes={x.nbytes/1e6:.0f}MB;"
                 f"flops={2*R*F:.2e}"))

    # pallas map phase wired into the grid: GridSession.run(impl="pallas")
    # vs the jnp reference fold over the same 4-region table
    from repro.core.grid import GridSession
    from repro.core.stats import MeanProgram
    from repro.core.table import make_mip_table

    t = make_mip_table(payload_shape=(16, 16),
                       presplit_keys=["g1", "g2", "g3"])
    gk = [f"g{i % 4}x{i:04d}" for i in range(64)]
    t.upload(sorted(gk), {
        "img": {"data": rng.normal(size=(64, 16, 16)).astype(np.float32)},
        "idx": {"size": rng.integers(6_000_000, 20_000_001, 64)}})
    sess = GridSession(t, default_eta=8)
    ref_res, _ = sess.run(MeanProgram(), impl="ref")
    pal_res, _ = sess.run(MeanProgram(), impl="pallas")
    err = float(jnp.abs(jnp.asarray(pal_res) - jnp.asarray(ref_res)).max())
    sess.blocks.clear_partials()

    def grid_pallas():
        sess._results.clear()
        sess.blocks.clear_partials()
        return sess.run(MeanProgram(), impl="pallas")[0]
    us = _time(lambda: grid_pallas())
    rows.append(("grid_map_phase_pallas_64x16x16", us,
                 f"maxerr_vs_ref={err:.1e};regions={len(t.regions)}"))

    # flash attention: one 128-block tile at head_dim 128
    B, H, S, D = 1, 4, 256, 128
    q = jnp.asarray(rng.normal(size=(B, H, S, D)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, H, S, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, H, S, D)).astype(np.float32))
    out = flash_attention(q, k, v, scale=D ** -0.5)
    ref = attention_ref(q, k, v, scale=D ** -0.5)
    err = float(jnp.abs(out - ref).max())
    us = _time(lambda *a: flash_attention(*a, scale=D ** -0.5, impl="ref"),
               q, k, v)
    rows.append(("flash_attention_b1h4s256d128", us,
                 f"maxerr={err:.1e};flops={4*B*H*S*S*D:.2e}"))

    # ssd scan: mamba2-native dims, one chunk stream
    B2, L, H2, P, N = 1, 256, 4, 64, 64
    xs = jnp.asarray(rng.normal(size=(B2, L, H2, P)).astype(np.float32)) * .5
    a = jnp.asarray(rng.uniform(0.8, 0.999, (B2, L, H2)).astype(np.float32))
    Bm = jnp.asarray(rng.normal(size=(B2, L, N)).astype(np.float32)) * .3
    Cm = jnp.asarray(rng.normal(size=(B2, L, N)).astype(np.float32)) * .3
    y, s_fin = ssd_scan(xs, a, Bm, Cm, chunk=128)
    y_ref, _ = ssd_scan(xs, a, Bm, Cm, impl="ref")
    err = float(jnp.abs(y - y_ref).max())
    us = _time(lambda *z: ssd_scan(*z, impl="ref"), xs, a, Bm, Cm)
    rows.append(("ssd_scan_l256_h4_p64_n64", us,
                 f"maxerr={err:.1e};state={H2*P*N*4}B"))

    metrics = _fused_fold_section(rng, rows)

    if verbose:
        for name, us, derived in rows:
            print(f"{name},{us:.0f},{derived}")
        for k, v in metrics.items():
            print(f"{k}={v:.2f}")
    return {"rows": rows, **metrics}


if __name__ == "__main__":
    run()
