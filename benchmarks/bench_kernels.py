"""Kernel microbench: allclose vs oracle + interpret-mode op accounting.

Wall-clock on CPU interpret mode is NOT a TPU perf signal; what this bench
certifies is (1) numeric agreement on production-shaped tiles, (2) the
analytic FLOPs/bytes per call that the roofline model uses for the kernels'
VMEM tiling story.
"""

from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssm_scan.ops import ssd_scan
from repro.kernels.streaming_stats.ops import streaming_stats
from repro.kernels.streaming_stats.ref import streaming_stats_ref


def _time(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6  # us


def run(verbose: bool = True):
    rng = np.random.default_rng(0)
    rows = []

    # streaming stats: one map-task chunk (eta=50 rows of 1MB fp32)
    R, F = 50, 262_144
    x = jnp.asarray(rng.normal(size=(R, F)).astype(np.float32))
    m = jnp.ones((R,), bool)
    s, _, c = streaming_stats(x, m)
    rs, _, rc = streaming_stats_ref(x, m)
    err = float(jnp.abs(s - rs).max())
    us = _time(lambda a, b: streaming_stats(a, b, impl="ref"), x, m)
    rows.append(("streaming_stats_eta50_1MBrows", us,
                 f"maxerr={err:.1e};bytes={x.nbytes/1e6:.0f}MB;"
                 f"flops={2*R*F:.2e}"))

    # pallas map phase wired into the grid: GridSession.run(impl="pallas")
    # vs the jnp reference fold over the same 4-region table
    from repro.core.grid import GridSession
    from repro.core.stats import MeanProgram
    from repro.core.table import make_mip_table

    t = make_mip_table(payload_shape=(16, 16),
                       presplit_keys=["g1", "g2", "g3"])
    gk = [f"g{i % 4}x{i:04d}" for i in range(64)]
    t.upload(sorted(gk), {
        "img": {"data": rng.normal(size=(64, 16, 16)).astype(np.float32)},
        "idx": {"size": rng.integers(6_000_000, 20_000_001, 64)}})
    sess = GridSession(t, default_eta=8)
    ref_res, _ = sess.run(MeanProgram(), impl="ref")
    pal_res, _ = sess.run(MeanProgram(), impl="pallas")
    err = float(jnp.abs(jnp.asarray(pal_res) - jnp.asarray(ref_res)).max())
    sess.blocks.clear_partials()

    def grid_pallas():
        sess._results.clear()
        sess.blocks.clear_partials()
        return sess.run(MeanProgram(), impl="pallas")[0]
    us = _time(lambda: grid_pallas())
    rows.append(("grid_map_phase_pallas_64x16x16", us,
                 f"maxerr_vs_ref={err:.1e};regions={len(t.regions)}"))

    # flash attention: one 128-block tile at head_dim 128
    B, H, S, D = 1, 4, 256, 128
    q = jnp.asarray(rng.normal(size=(B, H, S, D)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, H, S, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, H, S, D)).astype(np.float32))
    out = flash_attention(q, k, v, scale=D ** -0.5)
    ref = attention_ref(q, k, v, scale=D ** -0.5)
    err = float(jnp.abs(out - ref).max())
    us = _time(lambda *a: flash_attention(*a, scale=D ** -0.5, impl="ref"),
               q, k, v)
    rows.append(("flash_attention_b1h4s256d128", us,
                 f"maxerr={err:.1e};flops={4*B*H*S*S*D:.2e}"))

    # ssd scan: mamba2-native dims, one chunk stream
    B2, L, H2, P, N = 1, 256, 4, 64, 64
    xs = jnp.asarray(rng.normal(size=(B2, L, H2, P)).astype(np.float32)) * .5
    a = jnp.asarray(rng.uniform(0.8, 0.999, (B2, L, H2)).astype(np.float32))
    Bm = jnp.asarray(rng.normal(size=(B2, L, N)).astype(np.float32)) * .3
    Cm = jnp.asarray(rng.normal(size=(B2, L, N)).astype(np.float32)) * .3
    y, s_fin = ssd_scan(xs, a, Bm, Cm, chunk=128)
    y_ref, _ = ssd_scan(xs, a, Bm, Cm, impl="ref")
    err = float(jnp.abs(y - y_ref).max())
    us = _time(lambda *z: ssd_scan(*z, impl="ref"), xs, a, Bm, Cm)
    rows.append(("ssd_scan_l256_h4_p64_n64", us,
                 f"maxerr={err:.1e};state={H2*P*N*4}B"))

    if verbose:
        for name, us, derived in rows:
            print(f"{name},{us:.0f},{derived}")
    return {"rows": rows}


if __name__ == "__main__":
    run()
