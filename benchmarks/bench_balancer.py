"""Use case 1 / Fig. 3 A+B — heterogeneous cluster, load balancer.

Reproduces the paper's experiment: 5,153 single-image .gz compression jobs
(15 MB in, 8.9 MB out per job) on the 224-core heterogeneous grid
(8×12 slow + 4×32 fast cores), with artificial extra processing time
15–115 s, comparing:

    hadoop-default   — HBase balanced allocation (equal region count)
    hadoop-greedy    — the paper's #CPU×MIPS balancer
    sge              — central storage, all reads/writes over the network

Paper claims validated: greedy ≈1.5× faster wall time than default;
SGE wall-time flat (network-saturated) at small job lengths then linear.
"""

from __future__ import annotations

import numpy as np

from repro.core.balancer import (
    balanced_allocation,
    greedy_allocation,
)
from repro.core.simulator import ClusterSim, SimTask, paper_cluster

N_IMAGES = 5153
SIZE_IN = 15e6
SIZE_OUT = 8.9e6
BASE_WORK = 3.0          # intrinsic gzip seconds at MIPS=1
EXTRA_WORK = (15, 30, 45, 60, 75, 90, 105)
N_REGIONS = 416          # ~12 MB regions over 77.4 GB / ~186 MB each


def build_tasks(alloc, extra):
    rng = np.random.default_rng(7)
    region_of = rng.integers(0, N_REGIONS, N_IMAGES)
    return [
        SimTask(i, input_bytes=SIZE_IN, output_bytes=SIZE_OUT,
                work=BASE_WORK + extra, home_node=alloc[region_of[i]])
        for i in range(N_IMAGES)
    ]


def run(verbose: bool = True):
    nodes = paper_cluster()
    rng = np.random.default_rng(0)
    region_bytes = {i: int(b) for i, b in
                    enumerate(rng.integers(150e6, 220e6, N_REGIONS))}
    alloc_bal = balanced_allocation(region_bytes, nodes)
    alloc_gre = greedy_allocation(region_bytes, nodes)
    sim = ClusterSim(nodes, bandwidth=70e6)

    rows = []
    for extra in EXTRA_WORK:
        res = {}
        res["hadoop-default"] = sim.run(build_tasks(alloc_bal, extra), "hadoop")
        res["hadoop-greedy"] = sim.run(build_tasks(alloc_gre, extra), "hadoop")
        res["sge"] = sim.run(build_tasks(alloc_gre, extra), "sge")
        speedup = (res["hadoop-default"].wall_time
                   / res["hadoop-greedy"].wall_time)
        rows.append({
            "extra_s": extra,
            "wall_default": res["hadoop-default"].wall_time,
            "wall_greedy": res["hadoop-greedy"].wall_time,
            "wall_sge": res["sge"].wall_time,
            "rt_default": res["hadoop-default"].resource_time,
            "rt_greedy": res["hadoop-greedy"].resource_time,
            "rt_sge": res["sge"].resource_time,
            "balancer_speedup": speedup,
        })
        if verbose:
            r = rows[-1]
            print(f"extra={extra:4d}s  wall: default={r['wall_default']:8.0f} "
                  f"greedy={r['wall_greedy']:8.0f} sge={r['wall_sge']:8.0f}  "
                  f"speedup={speedup:.2f}x")
    mean_speedup = float(np.mean([r["balancer_speedup"] for r in rows]))
    if verbose:
        print(f"mean balancer speedup {mean_speedup:.2f}x "
              f"(paper: ~1.5x)")
    return {"rows": rows, "mean_balancer_speedup": mean_speedup}


if __name__ == "__main__":
    run()
