"""Benchmark harness — one entry per paper table/figure + roofline/kernels.

Prints ``name,value,derived`` CSV lines per benchmark plus the validation
summary EXPERIMENTS.md quotes, and writes one JSON artifact per bench
(``BENCH_<name>.json``) so the perf trajectory is diffable across PRs.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run --smoke    # CI-fast subset

``--smoke`` runs every artifact-emitting bench except the table-scheme
sweep and the roofline (balancer, chunk model, kernels, query pruning,
blockstore, fold engine, group_by, frontend, tiers, faults, sketches) —
CI uploads the JSON files from each
run and gates headline metrics against ``benchmarks/perf_baselines.json``
via ``benchmarks/check_regression.py``.
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Callable, Optional


def _write_artifact(name: str, payload: dict) -> None:
    path = f"BENCH_{name}.json"
    with open(path, "w") as f:
        json.dump({"bench": name, **payload}, f, indent=2, sort_keys=True)
    print(f"wrote {path}")


def _run_bench(
    name: str,
    title: str,
    runner: Callable[[], dict],
    summarize: Optional[Callable[[dict], str]] = None,
    payload: Optional[Callable[[dict], dict]] = None,
) -> None:
    """Time one bench, print its CSV summary line, write its artifact."""
    print(f"\n--- {title} ---")
    t0 = time.perf_counter()
    b = runner()
    elapsed_us = round((time.perf_counter() - t0) * 1e6)
    if summarize is not None:
        print(f"bench_{name},{elapsed_us},{summarize(b)}")
    _write_artifact(name, {"elapsed_us": elapsed_us,
                           **(payload(b) if payload else b)})


def run_balancer() -> None:
    from benchmarks import bench_balancer

    _run_bench(
        "balancer",
        "[Fig. 3] Use case 1: heterogeneous cluster / load balancer",
        bench_balancer.run,
        lambda b: f"mean_speedup={b['mean_balancer_speedup']:.2f}x;paper=1.5x")


def run_chunk_model() -> None:
    from benchmarks import bench_chunk_model

    _run_bench(
        "chunk_model",
        "[Fig. 4] Use case 2: large-dataset average / chunk model",
        bench_chunk_model.run,
        lambda b: (f"eta_star={b['eta_star_model']};paper=50-60;"
                   f"sge_wall_x={b['sge_wall_x']:.1f};paper=5-8;"
                   f"sge_rt_x={b['sge_rt_x']:.1f};paper=14-20"))


def run_table_scheme() -> None:
    from benchmarks import bench_table_scheme

    _run_bench(
        "table_scheme",
        "[Fig. 6/Table 3] Use case 3: table scheme / rapid query",
        bench_table_scheme.run,
        lambda b: (f"naive_over_proposed_small="
                   f"{b['naive_over_proposed_small']:.1f}x;paper=9x;"
                   f"sge_over_proposed_large="
                   f"{b['sge_over_proposed_large']:.1f}x;paper=3x"))


def run_query_pruning() -> None:
    from benchmarks import bench_query_pruning

    _run_bench(
        "query_pruning",
        "[PR 2] GridQuery region pruning: pruned vs naive scan",
        bench_query_pruning.run,
        lambda b: (f"regions_pruned={b['regions_pruned']}/{b['n_sites']};"
                   f"wall_vs_mask={b['wall_speedup_vs_mask_path']:.1f}x;"
                   f"sim_rt_x={b['sim_rt_speedup']:.1f}x"))


def run_blockstore() -> None:
    from benchmarks import bench_blockstore

    def summarize(b):
        total = b["refresh_blocks_reused"] + b["refresh_blocks_transferred"]
        return (f"refresh_x={b['refresh_speedup_vs_rebuild']:.1f};"
                f"reused={b['refresh_blocks_reused']}/{total};"
                f"overlap_2nd_gathers={b['overlap_second_gathers']}")

    _run_bench(
        "blockstore",
        "[PR 3] BlockStore: copy-on-write mutation/overlap reuse",
        bench_blockstore.run,
        summarize)


def run_fold_engine() -> None:
    from benchmarks import bench_fold_engine

    _run_bench(
        "fold_engine",
        "[PR 4] Block-granular fold engine: partial cache + fused CSE",
        bench_fold_engine.run,
        lambda b: (f"warm_x={b['warm_speedup_vs_refold']:.0f};"
                   f"dirty_rows={b['dirty_rows_folded']}/{b['n_rows']};"
                   f"cse_flops={b['cse_flop_ratio']:.2f}x"))


def run_group_by() -> None:
    from benchmarks import bench_group_by

    _run_bench(
        "group_by",
        "[PR 5] Grouped analytics: one-pass group_by + tree-reduce merge",
        bench_group_by.run,
        lambda b: (f"grouped_x={b['grouped_speedup_vs_loop']:.1f};"
                   f"warm_x={b['grouped_warm_speedup_vs_loop']:.1f};"
                   f"merge_tree_x={b['merge_tree_speedup']:.2f}"))


def run_frontend(smoke: bool = True) -> None:
    from benchmarks import bench_frontend

    _run_bench(
        "frontend",
        "[PR 7] GridFrontend: concurrent serving, cross-query coalescing",
        lambda: bench_frontend.run(smoke=smoke),
        lambda b: (f"repeat_x={b['coalesce_speedup_repeat']:.1f};"
                   f"grouped_x={b['coalesce_speedup_grouped']:.1f};"
                   f"mutation_x={b['coalesce_speedup_mutation']:.1f};"
                   f"qps={b['repeat_coalesced_qps']:.0f};"
                   f"p99_ms={b['repeat_coalesced_p99_ms']:.2f}"))


def run_tiers() -> None:
    from benchmarks import bench_tiers

    _run_bench(
        "tiers",
        "[PR 8] Tiered BlockStore: spill at 10x the device budget",
        bench_tiers.run,
        lambda b: (f"warm_over_cold={b['spill_warm_over_cold']:.3f};"
                   f"warm_disk_reads={b['warm_disk_reads']};"
                   f"promote_gathers={b['promote_gathers']};"
                   f"spills={b['cold_spills']}"))


def run_faults(smoke: bool = True) -> None:
    from benchmarks import bench_faults

    _run_bench(
        "faults",
        "[PR 9] Fault tolerance: armed-injector overhead + recovery walls",
        lambda: bench_faults.run(smoke=smoke),
        lambda b: (f"overhead_x={b['fault_overhead_ratio']:.3f};"
                   f"corrupt_recover_s={b['corrupt_recovery_wall_s']:.2f};"
                   f"quarantine_recover_s="
                   f"{b['quarantine_recovery_wall_s']:.2f}"))


def run_sketches() -> None:
    from benchmarks import bench_sketches

    _run_bench(
        "sketches",
        "[PR 10] Sketch statistics: fold overhead, warm repeat, accuracy",
        bench_sketches.run,
        lambda b: (f"overhead_x={b['sketch_fold_overhead_vs_moments']:.2f};"
                   f"warm_rows={b['warm_rows_folded']};"
                   f"cm_frac={b['cm_overcount_frac_of_bound']:.2f};"
                   f"hll_se={b['hll_err_frac_of_se']:.2f};"
                   f"rank_frac={b['quantile_rank_err_frac_of_bound']:.2f}"))


def run_kernels() -> None:
    from benchmarks import bench_kernels

    _run_bench(
        "kernels",
        "Kernels (interpret-mode validation)",
        bench_kernels.run,
        lambda b: (f"fused_fold_bytes_x="
                   f"{b['fused_fold_speedup_grouped']:.2f};"
                   f"bw_frac={b['fused_fold_roofline_bw_frac']:.2f}"),
        # rows become dicts for the artifact; every scalar metric (the
        # gated fused_fold ratios) passes through untouched
        payload=lambda b: {
            **{k: v for k, v in b.items() if k != "rows"},
            "rows": [{"name": n, "us": us, "derived": derived}
                     for n, us, derived in b["rows"]],
        })


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--smoke", action="store_true",
                        help="CI-fast subset: every artifact bench except "
                             "the table-scheme sweep and the roofline")
    args = parser.parse_args()

    print("=" * 72)
    print("ColoGrid benchmarks (paper: HadoopBase-MIP backend, Bao et al. 2017)")
    print("=" * 72)

    if args.smoke:
        run_balancer()
        run_chunk_model()
        run_kernels()
        run_query_pruning()
        run_blockstore()
        run_fold_engine()
        run_group_by()
        run_frontend(smoke=True)
        run_tiers()
        run_faults(smoke=True)
        run_sketches()
        print("\nsmoke benchmarks complete")
        return

    from benchmarks import bench_roofline

    run_balancer()
    run_chunk_model()
    run_table_scheme()
    run_query_pruning()
    run_blockstore()
    run_fold_engine()
    run_group_by()
    run_frontend(smoke=False)
    run_tiers()
    run_faults(smoke=False)
    run_sketches()
    run_kernels()

    print("\n--- Roofline (single-pod dry-run artifacts) ---")
    bench_roofline.run()

    print("\nall benchmarks complete")


if __name__ == "__main__":
    main()
