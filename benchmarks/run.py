"""Benchmark harness — one entry per paper table/figure + roofline/kernels.

Prints ``name,value,derived`` CSV lines per benchmark plus the validation
summary EXPERIMENTS.md quotes, and writes one JSON artifact per bench so the
perf trajectory is diffable across PRs.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run --smoke    # CI-fast subset
"""

from __future__ import annotations

import argparse
import json
import time


def _write_artifact(name: str, payload: dict) -> None:
    path = f"BENCH_{name}.json"
    with open(path, "w") as f:
        json.dump({"bench": name, **payload}, f, indent=2, sort_keys=True)
    print(f"wrote {path}")


def run_query_pruning() -> None:
    from benchmarks import bench_query_pruning

    print("\n--- [PR 2] GridQuery region pruning: pruned vs naive scan ---")
    t0 = time.perf_counter()
    b = bench_query_pruning.run()
    elapsed_us = (time.perf_counter() - t0) * 1e6
    print(f"bench_query_pruning,{elapsed_us:.0f},"
          f"regions_pruned={b['regions_pruned']}/{b['n_sites']};"
          f"wall_vs_mask={b['wall_speedup_vs_mask_path']:.1f}x;"
          f"sim_rt_x={b['sim_rt_speedup']:.1f}x")
    _write_artifact("query_pruning", {"elapsed_us": round(elapsed_us), **b})


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--smoke", action="store_true",
                        help="fast subset for CI: query-pruning bench only")
    args = parser.parse_args()

    print("=" * 72)
    print("ColoGrid benchmarks (paper: HadoopBase-MIP backend, Bao et al. 2017)")
    print("=" * 72)

    if args.smoke:
        run_query_pruning()
        print("\nsmoke benchmarks complete")
        return

    from benchmarks import (
        bench_balancer,
        bench_chunk_model,
        bench_kernels,
        bench_roofline,
        bench_table_scheme,
    )

    print("\n--- [Fig. 3] Use case 1: heterogeneous cluster / load balancer ---")
    t0 = time.perf_counter()
    b1 = bench_balancer.run()
    print(f"bench_balancer,{(time.perf_counter()-t0)*1e6:.0f},"
          f"mean_speedup={b1['mean_balancer_speedup']:.2f}x;paper=1.5x")

    print("\n--- [Fig. 4] Use case 2: large-dataset average / chunk model ---")
    t0 = time.perf_counter()
    b2 = bench_chunk_model.run()
    print(f"bench_chunk_model,{(time.perf_counter()-t0)*1e6:.0f},"
          f"eta_star={b2['eta_star_model']};paper=50-60;"
          f"sge_wall_x={b2['sge_wall_x']:.1f};paper=5-8;"
          f"sge_rt_x={b2['sge_rt_x']:.1f};paper=14-20")

    print("\n--- [Fig. 6/Table 3] Use case 3: table scheme / rapid query ---")
    t0 = time.perf_counter()
    b3 = bench_table_scheme.run()
    elapsed_us = (time.perf_counter() - t0) * 1e6
    print(f"bench_table_scheme,{elapsed_us:.0f},"
          f"naive_over_proposed_small={b3['naive_over_proposed_small']:.1f}x;"
          f"paper=9x;sge_over_proposed_large="
          f"{b3['sge_over_proposed_large']:.1f}x;paper=3x")
    _write_artifact("table_scheme", {"elapsed_us": round(elapsed_us), **b3})

    run_query_pruning()

    print("\n--- Kernels (interpret-mode validation) ---")
    bench_kernels.run()

    print("\n--- Roofline (single-pod dry-run artifacts) ---")
    bench_roofline.run()

    print("\nall benchmarks complete")


if __name__ == "__main__":
    main()
