"""Tiered BlockStore spill bench: query a dataset 10× the device budget.

The scenario the tier chain exists for: the payload is an order of
magnitude larger than the synthetic device-byte budget, so a cold query
continuously demotes committed blocks (device → host → disk) while
folding.  Measured:

1. **Cold wall** — first exact query under forced spill pressure.
2. **Warm wall** — the same query repeated after clearing the
   plan-result cache, so the answer is reconstructed from cached
   partials.  Partials are tiny and stay resident, so the warm pass must
   touch neither the fabric nor the spill files — ``warm_disk_reads``
   probes exactly that, and ``spill_warm_over_cold`` (gated, lower is
   better) is the warm/cold wall ratio.
3. **Promotion wall** — partials dropped, blocks demoted: the repeat
   query re-serves payloads from host/disk instead of re-gathering;
   ``promote_gathers`` counts table re-reads (0 when every byte was
   recovered from a lower tier).
"""

from __future__ import annotations

import shutil
import tempfile
import time

import numpy as np

import jax

from repro.core.grid import GridSession
from repro.core.stats import CountProgram, MeanProgram
from repro.core.table import make_mip_table

N_REGIONS = 16
PER_REGION = 8
PAYLOAD = (32, 32)                      # 4 KB float32 rows
ROW_BYTES = int(np.prod(PAYLOAD)) * 4


def _make_table(seed=0):
    rng = np.random.default_rng(seed)
    groups = [f"g{i:02d}" for i in range(N_REGIONS)]
    t = make_mip_table(payload_shape=PAYLOAD, presplit_keys=groups[1:])
    keys = [f"{g}x{i:04d}" for g in groups for i in range(PER_REGION)]
    n = len(keys)
    t.upload(keys, {
        "img": {"data": rng.normal(size=(n,) + PAYLOAD).astype(np.float32)},
        "idx": {"size": rng.integers(6_000_000, 20_000_001, n)}})
    return t


def _timed_run(session, program):
    t0 = time.perf_counter()
    res, rep = session.run(program)
    jax.block_until_ready(res)
    return (time.perf_counter() - t0), res, rep


def run(verbose: bool = True):
    t = _make_table()
    total = N_REGIONS * PER_REGION * ROW_BYTES
    device_budget = total // 10          # the 10× oversubscription
    spill_root = tempfile.mkdtemp(prefix="bench-tiers-")
    expect = t.column("img", "data").astype(np.float64).mean(0)

    session = GridSession(
        t, default_eta=PER_REGION,
        device_budget=device_budget,
        host_budget=total // 4,
        spill_dir=spill_root,
        prefetch=False,                  # measure the tiers, not overlap
    )
    try:
        # --- 1. cold: every block gathers, commits, and demotes -------
        cold_s, res, _ = _timed_run(session, MeanProgram())
        np.testing.assert_allclose(np.asarray(res), expect, atol=1e-4)
        cold = session.blocks.stats.snapshot()
        tiers_cold = session.blocks.tier_bytes()
        assert tiers_cold["device"] <= device_budget

        # --- 2. warm: partials answer; no fabric, no spill reads ------
        session._results.clear()
        warm_s, res, rep = _timed_run(session, MeanProgram())
        np.testing.assert_allclose(np.asarray(res), expect, atol=1e-4)
        warm = session.blocks.stats.snapshot()
        warm_disk_reads = warm.spill_reads - cold.spill_reads
        warm_gathers = warm.gathers - cold.gathers

        # --- 3. promotion: drop partials, re-serve payloads from the
        # lower tiers (host RAM + mmap'd spill files) ------------------
        session.blocks.clear_partials()
        session._results.clear()
        promote_s, res, _ = _timed_run(session, MeanProgram())
        np.testing.assert_allclose(np.asarray(res), expect, atol=1e-4)
        done = session.blocks.stats.snapshot()
        promote_gathers = done.gathers - warm.gathers
        promote_spill_reads = done.spill_reads - warm.spill_reads
    finally:
        session.close()
        shutil.rmtree(spill_root, ignore_errors=True)

    b = {
        "n_rows": N_REGIONS * PER_REGION,
        "payload_bytes_total": total,
        "device_budget_bytes": device_budget,
        "oversubscription_x": total / device_budget,
        "cold_wall_s": cold_s,
        "warm_wall_s": warm_s,
        "promote_wall_s": promote_s,
        "spill_warm_over_cold": warm_s / cold_s,
        "warm_disk_reads": warm_disk_reads,
        "warm_gathers": warm_gathers,
        "warm_rows_folded": rep.query.rows_folded,
        "promote_gathers": promote_gathers,
        "promote_spill_reads": promote_spill_reads,
        "cold_demotions": cold.demotions,
        "cold_spills": cold.spills,
        "cold_spill_drops": cold.spill_drops,
        "cold_host_serves": cold.host_serves,
        "device_bytes_peak_cold": tiers_cold["device"],
        "host_bytes_cold": tiers_cold["host"],
        "disk_bytes_cold": tiers_cold["disk"],
    }
    if verbose:
        for k, v in b.items():
            print(f"  {k}: {v}")
    return b


if __name__ == "__main__":
    run()
