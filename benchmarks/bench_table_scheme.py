"""Use case 3 / Table 3 + Fig. 6 — rapid NoSQL query, table scheme.

The paper's 10 experiments: average T1 subsets selected by age band × sex
(Table 3 counts), under three systems:

    hadoop-proposed — index family separate: predicate touches index bytes
                      only, map tasks average the selected rows in place
    hadoop-naive    — single family: the scan drags every image's bytes
                      through the read path before filtering
    sge             — no query problem, but every selected image crosses
                      the network from central storage

Byte counts come from the real TensorTable query engine
(indexed_query/naive_query); times from the cluster simulator with the
paper's hardware constants.  Validated claims: proposed ≈3×/6× better than
SGE on large subsets; naive degrades as subsets shrink (≈6.5× worse than
SGE, ≈9× worse than proposed on the smallest).
"""

from __future__ import annotations

import numpy as np

from repro.core.balancer import greedy_allocation
from repro.core.grid import GridSession
from repro.core.query import age_sex_predicate, naive_query
from repro.core.simulator import ClusterSim, SimTask, paper_cluster
from repro.core.stats import MeanProgram
from repro.data.pipeline import synthetic_image_population
from repro.core.table import ColumnSpec, make_naive_table

ETA = 50                 # the paper fixes 50 images per map task
SIZE_GEN = 21e6
AVG = lambda n: 0.4 * n + 5.0

EXPERIMENTS = [
    ("all-female", None, None, 1),
    ("all-male", None, None, 0),
    ("4-20-female", 4, 20, 1),
    ("4-20-male", 4, 20, 0),
    ("20-40-female", 20, 40, 1),
    ("20-40-male", 20, 40, 0),
    ("40-60-female", 40, 60, 1),
    ("40-60-male", 40, 60, 0),
    (">60-female", 60, 200, 1),
    (">60-male", 60, 200, 0),
]


def scan_then_average(sim, nodes, alloc, n_regions, n_sel, scan_bytes_total):
    """Simulate: distributed scan of `scan_bytes_total` + averaging job."""
    rng = np.random.default_rng(n_sel)
    tasks = []
    # scan phase: one task per region reading its share of the scanned bytes
    per_region = scan_bytes_total / n_regions
    for i in range(n_regions):
        tasks.append(SimTask(i, input_bytes=per_region, output_bytes=0,
                             work=0.0, home_node=alloc[i]))
    # map/average phase
    n_maps = max(n_sel // ETA, 1)
    for j in range(n_maps):
        tasks.append(SimTask(n_regions + j, input_bytes=ETA * 13e6,
                             output_bytes=SIZE_GEN, work=AVG(ETA),
                             home_node=alloc[int(rng.integers(n_regions))]))
    tasks.append(SimTask(n_regions + n_maps, input_bytes=n_maps * SIZE_GEN,
                         output_bytes=SIZE_GEN, work=AVG(n_maps),
                         home_node=None))
    return sim.run(tasks, "hadoop")


def sge_average(sim, n_sel):
    n_maps = max(n_sel // ETA, 1)
    tasks = [SimTask(j, input_bytes=ETA * 13e6, output_bytes=SIZE_GEN,
                     work=AVG(ETA), home_node=None) for j in range(n_maps)]
    tasks.append(SimTask(n_maps, input_bytes=n_maps * SIZE_GEN,
                         output_bytes=SIZE_GEN, work=AVG(n_maps),
                         home_node=None))
    return sim.run(tasks, "sge")


def run(verbose: bool = True):
    # small payloads, REAL index columns; logical sizes carry the 6-20MB
    pop = synthetic_image_population(payload_shape=(4, 4, 4), scale=1.0)
    naive = make_naive_table(
        payload_shape=(4, 4, 4),
        extra_index_columns=[ColumnSpec("age", (), np.float32),
                             ColumnSpec("sex", (), np.int8)])
    keys = [k.decode() for k in pop.keys]
    naive.upload(keys, {"img": {
        "data": pop.column("img", "data"),
        "size": pop.column("idx", "size"),
        "age": pop.column("idx", "age"),
        "sex": pop.column("idx", "sex")}})

    nodes = paper_cluster()
    rng = np.random.default_rng(0)
    n_regions = 416
    region_bytes = {i: int(b) for i, b in
                    enumerate(rng.integers(150e6, 220e6, n_regions))}
    alloc = greedy_allocation(region_bytes, nodes)
    sim = ClusterSim(nodes, bandwidth=70e6)

    # the proposed scheme's queries go through the session facade: the
    # pushdown path both produces the byte accounting the simulator consumes
    # and computes the subset template on the mesh.
    session = GridSession(pop, default_eta=ETA)

    rows = []
    for name, lo, hi, sex in EXPERIMENTS:
        pred = age_sex_predicate(lo, hi, sex)
        avg, report = session.run_where(pred, MeanProgram(), ["age", "sex"])
        st_prop = report.query
        m_naive, st_naive = naive_query(naive, pred, ["age", "sex"])
        assert st_prop.rows_selected == int(m_naive.sum())
        # the pushdown selected the SAME rows: its template must match the
        # naive mask's numpy average (count equality alone can't tell)
        if m_naive.any():
            ref = pop.column("img", "data")[m_naive].mean(axis=0)
            assert np.allclose(np.asarray(avg), ref, atol=1e-5)
        assert st_prop.payload_bytes_moved <= st_prop.rows_selected * int(
            pop.physical_row_nbytes(["img"]))
        n_sel = st_prop.rows_selected

        r_prop = scan_then_average(sim, nodes, alloc, n_regions, n_sel,
                                   st_prop.total_bytes_scanned)
        r_naive = scan_then_average(sim, nodes, alloc, n_regions, n_sel,
                                    st_naive.total_bytes_scanned)
        r_sge = sge_average(sim, n_sel)
        rows.append({
            "experiment": name, "n_selected": n_sel,
            "wall_proposed": r_prop.wall_time,
            "wall_naive": r_naive.wall_time,
            "wall_sge": r_sge.wall_time,
            "rt_proposed": r_prop.resource_time,
            "rt_naive": r_naive.resource_time,
            "rt_sge": r_sge.resource_time,
        })
        if verbose:
            r = rows[-1]
            print(f"{name:14s} n={n_sel:5d}  wall: prop={r['wall_proposed']:7.1f} "
                  f"naive={r['wall_naive']:7.1f} sge={r['wall_sge']:7.1f}  "
                  f"naive/prop={r['wall_naive']/r['wall_proposed']:5.1f}x")

    smallest = min(rows, key=lambda r: r["n_selected"])
    naive_x = smallest["wall_naive"] / smallest["wall_proposed"]
    naive_vs_sge = smallest["wall_naive"] / smallest["wall_sge"]
    largest = max(rows, key=lambda r: r["n_selected"])
    sge_x = largest["wall_sge"] / largest["wall_proposed"]
    if verbose:
        print(f"\nsmallest subset ({smallest['experiment']}): naive/proposed "
              f"{naive_x:.1f}x (paper ~9x), naive/SGE {naive_vs_sge:.1f}x "
              f"(paper ~6.5x)")
        print(f"largest subset ({largest['experiment']}): SGE/proposed "
              f"{sge_x:.1f}x wall (paper ~3x)")
    return {"rows": rows, "naive_over_proposed_small": naive_x,
            "naive_over_sge_small": naive_vs_sge,
            "sge_over_proposed_large": sge_x}


if __name__ == "__main__":
    run()
