"""Perf gate: compare emitted ``BENCH_*.json`` headline metrics against the
committed baselines and FAIL on a regression beyond tolerance.

Baselines live in ``benchmarks/perf_baselines.json``::

    {
      "default_tolerance": 0.25,
      "metrics": {
        "fold_engine": {
          "warm_speedup_vs_refold": {"baseline": 6.0, "direction": "higher"}
        },
        ...
      }
    }

Every gated metric is a *ratio* (speedup vs an in-run baseline), so it
self-normalizes across machines — absolute wall clocks are deliberately
not gated.  ``direction: "higher"`` fails when
``value < baseline * (1 - tolerance)``; ``"lower"`` fails when
``value > baseline * (1 + tolerance)``.  A missing artifact or metric is a
FAILURE (the gate must not pass vacuously) unless the entry sets
``"optional": true``.

    PYTHONPATH=src python -m benchmarks.check_regression
    PYTHONPATH=src python -m benchmarks.check_regression --bench-dir out/

Exit code 0 = all gated metrics within tolerance; 1 = regression (or
missing required data).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List, Tuple

DEFAULT_BASELINES = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                 "perf_baselines.json")


def check_metric(name: str, value: float, baseline: float,
                 direction: str, tolerance: float) -> Tuple[bool, str]:
    """One gated metric: ``(ok, human-readable verdict line)``."""
    if direction == "higher":
        floor = baseline * (1.0 - tolerance)
        ok = value >= floor
        bound = f">= {floor:.3f}"
    elif direction == "lower":
        ceil = baseline * (1.0 + tolerance)
        ok = value <= ceil
        bound = f"<= {ceil:.3f}"
    else:
        return False, f"{name}: unknown direction {direction!r}"
    verdict = "ok" if ok else "REGRESSION"
    return ok, (f"{name}: {value:.3f} (baseline {baseline:.3f}, "
                f"need {bound}) {verdict}")


def run_gate(bench_dir: str, baselines_path: str) -> Tuple[bool, List[str]]:
    with open(baselines_path) as f:
        spec = json.load(f)
    default_tol = float(spec.get("default_tolerance", 0.25))
    lines: List[str] = []
    ok_all = True
    for bench, metrics in sorted(spec.get("metrics", {}).items()):
        path = os.path.join(bench_dir, f"BENCH_{bench}.json")
        if not os.path.exists(path):
            if all(m.get("optional") for m in metrics.values()):
                lines.append(f"BENCH_{bench}.json: missing (optional), "
                             f"skipped")
                continue
            lines.append(f"BENCH_{bench}.json: MISSING (required artifact)")
            ok_all = False
            continue
        with open(path) as f:
            payload = json.load(f)
        for metric, m in sorted(metrics.items()):
            label = f"{bench}.{metric}"
            if metric not in payload:
                if m.get("optional"):
                    lines.append(f"{label}: missing (optional), skipped")
                    continue
                lines.append(f"{label}: MISSING from artifact")
                ok_all = False
                continue
            value = float(payload[metric])
            if m.get("optional") and value == 0.0:
                # optional probes report 0 when their environment (e.g. a
                # multi-device subprocess) is unavailable — not a regression
                lines.append(f"{label}: 0.0 (optional probe unavailable), "
                             f"skipped")
                continue
            ok, line = check_metric(
                label, value, float(m["baseline"]),
                m.get("direction", "higher"),
                float(m.get("tolerance", default_tol)))
            lines.append(line)
            ok_all = ok_all and ok
    return ok_all, lines


def main() -> int:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--bench-dir", default=".",
                        help="directory holding the emitted BENCH_*.json")
    parser.add_argument("--baselines", default=DEFAULT_BASELINES,
                        help="committed baseline/tolerance file")
    args = parser.parse_args()
    ok, lines = run_gate(args.bench_dir, args.baselines)
    print("perf gate:", args.baselines)
    for line in lines:
        print(" ", line)
    if not ok:
        print("perf gate FAILED: headline metric regressed beyond tolerance")
        return 1
    print("perf gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
