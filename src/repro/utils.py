"""Small shared utilities (mesh construction, tree sizing, rng)."""

from __future__ import annotations

from typing import Sequence, Tuple

import numpy as np

import jax


def make_mesh(shape: Sequence[int], axis_names: Sequence[str]) -> jax.sharding.Mesh:
    """``jax.make_mesh`` pinned to Auto axis types (portable across JAX 0.8/0.9)."""
    return jax.make_mesh(
        tuple(shape),
        tuple(axis_names),
        axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names),
    )


def tree_size_bytes(tree) -> int:
    """Total bytes of all array leaves in a pytree (by shape/dtype, not
    device residency)."""
    return sum(
        int(np.prod(x.shape, dtype=np.int64)) * x.dtype.itemsize
        for x in jax.tree.leaves(tree)
        if hasattr(x, "shape") and hasattr(x, "dtype")
    )


def tree_param_count(tree) -> int:
    return sum(
        int(np.prod(x.shape, dtype=np.int64))
        for x in jax.tree.leaves(tree)
        if hasattr(x, "shape")
    )


def fold_seed(seed: int, *names: str) -> jax.Array:
    """Deterministic named rng derivation."""
    key = jax.random.key(seed)
    for n in names:
        key = jax.random.fold_in(key, int(np.uint32(abs(hash(n)) & 0xFFFFFFFF)))
    return key
