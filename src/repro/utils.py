"""Small shared utilities (mesh construction, tree sizing, rng)."""

from __future__ import annotations

from typing import Sequence, Tuple

import numpy as np

import jax


def shard_map_compat(
    f,
    mesh: jax.sharding.Mesh,
    in_specs,
    out_specs,
    axis_names=None,
    check: bool = False,
):
    """``shard_map`` across JAX versions.

    Newer JAX exposes ``jax.shard_map(..., axis_names=..., check_vma=...)``;
    JAX 0.4.x ships it as ``jax.experimental.shard_map.shard_map(...,
    auto=..., check_rep=...)`` where ``auto`` is the *complement* of the
    manual axes.  ``axis_names=None`` means manual over every mesh axis.
    """
    try:
        from jax import shard_map as _shard_map  # JAX >= 0.6
        kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=check)
        if axis_names is not None:
            kwargs["axis_names"] = set(axis_names)
        return _shard_map(f, **kwargs)
    except ImportError:
        from jax.experimental.shard_map import shard_map as _shard_map
        # NOTE: partial-manual (`auto=`) on 0.4.x trips a fatal XLA sharding
        # check on CPU, so the compat path runs fully manual: axes absent
        # from the specs are replicated, which preserves results (collectives
        # only name the manual axes) at some redundant compute.
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check)


def make_mesh(shape: Sequence[int], axis_names: Sequence[str]) -> jax.sharding.Mesh:
    """``jax.make_mesh`` pinned to Auto axis types (portable across JAX 0.8/0.9).

    JAX 0.4.x has neither ``AxisType`` nor the ``axis_types`` kwarg — there
    every mesh axis is Auto already, so the plain call is equivalent.
    """
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            tuple(shape),
            tuple(axis_names),
            axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names),
        )
    return jax.make_mesh(tuple(shape), tuple(axis_names))


def tree_size_bytes(tree) -> int:
    """Total bytes of all array leaves in a pytree (by shape/dtype, not
    device residency)."""
    return sum(
        int(np.prod(x.shape, dtype=np.int64)) * x.dtype.itemsize
        for x in jax.tree.leaves(tree)
        if hasattr(x, "shape") and hasattr(x, "dtype")
    )


def tree_param_count(tree) -> int:
    return sum(
        int(np.prod(x.shape, dtype=np.int64))
        for x in jax.tree.leaves(tree)
        if hasattr(x, "shape")
    )


def fold_seed(seed: int, *names: str) -> jax.Array:
    """Deterministic named rng derivation."""
    key = jax.random.key(seed)
    for n in names:
        key = jax.random.fold_in(key, int(np.uint32(abs(hash(n)) & 0xFFFFFFFF)))
    return key
