"""Parameter initialization + logical-axis sharding resolution.

Every parameter is created together with a tuple of *logical axis names*
(one per dim, e.g. ``("embed", "heads")``).  A rules table maps logical names
to mesh axes (MaxText-style), and :func:`resolve_spec` turns (shape, logical
axes, rules, mesh) into a concrete ``PartitionSpec`` — **dropping any mesh
axis that does not divide the dimension** (e.g. 8 KV heads cannot shard over
a 16-way model axis, so they stay replicated; mixtral's 8 experts shard their
FFN dim over the model axis instead).  This single resolution point is what
lets every assigned architecture reuse one sharding system without
per-arch special cases.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Mapping, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

LogicalAxes = Tuple[Optional[str], ...]

# ----------------------------------------------------------------------
# rules: logical axis -> candidate mesh axes, in priority order
# ----------------------------------------------------------------------

def sharding_rules(fsdp: bool = True, expert_parallel: bool = True) -> Dict[str, Tuple[str, ...]]:
    """The default mapping (see DESIGN.md §5).

    - ``model`` carries tensor parallelism (heads / mlp / vocab / experts);
    - ``data`` carries FSDP parameter sharding (the "embed" dim of every
      weight) in addition to batch data-parallelism;
    - ``pod`` carries pure DP (gradient sync over DCN) and joins FSDP for
      the very largest weights only via the "embed_pod" logical name.
    """
    rules = {
        "batch": ("pod", "data"),
        "seq": (),
        "embed_act": (),   # hidden dim of activations (→ "model" enables SP)
        "vocab": ("model",),
        "embed": ("data",) if fsdp else (),
        "heads": ("model",),
        "kv_heads": ("model",),
        "qk_dim": (),
        "mlp": ("model",),
        "experts": ("model",) if expert_parallel else (),
        "expert_mlp": ("model",) if not expert_parallel else ("model",),
        "lora": (),
        "state": (),
        "conv": (),
        "frames": (),
        "layers": (),
        None: (),
    }
    return rules


def resolve_spec(
    shape: Sequence[int],
    axes: Optional[LogicalAxes],
    rules: Mapping[Optional[str], Tuple[str, ...]],
    mesh_shape: Mapping[str, int],
) -> P:
    """Logical axes -> PartitionSpec with divisibility + axis-reuse checks."""
    if axes is None:
        axes = (None,) * len(shape)
    if len(axes) != len(shape):
        raise ValueError(f"axes {axes} rank != shape {shape}")
    used: set = set()
    parts = []
    for dim, lname in zip(shape, axes):
        assigned: list = []
        factor = 1
        for maxis in rules.get(lname, ()):
            if maxis not in mesh_shape or maxis in used:
                continue
            size = mesh_shape[maxis]
            if size > 1 and dim % (factor * size) == 0:
                assigned.append(maxis)
                used.add(maxis)
                factor *= size
        if not assigned:
            parts.append(None)
        elif len(assigned) == 1:
            parts.append(assigned[0])
        else:
            parts.append(tuple(assigned))
    # trim trailing Nones for tidy specs
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def resolve_tree(
    params: Any,
    logical: Any,
    rules: Mapping[Optional[str], Tuple[str, ...]],
    mesh: Mesh,
) -> Any:
    """Zip a params tree with its logical-axes tree into PartitionSpecs.

    Structure mismatch between the two trees raises — this is the guard that
    keeps ``init`` and ``logical_axes`` definitions in sync.
    """
    mesh_shape = dict(mesh.shape)

    def one(p, ax):
        return resolve_spec(np.shape(p), ax, rules, mesh_shape)

    return jax.tree.map(one, params, logical, is_leaf=lambda x: x is None or (
        isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x)
    ))


def named_shardings(spec_tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


# ----------------------------------------------------------------------
# initializers (params always carry their own dtype; compute casts later)
# ----------------------------------------------------------------------

def normal_init(key, shape, dtype, scale: Optional[float] = None,
                fan_in: Optional[int] = None):
    fi = fan_in if fan_in is not None else (shape[-2] if len(shape) >= 2 else shape[-1])
    std = scale if scale is not None else 1.0 / math.sqrt(max(fi, 1))
    return (jax.random.normal(key, shape) * std).astype(dtype)


def zeros_init(key, shape, dtype, **_):
    return jnp.zeros(shape, dtype)


def ones_init(key, shape, dtype, **_):
    return jnp.ones(shape, dtype)


def embed_init(key, shape, dtype, **_):
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


class KeyGen:
    """Splits a PRNG key on demand, by name, deterministically."""

    def __init__(self, key: jax.Array):
        self._key = key
        self._n = 0

    def __call__(self) -> jax.Array:
        self._n += 1
        return jax.random.fold_in(self._key, self._n)
