"""Attention variants: GQA (llama/qwen), qk-norm, QKV-bias, sliding-window,
M-RoPE, cross-attention (whisper), and DeepSeek MLA with absorbed decode.

All functions are pure; caches are explicit pytrees.  Three entry modes:

- ``full``   — training / prefill over a whole sequence (causal or not);
- ``decode`` — one new token against a cache (the ``serve_step`` path);
- cross-attention takes precomputed encoder KV.

The XLA path here is the dry-run/roofline path (cost_analysis sees real
einsums); the Pallas flash kernel in :mod:`repro.kernels.flash_attention` is
a drop-in for the ``full`` softmax-attention inner product.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import apply_mrope, apply_rope, rms_norm
from repro.models.params import KeyGen, normal_init, zeros_init


# ----------------------------------------------------------------------
# masks
# ----------------------------------------------------------------------

NEG_INF = -1e30


def causal_mask(q_len: int, kv_len: int, window: Optional[int] = None,
                q_offset: int = 0) -> jax.Array:
    """[q_len, kv_len] additive mask; supports sliding window and a query
    position offset (for chunked prefill)."""
    q_pos = jnp.arange(q_len)[:, None] + q_offset
    k_pos = jnp.arange(kv_len)[None, :]
    ok = k_pos <= q_pos
    if window is not None:
        ok &= k_pos > q_pos - window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _sdpa_chunked(q: jax.Array, k: jax.Array, v: jax.Array, scale: float,
                  causal: bool, window: Optional[int],
                  block: int = 1024) -> jax.Array:
    """Blockwise online-softmax attention in pure XLA (flash-style).

    Scans KV blocks with running (max, normalizer, accumulator) carry, so
    the [S, T] score matrix never exists whole — peak attention memory drops
    from O(S·T) to O(S·block) per head (the temp-memory blocker on the
    long-context train/prefill cells; see EXPERIMENTS.md §Perf).
    """
    B, S, H, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = H // Hkv
    blk = min(block, T)
    nb = -(-T // blk)
    pad = nb * blk - T
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qg = (q.reshape(B, S, Hkv, G, D) * scale).astype(q.dtype)
    kb = jnp.moveaxis(k.reshape(B, nb, blk, Hkv, D), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nb, blk, Hkv, Dv), 1, 0)
    q_pos = jnp.arange(S)[:, None]

    def body(carry, xs):
        m, l, acc = carry
        kblk, vblk, j = xs                                # [B,blk,Hkv,D], j
        s = jnp.einsum("bshgd,bthd->bhgst", qg, kblk,
                       preferred_element_type=jnp.float32)
        k_pos = j * blk + jnp.arange(blk)[None, :]
        ok = k_pos < T
        if causal:
            ok = ok & (k_pos <= q_pos)
            if window is not None:
                ok = ok & (k_pos > q_pos - window)
        s = jnp.where(ok[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))            # [B,Hkv,G,S]
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bhgst,bthd->bhgsd", p.astype(vblk.dtype), vblk)
        acc = acc * corr[..., None] + pv.astype(jnp.float32)
        return (m_new, l, acc), None

    m0 = jnp.full((B, Hkv, G, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, S), jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, S, Dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (kb, vb, jnp.arange(nb)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = jnp.moveaxis(out, 3, 1).reshape(B, S, H, Dv)
    return out.astype(q.dtype)


def _sdpa(q: jax.Array, k: jax.Array, v: jax.Array,
          mask: Optional[jax.Array], scale: float) -> jax.Array:
    """q [B,S,H,Dqk], k [B,T,Hkv,Dqk], v [B,T,Hkv,Dv] -> [B,S,H,Dv].

    GQA broadcast via grouping; MLA passes Dv != Dqk."""
    B, S, H, D = q.shape
    Hkv = k.shape[2]
    Dv = v.shape[-1]
    group = H // Hkv
    qg = q.reshape(B, S, Hkv, group, D)
    logits = jnp.einsum("bshgd,bthd->bhgst", qg, k,
                        preferred_element_type=jnp.float32) * scale
    if mask is not None:
        logits = logits + mask  # mask broadcasts over [B,h,g]
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgst,bthd->bshgd", w.astype(v.dtype), v)
    return out.reshape(B, S, H, Dv)


# ----------------------------------------------------------------------
# standard multi-head attention (GQA superset)
# ----------------------------------------------------------------------

def init_attention(cfg: ModelConfig, kg: KeyGen, cross: bool = False) -> Dict:
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    dt = cfg.param_dtype
    p = {
        "wq": normal_init(kg(), (d, qd), dt),
        "wk": normal_init(kg(), (d, kvd), dt),
        "wv": normal_init(kg(), (d, kvd), dt),
        "wo": normal_init(kg(), (qd, d), dt, fan_in=qd),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((qd,), dt)
        p["bk"] = jnp.zeros((kvd,), dt)
        p["bv"] = jnp.zeros((kvd,), dt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((cfg.head_dim,), dt)
        p["k_norm"] = jnp.ones((cfg.head_dim,), dt)
    return p


def attention_axes(cfg: ModelConfig, cross: bool = False) -> Dict:
    ax = {
        "wq": ("embed", "heads"),
        "wk": ("embed", "kv_heads"),
        "wv": ("embed", "kv_heads"),
        "wo": ("heads", "embed"),
    }
    if cfg.qkv_bias and not cross:
        ax.update({"bq": ("heads",), "bk": ("kv_heads",), "bv": ("kv_heads",)})
    if cfg.qk_norm:
        ax.update({"q_norm": (None,), "k_norm": (None,)})
    return ax


def _project_qkv(cfg: ModelConfig, p: Dict, xq: jax.Array,
                 xkv: jax.Array, compute_dtype):
    B, S, _ = xq.shape
    T = xkv.shape[1]
    q = jnp.einsum("bsd,dh->bsh", xq, p["wq"].astype(compute_dtype))
    k = jnp.einsum("btd,dh->bth", xkv, p["wk"].astype(compute_dtype))
    v = jnp.einsum("btd,dh->bth", xkv, p["wv"].astype(compute_dtype))
    if "bq" in p:
        q = q + p["bq"].astype(compute_dtype)
        k = k + p["bk"].astype(compute_dtype)
        v = v + p["bv"].astype(compute_dtype)
    q = q.reshape(B, S, cfg.n_heads, cfg.head_dim)
    k = k.reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    return q, k, v


def attention_full(
    cfg: ModelConfig,
    p: Dict,
    x: jax.Array,                       # [B, S, D]
    positions: jax.Array,               # [B, S] or [B, 3, S] under M-RoPE
    causal: bool = True,
) -> Tuple[jax.Array, Dict]:
    """Training / prefill; returns output and the KV cache content.

    ``positions=None`` skips RoPE entirely (whisper uses absolute position
    embeddings added at the input instead)."""
    dt = x.dtype
    q, k, v = _project_qkv(cfg, p, x, x, dt)
    if positions is None:
        pass
    elif cfg.mrope:
        q = apply_mrope(q, positions, cfg.rope_theta)
        k = apply_mrope(k, positions, cfg.rope_theta)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    S = x.shape[1]
    if cfg.attention_impl == "chunked":
        out = _sdpa_chunked(q, k, v, cfg.head_dim ** -0.5, causal,
                            cfg.sliding_window, cfg.attention_block)
    else:
        mask = causal_mask(S, S, cfg.sliding_window) if causal else None
        out = _sdpa(q, k, v, mask, cfg.head_dim ** -0.5)
    y = jnp.einsum("bsh,hd->bsd", out.reshape(out.shape[0], S, -1),
                   p["wo"].astype(dt))
    return y, {"k": k, "v": v}


def attention_decode(
    cfg: ModelConfig,
    p: Dict,
    x: jax.Array,                       # [B, 1, D]
    cache: Dict,                        # {"k","v": [B, T, Hkv, Dh]}
    pos: jax.Array,                     # [B] current position index
    use_rope: bool = True,
) -> Tuple[jax.Array, Dict]:
    """One-token decode against a fixed-capacity cache (in-place update).

    ``use_rope=False`` callers (whisper) pass positions only for the cache
    scatter/mask."""
    dt = x.dtype
    q, k_new, v_new = _project_qkv(cfg, p, x, x, dt)
    if not use_rope:
        pass
    elif cfg.mrope:
        # decode: text token — all three channels share the position
        pos3 = jnp.broadcast_to(pos[:, None, None], (pos.shape[0], 3, 1))
        q = apply_mrope(q, pos3, cfg.rope_theta)
        k_new = apply_mrope(k_new, pos3, cfg.rope_theta)
    else:
        q = apply_rope(q, pos[:, None], cfg.rope_theta)
        k_new = apply_rope(k_new, pos[:, None], cfg.rope_theta)

    T = cache["k"].shape[1]
    b_idx = jnp.arange(x.shape[0])
    k = cache["k"].at[b_idx, pos].set(k_new[:, 0])
    v = cache["v"].at[b_idx, pos].set(v_new[:, 0])

    k_pos = jnp.arange(T)[None, :]
    ok = k_pos <= pos[:, None]
    if cfg.sliding_window is not None:
        ok &= k_pos > (pos[:, None] - cfg.sliding_window)
    mask = jnp.where(ok, 0.0, NEG_INF)[:, None, None, None, :]  # [B,1,1,1,T]
    out = _sdpa(q, k, v, mask, cfg.head_dim ** -0.5)
    y = jnp.einsum("bsh,hd->bsd", out.reshape(out.shape[0], 1, -1),
                   p["wo"].astype(dt))
    return y, {"k": k, "v": v}


def cross_attention(
    cfg: ModelConfig,
    p: Dict,
    x: jax.Array,                       # [B, S, D] decoder states
    enc_kv: Dict,                       # {"k","v": [B, T, H, Dh]} precomputed
) -> jax.Array:
    dt = x.dtype
    B, S, _ = x.shape
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(dt))
    q = q.reshape(B, S, cfg.n_heads, cfg.head_dim)
    out = _sdpa(q, enc_kv["k"], enc_kv["v"], None, cfg.head_dim ** -0.5)
    return jnp.einsum("bsh,hd->bsd", out.reshape(B, S, -1), p["wo"].astype(dt))


def encode_cross_kv(cfg: ModelConfig, p: Dict, enc_out: jax.Array) -> Dict:
    """Precompute encoder KV once per request (whisper decoder)."""
    dt = enc_out.dtype
    B, T, _ = enc_out.shape
    k = jnp.einsum("btd,dh->bth", enc_out, p["wk"].astype(dt))
    v = jnp.einsum("btd,dh->bth", enc_out, p["wv"].astype(dt))
    return {
        "k": k.reshape(B, T, cfg.n_kv_heads, cfg.head_dim),
        "v": v.reshape(B, T, cfg.n_kv_heads, cfg.head_dim),
    }


# ----------------------------------------------------------------------
# DeepSeek Multi-head Latent Attention
# ----------------------------------------------------------------------

def init_mla(cfg: ModelConfig, kg: KeyGen) -> Dict:
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    dt = cfg.param_dtype
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wq_a": normal_init(kg(), (d, m.q_lora_rank), dt),
        "q_a_norm": jnp.ones((m.q_lora_rank,), dt),
        "wq_b": normal_init(kg(), (m.q_lora_rank, H * qk_head), dt),
        "wkv_a": normal_init(kg(), (d, m.kv_lora_rank + m.qk_rope_head_dim), dt),
        "kv_a_norm": jnp.ones((m.kv_lora_rank,), dt),
        "wk_b": normal_init(kg(), (m.kv_lora_rank, H * m.qk_nope_head_dim), dt),
        "wv_b": normal_init(kg(), (m.kv_lora_rank, H * m.v_head_dim), dt),
        "wo": normal_init(kg(), (H * m.v_head_dim, d), dt, fan_in=H * m.v_head_dim),
    }


def mla_axes(cfg: ModelConfig) -> Dict:
    return {
        "wq_a": ("embed", "lora"),
        "q_a_norm": ("lora",),
        "wq_b": ("lora", "heads"),
        "wkv_a": ("embed", "lora"),
        "kv_a_norm": ("lora",),
        "wk_b": ("lora", "heads"),
        "wv_b": ("lora", "heads"),
        "wo": ("heads", "embed"),
    }


def _mla_q(cfg: ModelConfig, p: Dict, x: jax.Array, dt):
    m = cfg.mla
    H = cfg.n_heads
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    cq = jnp.einsum("bsd,dr->bsr", x, p["wq_a"].astype(dt))
    cq = rms_norm(cq, p["q_a_norm"])
    q = jnp.einsum("bsr,rh->bsh", cq, p["wq_b"].astype(dt))
    q = q.reshape(*x.shape[:2], H, qk_head)
    return q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]


def mla_full(
    cfg: ModelConfig,
    p: Dict,
    x: jax.Array,
    positions: jax.Array,
    causal: bool = True,
) -> Tuple[jax.Array, Dict]:
    """MLA prefill/training; cache holds the *compressed* latents."""
    m = cfg.mla
    dt = x.dtype
    B, S, _ = x.shape
    H = cfg.n_heads
    q_nope, q_rope = _mla_q(cfg, p, x, dt)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    ckv_full = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"].astype(dt))
    c_kv = rms_norm(ckv_full[..., : m.kv_lora_rank], p["kv_a_norm"])
    k_rope = ckv_full[..., m.kv_lora_rank:][:, :, None, :]       # [B,S,1,dr]
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)

    k_nope = jnp.einsum("bsr,rh->bsh", c_kv, p["wk_b"].astype(dt))
    k_nope = k_nope.reshape(B, S, H, m.qk_nope_head_dim)
    v = jnp.einsum("bsr,rh->bsh", c_kv, p["wv_b"].astype(dt))
    v = v.reshape(B, S, H, m.v_head_dim)

    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (B, S, H, m.qk_rope_head_dim))],
        axis=-1,
    )
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    if cfg.attention_impl == "chunked":
        out = _sdpa_chunked(q, k, v, scale, causal, cfg.sliding_window,
                            cfg.attention_block)
    else:
        mask = causal_mask(S, S, cfg.sliding_window) if causal else None
        out = _sdpa(q, k, v, mask, scale)
    y = jnp.einsum("bsh,hd->bsd", out.reshape(B, S, -1), p["wo"].astype(dt))
    return y, {"c_kv": c_kv, "k_rope": k_rope[:, :, 0, :]}


def mla_decode(
    cfg: ModelConfig,
    p: Dict,
    x: jax.Array,                       # [B, 1, D]
    cache: Dict,                        # {"c_kv": [B,T,r], "k_rope": [B,T,dr]}
    pos: jax.Array,                     # [B]
) -> Tuple[jax.Array, Dict]:
    """Absorbed-matmul MLA decode: attention runs in the compressed space —
    the cache stays rank-sized (DeepSeek's KV-memory win) and per-step work
    is O(T·(rank + rope)) per head instead of O(T·head_dim·expand)."""
    m = cfg.mla
    dt = x.dtype
    B = x.shape[0]
    H = cfg.n_heads
    q_nope, q_rope = _mla_q(cfg, p, x, dt)                     # [B,1,H,*]
    q_rope = apply_rope(q_rope, pos[:, None], cfg.rope_theta)

    ckv_full = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"].astype(dt))
    c_new = rms_norm(ckv_full[..., : m.kv_lora_rank], p["kv_a_norm"])[:, 0]
    kr_new = apply_rope(
        ckv_full[..., m.kv_lora_rank:][:, :, None, :], pos[:, None],
        cfg.rope_theta,
    )[:, 0, 0]

    b_idx = jnp.arange(B)
    c_kv = cache["c_kv"].at[b_idx, pos].set(c_new)             # [B,T,r]
    k_rope = cache["k_rope"].at[b_idx, pos].set(kr_new)        # [B,T,dr]

    # absorb W_k_b into the query: q_c [B,H,r]
    wk_b = p["wk_b"].astype(dt).reshape(m.kv_lora_rank, H, m.qk_nope_head_dim)
    q_c = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], wk_b)
    T = c_kv.shape[1]
    logits = (
        jnp.einsum("bhr,btr->bht", q_c, c_kv, preferred_element_type=jnp.float32)
        + jnp.einsum("bhd,btd->bht", q_rope[:, 0],
                     k_rope, preferred_element_type=jnp.float32)
    ) * ((m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5)
    mask = jnp.where(jnp.arange(T)[None, None, :] <= pos[:, None, None], 0.0, NEG_INF)
    w = jax.nn.softmax(logits + mask, axis=-1).astype(dt)
    ctx = jnp.einsum("bht,btr->bhr", w, c_kv)                  # [B,H,r]
    wv_b = p["wv_b"].astype(dt).reshape(m.kv_lora_rank, H, m.v_head_dim)
    out = jnp.einsum("bhr,rhd->bhd", ctx, wv_b)                # [B,H,dv]
    y = jnp.einsum("bh,hd->bd", out.reshape(B, -1), p["wo"].astype(dt))
    return y[:, None, :], {"c_kv": c_kv, "k_rope": k_rope}
