"""Activation-sharding policy — logical constraints inside model code.

Model code calls ``constrain(x, ("batch", "seq", "embed"))`` at block
boundaries; outside any policy this is a no-op (CPU smoke tests), under a
:class:`ShardingPolicy` (installed by the launcher/dry-run) it becomes a
``with_sharding_constraint`` resolved through the same rules table as the
parameters — so flipping e.g. sequence parallelism on is a one-line rules
change, not a model edit.
"""

from __future__ import annotations

import contextlib
import contextvars
from typing import Mapping, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.params import resolve_spec

_POLICY: contextvars.ContextVar[Optional["ShardingPolicy"]] = \
    contextvars.ContextVar("cologrid_sharding_policy", default=None)


class ShardingPolicy:
    def __init__(self, mesh: Mesh, rules: Mapping[Optional[str], Tuple[str, ...]]):
        self.mesh = mesh
        self.rules = dict(rules)

    def spec_for(self, shape: Sequence[int], names: Sequence[Optional[str]]) -> P:
        return resolve_spec(shape, tuple(names), self.rules, dict(self.mesh.shape))

    def constrain(self, x: jax.Array, names: Sequence[Optional[str]]) -> jax.Array:
        spec = self.spec_for(x.shape, names)
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))


@contextlib.contextmanager
def use_policy(policy: Optional[ShardingPolicy]):
    token = _POLICY.set(policy)
    try:
        yield
    finally:
        _POLICY.reset(token)


def current_policy() -> Optional[ShardingPolicy]:
    return _POLICY.get()


def constrain(x: jax.Array, names: Sequence[Optional[str]]) -> jax.Array:
    """Apply the active policy's constraint, or pass through."""
    pol = _POLICY.get()
    if pol is None:
        return x
    return pol.constrain(x, names)


def _is_axes_leaf(x):
    return x is None or (isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x))


def compute_view(params, axes_tree):
    """FSDP storage -> compute layout: re-constrain a param subtree with the
    data/pod (storage) axes dropped, i.e. an explicit just-in-time weight
    all-gather.

    Without this, XLA SPMD contracts einsums over the data-sharded "embed"
    dim and emits partial-sum all-reduces of the (much larger) activations —
    measured at ~60 GB/layer on mixtral train_4k (EXPERIMENTS.md §Perf).
    Gathering the weights (~0.2 GB/layer) is the production-FSDP semantics.
    """
    pol = _POLICY.get()
    if pol is None:
        return params
    compute_rules = {
        k: tuple(a for a in v if a not in ("data", "pod"))
        for k, v in pol.rules.items()
    }

    def one(w, ax):
        if ax is None:
            return w
        from repro.models.params import resolve_spec
        sp = resolve_spec(w.shape, tuple(ax), compute_rules,
                          dict(pol.mesh.shape))
        return jax.lax.with_sharding_constraint(
            w, NamedSharding(pol.mesh, sp))

    return jax.tree.map(one, params, axes_tree, is_leaf=_is_axes_leaf)
