"""Model configuration dataclasses for every assigned architecture family."""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2/V3 Multi-head Latent Attention."""

    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8
    top_k: int = 2
    d_ff_expert: int = 14336
    n_shared_experts: int = 0          # deepseek: 1 shared expert
    first_k_dense: int = 0             # deepseek: first 3 layers dense
    capacity_factor: float = 1.25
    router_noise: float = 0.0
    aux_loss_weight: float = 0.01
    # >1 splits tokens into independently-capacitied groups (GShard style);
    # aligned to the batch sharding, dispatch scatters stay shard-local
    n_groups: int = 1


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) mixer."""

    d_state: int = 64
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 128                   # SSD chunk length


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    """RWKV-6 "Finch" time/channel mixing."""

    head_dim: int = 64
    decay_lora: int = 64               # rank of the data-dependent decay MLP
    gate_lora: int = 32


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for enc-dec models (whisper); frontend is a stub —
    ``input_specs`` provides precomputed frame/patch embeddings."""

    n_layers: int = 32
    n_frames: int = 1500               # whisper: 30 s of audio after conv
    d_model: int = 1280
    n_heads: int = 20
    d_ff: int = 5120


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                        # dense|moe|vlm|audio|ssm|hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None     # default d_model // n_heads

    # attention flavour flags
    rope_theta: float = 10_000.0
    qkv_bias: bool = False             # qwen2.5
    qk_norm: bool = False              # qwen3
    sliding_window: Optional[int] = None  # mixtral SWA
    mrope: bool = False                # qwen2-vl M-RoPE (3D positions)
    tie_embeddings: bool = False

    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    encoder: Optional[EncoderConfig] = None

    # hybrid layout (zamba2): cycle of block kinds; "attn_shared" blocks all
    # reuse ONE set of attention weights (the Zamba trick)
    block_pattern: Tuple[str, ...] = ("attn",)

    mtp_depth: int = 0                 # deepseek multi-token-prediction heads

    # numerics
    dtype: jnp.dtype = jnp.bfloat16    # activations/compute
    param_dtype: jnp.dtype = jnp.float32

    # training-time knobs
    remat_policy: str = "dots"         # none|dots|full
    scan_layers: bool = True
    attention_impl: str = "einsum"     # einsum | chunked (flash-style XLA)
    attention_block: int = 1024        # KV block for the chunked path
    train_microbatches: int = 1        # grad-accumulation depth per step
    microbatch_unroll: bool = False    # accounting mode (see TrainStepConfig)

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # ------------------------------------------------------------------

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def attention_free(self) -> bool:
        return all(k in ("ssm", "rwkv") for k in self.block_pattern)

    @property
    def subquadratic(self) -> bool:
        """Strictly sub-quadratic in sequence length (every block is
        recurrent or windowed)."""
        for kind in self.block_pattern:
            if kind in ("attn", "attn_shared") and self.sliding_window is None:
                return False
        return True

    @property
    def runs_long_context(self) -> bool:
        """Eligible for the ``long_500k`` cell: SSM/hybrid/linear-attn archs
        run it (per the assignment), pure full-attention archs skip it.
        A hybrid's occasional full-attention block decodes in O(S)/token, so
        hybrids qualify even though their prefill is quadratic."""
        if self.is_encdec:
            return False
        has_recurrent = any(k in ("ssm", "rwkv") for k in self.block_pattern)
        return has_recurrent or self.subquadratic

    @property
    def is_encdec(self) -> bool:
        return self.encoder is not None

    def layer_kinds(self) -> Tuple[str, ...]:
        """Expanded per-layer block kinds of the decoder stack."""
        pat = self.block_pattern
        return tuple(pat[i % len(pat)] for i in range(self.n_layers))

    # -- parameter counting (for 6ND roofline math) ----------------------

    def param_count(self) -> int:
        """Exact decoder-stack parameter count (embeddings included)."""
        from repro.models.model import count_params_from_shapes  # lazy
        return count_params_from_shapes(self)

    def active_param_count(self) -> int:
        """Activated params per token (MoE: only routed-in experts)."""
        from repro.models.model import count_active_params  # lazy
        return count_active_params(self)
