"""Mixture-of-Experts: top-k routing with capacity-based sorted dispatch.

Covers mixtral-8x7b (8 experts, top-2, softmax gate) and deepseek-v3-671b
(256 routed + 1 shared expert, top-8, sigmoid gate with normalized weights,
first-3-layers dense).

TPU adaptation: token->expert dispatch uses the *sort-by-expert* scheme
(cumsum positions + scatter into an ``[E, capacity, D]`` buffer) instead of a
one-hot dispatch einsum — dispatch cost becomes memory movement, not
``O(T·E·C·D)`` MXU flops, and the expert matmuls stay dense ``[E,C,D]x[E,D,F]``
einsums that shard cleanly: experts over the ``model`` axis when ``E`` divides
it (deepseek: 256 % 16 == 0), else the expert FFN dim shards instead (mixtral:
8 experts, d_ff 14336 % 16 == 0) — resolved automatically by
:func:`repro.models.params.resolve_spec`.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig, MoEConfig
from repro.models.params import KeyGen, normal_init


def init_moe(cfg: ModelConfig, kg: KeyGen) -> Dict:
    m = cfg.moe
    d, f, E = cfg.d_model, m.d_ff_expert, m.n_experts
    dt = cfg.param_dtype
    p = {
        "router": normal_init(kg(), (d, E), dt, scale=0.02),
        "gate": normal_init(kg(), (E, d, f), dt, fan_in=d),
        "up": normal_init(kg(), (E, d, f), dt, fan_in=d),
        "down": normal_init(kg(), (E, f, d), dt, fan_in=f),
    }
    if m.n_shared_experts:
        fs = f * m.n_shared_experts
        p["shared"] = {
            "gate": normal_init(kg(), (d, fs), dt),
            "up": normal_init(kg(), (d, fs), dt),
            "down": normal_init(kg(), (fs, d), dt),
        }
    return p


def moe_axes(cfg: ModelConfig) -> Dict:
    ax = {
        "router": ("embed", None),
        "gate": ("experts", "embed", "expert_mlp"),
        "up": ("experts", "embed", "expert_mlp"),
        "down": ("experts", "expert_mlp", "embed"),
    }
    if cfg.moe.n_shared_experts:
        ax["shared"] = {
            "gate": ("embed", "mlp"),
            "up": ("embed", "mlp"),
            "down": ("mlp", "embed"),
        }
    return ax


def _route(m: MoEConfig, logits: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """-> (weights [T,k], experts [T,k], aux_loss).  Softmax-gate for mixtral;
    deepseek-v3 uses sigmoid scores with weight normalization."""
    if m.n_experts > 64:  # deepseek-style sigmoid routing
        scores = jax.nn.sigmoid(logits.astype(jnp.float32))
        w, e = jax.lax.top_k(scores, m.top_k)
        w = w / (w.sum(axis=-1, keepdims=True) + 1e-9)
        probs = scores / (scores.sum(-1, keepdims=True) + 1e-9)
    else:
        probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
        w, e = jax.lax.top_k(probs, m.top_k)
        w = w / (w.sum(axis=-1, keepdims=True) + 1e-9)
    # load-balance aux loss (Switch-style): E * Σ_e f_e · P_e
    T = logits.shape[0]
    f_e = jnp.zeros((m.n_experts,), jnp.float32).at[e.reshape(-1)].add(1.0)
    f_e = f_e / (T * m.top_k)
    p_e = probs.mean(axis=0)
    aux = m.n_experts * jnp.sum(f_e * p_e)
    return w.astype(jnp.float32), e, aux


def _dispatch_group(m: MoEConfig, xt, w, e, cap, p, compute_dtype):
    """Scatter->expert-matmul->gather for ONE token group.  xt [T,D]."""
    T, D = xt.shape
    k, E = m.top_k, m.n_experts
    flat_e = e.reshape(-1)                             # [T*k]
    flat_w = w.reshape(-1)
    # position of each (token, slot) within its expert, in token order
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)          # [T*k, E]
    pos_in_e = (jnp.cumsum(onehot, axis=0) - onehot)             # exclusive
    pos = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]
    keep = pos < cap                                   # dropped beyond capacity
    dest = flat_e * cap + jnp.where(keep, pos, 0)

    buf = jnp.zeros((E * cap, D), compute_dtype)
    src = jnp.repeat(xt, k, axis=0)                    # token for each slot
    buf = buf.at[dest].add(jnp.where(keep[:, None], src, 0))

    eb = buf.reshape(E, cap, D)
    h = jnp.einsum("ecd,edf->ecf", eb, p["gate"].astype(compute_dtype))
    u = jnp.einsum("ecd,edf->ecf", eb, p["up"].astype(compute_dtype))
    h = jax.nn.silu(h) * u
    out_e = jnp.einsum("ecf,efd->ecd", h, p["down"].astype(compute_dtype))

    gathered = out_e.reshape(E * cap, D)[dest]         # [T*k, D]
    gathered = jnp.where(keep[:, None], gathered, 0)
    return (gathered * flat_w[:, None].astype(compute_dtype)
            ).reshape(T, k, D).sum(1)


def _moe_shard_local(cfg, p, x, compute_dtype):
    """Dispatch inside ``shard_map`` manual over the batch axes: the
    scatter/gather *cannot* leave the shard, so the only collectives left
    are the expert einsums' model-axis traffic.  Capacity is per shard
    (GShard groups == device shards).  Falls back to the global path when
    no sharding policy is installed (CPU tests)."""
    from jax.sharding import PartitionSpec as P
    from repro.models.sharding import current_policy
    from repro.utils import shard_map_compat

    m = cfg.moe
    pol = current_policy()
    batch_axes = tuple(a for a in ("pod", "data")
                       if pol is not None and a in pol.mesh.shape
                       and pol.mesh.shape[a] > 1)
    if pol is None or not batch_axes or x.shape[0] % int(
            __import__("numpy").prod([pol.mesh.shape[a]
                                      for a in batch_axes])) != 0:
        cfg1 = cfg  # fall back: single global group
        import dataclasses as _dc
        cfg1 = _dc.replace(cfg, moe=_dc.replace(m, n_groups=1))
        return moe_apply(cfg1, p, x, compute_dtype)

    def body(x_loc, router, gate, up, down, *shared):
        B_loc, S, D = x_loc.shape
        T_loc = B_loc * S
        xt = x_loc.reshape(T_loc, D)
        logits = jnp.einsum("td,de->te", xt, router.astype(compute_dtype))
        w, e, aux = _route(m, logits)
        cap = max(int(m.capacity_factor * T_loc * m.top_k / m.n_experts), 1)
        cap = -(-cap // 8) * 8
        pp = {"gate": gate, "up": up, "down": down}
        y = _dispatch_group(m, xt, w, e, cap, pp, compute_dtype)
        if shared:
            sp = {"gate": shared[0], "up": shared[1], "down": shared[2]}
            h = jax.nn.silu(jnp.einsum("td,df->tf", xt,
                                       sp["gate"].astype(compute_dtype)))
            h = h * jnp.einsum("td,df->tf", xt, sp["up"].astype(compute_dtype))
            y = y + jnp.einsum("tf,fd->td", h, sp["down"].astype(compute_dtype))
        # aux is shard-local; mean over the manual axes
        for ax in batch_axes:
            aux = jax.lax.pmean(aux, ax)
        return y.reshape(B_loc, S, D), aux

    args = [x, p["router"], p["gate"], p["up"], p["down"]]
    if m.n_shared_experts:
        args += [p["shared"]["gate"], p["shared"]["up"], p["shared"]["down"]]
    in_specs = tuple([P(batch_axes)] + [P()] * (len(args) - 1))
    out = shard_map_compat(
        body, mesh=pol.mesh, in_specs=in_specs,
        out_specs=(P(batch_axes), P()),
        axis_names=set(batch_axes), check=False,
    )(*args)
    return out


def moe_apply(
    cfg: ModelConfig,
    p: Dict,
    x: jax.Array,                      # [B, S, D]
    compute_dtype,
) -> Tuple[jax.Array, jax.Array]:
    """-> (output [B,S,D], aux_loss scalar).

    With ``n_groups > 1`` (GShard-style), tokens split into groups with
    independent capacity; aligning groups to the batch sharding keeps every
    scatter/gather shard-local and turns the dispatch collectives into the
    single expert all-to-all XLA derives from the grouped einsum — the
    collective-bound fix measured in EXPERIMENTS.md §Perf (mixtral cell).
    """
    m = cfg.moe
    B, S, D = x.shape
    T = B * S
    if m.n_groups == -1:
        return _moe_shard_local(cfg, p, x, compute_dtype)
    xt = x.reshape(T, D)
    logits = jnp.einsum("td,de->te", xt, p["router"].astype(compute_dtype))
    w, e, aux = _route(m, logits)                      # [T,k]

    k = m.top_k
    E = m.n_experts
    G = m.n_groups if T % m.n_groups == 0 else 1
    cap = max(int(m.capacity_factor * (T // G) * k / E), 1)
    cap = -(-cap // 8) * 8                             # lane-friendly

    if G == 1:
        combined = _dispatch_group(m, xt, w, e, cap, p, compute_dtype)
    else:
        from repro.models.sharding import constrain
        Tg = T // G
        xg = constrain(xt.reshape(G, Tg, D), ("batch", None, "embed_act"))
        flat_e = e.reshape(G, Tg * k)
        flat_w = w.reshape(G, Tg * k)
        onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)   # [G,Tg*k,E]
        pos_in_e = jnp.cumsum(onehot, axis=1) - onehot
        pos = jnp.take_along_axis(pos_in_e, flat_e[..., None],
                                  axis=2)[..., 0]             # [G,Tg*k]
        keep = pos < cap
        dest = flat_e * cap + jnp.where(keep, pos, 0)

        # pin every scatter operand to the group sharding BEFORE the
        # scatter: otherwise XLA runs it replicated and pays a full
        # all-reduce of the 20+GB buffer per layer (measured; §Perf)
        dest = constrain(dest, ("batch", None))
        keep = constrain(keep, ("batch", None))
        src = constrain(jnp.repeat(xg, k, axis=1),
                        ("batch", None, "embed_act"))         # [G,Tg*k,D]
        g_idx = jnp.arange(G)[:, None]
        buf = constrain(jnp.zeros((G, E * cap, D), compute_dtype),
                        ("batch", None, "embed_act"))
        buf = buf.at[g_idx, dest].add(jnp.where(keep[..., None], src, 0))
        buf = constrain(buf, ("batch", None, "embed_act"))

        eb = buf.reshape(G, E, cap, D)
        h = jnp.einsum("gecd,edf->gecf", eb, p["gate"].astype(compute_dtype))
        u = jnp.einsum("gecd,edf->gecf", eb, p["up"].astype(compute_dtype))
        h = jax.nn.silu(h) * u
        out_e = jnp.einsum("gecf,efd->gecd", h,
                           p["down"].astype(compute_dtype))
        out_e = constrain(out_e.reshape(G, E * cap, D),
                          ("batch", None, "embed_act"))

        gathered = out_e[g_idx, dest]                         # [G,Tg*k,D]
        gathered = jnp.where(keep[..., None], gathered, 0)
        combined = (gathered * flat_w[..., None].astype(compute_dtype)
                    ).reshape(G, Tg, k, D).sum(2).reshape(T, D)
    y = combined.reshape(B, S, D)

    if m.n_shared_experts:
        sp = p["shared"]
        h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, sp["gate"].astype(compute_dtype)))
        h = h * jnp.einsum("bsd,df->bsf", x, sp["up"].astype(compute_dtype))
        y = y + jnp.einsum("bsf,fd->bsd", h, sp["down"].astype(compute_dtype))
    return y, aux
