"""Shared layer primitives: norms, RoPE/M-RoPE, MLPs, embeddings."""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.params import KeyGen, embed_init, normal_init, ones_init, zeros_init


# ----------------------------------------------------------------------
# norms
# ----------------------------------------------------------------------

def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)).astype(dt)


def layer_norm(x: jax.Array, weight: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def init_rms_norm(d: int, dtype) -> Dict:
    return {"scale": jnp.ones((d,), dtype)}


def rms_norm_axes() -> Dict:
    return {"scale": ("embed",)}


def init_layer_norm(d: int, dtype) -> Dict:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layer_norm_axes() -> Dict:
    return {"scale": ("embed",), "bias": ("embed",)}


# ----------------------------------------------------------------------
# rotary embeddings (standard + M-RoPE)
# ----------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """[head_dim/2] inverse frequencies."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotate ``x [..., S, H, D]`` by ``positions [..., S]`` (standard RoPE)."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                       # [D/2]
    ang = positions[..., :, None].astype(jnp.float32) * inv  # [..., S, D/2]
    cos = jnp.cos(ang)[..., None, :]                 # [..., S, 1, D/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions3: jax.Array, theta: float,
                sections: Tuple[int, int, int] = (1, 1, 2)) -> jax.Array:
    """Qwen2-VL multimodal RoPE.

    ``positions3 [..., 3, S]`` carries (temporal, height, width) position ids;
    the head dim's frequency bands are partitioned among the three in the
    ratio ``sections`` (t:h:w = 1:1:2 by default, matching Qwen2-VL).  Text
    tokens carry identical ids in all three channels, reducing to RoPE.
    """
    d = x.shape[-1]
    half = d // 2
    inv = rope_freqs(d, theta)                       # [half]
    total = sum(sections)
    bounds = []
    acc = 0
    for s in sections:
        acc += int(half * s / total)
        bounds.append(acc)
    bounds[-1] = half
    band = jnp.zeros((half,), jnp.int32)
    band = band.at[bounds[0]:bounds[1]].set(1)
    band = band.at[bounds[1]:].set(2)
    # pick the position channel per frequency band:
    # positions3 [..., 3, S] -> [..., S, 3] -> gather bands -> [..., S, half]
    p = jnp.moveaxis(positions3.astype(jnp.float32), -2, -1)
    pos = jnp.take(p, band, axis=-1)
    ang = pos * inv                                  # [..., S, half]
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoid_positions(n: int, d: int) -> jax.Array:
    """Whisper-style fixed sinusoidal embeddings [n, d]."""
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10_000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ----------------------------------------------------------------------
# MLPs
# ----------------------------------------------------------------------

def init_swiglu(d_model: int, d_ff: int, dtype, kg: KeyGen) -> Dict:
    return {
        "gate": normal_init(kg(), (d_model, d_ff), dtype),
        "up": normal_init(kg(), (d_model, d_ff), dtype),
        "down": normal_init(kg(), (d_ff, d_model), dtype),
    }


def swiglu_axes() -> Dict:
    return {
        "gate": ("embed", "mlp"),
        "up": ("embed", "mlp"),
        "down": ("mlp", "embed"),
    }


def swiglu_apply(p: Dict, x: jax.Array, compute_dtype) -> jax.Array:
    h = jnp.einsum("...d,df->...f", x, p["gate"].astype(compute_dtype))
    u = jnp.einsum("...d,df->...f", x, p["up"].astype(compute_dtype))
    h = jax.nn.silu(h) * u
    return jnp.einsum("...f,fd->...d", h, p["down"].astype(compute_dtype))


def init_gelu_mlp(d_model: int, d_ff: int, dtype, kg: KeyGen) -> Dict:
    return {
        "fc1": normal_init(kg(), (d_model, d_ff), dtype),
        "b1": jnp.zeros((d_ff,), dtype),
        "fc2": normal_init(kg(), (d_ff, d_model), dtype),
        "b2": jnp.zeros((d_model,), dtype),
    }


def gelu_mlp_axes() -> Dict:
    return {"fc1": ("embed", "mlp"), "b1": ("mlp",),
            "fc2": ("mlp", "embed"), "b2": ("embed",)}


def gelu_mlp_apply(p: Dict, x: jax.Array, compute_dtype) -> jax.Array:
    h = jnp.einsum("...d,df->...f", x, p["fc1"].astype(compute_dtype))
    h = jax.nn.gelu(h + p["b1"].astype(compute_dtype), approximate=True)
    return jnp.einsum("...f,fd->...d", h, p["fc2"].astype(compute_dtype)) + \
        p["b2"].astype(compute_dtype)


# ----------------------------------------------------------------------
# embeddings / unembedding
# ----------------------------------------------------------------------

def init_embedding(vocab: int, d_model: int, dtype, kg: KeyGen) -> Dict:
    return {"table": embed_init(kg(), (vocab, d_model), dtype)}


def embedding_axes() -> Dict:
    return {"table": ("vocab", "embed")}


def embed_apply(p: Dict, tokens: jax.Array, compute_dtype) -> jax.Array:
    return jnp.take(p["table"].astype(compute_dtype), tokens, axis=0)


def unembed_apply(p: Dict, x: jax.Array, compute_dtype) -> jax.Array:
    return jnp.einsum("...d,vd->...v", x, p["table"].astype(compute_dtype))
