"""Mamba2 (SSD) mixer — used by zamba2 and as the hybrid SSM block.

The state-space recurrence per head h with scalar decay:

    s_t = a_t · s_{t-1} + dt_t · B_t ⊗ x_t          s ∈ R^{P×N}
    y_t = C_t · s_t  (+ D ⊙ x_t)

with ``a_t = exp(dt_t · A)`` (A < 0 learned per head, dt data-dependent via
softplus).  Training/prefill uses the chunked SSD form (intra-chunk matmuls +
inter-chunk state scan) — O(L·Q) matmul work with MXU-shaped operands, which
is also the structure the Pallas kernel tiles for VMEM.  Decode keeps the
O(1)-per-token recurrent form.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import rms_norm
from repro.models.params import KeyGen, normal_init


def ssm_dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    return d_inner, n_heads, s.d_state


def init_ssm(cfg: ModelConfig, kg: KeyGen) -> Dict:
    s = cfg.ssm
    d = cfg.d_model
    d_inner, H, N = ssm_dims(cfg)
    dt = cfg.param_dtype
    conv_ch = d_inner + 2 * N            # x, B, C go through the conv
    return {
        # in_proj -> [z, xBC, dt]
        "in_proj": normal_init(kg(), (d, 2 * d_inner + 2 * N + H), dt),
        "conv_w": normal_init(kg(), (s.conv_width, conv_ch), dt,
                              fan_in=s.conv_width),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(dt),  # A = -exp
        "dt_bias": jnp.zeros((H,), dt),
        "d_skip": jnp.ones((H,), dt),
        "norm": jnp.ones((d_inner,), dt),
        "out_proj": normal_init(kg(), (d_inner, d), dt, fan_in=d_inner),
    }


def ssm_axes(cfg: ModelConfig) -> Dict:
    return {
        "in_proj": ("embed", "mlp"),
        "conv_w": ("conv", None),
        "conv_b": (None,),
        "a_log": (None,),
        "dt_bias": (None,),
        "d_skip": (None,),
        "norm": ("mlp",),
        "out_proj": ("mlp", "embed"),
    }


def _split_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    d_inner, H, N = ssm_dims(cfg)
    z = zxbcdt[..., :d_inner]
    xBC = zxbcdt[..., d_inner: 2 * d_inner + 2 * N]
    dt_raw = zxbcdt[..., 2 * d_inner + 2 * N:]
    return z, xBC, dt_raw


def _causal_conv(xBC: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None):
    """Depthwise causal conv along time.  x [B,L,C], w [W,C].

    Returns (out [B,L,C], new_state [B,W-1,C])."""
    W = w.shape[0]
    if state is None:
        pad = jnp.zeros((xBC.shape[0], W - 1, xBC.shape[2]), xBC.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, xBC], axis=1)          # [B, L+W-1, C]
    out = sum(
        xp[:, i: i + xBC.shape[1], :] * w[i][None, None, :] for i in range(W)
    ) + b[None, None, :]
    new_state = xp[:, -(W - 1):, :] if W > 1 else pad
    return jax.nn.silu(out), new_state


def ssd_chunked_ref(
    x: jax.Array,      # [B, L, H, P]  (dt already folded in)
    a: jax.Array,      # [B, L, H]     per-step decay in (0,1)
    Bm: jax.Array,     # [B, L, N]
    Cm: jax.Array,     # [B, L, N]
    chunk: int,
    init_state: Optional[jax.Array] = None,  # [B, H, P, N]
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan (pure-jnp oracle; the Pallas kernel mirrors this).

    Returns (y [B,L,H,P], final_state [B,H,P,N]).
    """
    Bsz, L, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, L)
    pad = -L % Q
    if pad:
        # identity-pad the tail: decay 1 and zero input leave the state
        # untouched; the padded outputs are sliced away below
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Lp = L + pad
    nc = Lp // Q

    xc = x.reshape(Bsz, nc, Q, H, P)
    ac = a.reshape(Bsz, nc, Q, H)
    Bc = Bm.reshape(Bsz, nc, Q, N)
    Cc = Cm.reshape(Bsz, nc, Q, N)

    la = jnp.log(jnp.maximum(ac.astype(jnp.float32), 1e-20))
    cum = jnp.cumsum(la, axis=2)                       # [B,nc,Q,H] inclusive
    # intra-chunk decay matrix Lmat[i,j] = prod a_{j+1..i} for j<=i.
    # Mask BEFORE exp: the i<j entries have positive exponents that overflow
    # in the backward pass if computed then discarded (inf·0 -> NaN grads).
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # [B,nc,Q,Q,H]
    i_ge_j = jnp.tril(jnp.ones((Q, Q), bool))
    seg = jnp.where(i_ge_j[None, None, :, :, None], seg, -jnp.inf)
    Lmat = jnp.exp(seg)

    # diagonal (intra-chunk) output: y_ij = C_i·B_j L_ij x_j
    cb = jnp.einsum("bcin,bcjn->bcij", Cc.astype(jnp.float32),
                    Bc.astype(jnp.float32))
    ydiag = jnp.einsum("bcij,bcijh,bcjhp->bcihp", cb, Lmat,
                       xc.astype(jnp.float32))

    # per-chunk input to the carried state: S_c = Σ_j (decay j..end) B_j x_j
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)    # [B,nc,Q,H]
    Schunk = jnp.einsum("bcjh,bcjn,bcjhp->bchpn", decay_to_end,
                        Bc.astype(jnp.float32), xc.astype(jnp.float32))
    chunk_decay = jnp.exp(cum[:, :, -1, :])            # [B,nc,H]

    s0 = (jnp.zeros((Bsz, H, P, N), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def body(s, inp):
        s_in, dec = inp                                # [B,H,P,N], [B,H]
        out_prev = s
        s = s * dec[:, :, None, None] + s_in
        return s, out_prev

    Schunk_t = jnp.moveaxis(Schunk, 1, 0)              # [nc,B,H,P,N]
    dec_t = jnp.moveaxis(chunk_decay, 1, 0)            # [nc,B,H]
    final, prev_states = jax.lax.scan(body, s0, (Schunk_t, dec_t))
    prev_states = jnp.moveaxis(prev_states, 0, 1)      # [B,nc,H,P,N]

    # off-diagonal: contribution of the carried state entering each chunk
    decay_in = jnp.exp(cum)                            # decay 1..i within chunk
    yoff = jnp.einsum("bcin,bcih,bchpn->bcihp",
                      Cc.astype(jnp.float32), decay_in, prev_states)

    y = (ydiag + yoff).reshape(Bsz, Lp, H, P)[:, :L]
    return y, final


def ssm_full(
    cfg: ModelConfig,
    p: Dict,
    x: jax.Array,                       # [B, L, D]
    state: Optional[Dict] = None,
) -> Tuple[jax.Array, Dict]:
    """Training/prefill pass; returns output and final recurrent state."""
    s = cfg.ssm
    dt_c = x.dtype
    d_inner, H, N = ssm_dims(cfg)
    zxbcdt = jnp.einsum("bld,dk->blk", x, p["in_proj"].astype(dt_c))
    z, xBC, dt_raw = _split_proj(cfg, zxbcdt)
    conv_state = None if state is None else state["conv"]
    xBC, new_conv = _causal_conv(xBC, p["conv_w"].astype(dt_c),
                                 p["conv_b"].astype(dt_c), conv_state)
    xs = xBC[..., :d_inner]
    Bm = xBC[..., d_inner: d_inner + N]
    Cm = xBC[..., d_inner + N:]

    dt_v = jax.nn.softplus(dt_raw.astype(jnp.float32)
                           + p["dt_bias"].astype(jnp.float32))  # [B,L,H]
    A = -jnp.exp(p["a_log"].astype(jnp.float32))                # [H]
    a = jnp.exp(dt_v * A[None, None, :])                        # decay
    xh = xs.reshape(*xs.shape[:2], H, s.head_dim)
    xin = xh.astype(jnp.float32) * dt_v[..., None]

    ssm_state = None if state is None else state["ssm"]
    y, final = ssd_chunked_ref(xin, a, Bm, Cm, min(s.chunk, xs.shape[1]),
                               ssm_state)
    y = y + xh.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(*xs.shape[:2], d_inner).astype(dt_c)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    out = jnp.einsum("bli,id->bld", y, p["out_proj"].astype(dt_c))
    return out, {"conv": new_conv, "ssm": final.astype(jnp.float32)}


def ssm_decode(
    cfg: ModelConfig,
    p: Dict,
    x: jax.Array,                       # [B, 1, D]
    state: Dict,                        # {"conv": [B,W-1,C], "ssm": [B,H,P,N]}
) -> Tuple[jax.Array, Dict]:
    """O(1) single-token recurrence."""
    s = cfg.ssm
    dt_c = x.dtype
    d_inner, H, N = ssm_dims(cfg)
    zxbcdt = jnp.einsum("bld,dk->blk", x, p["in_proj"].astype(dt_c))
    z, xBC, dt_raw = _split_proj(cfg, zxbcdt)
    xBC, new_conv = _causal_conv(xBC, p["conv_w"].astype(dt_c),
                                 p["conv_b"].astype(dt_c), state["conv"])
    xs = xBC[..., :d_inner]
    Bm = xBC[..., d_inner: d_inner + N][:, 0]           # [B,N]
    Cm = xBC[..., d_inner + N:][:, 0]

    dt_v = jax.nn.softplus(dt_raw.astype(jnp.float32)
                           + p["dt_bias"].astype(jnp.float32))[:, 0]  # [B,H]
    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    a = jnp.exp(dt_v * A[None, :])                      # [B,H]
    xh = xs.reshape(xs.shape[0], H, s.head_dim).astype(jnp.float32)
    xin = xh * dt_v[..., None]                          # [B,H,P]

    s_new = (state["ssm"] * a[:, :, None, None]
             + jnp.einsum("bhp,bn->bhpn", xin, Bm.astype(jnp.float32)))
    y = jnp.einsum("bn,bhpn->bhp", Cm.astype(jnp.float32), s_new)
    y = y + xh * p["d_skip"].astype(jnp.float32)[None, :, None]
    y = y.reshape(xs.shape[0], 1, d_inner).astype(dt_c)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    out = jnp.einsum("bli,id->bld", y, p["out_proj"].astype(dt_c))
    return out, {"conv": new_conv, "ssm": s_new}
