"""Encoder-decoder stack (whisper-large-v3 backbone).

The conv/mel frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings ``[B, n_frames, d_model]``.  Encoder blocks are
pre-LN bidirectional attention + GELU MLP with fixed sinusoidal positions;
decoder blocks add causal self-attention (cached for decode) and
cross-attention against precomputed encoder KV.  No RoPE anywhere (whisper
uses absolute positions), which the attention module supports via
``positions=None``.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.config import ModelConfig
from repro.models.layers import (
    embed_apply,
    embedding_axes,
    gelu_mlp_axes,
    gelu_mlp_apply,
    init_embedding,
    init_gelu_mlp,
    init_layer_norm,
    layer_norm,
    layer_norm_axes,
    sinusoid_positions,
    unembed_apply,
)
from repro.models.params import KeyGen, normal_init


# ----------------------------------------------------------------------
# encoder
# ----------------------------------------------------------------------

def _enc_cfg(cfg: ModelConfig) -> ModelConfig:
    """A view of the config with the encoder's dims (whisper enc == dec dims)."""
    return cfg  # whisper-large-v3: encoder and decoder share dimensions


def init_encoder_block(cfg: ModelConfig, kg: KeyGen) -> Dict:
    d = cfg.d_model
    dt = cfg.param_dtype
    return {
        "ln1": init_layer_norm(d, dt),
        "attn": attn.init_attention(cfg, kg),
        "ln2": init_layer_norm(d, dt),
        "mlp": init_gelu_mlp(d, cfg.d_ff, dt, kg),
    }


def encoder_block_axes(cfg: ModelConfig) -> Dict:
    return {
        "ln1": layer_norm_axes(),
        "attn": attn.attention_axes(cfg),
        "ln2": layer_norm_axes(),
        "mlp": gelu_mlp_axes(),
    }


def encoder_block_apply(cfg: ModelConfig, p: Dict, x: jax.Array) -> jax.Array:
    h = layer_norm(x, p["ln1"]["scale"], p["ln1"]["bias"])
    y, _ = attn.attention_full(cfg, p["attn"], h, positions=None, causal=False)
    x = x + y
    h = layer_norm(x, p["ln2"]["scale"], p["ln2"]["bias"])
    return x + gelu_mlp_apply(p["mlp"], h, x.dtype)


# ----------------------------------------------------------------------
# decoder
# ----------------------------------------------------------------------

def init_decoder_block(cfg: ModelConfig, kg: KeyGen) -> Dict:
    d = cfg.d_model
    dt = cfg.param_dtype
    return {
        "ln1": init_layer_norm(d, dt),
        "self_attn": attn.init_attention(cfg, kg),
        "ln_x": init_layer_norm(d, dt),
        "cross_attn": attn.init_attention(cfg, kg, cross=True),
        "ln2": init_layer_norm(d, dt),
        "mlp": init_gelu_mlp(d, cfg.d_ff, dt, kg),
    }


def decoder_block_axes(cfg: ModelConfig) -> Dict:
    return {
        "ln1": layer_norm_axes(),
        "self_attn": attn.attention_axes(cfg),
        "ln_x": layer_norm_axes(),
        "cross_attn": attn.attention_axes(cfg, cross=True),
        "ln2": layer_norm_axes(),
        "mlp": gelu_mlp_axes(),
    }


def decoder_block_full(cfg: ModelConfig, p: Dict, x: jax.Array,
                       enc_kv: Dict) -> Tuple[jax.Array, Dict]:
    h = layer_norm(x, p["ln1"]["scale"], p["ln1"]["bias"])
    y, cache = attn.attention_full(cfg, p["self_attn"], h, positions=None)
    x = x + y
    h = layer_norm(x, p["ln_x"]["scale"], p["ln_x"]["bias"])
    x = x + attn.cross_attention(cfg, p["cross_attn"], h, enc_kv)
    h = layer_norm(x, p["ln2"]["scale"], p["ln2"]["bias"])
    return x + gelu_mlp_apply(p["mlp"], h, x.dtype), cache


def decoder_block_decode(cfg: ModelConfig, p: Dict, x: jax.Array,
                         pos: jax.Array, cache: Dict,
                         enc_kv: Dict) -> Tuple[jax.Array, Dict]:
    h = layer_norm(x, p["ln1"]["scale"], p["ln1"]["bias"])
    y, cache = attn.attention_decode(cfg, p["self_attn"], h, cache, pos,
                                     use_rope=False)
    x = x + y
    h = layer_norm(x, p["ln_x"]["scale"], p["ln_x"]["bias"])
    x = x + attn.cross_attention(cfg, p["cross_attn"], h, enc_kv)
    h = layer_norm(x, p["ln2"]["scale"], p["ln2"]["bias"])
    return x + gelu_mlp_apply(p["mlp"], h, x.dtype), cache


# ----------------------------------------------------------------------
# full model
# ----------------------------------------------------------------------

def init_encdec(cfg: ModelConfig, key: jax.Array) -> Dict:
    kg = KeyGen(key)
    enc = cfg.encoder
    enc_keys = jax.random.split(kg(), enc.n_layers)
    dec_keys = jax.random.split(kg(), cfg.n_layers)
    return {
        "embed": init_embedding(cfg.vocab, cfg.d_model, cfg.param_dtype, kg),
        "pos_embed": normal_init(kg(), (8192, cfg.d_model), cfg.param_dtype,
                                 scale=0.01),
        "encoder": jax.vmap(lambda k: init_encoder_block(cfg, KeyGen(k)))(enc_keys),
        "enc_ln": init_layer_norm(cfg.d_model, cfg.param_dtype),
        "decoder": jax.vmap(lambda k: init_decoder_block(cfg, KeyGen(k)))(dec_keys),
        "dec_ln": init_layer_norm(cfg.d_model, cfg.param_dtype),
    }


def encdec_axes(cfg: ModelConfig) -> Dict:
    stack = lambda bx: jax.tree.map(
        lambda a: ("layers",) + tuple(a), bx,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))
    return {
        "embed": embedding_axes(),
        "pos_embed": (None, "embed"),
        "encoder": stack(encoder_block_axes(cfg)),
        "enc_ln": layer_norm_axes(),
        "decoder": stack(decoder_block_axes(cfg)),
        "dec_ln": layer_norm_axes(),
    }


def encode(cfg: ModelConfig, params: Dict, frames: jax.Array) -> jax.Array:
    """frames [B, T, D] (stub frontend output) -> encoder states."""
    T = frames.shape[1]
    x = frames + sinusoid_positions(T, cfg.d_model)[None].astype(frames.dtype)

    def body(h, p):
        return encoder_block_apply(cfg, p, h), None

    x, _ = jax.lax.scan(body, x, params["encoder"])
    return layer_norm(x, params["enc_ln"]["scale"], params["enc_ln"]["bias"])


def cross_kv_all(cfg: ModelConfig, params: Dict, enc_out: jax.Array) -> Dict:
    """Precompute per-layer cross KV once per request."""
    def body(_, p):
        return None, attn.encode_cross_kv(cfg, p["cross_attn"], enc_out)
    _, kv = jax.lax.scan(body, None, params["decoder"])
    return kv    # leaves stacked [L, B, T, H, Dh]


def decode_full(cfg: ModelConfig, params: Dict, tokens: jax.Array,
                enc_out: jax.Array,
                collect_cache: bool = False) -> Tuple[jax.Array, Any]:
    """Teacher-forced decoder pass (training / prefill)."""
    B, S = tokens.shape
    x = embed_apply(params["embed"], tokens, cfg.dtype)
    n_pos = params["pos_embed"].shape[0]
    pe = params["pos_embed"][jnp.arange(S) % n_pos]
    x = x + pe[None].astype(x.dtype)
    kv = cross_kv_all(cfg, params, enc_out)

    def body(h, xs):
        p, ekv = xs
        h, cache = decoder_block_full(cfg, p, h, ekv)
        return h, cache if collect_cache else None

    x, caches = jax.lax.scan(body, x, (params["decoder"], kv))
    x = layer_norm(x, params["dec_ln"]["scale"], params["dec_ln"]["bias"])
    logits = unembed_apply(params["embed"], x, x.dtype)
    return logits, (caches, kv)


def decode_step(cfg: ModelConfig, params: Dict, token: jax.Array,
                pos: jax.Array, caches: Any, kv: Dict) -> Tuple[jax.Array, Any]:
    """Single-token decoder step against self-attn caches + encoder KV."""
    x = embed_apply(params["embed"], token, cfg.dtype)        # [B,1,D]
    # whisper's real positional range is 448; decode_32k is exercised
    # structurally (see DESIGN.md §Arch-applicability) — wrap the table.
    pe = jnp.take(params["pos_embed"], pos % params["pos_embed"].shape[0],
                  axis=0)[:, None, :]
    x = x + pe.astype(x.dtype)

    def body(h, xs):
        p, cache, ekv = xs
        h, c = decoder_block_decode(cfg, p, h, pos, cache, ekv)
        return h, c

    x, new_caches = jax.lax.scan(body, x, (params["decoder"], caches, kv))
    x = layer_norm(x, params["dec_ln"]["scale"], params["dec_ln"]["bias"])
    logits = unembed_apply(params["embed"], x, x.dtype)
    return logits, new_caches
