"""RWKV-6 "Finch" — attention-free time mixing with data-dependent decay.

Per head (size N) the WKV state S ∈ R^{N×N} evolves as

    y_t = r_t · (S_{t-1} + diag(u) k_t v_tᵀ)
    S_t = diag(w_t) S_{t-1} + k_t v_tᵀ

with the decay ``w_t = exp(-exp(w0 + lora(x̃_t)))`` *data-dependent* (the
Finch contribution) and token-shift interpolations (ddlerp) feeding every
projection.  Training scans over time (O(1) memory in L); decode carries
``(S, last_x)`` — constant-size state, which is why rwkv6 runs the
``long_500k`` cell that quadratic attention cannot.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.params import KeyGen, normal_init

MIX_NAMES = ("w", "k", "v", "r", "g")


def rwkv_dims(cfg: ModelConfig) -> Tuple[int, int]:
    N = cfg.rwkv.head_dim
    H = cfg.d_model // N
    return H, N


def init_rwkv_time(cfg: ModelConfig, kg: KeyGen) -> Dict:
    d = cfg.d_model
    r = cfg.rwkv
    dt = cfg.param_dtype
    H, N = rwkv_dims(cfg)
    return {
        "mu_x": jnp.full((d,), 0.5, dt),
        "mix_w1": normal_init(kg(), (d, 5 * r.gate_lora), dt, scale=1e-2),
        "mix_w2": normal_init(kg(), (5, r.gate_lora, d), dt, scale=1e-2),
        "mu": jnp.full((5, d), 0.5, dt),
        "wr": normal_init(kg(), (d, d), dt),
        "wk": normal_init(kg(), (d, d), dt),
        "wv": normal_init(kg(), (d, d), dt),
        "wg": normal_init(kg(), (d, d), dt),
        "wo": normal_init(kg(), (d, d), dt),
        "w0": jnp.full((d,), -6.0, dt),            # slow initial decay
        "decay_w1": normal_init(kg(), (d, r.decay_lora), dt, scale=1e-2),
        "decay_w2": normal_init(kg(), (r.decay_lora, d), dt, scale=1e-2),
        "u": normal_init(kg(), (d,), dt, scale=0.5, fan_in=1),
        "ln_scale": jnp.ones((d,), dt),            # per-head group norm
        "ln_bias": jnp.zeros((d,), dt),
    }


def rwkv_time_axes(cfg: ModelConfig) -> Dict:
    return {
        "mu_x": ("embed",), "mix_w1": ("embed", None), "mix_w2": (None, None, "embed"),
        "mu": (None, "embed"),
        "wr": ("embed", "mlp"), "wk": ("embed", "mlp"), "wv": ("embed", "mlp"),
        "wg": ("embed", "mlp"), "wo": ("mlp", "embed"),
        "w0": ("embed",), "decay_w1": ("embed", None), "decay_w2": (None, "embed"),
        "u": ("embed",), "ln_scale": ("embed",), "ln_bias": ("embed",),
    }


def init_rwkv_channel(cfg: ModelConfig, kg: KeyGen) -> Dict:
    d, f = cfg.d_model, cfg.d_ff
    dt = cfg.param_dtype
    return {
        "mu_k": jnp.full((d,), 0.5, dt),
        "mu_r": jnp.full((d,), 0.5, dt),
        "wk": normal_init(kg(), (d, f), dt),
        "wv": normal_init(kg(), (f, d), dt),
        "wr": normal_init(kg(), (d, d), dt),
    }


def rwkv_channel_axes(cfg: ModelConfig) -> Dict:
    return {"mu_k": ("embed",), "mu_r": ("embed",),
            "wk": ("embed", "mlp"), "wv": ("mlp", "embed"),
            "wr": ("embed", "mlp")}


def _shift(x: jax.Array, prev: Optional[jax.Array]) -> jax.Array:
    """x_{t-1} along time; ``prev`` [B,D] seeds position 0 (decode/chunking)."""
    if prev is None:
        prev = jnp.zeros_like(x[:, 0])
    return jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)


def _ddlerp(p: Dict, x: jax.Array, x_prev: jax.Array, dt_c):
    """Data-dependent token-shift mixes for (w,k,v,r,g)."""
    xx = x_prev - x
    xxx = x + xx * p["mu_x"].astype(dt_c)
    # [B,L,5*G] -> [5,B,L,G] -> lora out [5,B,L,D]
    h = jnp.tanh(jnp.einsum("bld,dg->blg", xxx, p["mix_w1"].astype(dt_c)))
    G = h.shape[-1] // 5
    h5 = h.reshape(*h.shape[:-1], 5, G)
    mix = jnp.einsum("blcg,cgd->cbld", h5, p["mix_w2"].astype(dt_c))
    outs = []
    for i, _ in enumerate(MIX_NAMES):
        mu_i = p["mu"][i].astype(dt_c)
        outs.append(x + xx * (mu_i + mix[i]))
    return outs  # w, k, v, r, g inputs


def _group_norm(y: jax.Array, scale: jax.Array, bias: jax.Array, H: int):
    """Per-head layer norm over the head dim ([..., H, N] flattened)."""
    B, L, D = y.shape
    N = D // H
    yh = y.reshape(B, L, H, N).astype(jnp.float32)
    mu = yh.mean(-1, keepdims=True)
    var = yh.var(-1, keepdims=True)
    yh = (yh - mu) * jax.lax.rsqrt(var + 64e-5)
    out = yh.reshape(B, L, D) * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(y.dtype)


def rwkv_time_full(
    cfg: ModelConfig,
    p: Dict,
    x: jax.Array,                       # [B, L, D]
    state: Optional[Dict] = None,       # {"S": [B,H,N,N], "x_prev": [B,D]}
) -> Tuple[jax.Array, Dict]:
    dt_c = x.dtype
    H, N = rwkv_dims(cfg)
    B, L, D = x.shape
    x_prev = None if state is None else state["x_prev"]
    xw, xk, xv, xr, xg = _ddlerp(p, x, _shift(x, x_prev), dt_c)

    r = jnp.einsum("bld,dk->blk", xr, p["wr"].astype(dt_c))
    k = jnp.einsum("bld,dk->blk", xk, p["wk"].astype(dt_c))
    v = jnp.einsum("bld,dk->blk", xv, p["wv"].astype(dt_c))
    g = jax.nn.silu(jnp.einsum("bld,dk->blk", xg, p["wg"].astype(dt_c)))
    w = jnp.exp(-jnp.exp(
        p["w0"].astype(jnp.float32)
        + jnp.einsum("blg,gd->bld",
                     jnp.tanh(jnp.einsum("bld,dg->blg", xw,
                                         p["decay_w1"].astype(dt_c))),
                     p["decay_w2"].astype(dt_c)).astype(jnp.float32)
    ))                                               # [B,L,D] in (0,1)

    rh = r.reshape(B, L, H, N).astype(jnp.float32)
    kh = k.reshape(B, L, H, N).astype(jnp.float32)
    vh = v.reshape(B, L, H, N).astype(jnp.float32)
    wh = w.reshape(B, L, H, N)
    uh = p["u"].astype(jnp.float32).reshape(H, N)

    s0 = (jnp.zeros((B, H, N, N), jnp.float32) if state is None
          else state["S"].astype(jnp.float32))

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp                     # [B,H,N] each
        kv = jnp.einsum("bhi,bhj->bhij", k_t, v_t)   # [B,H,N,N]
        y = jnp.einsum("bhi,bhij->bhj", r_t,
                       S + uh[None, :, :, None] * kv)
        S = w_t[..., None] * S + kv
        return S, y

    inputs = tuple(jnp.moveaxis(t, 1, 0) for t in (rh, kh, vh, wh))
    S_fin, ys = jax.lax.scan(step, s0, inputs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, L, D).astype(dt_c)
    y = _group_norm(y, p["ln_scale"], p["ln_bias"], H) * g
    out = jnp.einsum("bld,dk->blk", y, p["wo"].astype(dt_c))
    return out, {"S": S_fin, "x_prev": x[:, -1, :]}


def rwkv_time_decode(cfg: ModelConfig, p: Dict, x: jax.Array,
                     state: Dict) -> Tuple[jax.Array, Dict]:
    """Single-token step — same math, no scan."""
    return rwkv_time_full(cfg, p, x, state)


def rwkv_channel_full(
    cfg: ModelConfig,
    p: Dict,
    x: jax.Array,
    state: Optional[Dict] = None,       # {"x_prev": [B,D]}
) -> Tuple[jax.Array, Dict]:
    dt_c = x.dtype
    x_prev = None if state is None else state["x_prev"]
    xs = _shift(x, x_prev)
    xk = x + (xs - x) * p["mu_k"].astype(dt_c)
    xr = x + (xs - x) * p["mu_r"].astype(dt_c)
    k = jnp.einsum("bld,df->blf", xk, p["wk"].astype(dt_c))
    k = jnp.square(jax.nn.relu(k))
    kv = jnp.einsum("blf,fd->bld", k, p["wv"].astype(dt_c))
    r = jax.nn.sigmoid(jnp.einsum("bld,dk->blk", xr, p["wr"].astype(dt_c)))
    return r * kv, {"x_prev": x[:, -1, :]}
