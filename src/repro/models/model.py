"""Unified model interface: init / axes / train forward / prefill / decode.

``build_model(cfg)`` returns an :class:`LM` (decoder stacks, incl. VLM stub
inputs) or :class:`EncDecModel` (whisper).  All methods are pure functions of
(params, inputs, caches) so they jit/pjit directly; cache pytrees are explicit
and fixed-shape (scatter-updated at the position index), which is what lets
``serve_step`` lower for the decode shapes with donated buffers.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.models import encdec as encdec_mod
from repro.models import transformer as tf
from repro.models.config import ModelConfig
from repro.models.layers import embed_apply
from repro.models.params import KeyGen
from repro.models.sharding import constrain


# ----------------------------------------------------------------------
# cache construction
# ----------------------------------------------------------------------

def _attn_cache(cfg: ModelConfig, n: Optional[int], B: int, T: int):
    """KV (or MLA latent) cache for one run of n layers (n=None: unstacked)."""
    lead = () if n is None else (n,)
    if cfg.mla:
        m = cfg.mla
        return {
            "c_kv": jnp.zeros(lead + (B, T, m.kv_lora_rank), cfg.dtype),
            "k_rope": jnp.zeros(lead + (B, T, m.qk_rope_head_dim), cfg.dtype),
        }
    return {
        "k": jnp.zeros(lead + (B, T, cfg.n_kv_heads, cfg.head_dim), cfg.dtype),
        "v": jnp.zeros(lead + (B, T, cfg.n_kv_heads, cfg.head_dim), cfg.dtype),
    }


def _attn_cache_axes(cfg: ModelConfig, stacked: bool):
    lead = ("layers",) if stacked else ()
    if cfg.mla:
        return {"c_kv": lead + ("batch", "seq", None),
                "k_rope": lead + ("batch", "seq", None)}
    return {"k": lead + ("batch", "seq", "kv_heads", None),
            "v": lead + ("batch", "seq", "kv_heads", None)}


def _ssm_cache(cfg: ModelConfig, n: Optional[int], B: int):
    s = cfg.ssm
    d_inner, H, N = __import__("repro.models.ssm", fromlist=["ssm_dims"]).ssm_dims(cfg)
    lead = () if n is None else (n,)
    conv_ch = d_inner + 2 * N
    return {
        "conv": jnp.zeros(lead + (B, s.conv_width - 1, conv_ch), cfg.dtype),
        "ssm": jnp.zeros(lead + (B, H, s.head_dim, N), jnp.float32),
    }


def _ssm_cache_axes(cfg: ModelConfig, stacked: bool):
    lead = ("layers",) if stacked else ()
    return {"conv": lead + ("batch", None, "mlp"),
            "ssm": lead + ("batch", "heads", None, None)}


def _rwkv_cache(cfg: ModelConfig, n: Optional[int], B: int):
    from repro.models.rwkv import rwkv_dims
    H, N = rwkv_dims(cfg)
    lead = () if n is None else (n,)
    return {
        "time": {
            "S": jnp.zeros(lead + (B, H, N, N), jnp.float32),
            "x_prev": jnp.zeros(lead + (B, cfg.d_model), cfg.dtype),
        },
        "channel": {"x_prev": jnp.zeros(lead + (B, cfg.d_model), cfg.dtype)},
    }


def _rwkv_cache_axes(cfg: ModelConfig, stacked: bool):
    lead = ("layers",) if stacked else ()
    return {
        "time": {"S": lead + ("batch", "heads", None, None),
                 "x_prev": lead + ("batch", "embed_act")},
        "channel": {"x_prev": lead + ("batch", "embed_act")},
    }


def init_cache(cfg: ModelConfig, B: int, T: int) -> List[Any]:
    """Fixed-capacity decode caches, one entry per run."""
    caches: List[Any] = []
    for run in tf.build_runs(cfg):
        n = run.n if (cfg.scan_layers and run.n > 1) else None
        if run.kind in ("attn",):
            if n is None:
                caches.append([_attn_cache(cfg, None, B, T) for _ in range(run.n)])
            else:
                caches.append(_attn_cache(cfg, n, B, T))
        elif run.kind == "attn_shared":
            caches.append(_attn_cache(cfg, None, B, T))
        elif run.kind == "ssm":
            if n is None:
                caches.append([_ssm_cache(cfg, None, B) for _ in range(run.n)])
            else:
                caches.append(_ssm_cache(cfg, n, B))
        elif run.kind == "rwkv":
            if n is None:
                caches.append([_rwkv_cache(cfg, None, B) for _ in range(run.n)])
            else:
                caches.append(_rwkv_cache(cfg, n, B))
    return caches


def _pad_attn_cache(cfg: ModelConfig, cache: Dict, T: int) -> Dict:
    """Pad a prefill KV/latent cache out to serving capacity T (seq axis)."""
    def pad(x, axis):
        cur = x.shape[axis]
        if cur >= T:
            return x
        widths = [(0, 0)] * x.ndim
        widths[axis] = (0, T - cur)
        return jnp.pad(x, widths)

    if cfg.mla:
        return {"c_kv": pad(cache["c_kv"], -2),
                "k_rope": pad(cache["k_rope"], -2)}
    return {"k": pad(cache["k"], -3), "v": pad(cache["v"], -3)}


def pad_caches(cfg: ModelConfig, caches: List[Any], T: int) -> List[Any]:
    """Grow attention caches from prompt length to decode capacity T.
    SSM/RWKV states are fixed-size and pass through."""
    out: List[Any] = []
    for run, cache in zip(tf.build_runs(cfg), caches):
        if run.kind in ("attn", "attn_shared"):
            if isinstance(cache, list):
                out.append([_pad_attn_cache(cfg, c, T) for c in cache])
            else:
                out.append(_pad_attn_cache(cfg, cache, T))
        else:
            out.append(cache)
    return out


def cache_axes(cfg: ModelConfig) -> List[Any]:
    axes: List[Any] = []
    for run in tf.build_runs(cfg):
        stacked = cfg.scan_layers and run.n > 1
        if run.kind == "attn":
            a = _attn_cache_axes(cfg, stacked)
        elif run.kind == "attn_shared":
            a = _attn_cache_axes(cfg, False)
        elif run.kind == "ssm":
            a = _ssm_cache_axes(cfg, stacked)
        else:
            a = _rwkv_cache_axes(cfg, stacked)
        axes.append(a if stacked or run.kind == "attn_shared"
                    else [a for _ in range(run.n)])
    return axes


# ----------------------------------------------------------------------
# decoder-only LM
# ----------------------------------------------------------------------

class LM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # -- params -------------------------------------------------------

    def init(self, key: jax.Array) -> Dict:
        return tf.init_stack(self.cfg, key)

    def logical_axes(self) -> Dict:
        return tf.stack_axes(self.cfg)

    # -- training forward ----------------------------------------------

    def forward_train(
        self,
        params: Dict,
        tokens: Optional[jax.Array] = None,     # [B, S] int32
        embeds: Optional[jax.Array] = None,     # [B, S, D] (VLM stub path)
        positions: Optional[jax.Array] = None,
    ) -> Tuple[jax.Array, jax.Array]:
        """-> (logits [B,S,V], aux_loss)."""
        cfg = self.cfg
        if embeds is None:
            x = embed_apply(params["embed"], tokens, cfg.dtype)
        else:
            x = embeds.astype(cfg.dtype)
        B, S = x.shape[:2]
        if positions is None:
            base = jnp.arange(S, dtype=jnp.int32)[None, :]
            if cfg.mrope:
                positions = jnp.broadcast_to(base[:, None, :], (B, 3, S))
            else:
                positions = jnp.broadcast_to(base, (B, S))
        x = constrain(x, ("batch", "seq", "embed_act"))
        h, aux, _ = tf.stack_full(cfg, params, x, positions)
        logits = tf.lm_logits(cfg, params, h)
        return logits, aux

    def mtp_logits(self, params: Dict, hidden: jax.Array,
                   next_tokens: jax.Array) -> jax.Array:
        """DeepSeek MTP head: predict token t+2 from (h_t, emb(token t+1))."""
        cfg = self.cfg
        emb = embed_apply(params["embed"], next_tokens, cfg.dtype)
        h = jnp.concatenate([hidden, emb], axis=-1)
        h = jnp.einsum("bsd,dk->bsk", h, params["mtp"]["proj"].astype(cfg.dtype))
        B, S = h.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        h, _, _ = tf.block_full(
            cfg, "attn", "moe" if cfg.moe is not None else "dense",
            params["mtp"]["block"], h, positions, None)
        from repro.models.layers import rms_norm
        h = rms_norm(h, params["mtp"]["norm"]["scale"])
        return tf.lm_logits(cfg, params, h)

    def forward_hidden(self, params, tokens):
        cfg = self.cfg
        x = embed_apply(params["embed"], tokens, cfg.dtype)
        B, S = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        h, aux, _ = tf.stack_full(cfg, params, x, positions)
        return h, aux

    # -- serving ---------------------------------------------------------

    def prefill(
        self,
        params: Dict,
        tokens: Optional[jax.Array] = None,
        embeds: Optional[jax.Array] = None,
    ) -> Tuple[jax.Array, List[Any]]:
        """-> (last-token logits [B,V], caches).

        Attention caches come back sized to the prompt; pad to serving
        capacity with :func:`pad_caches` before decoding.
        """
        cfg = self.cfg
        if embeds is None:
            x = embed_apply(params["embed"], tokens, cfg.dtype)
        else:
            x = embeds.astype(cfg.dtype)
        B, S = x.shape[:2]
        base = jnp.arange(S, dtype=jnp.int32)[None, :]
        if cfg.mrope:
            positions = jnp.broadcast_to(base[:, None, :], (B, 3, S))
        else:
            positions = jnp.broadcast_to(base, (B, S))
        h, _, caches = tf.stack_full(cfg, params, x, positions,
                                     collect_cache=True)
        logits = tf.lm_logits(cfg, params, h[:, -1:, :])[:, 0]
        return logits, caches

    def decode_step(
        self,
        params: Dict,
        token: jax.Array,                # [B] int32
        pos: jax.Array,                  # [B] int32 position of `token`
        caches: List[Any],
    ) -> Tuple[jax.Array, List[Any]]:
        cfg = self.cfg
        x = embed_apply(params["embed"], token[:, None], cfg.dtype)
        x, new_caches = tf.stack_decode(cfg, params, x, pos, caches)
        logits = tf.lm_logits(cfg, params, x)[:, 0]
        return logits, new_caches

    def init_cache(self, B: int, T: int) -> List[Any]:
        return init_cache(self.cfg, B, T)

    def cache_axes(self) -> List[Any]:
        return cache_axes(self.cfg)


# ----------------------------------------------------------------------
# encoder-decoder (whisper)
# ----------------------------------------------------------------------

class EncDecModel:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def init(self, key: jax.Array) -> Dict:
        return encdec_mod.init_encdec(self.cfg, key)

    def logical_axes(self) -> Dict:
        return encdec_mod.encdec_axes(self.cfg)

    def forward_train(self, params: Dict, frames: jax.Array,
                      tokens: jax.Array) -> Tuple[jax.Array, jax.Array]:
        enc = encdec_mod.encode(self.cfg, params, frames)
        logits, _ = encdec_mod.decode_full(self.cfg, params, tokens, enc)
        return logits, jnp.zeros((), jnp.float32)

    def prefill(self, params: Dict, frames: jax.Array,
                tokens: jax.Array) -> Tuple[jax.Array, Any]:
        enc = encdec_mod.encode(self.cfg, params, frames)
        logits, (caches, kv) = encdec_mod.decode_full(
            self.cfg, params, tokens, enc, collect_cache=True)
        return logits[:, -1], (caches, kv)

    def decode_step(self, params: Dict, token: jax.Array, pos: jax.Array,
                    state: Any) -> Tuple[jax.Array, Any]:
        caches, kv = state
        logits, new_caches = encdec_mod.decode_step(
            self.cfg, params, token[:, None], pos, caches, kv)
        return logits[:, 0], (new_caches, kv)

    def init_cache(self, B: int, T: int) -> Any:
        cfg = self.cfg
        self_cache = _attn_cache(cfg, cfg.n_layers, B, T)
        H = cfg.n_heads
        kv = {
            "k": jnp.zeros((cfg.n_layers, B, cfg.encoder.n_frames, H,
                            cfg.head_dim), cfg.dtype),
            "v": jnp.zeros((cfg.n_layers, B, cfg.encoder.n_frames, H,
                            cfg.head_dim), cfg.dtype),
        }
        return (self_cache, kv)


def build_model(cfg: ModelConfig):
    return EncDecModel(cfg) if cfg.is_encdec else LM(cfg)


# ----------------------------------------------------------------------
# parameter counting (no allocation — eval_shape)
# ----------------------------------------------------------------------

def count_params_from_shapes(cfg: ModelConfig) -> int:
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.key(0))
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(shapes))


def count_active_params(cfg: ModelConfig) -> int:
    """Params activated per token: MoE counts top_k + shared experts only."""
    total = count_params_from_shapes(cfg)
    if cfg.moe is None:
        return total
    m = cfg.moe
    d, f = cfg.d_model, m.d_ff_expert
    n_moe_layers = sum(
        1 for i, k in enumerate(cfg.layer_kinds())
        if k == "attn" and i >= m.first_k_dense
    )
    per_expert = 3 * d * f
    inactive = n_moe_layers * (m.n_experts - m.top_k) * per_expert
    if cfg.mtp_depth > 0:
        inactive += (m.n_experts - m.top_k) * per_expert  # the MTP block
    return total - inactive
