"""Decoder-stack assembly: mixed block kinds, scan-over-layers, decode caches.

The stack is organized as **runs** — maximal groups of consecutive layers with
identical structure (kind × dense/moe variant).  Each run's parameters are
stacked ``[n, ...]`` and applied with ``lax.scan`` (HLO size independent of
depth; remat policy applied to the body), except ``attn_shared`` blocks
(zamba2), whose single weight set is reused at every occurrence.

Runs cover every assigned family:
  dense GQA (llama/qwen)        -> one run of "attn"/dense
  deepseek-v3                   -> "attn"/dense ×3 then "attn"/moe ×58
  mixtral                       -> "attn"/moe ×32 (SWA inside attention)
  rwkv6                         -> "rwkv" ×32
  zamba2 hybrid                 -> ssm runs interleaved with shared attn
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.models import ssm as ssm_mod
from repro.models.config import ModelConfig
from repro.models.layers import (
    embed_apply,
    embedding_axes,
    init_embedding,
    init_rms_norm,
    init_swiglu,
    rms_norm,
    rms_norm_axes,
    swiglu_apply,
    swiglu_axes,
    unembed_apply,
)
from repro.models.params import KeyGen, normal_init
from repro.models.sharding import compute_view, constrain


@dataclasses.dataclass(frozen=True)
class Run:
    kind: str       # attn | attn_shared | ssm | rwkv
    variant: str    # dense | moe | ""
    n: int


def build_runs(cfg: ModelConfig) -> List[Run]:
    kinds = cfg.layer_kinds()
    variants = []
    for i, k in enumerate(kinds):
        if k in ("attn",):
            if cfg.moe is not None and i >= cfg.moe.first_k_dense:
                variants.append("moe")
            else:
                variants.append("dense")
        else:
            variants.append("")
    runs: List[Run] = []
    for k, v in zip(kinds, variants):
        if runs and runs[-1].kind == k and runs[-1].variant == v \
                and k != "attn_shared":
            runs[-1] = dataclasses.replace(runs[-1], n=runs[-1].n + 1)
        else:
            runs.append(Run(k, v, 1))
    return runs


# ----------------------------------------------------------------------
# per-layer block init/axes/apply
# ----------------------------------------------------------------------

def init_block(cfg: ModelConfig, kind: str, variant: str, kg: KeyGen) -> Dict:
    d = cfg.d_model
    dt = cfg.param_dtype
    if kind in ("attn", "attn_shared"):
        p = {"ln1": init_rms_norm(d, dt)}
        p["attn"] = attn.init_mla(cfg, kg) if cfg.mla else attn.init_attention(cfg, kg)
        p["ln2"] = init_rms_norm(d, dt)
        if variant == "moe":
            p["mlp"] = moe_mod.init_moe(cfg, kg)
        else:
            p["mlp"] = init_swiglu(d, cfg.d_ff, dt, kg)
        return p
    if kind == "ssm":
        return {"ln1": init_rms_norm(d, dt), "ssm": ssm_mod.init_ssm(cfg, kg)}
    if kind == "rwkv":
        return {
            "ln1": init_rms_norm(d, dt),
            "time": rwkv_mod.init_rwkv_time(cfg, kg),
            "ln2": init_rms_norm(d, dt),
            "channel": rwkv_mod.init_rwkv_channel(cfg, kg),
        }
    raise ValueError(f"unknown block kind {kind!r}")


def block_axes(cfg: ModelConfig, kind: str, variant: str) -> Dict:
    if kind in ("attn", "attn_shared"):
        ax = {"ln1": rms_norm_axes(), "ln2": rms_norm_axes()}
        ax["attn"] = attn.mla_axes(cfg) if cfg.mla else attn.attention_axes(cfg)
        ax["mlp"] = moe_mod.moe_axes(cfg) if variant == "moe" else swiglu_axes()
        return ax
    if kind == "ssm":
        return {"ln1": rms_norm_axes(), "ssm": ssm_mod.ssm_axes(cfg)}
    if kind == "rwkv":
        return {
            "ln1": rms_norm_axes(),
            "time": rwkv_mod.rwkv_time_axes(cfg),
            "ln2": rms_norm_axes(),
            "channel": rwkv_mod.rwkv_channel_axes(cfg),
        }
    raise ValueError(kind)


def block_full(
    cfg: ModelConfig,
    kind: str,
    variant: str,
    p: Dict,
    x: jax.Array,
    positions: jax.Array,
    state: Optional[Any],
) -> Tuple[jax.Array, Any, jax.Array]:
    """Whole-sequence block application -> (x, new_state, aux_loss)."""
    p = compute_view(p, block_axes(cfg, kind, variant))  # FSDP JIT gather
    aux = jnp.zeros((), jnp.float32)
    if kind in ("attn", "attn_shared"):
        h = rms_norm(x, p["ln1"]["scale"])
        if cfg.mla:
            y, cache = attn.mla_full(cfg, p["attn"], h, positions)
        else:
            y, cache = attn.attention_full(cfg, p["attn"], h, positions)
        x = x + y
        h = rms_norm(x, p["ln2"]["scale"])
        if variant == "moe":
            y, aux = moe_mod.moe_apply(cfg, p["mlp"], h, x.dtype)
        else:
            y = swiglu_apply(p["mlp"], h, x.dtype)
        x = x + y
        x = constrain(x, ("batch", "seq", "embed_act"))
        return x, cache, aux
    if kind == "ssm":
        h = rms_norm(x, p["ln1"]["scale"])
        y, new_state = ssm_mod.ssm_full(cfg, p["ssm"], h, state)
        x = x + y
        x = constrain(x, ("batch", "seq", "embed_act"))
        return x, new_state, aux
    if kind == "rwkv":
        h = rms_norm(x, p["ln1"]["scale"])
        tstate = None if state is None else state["time"]
        y, t_new = rwkv_mod.rwkv_time_full(cfg, p["time"], h, tstate)
        x = x + y
        h = rms_norm(x, p["ln2"]["scale"])
        cstate = None if state is None else state["channel"]
        y, c_new = rwkv_mod.rwkv_channel_full(cfg, p["channel"], h, cstate)
        x = x + y
        x = constrain(x, ("batch", "seq", "embed_act"))
        return x, {"time": t_new, "channel": c_new}, aux
    raise ValueError(kind)


def block_decode(
    cfg: ModelConfig,
    kind: str,
    variant: str,
    p: Dict,
    x: jax.Array,                      # [B, 1, D]
    pos: jax.Array,                    # [B]
    state: Any,
) -> Tuple[jax.Array, Any]:
    # NOTE: no FSDP compute_view here — at decode, weights dominate bytes;
    # they must stay resident in their storage sharding and the (tiny)
    # token activations move instead (measured: gathering weights per step
    # cost +0.5s/step memory term on deepseek decode_32k; §Perf)
    if kind in ("attn", "attn_shared"):
        h = rms_norm(x, p["ln1"]["scale"])
        if cfg.mla:
            y, cache = attn.mla_decode(cfg, p["attn"], h, state, pos)
        else:
            y, cache = attn.attention_decode(cfg, p["attn"], h, state, pos)
        x = x + y
        h = rms_norm(x, p["ln2"]["scale"])
        if variant == "moe":
            y, _ = moe_mod.moe_apply(cfg, p["mlp"], h, x.dtype)
        else:
            y = swiglu_apply(p["mlp"], h, x.dtype)
        return x + y, cache
    if kind == "ssm":
        h = rms_norm(x, p["ln1"]["scale"])
        y, new_state = ssm_mod.ssm_decode(cfg, p["ssm"], h, state)
        return x + y, new_state
    if kind == "rwkv":
        h = rms_norm(x, p["ln1"]["scale"])
        y, t_new = rwkv_mod.rwkv_time_decode(cfg, p["time"], h, state["time"])
        x = x + y
        h = rms_norm(x, p["ln2"]["scale"])
        y, c_new = rwkv_mod.rwkv_channel_full(cfg, p["channel"], h,
                                              state["channel"])
        return x + y, {"time": t_new, "channel": c_new}
    raise ValueError(kind)


# ----------------------------------------------------------------------
# stack init
# ----------------------------------------------------------------------

def init_stack(cfg: ModelConfig, key: jax.Array) -> Dict:
    kg = KeyGen(key)
    runs = build_runs(cfg)
    params: Dict[str, Any] = {
        "embed": init_embedding(cfg.vocab, cfg.d_model, cfg.param_dtype, kg),
        "final_norm": init_rms_norm(cfg.d_model, cfg.param_dtype),
        "runs": [],
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = {
            "w": normal_init(kg(), (cfg.d_model, cfg.vocab), cfg.param_dtype)
        }
    shared_needed = any(r.kind == "attn_shared" for r in runs)
    if shared_needed:
        params["shared_block"] = init_block(cfg, "attn_shared", "dense", kg)
    for run in runs:
        if run.kind == "attn_shared":
            params["runs"].append({})      # weights live in shared_block
            continue
        keys = jax.random.split(kg(), run.n)
        stacked = jax.vmap(
            lambda k: init_block(cfg, run.kind, run.variant, KeyGen(k))
        )(keys)
        params["runs"].append(stacked)
    if cfg.mtp_depth > 0:
        params["mtp"] = {
            "proj": normal_init(kg(), (2 * cfg.d_model, cfg.d_model),
                                cfg.param_dtype),
            "block": init_block(
                cfg, "attn",
                "moe" if cfg.moe is not None else "dense", kg),
            "norm": init_rms_norm(cfg.d_model, cfg.param_dtype),
        }
    return params


def stack_axes(cfg: ModelConfig) -> Dict:
    runs = build_runs(cfg)
    ax: Dict[str, Any] = {
        "embed": embedding_axes(),
        "final_norm": rms_norm_axes(),
        "runs": [],
    }
    if not cfg.tie_embeddings:
        ax["lm_head"] = {"w": ("embed", "vocab")}
    if any(r.kind == "attn_shared" for r in runs):
        ax["shared_block"] = block_axes(cfg, "attn_shared", "dense")
    for run in runs:
        if run.kind == "attn_shared":
            ax["runs"].append({})
            continue
        bx = block_axes(cfg, run.kind, run.variant)
        # stacked leading "layers" axis on every leaf
        stacked = jax.tree.map(
            lambda a: ("layers",) + tuple(a),
            bx,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x),
        )
        ax["runs"].append(stacked)
    if cfg.mtp_depth > 0:
        ax["mtp"] = {
            "proj": ("embed", None),
            "block": block_axes(cfg, "attn",
                                "moe" if cfg.moe is not None else "dense"),
            "norm": rms_norm_axes(),
        }
    return ax


# ----------------------------------------------------------------------
# stack apply
# ----------------------------------------------------------------------

def _remat(cfg: ModelConfig, fn):
    if cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def stack_full(
    cfg: ModelConfig,
    params: Dict,
    x: jax.Array,                       # [B, S, D] embedded inputs
    positions: jax.Array,
    collect_cache: bool = False,
) -> Tuple[jax.Array, jax.Array, List[Any]]:
    """Whole-sequence pass -> (hidden, aux_loss, caches per run)."""
    runs = build_runs(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    caches: List[Any] = []
    for run, rp in zip(runs, params["runs"]):
        if run.kind == "attn_shared":
            bp = params["shared_block"]
            x, cache, aux = block_full(cfg, "attn", "dense", bp, x,
                                       positions, None)
            aux_total = aux_total + aux
            caches.append(cache if collect_cache else None)
            continue

        if cfg.scan_layers and run.n > 1:
            def body(carry, layer_params):
                h, aux_acc = carry
                h, cache, aux = block_full(cfg, run.kind, run.variant,
                                           layer_params, h, positions, None)
                out = cache if collect_cache else None
                return (h, aux_acc + aux), out

            (x, aux_total), run_cache = jax.lax.scan(
                _remat(cfg, body), (x, aux_total), rp)
            caches.append(run_cache)
        else:
            # unrolled path (probes / scan_layers=False): remat each block
            # identically to the scanned body so per-layer costs match
            def one_block(h, lp):
                return block_full(cfg, run.kind, run.variant, lp, h,
                                  positions, None)
            one_block_r = _remat(cfg, one_block)
            run_cache = []
            for i in range(run.n):
                lp = jax.tree.map(lambda a: a[i], rp)
                x, cache, aux = one_block_r(x, lp)
                aux_total = aux_total + aux
                run_cache.append(cache if collect_cache else None)
            caches.append(run_cache)
    return x, aux_total, caches


def stack_decode(
    cfg: ModelConfig,
    params: Dict,
    x: jax.Array,                       # [B, 1, D]
    pos: jax.Array,                     # [B]
    caches: List[Any],
) -> Tuple[jax.Array, List[Any]]:
    runs = build_runs(cfg)
    new_caches: List[Any] = []
    shared_i = 0
    for run, rp, cache in zip(runs, params["runs"], caches):
        if run.kind == "attn_shared":
            bp = params["shared_block"]
            x, c = block_decode(cfg, "attn", "dense", bp, x, pos, cache)
            new_caches.append(c)
            continue
        if cfg.scan_layers and run.n > 1:
            def body(h, xs):
                layer_params, layer_cache = xs
                h, c = block_decode(cfg, run.kind, run.variant, layer_params,
                                    h, pos, layer_cache)
                return h, c
            x, run_cache = jax.lax.scan(body, x, (rp, cache))
            new_caches.append(run_cache)
        else:
            outs = []
            for i in range(run.n):
                lp = jax.tree.map(lambda a: a[i], rp)
                x, c = block_decode(cfg, run.kind, run.variant, lp, x, pos,
                                    cache[i])
                outs.append(c)
            new_caches.append(outs)
    return x, new_caches


def lm_logits(cfg: ModelConfig, params: Dict, x: jax.Array) -> jax.Array:
    h = rms_norm(x, params["final_norm"]["scale"])
    if cfg.tie_embeddings:
        embed = compute_view(params["embed"], embedding_axes())
        logits = unembed_apply(embed, h, x.dtype)
    else:
        head = compute_view(params["lm_head"], {"w": ("embed", "vocab")})
        logits = jnp.einsum("...d,dv->...v", h,
                            head["w"].astype(x.dtype))
    # keep the vocab dim sharded over `model` — un-constrained, XLA SPMD
    # replicates [B,S,V] logits per device (+33.6 GB fp32 on llama3.2-1b
    # train_4k; see EXPERIMENTS.md §Perf)
    return constrain(logits, ("batch", "seq", "vocab"))
