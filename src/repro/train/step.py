"""Train-step builder: grad accumulation, AdamW, schedules, compression hook.

``make_train_step`` returns a pure ``(params, opt_state, batch, step) ->
(params, opt_state, metrics)`` suitable for ``jax.jit`` with donated params/
opt_state.  Microbatch accumulation is a ``lax.scan`` over batch slices —
activation memory is one microbatch deep while the gradient psum still
happens once (XLA hoists the cross-replica reduction out of the scan), which
is also what lets the DCN (pod) gradient sync overlap the last microbatch's
backward on real hardware.

``grad_compression='int8_pod'`` quantizes the *pod-axis* gradient reduction
to int8 (see optim/compression.py): the step becomes a ``shard_map`` manual
over ``pod`` / auto over (data, model), with an explicit quantize → psum →
dequantize replacing the implicit fp32 all-reduce on the slowest wire.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.compression import int8_compress, int8_decompress
from repro.train.loss import lm_loss

PyTree = Any


@dataclasses.dataclass(frozen=True)
class TrainStepConfig:
    num_microbatches: int = 1
    # scan (deployed: XLA serializes -> true 1-microbatch peak memory) vs
    # unrolled (accounting: cost_analysis counts every microbatch; the
    # scheduler may interleave, overstating peak memory)
    unroll_microbatches: bool = False
    grad_compression: str = "none"       # none | int8_pod
    schedule: Optional[Callable] = None  # step -> lr scale


def make_train_state(cfg: ModelConfig, model, key) -> Tuple[PyTree, PyTree]:
    params = model.init(key)
    return params, adamw_init(params)


def _accumulated_grads(loss_fn, params, batch, n_micro: int,
                       unroll: bool = False):
    """-> (grads, metrics) averaged over microbatches."""
    if n_micro == 1:
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return grads, metrics

    B = batch.shape[0]
    mb = batch.reshape(n_micro, B // n_micro, *batch.shape[1:])

    if unroll:
        # accounting mode: cost_analysis counts every microbatch
        grads = None
        metrics = None
        for i in range(n_micro):
            (_, m_i), g_i = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mb[i])
            if grads is None:
                grads = jax.tree.map(lambda g: g.astype(jnp.float32), g_i)
                metrics = m_i
            else:
                grads = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                     grads, g_i)
                metrics = jax.tree.map(jnp.add, metrics, m_i)
        inv = 1.0 / n_micro
        return (jax.tree.map(lambda g: g * inv, grads),
                jax.tree.map(lambda m: m * inv, metrics))

    def body(carry, micro):
        g_acc, m_acc = carry
        (_, metrics), g = jax.value_and_grad(
            loss_fn, has_aux=True)(params, micro)
        g_acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                             g_acc, g)
        m_acc = jax.tree.map(jnp.add, m_acc, metrics)
        return (g_acc, m_acc), None

    g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (_, metrics_shape), _ = jax.eval_shape(
        lambda p, b: jax.value_and_grad(loss_fn, has_aux=True)(p, b),
        params, mb[0])
    m0 = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), metrics_shape)
    (grads, metrics), _ = jax.lax.scan(body, (g0, m0), mb)
    inv = 1.0 / n_micro
    return (jax.tree.map(lambda g: g * inv, grads),
            jax.tree.map(lambda m: m * inv, metrics))


def make_train_step(
    cfg: ModelConfig,
    model,
    opt_cfg: AdamWConfig,
    step_cfg: TrainStepConfig = TrainStepConfig(),
    loss_fn: Optional[Callable] = None,
):
    """Returns ``step(params, opt_state, batch, step_idx) -> (p, o, metrics)``."""
    if loss_fn is None:
        def loss_fn(p, tokens):
            return lm_loss(cfg, model, p, tokens)

    def step(params, opt_state, batch, step_idx):
        grads, metrics = _accumulated_grads(
            loss_fn, params, batch, step_cfg.num_microbatches,
            unroll=step_cfg.unroll_microbatches)
        lr_scale = (step_cfg.schedule(step_idx)
                    if step_cfg.schedule is not None else 1.0)
        params, opt_state, gnorm = adamw_update(
            opt_cfg, params, grads, opt_state, lr_scale)
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        metrics["lr_scale"] = jnp.asarray(lr_scale, jnp.float32)
        return params, opt_state, metrics

    return step


def make_compressed_train_step(
    cfg: ModelConfig,
    model,
    opt_cfg: AdamWConfig,
    mesh,
    step_cfg: TrainStepConfig = TrainStepConfig(),
):
    """int8 pod-axis gradient sync: manual over 'pod', auto elsewhere.

    Each pod computes grads on ITS batch shard (no cross-pod reduction —
    the loss is pod-local), quantizes, psums int32 over DCN, dequantizes and
    averages, then applies an identical AdamW update on every pod.
    """
    from jax.sharding import PartitionSpec as P

    from repro.utils import shard_map_compat

    def loss_fn(p, tokens):
        return lm_loss(cfg, model, p, tokens)

    def pod_body(params, opt_state, batch, step_idx):
        grads, metrics = _accumulated_grads(
            loss_fn, params, batch, step_cfg.num_microbatches)
        q, scales = int8_compress(grads)
        # int8 payload over the wire; sum in int32 to avoid overflow
        q_sum = jax.tree.map(
            lambda x: jax.lax.psum(x.astype(jnp.int32), "pod"), q)
        s_max = jax.tree.map(lambda s: jax.lax.pmax(s, "pod"), scales)
        n_pods = jax.lax.psum(1, "pod")
        grads = jax.tree.map(
            lambda qq, ss: (qq.astype(jnp.float32) * ss) / n_pods,
            q_sum, s_max)
        metrics = jax.tree.map(lambda m: jax.lax.pmean(m, "pod"), metrics)
        lr_scale = (step_cfg.schedule(step_idx)
                    if step_cfg.schedule is not None else 1.0)
        params, opt_state, gnorm = adamw_update(
            opt_cfg, params, grads, opt_state, lr_scale)
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        return params, opt_state, metrics

    return shard_map_compat(
        pod_body,
        mesh=mesh,
        in_specs=(P(), P(), P("pod"), P()),
        out_specs=(P(), P(), P()),
        axis_names={"pod"},
        check=False,
    )
