"""Losses: next-token CE (+ MoE aux, + DeepSeek MTP)."""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


def cross_entropy(logits: jax.Array, targets: jax.Array,
                  mask: Optional[jax.Array] = None,
                  sharded_safe: bool = True) -> jax.Array:
    """Token-mean CE in fp32.  logits [..., V], targets [...] int.

    ``sharded_safe`` (default) computes the target logit with a masked
    reduction instead of ``take_along_axis`` — the gather's backward forces
    XLA SPMD to materialize FULL-vocab fp32 logits per device (measured:
    +33.6 GB/device on llama3.2-1b train_4k @ 256 chips; see EXPERIMENTS.md
    §Perf iteration 1), while the masked reduction partitions cleanly over a
    vocab-sharded last dim."""
    z = logits.astype(jnp.float32)
    if sharded_safe:
        lse = jax.nn.logsumexp(z, axis=-1)
        iota = jax.lax.broadcasted_iota(jnp.int32, z.shape, z.ndim - 1)
        tgt_logit = jnp.where(iota == targets[..., None], z, 0.0).sum(-1)
        nll = lse - tgt_logit
    else:
        lp = jax.nn.log_softmax(z, axis=-1)
        nll = -jnp.take_along_axis(lp, targets[..., None], axis=-1)[..., 0]
    if mask is None:
        return nll.mean()
    m = mask.astype(jnp.float32)
    return (nll * m).sum() / jnp.maximum(m.sum(), 1.0)


def lm_loss(
    cfg: ModelConfig,
    model,
    params: Dict,
    tokens: jax.Array,          # [B, S]
    mask: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Next-token loss over tokens[:, :-1] -> tokens[:, 1:]."""
    inputs = tokens[:, :-1]
    targets = tokens[:, 1:]
    tgt_mask = None if mask is None else mask[:, 1:]

    if cfg.mtp_depth > 0:
        hidden, aux = model.forward_hidden(params, inputs)
        from repro.models.transformer import lm_logits
        logits = lm_logits(cfg, params, hidden)
        ce = cross_entropy(logits, targets, tgt_mask)
        # MTP: from h_t and emb(t+1), predict token t+2
        mtp_logits = model.mtp_logits(params, hidden[:, :-1], inputs[:, 1:])
        mtp_ce = cross_entropy(mtp_logits, targets[:, 1:],
                               None if tgt_mask is None else tgt_mask[:, 1:])
        loss = ce + 0.3 * mtp_ce + cfg.moe.aux_loss_weight * aux \
            if cfg.moe else ce + 0.3 * mtp_ce
        metrics = {"ce": ce, "mtp_ce": mtp_ce, "aux": aux}
    else:
        logits, aux = model.forward_train(params, inputs)
        ce = cross_entropy(logits, targets, tgt_mask)
        loss = ce + (cfg.moe.aux_loss_weight * aux if cfg.moe else 0.0)
        metrics = {"ce": ce, "aux": aux}
    metrics["loss"] = loss
    return loss, metrics


def encdec_loss(cfg, model, params, frames, tokens):
    logits, aux = model.forward_train(params, frames, tokens[:, :-1])
    ce = cross_entropy(logits, tokens[:, 1:])
    return ce, {"ce": ce, "loss": ce, "aux": aux}
