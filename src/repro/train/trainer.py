"""Trainer loop: data from the colocation grid, periodic checkpoints,
failure/straggler hooks wired to the GridScheduler.

The loop is deliberately thin — all heavy lifting is in the jitted step —
but it owns the *operational* concerns a 1000-node run needs: resume from
the latest checkpoint, checkpoint cadence, metric logging, and (through the
scheduler) reacting to observed step-time skew by re-balancing the data
placement."""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import numpy as np

import jax

from repro.ckpt.checkpoint import CheckpointManager
from repro.core.scheduler import GridScheduler

PyTree = Any


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    log_every: int = 10
    checkpoint_every: int = 50
    checkpoint_dir: Optional[str] = None
    keep_last: int = 3


class Trainer:
    def __init__(
        self,
        step_fn: Callable,              # jitted (p, o, batch, i) -> (p, o, m)
        dataset,                        # ColocatedTokenDataset-like
        cfg: TrainerConfig,
        scheduler: Optional[GridScheduler] = None,
    ):
        self.step_fn = step_fn
        self.dataset = dataset
        self.cfg = cfg
        self.scheduler = scheduler
        self.ckpt = (CheckpointManager(cfg.checkpoint_dir, cfg.keep_last)
                     if cfg.checkpoint_dir else None)
        self.history: List[Dict[str, float]] = []

    def run(self, params: PyTree, opt_state: PyTree):
        start = 0
        if self.ckpt is not None:
            latest = self.ckpt.latest_step()
            if latest is not None:
                state, meta = self.ckpt.restore(
                    {"params": params, "opt": opt_state})
                params, opt_state = state["params"], state["opt"]
                start = int(meta.get("next_step", latest + 1))

        t_prev = time.perf_counter()
        for step in range(start, self.cfg.total_steps):
            batch = self.dataset.next_batch(step)
            params, opt_state, metrics = self.step_fn(
                params, opt_state, batch, step)

            if (step + 1) % self.cfg.log_every == 0 or step == start:
                m = {k: float(np.asarray(jax.device_get(v)))
                     for k, v in metrics.items()}
                now = time.perf_counter()
                m["step"] = step
                m["step_time_s"] = (now - t_prev) / max(
                    self.cfg.log_every if step != start else 1, 1)
                t_prev = now
                self.history.append(m)
                print(f"step {step:6d}  loss {m.get('loss', 0):8.4f}  "
                      f"grad_norm {m.get('grad_norm', 0):7.3f}  "
                      f"({m['step_time_s']*1e3:7.1f} ms/step)")

            if self.ckpt is not None and (step + 1) % self.cfg.checkpoint_every == 0:
                self.ckpt.save(step + 1,
                               {"params": params, "opt": opt_state},
                               metadata={"next_step": step + 1})

        if self.ckpt is not None:
            self.ckpt.save(self.cfg.total_steps,
                           {"params": params, "opt": opt_state},
                           metadata={"next_step": self.cfg.total_steps})
            self.ckpt.wait()
        return params, opt_state, self.history
