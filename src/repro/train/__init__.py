from repro.train.loss import lm_loss
from repro.train.step import TrainStepConfig, make_train_step, make_train_state
from repro.train.trainer import Trainer, TrainerConfig

__all__ = [
    "lm_loss", "TrainStepConfig", "make_train_step", "make_train_state",
    "Trainer", "TrainerConfig",
]
