"""Exact NumPy oracles for the approximate-sketch programs.

Every sketch in :mod:`repro.core.stats` (count-min, HyperLogLog, the dyadic
quantile sketch) is verified in the test suite against the *exact* answer
computed here in float64 NumPy — no JAX, no hashing, no approximation — with
the documented error bound asserted explicitly (ε·n / δ for count-min,
``1.04/sqrt(m)`` standard-error multiples for HLL, the dyadic rank bound for
quantiles).

Item identity matters: the sketches hash the canonicalized float32 bit
pattern of each element (``-0.0 == +0.0``; see
:func:`repro.core.stats.host_element_keys`), so the oracles quantize to the
same universe of items before counting.  Values stay float32 for identity
and are promoted to float64 only for order statistics.
"""

from __future__ import annotations

from typing import Sequence, Tuple

import numpy as np


def canonical_items(values) -> np.ndarray:
    """Flatten to the sketch programs' item universe: canonical float32
    values (``-0.0`` folded into ``+0.0``), one item per element."""
    x = np.asarray(values, np.float32).reshape(-1)
    return np.where(x == 0.0, np.float32(0.0), x)


def exact_frequencies(values) -> Tuple[np.ndarray, np.ndarray]:
    """``(unique_values, counts)`` over the canonical items — the count-min
    oracle.  Exact integer counts; NaNs collapse to one item like the
    sketch's single NaN bit pattern."""
    items = canonical_items(values)
    uniq, counts = np.unique(items, return_counts=True)
    return uniq, counts.astype(np.int64)


def exact_distinct(values) -> int:
    """Exact distinct-item count — the HyperLogLog oracle."""
    return int(len(np.unique(canonical_items(values))))


def exact_heavy_hitters(values, phi: float) -> Sequence[Tuple[float, int]]:
    """All items with exact frequency ``>= phi * n``, descending — the set
    count-min's one-sided screen must be a superset of."""
    uniq, counts = exact_frequencies(values)
    n = counts.sum()
    keep = counts >= phi * n
    order = np.argsort(-counts[keep], kind="stable")
    return [(float(v), int(c))
            for v, c in zip(uniq[keep][order], counts[keep][order])]


def exact_quantiles(values, probes: Sequence[float]) -> np.ndarray:
    """Exact order statistics at the probe ranks (float64 sort; the item at
    rank ``ceil(q * n)``) — the quantile-sketch oracle."""
    items = np.sort(canonical_items(values).astype(np.float64))
    n = len(items)
    if n == 0:
        return np.full(len(probes), np.nan)
    ranks = np.clip(np.ceil(np.asarray(probes, np.float64) * n).astype(
        np.int64), 1, n)
    return items[ranks - 1]


def rank_interval(values, vs) -> Tuple[np.ndarray, np.ndarray]:
    """Per query value, the exact rank interval ``[strictly_below,
    at_or_below]`` among the canonical items (int64).  A rank estimate r̂
    for ``v`` is correct within slack ``s`` iff the distance from r̂ to
    this interval is at most ``s`` — ties at ``v`` never count as error."""
    items = np.sort(canonical_items(values).astype(np.float64))
    q = np.asarray(vs, np.float64).reshape(-1)
    below = np.searchsorted(items, q, side="left").astype(np.int64)
    at_or_below = np.searchsorted(items, q, side="right").astype(np.int64)
    return below, at_or_below


def interval_distance(value, lo, hi) -> np.ndarray:
    """Elementwise distance from ``value`` to the closed interval
    ``[lo, hi]`` (0 inside) — the error a bound assertion charges."""
    v = np.asarray(value, np.float64)
    return np.maximum(np.maximum(np.asarray(lo, np.float64) - v,
                                 v - np.asarray(hi, np.float64)), 0.0)
