"""Placement — region→device maps realized as JAX sharded layouts.

The balancer decides *which node owns which region*; this module turns that
decision into something XLA can execute.  SPMD requires equal per-device array
shards, so (exactly like the paper, which moves uniform *regions* rather than
bytes) heterogeneity is expressed as **different numbers of row slots per
device filled**: the table's rows are gathered into a ``[devices, capacity,
...]`` layout (rowkey order preserved within a device), padded with a validity
mask, and sharded along the mesh's ``data`` axis.  Map tasks then iterate
device-local chunks; the mask keeps the lockstep SPMD program correct while
devices carry different amounts of real work — the schedule is where the
imbalance lives, not the array type.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.balancer import (
    Allocation,
    NodeSpec,
    assign_new_regions,
    balanced_allocation,
    central_allocation,
    greedy_allocation,
    node_loads,
)
from repro.core.table import TensorTable


@dataclasses.dataclass
class Placement:
    """A realized region→node assignment over a table."""

    table: TensorTable
    nodes: Tuple[NodeSpec, ...]
    alloc: Allocation  # region id -> node id
    # bumped whenever ``alloc`` changes (splits adopted, rebalance applied);
    # consumers caching derived row pools key on it.
    version: int = 0

    # ------------------------------------------------------------------
    # constructors
    # ------------------------------------------------------------------

    @classmethod
    def from_strategy(
        cls,
        table: TensorTable,
        nodes: Sequence[NodeSpec],
        strategy: str = "greedy",
    ) -> "Placement":
        region_bytes = table.region_bytes()
        if strategy == "greedy":
            alloc = greedy_allocation(region_bytes, nodes)
        elif strategy == "balanced":
            alloc = balanced_allocation(region_bytes, nodes)
        elif strategy == "central":
            alloc = central_allocation(region_bytes, nodes)
        else:
            raise ValueError(f"unknown strategy {strategy!r}")
        return cls(table, tuple(nodes), alloc)

    # ------------------------------------------------------------------
    # bookkeeping
    # ------------------------------------------------------------------

    def apply_splits(self) -> None:
        """Children of a split region inherit the parent's node (HBase
        keeps daughters on the same region server until a balancer run).

        ``version`` bumps only when the region→node map actually changed:
        consumers key caches on it (row pools, bound plan signatures), so a
        split-free upload must not read as a placement change."""
        changed = False
        for parent, left, right in self.table.split_log:
            if parent.rid in self.alloc:
                nid = self.alloc.pop(parent.rid)
                self.alloc[left.rid] = nid
                self.alloc[right.rid] = nid
                changed = True
        self.table.split_log.clear()
        # adopt any regions still missing (e.g. created before this placement)
        # at the neediest node vs its #CPU×MIPS share — not blindly node 0
        adopted = assign_new_regions(
            self.alloc, self.table.region_bytes(), self.nodes)
        if adopted:
            self.alloc.update(adopted)
            changed = True
        if changed:
            self.version += 1

    def node_bytes(self) -> Dict[int, float]:
        return node_loads(self.alloc, self.table.region_bytes(), self.nodes)

    def rows_for_node(self, node_id: int) -> np.ndarray:
        """Positional row indices owned by ``node_id``, in rowkey order."""
        keys = self.table.keys
        pieces: List[np.ndarray] = []
        for region in self.table.regions:
            if self.alloc.get(region.rid) == node_id:
                s = region.row_slice(keys)
                pieces.append(np.arange(s.start, s.stop, dtype=np.int64))
        if not pieces:
            return np.empty((0,), dtype=np.int64)
        return np.sort(np.concatenate(pieces))

    def node_row_counts(self) -> Dict[int, int]:
        counts = {n.node_id: 0 for n in self.nodes}
        rc = self.table.region_row_counts()
        for rid, nid in self.alloc.items():
            counts[nid] += rc.get(rid, 0)
        return counts

    # ------------------------------------------------------------------
    # device layouts
    # ------------------------------------------------------------------

    def device_layout(
        self, capacity: Optional[int] = None, chunk_size: int = 1
    ) -> Tuple[np.ndarray, np.ndarray]:
        """``(row_ids[D, C], valid[D, C])`` with ``C`` a chunk_size multiple.

        ``row_ids`` holds positional indices into the table's row order
        (0 where padded); ``valid`` marks real slots.  ``capacity`` defaults
        to the maximum per-node row count, rounded up to ``chunk_size``.
        """
        per_node = [self.rows_for_node(n.node_id) for n in self.nodes]
        need = max((len(p) for p in per_node), default=0)
        cap = capacity if capacity is not None else need
        if cap < need:
            raise ValueError(f"capacity {cap} < max per-node rows {need}")
        cap = max(chunk_size, -(-cap // chunk_size) * chunk_size)
        D = len(self.nodes)
        row_ids = np.zeros((D, cap), dtype=np.int64)
        valid = np.zeros((D, cap), dtype=bool)
        for d, rows in enumerate(per_node):
            row_ids[d, : len(rows)] = rows
            valid[d, : len(rows)] = True
        return row_ids, valid

    def gather_column(
        self,
        family: str,
        qualifier: str,
        capacity: Optional[int] = None,
        chunk_size: int = 1,
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Materialize a column in device layout: ``values[D, C, ...], valid``."""
        row_ids, valid = self.device_layout(capacity, chunk_size)
        col = self.table.column(family, qualifier)
        values = col[row_ids]          # padded slots read row 0; masked off
        values = np.where(
            valid.reshape(valid.shape + (1,) * (values.ndim - 2)), values, 0
        )
        return values, valid

    @staticmethod
    def data_sharding(mesh: Mesh, data_axis: str = "data") -> NamedSharding:
        """Sharding for ``[D, C, ...]`` layouts: leading dim over ``data``.

        When the mesh has extra axes (pod/model) the layout is replicated
        over them — map tasks are a data-axis concern.
        """
        return NamedSharding(mesh, P(data_axis))

    def put_column(
        self,
        mesh: Mesh,
        family: str,
        qualifier: str,
        data_axis: str = "data",
        capacity: Optional[int] = None,
        chunk_size: int = 1,
    ) -> Tuple[jax.Array, jax.Array]:
        """Device-put a column with colocation: shard d ↔ node d's rows."""
        values, valid = self.gather_column(family, qualifier, capacity, chunk_size)
        D = mesh.shape[data_axis]
        if len(self.nodes) != D:
            raise ValueError(
                f"placement has {len(self.nodes)} nodes but mesh axis "
                f"{data_axis!r} has {D} devices"
            )
        sh = self.data_sharding(mesh, data_axis)
        return jax.device_put(values, sh), jax.device_put(valid, sh)

    # ------------------------------------------------------------------
    # schedule / diagnostics
    # ------------------------------------------------------------------

    def rounds(self, chunk_size: int) -> int:
        """SPMD map rounds = chunks on the busiest device (the wall clock)."""
        counts = self.node_row_counts().values()
        return max((-(-c // chunk_size) for c in counts), default=0)

    def total_chunks(self, chunk_size: int) -> int:
        """Σ real chunks (the resource clock; ≙ the paper's #job)."""
        return sum(-(-c // chunk_size) for c in self.node_row_counts().values() if c)

    def describe(self) -> str:
        nb = self.node_bytes()
        rc = self.node_row_counts()
        lines = [f"Placement over {len(self.nodes)} nodes, "
                 f"{len(self.table.regions)} regions, {self.table.num_rows} rows"]
        for n in self.nodes:
            lines.append(
                f"  node {n.node_id:4d} power={n.power:8.1f} "
                f"rows={rc[n.node_id]:6d} bytes={nb[n.node_id]:.3e}"
            )
        return "\n".join(lines)
