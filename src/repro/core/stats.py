"""Summary-statistic MapReduce programs (§2.2's workload family).

The paper's exemplar is population-template construction: averaging 5,153
registered T1 volumes with ANTS ``AverageImages``.  That is a mean fold; this
module provides it plus the statistics a population study actually asks for
(variance via Chan/Welford parallel merge, higher moments, histograms), all as
:class:`~repro.core.mapreduce.MapReduceProgram` monoids so the same engine,
chunk model and table scheme apply.

Accumulation dtype defaults to float32 (TPU-native); pass ``acc_dtype=
jnp.float64`` on CPU for reference-grade accumulation.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.mapreduce import MapReduceProgram


def _masked(rows: jax.Array, valid: jax.Array, acc_dtype) -> jax.Array:
    """Zero out invalid rows and cast to the accumulator dtype."""
    v = valid.reshape(valid.shape + (1,) * (rows.ndim - 1))
    return jnp.where(v, rows, 0).astype(acc_dtype)


#: The shared-accumulator vocabulary of the CSE protocol: the masked row
#: count and the elementwise raw power sums Σx..Σx⁴.  Every statistic that
#: is a projection of these (mean, variance, moments, ...) can declare
#: ``requires()`` and ride one shared fold inside a CSE'd FusedProgram.
SHARED_ACCUMULATORS: Tuple[str, ...] = ("count", "s1", "s2", "s3", "s4")


def shared_zero(names: Tuple[str, ...], row_shape, acc_dtype
                ) -> Dict[str, jax.Array]:
    z = jnp.zeros(row_shape, acc_dtype)
    return {n: (jnp.zeros((), acc_dtype) if n == "count" else z)
            for n in names}


def shared_map_chunk(rows: jax.Array, valid: jax.Array,
                     names: Tuple[str, ...], acc_dtype
                     ) -> Dict[str, jax.Array]:
    """Fold one chunk into exactly the requested shared accumulators.

    This is the CSE: the masked cast ``x`` and the square ``x²`` are each
    materialized once and reused across every moment that needs them,
    however many member programs asked.
    """
    out: Dict[str, jax.Array] = {}
    if "count" in names:
        out["count"] = valid.sum().astype(acc_dtype)
    if any(n in names for n in ("s1", "s2", "s3", "s4")):
        x = _masked(rows, valid, acc_dtype)
        if "s1" in names:
            out["s1"] = x.sum(axis=0)
        if any(n in names for n in ("s2", "s3", "s4")):
            x2 = x * x
            if "s2" in names:
                out["s2"] = x2.sum(axis=0)
            if "s3" in names:
                out["s3"] = (x2 * x).sum(axis=0)
            if "s4" in names:
                out["s4"] = (x2 * x2).sum(axis=0)
    return out


@dataclasses.dataclass(frozen=True)
class MeanProgram(MapReduceProgram):
    """ANTS AverageImages analogue: elementwise mean over the population."""

    acc_dtype: jnp.dtype = jnp.float32
    additive = True

    def zero(self, row_shape, dtype):
        return {
            "sum": jnp.zeros(row_shape, self.acc_dtype),
            "count": jnp.zeros((), self.acc_dtype),
        }

    def map_chunk(self, rows, valid):
        return {
            "sum": _masked(rows, valid, self.acc_dtype).sum(axis=0),
            "count": valid.sum().astype(self.acc_dtype),
        }

    def merge(self, a, b):
        return jax.tree.map(jnp.add, a, b)

    def finalize(self, p):
        return p["sum"] / jnp.maximum(p["count"], 1)

    def requires(self):
        return ("count", "s1")

    def finalize_shared(self, shared):
        return self.finalize({"sum": shared["s1"], "count": shared["count"]})

    def shared_fold_spec(self):
        if jnp.dtype(self.acc_dtype) != jnp.float32:
            return None
        return self.requires()

    def partial_from_shared(self, shared):
        return {"sum": shared["s1"], "count": shared["count"]}


@dataclasses.dataclass(frozen=True)
class VarianceProgram(MapReduceProgram):
    """Elementwise population mean/variance with Chan's parallel merge.

    Deliberately *non-additive* (partials carry running means), exercising the
    engine's all-gather + fold reduce path and demonstrating that arbitrary
    associative statistics ride the same colocation machinery.
    """

    acc_dtype: jnp.dtype = jnp.float32
    additive = False

    def zero(self, row_shape, dtype):
        return {
            "count": jnp.zeros((), self.acc_dtype),
            "mean": jnp.zeros(row_shape, self.acc_dtype),
            "m2": jnp.zeros(row_shape, self.acc_dtype),
        }

    def map_chunk(self, rows, valid):
        x = _masked(rows, valid, self.acc_dtype)
        n = valid.sum().astype(self.acc_dtype)
        safe_n = jnp.maximum(n, 1)
        mean = x.sum(axis=0) / safe_n
        v = valid.reshape(valid.shape + (1,) * (rows.ndim - 1))
        centered = jnp.where(v, x - mean, 0)
        m2 = (centered * centered).sum(axis=0)
        return {"count": n, "mean": mean, "m2": m2}

    def merge(self, a, b):
        na, nb = a["count"], b["count"]
        n = na + nb
        safe_n = jnp.maximum(n, 1)
        delta = b["mean"] - a["mean"]
        mean = a["mean"] + delta * (nb / safe_n)
        m2 = a["m2"] + b["m2"] + (delta * delta) * (na * nb / safe_n)
        # empty-side guards: merging with a zero partial must be identity
        mean = jnp.where(na == 0, b["mean"], jnp.where(nb == 0, a["mean"], mean))
        m2 = jnp.where(na == 0, b["m2"], jnp.where(nb == 0, a["m2"], m2))
        return {"count": n, "mean": mean, "m2": m2}

    def finalize(self, p):
        var = p["m2"] / jnp.maximum(p["count"], 1)
        return {"mean": p["mean"], "var": var, "count": p["count"]}

    def requires(self):
        # inside a CSE'd fusion the Chan partial gives way to the shared
        # raw sums (count, Σx, Σx²): same result up to float associativity,
        # and the shared path is additive — the fusion keeps the psum reduce
        return ("count", "s1", "s2")

    def finalize_shared(self, shared):
        n = jnp.maximum(shared["count"], 1)
        mean = shared["s1"] / n
        var = jnp.maximum(shared["s2"] / n - mean * mean, 0)
        return {"mean": mean, "var": var, "count": shared["count"]}

    def shared_fold_spec(self):
        if jnp.dtype(self.acc_dtype) != jnp.float32:
            return None
        return self.requires()

    def partial_from_shared(self, shared):
        # raw sums -> the Chan partial: mean = Σx/n, M2 = Σx² - n·mean²
        # (equal up to float associativity; merge stays the Chan merge)
        n = shared["count"]
        safe_n = jnp.maximum(n, 1)
        mean = shared["s1"] / safe_n
        m2 = jnp.maximum(shared["s2"] - mean * shared["s1"], 0)
        return {"count": n, "mean": mean, "m2": m2}


@dataclasses.dataclass(frozen=True)
class MomentsProgram(MapReduceProgram):
    """Raw moments 1..4 (additive) → mean/var/skew/kurtosis per voxel."""

    acc_dtype: jnp.dtype = jnp.float32
    additive = True

    def zero(self, row_shape, dtype):
        z = jnp.zeros(row_shape, self.acc_dtype)
        return {"count": jnp.zeros((), self.acc_dtype),
                "s1": z, "s2": z, "s3": z, "s4": z}

    def map_chunk(self, rows, valid):
        x = _masked(rows, valid, self.acc_dtype)
        x2 = x * x
        return {
            "count": valid.sum().astype(self.acc_dtype),
            "s1": x.sum(axis=0),
            "s2": x2.sum(axis=0),
            "s3": (x2 * x).sum(axis=0),
            "s4": (x2 * x2).sum(axis=0),
        }

    def merge(self, a, b):
        return jax.tree.map(jnp.add, a, b)

    def finalize(self, p):
        n = jnp.maximum(p["count"], 1)
        m = p["s1"] / n
        ex2 = p["s2"] / n
        var = jnp.maximum(ex2 - m * m, 0)
        std = jnp.sqrt(jnp.maximum(var, 1e-30))
        m3 = p["s3"] / n - 3 * m * ex2 + 2 * m**3
        m4 = (p["s4"] / n - 4 * m * (p["s3"] / n) + 6 * m * m * ex2 - 3 * m**4)
        return {
            "mean": m,
            "var": var,
            "skew": m3 / std**3,
            "kurtosis": m4 / jnp.maximum(var * var, 1e-30),
            "count": p["count"],
        }

    def requires(self):
        return ("count", "s1", "s2", "s3", "s4")

    def finalize_shared(self, shared):
        # the private partial IS the raw power sums — reuse finalize as-is
        return self.finalize(dict(shared))

    def shared_fold_spec(self):
        if jnp.dtype(self.acc_dtype) != jnp.float32:
            return None
        return self.requires()

    def partial_from_shared(self, shared):
        return dict(shared)


@dataclasses.dataclass(frozen=True)
class CountProgram(MapReduceProgram):
    """Row count (additive) — the cheapest statistic, and an end-to-end
    oracle: a fold over a block-assembled layout must count exactly the
    slots the scan's row mask selected, so the differential harness checks
    it against ``QueryStats.rows_selected`` (a mask/padding bug anywhere in
    the block plumbing shows up here first).

    Accumulates in int32 (``psum`` is exact on integers; int64 would need
    x64 mode), not the float32 the statistic programs default to — callers
    assert exact equality and float32 loses integer exactness past 2^24
    rows.  Deliberately NOT in the CSE pool: the shared ``count``
    accumulates in the pool's float dtype, which would re-lose that
    exactness — the private int32 fold is the whole point."""

    acc_dtype: jnp.dtype = jnp.int32
    additive = True

    def zero(self, row_shape, dtype):
        return {"count": jnp.zeros((), self.acc_dtype)}

    def map_chunk(self, rows, valid):
        return {"count": valid.sum().astype(self.acc_dtype)}

    def merge(self, a, b):
        return jax.tree.map(jnp.add, a, b)

    def finalize(self, p):
        return p["count"]


@dataclasses.dataclass(frozen=True)
class FusedProgram(MapReduceProgram):
    """The monoid product of N statistic programs — one pass, N answers.

    ``GridQuery`` fuses every ``.map(program)`` on a plan into one of these,
    so mean+variance+histogram share a single gather and a single engine
    pass.  With ``cse=True`` (the default) members that declare
    :meth:`~repro.core.mapreduce.MapReduceProgram.requires` pool their raw
    accumulators: each shared accumulator (count, Σx, Σx², ...) is folded
    ONCE per chunk — via :func:`shared_map_chunk`, which also reuses the
    masked cast and the square across moments — and ``finalize`` projects
    every member's result from the pool.  Members without ``requires()``
    (histogram, the exact int32 count) keep their private folds alongside.

    The partial is ``{"shared": {dtype: {name: acc}}, "private": (...)}``;
    shared accumulators merge by sum, so the fusion is additive (single
    ``psum``) unless a *private* member is non-additive.  ``cse=False``
    recovers the naive product (every member folds the chunk itself) — kept
    for the FLOP-comparison bench and as an escape hatch.
    """

    programs: Tuple[MapReduceProgram, ...] = ()
    cse: bool = True

    def __post_init__(self):
        if not self.programs:
            raise ValueError("FusedProgram needs at least one program")
        object.__setattr__(self, "programs", tuple(self.programs))
        # role per member: the index into the private tuple, or the shared
        # pool key (accumulator dtype) it projects from
        private: Tuple[MapReduceProgram, ...] = ()
        roles = []
        groups: Dict[str, Tuple[str, ...]] = {}
        for p in self.programs:
            req = p.requires() if self.cse else ()
            if req:
                dt = str(jnp.dtype(getattr(p, "acc_dtype", jnp.float32)))
                merged = set(groups.get(dt, ())) | set(req)
                groups[dt] = tuple(n for n in SHARED_ACCUMULATORS
                                   if n in merged)
                roles.append(("shared", dt))
            else:
                roles.append(("private", len(private)))
                private = private + (p,)
        object.__setattr__(self, "_roles", tuple(roles))
        object.__setattr__(self, "_private", private)
        object.__setattr__(self, "_shared_groups",
                           tuple(sorted(groups.items())))
        object.__setattr__(
            self, "additive", all(p.additive for p in private))

    def zero(self, row_shape, dtype):
        shared = {dt: shared_zero(names, row_shape, jnp.dtype(dt))
                  for dt, names in self._shared_groups}
        return {"shared": shared,
                "private": tuple(p.zero(row_shape, dtype)
                                 for p in self._private)}

    def map_chunk(self, rows, valid):
        shared = {dt: shared_map_chunk(rows, valid, names, jnp.dtype(dt))
                  for dt, names in self._shared_groups}
        return {"shared": shared,
                "private": tuple(p.map_chunk(rows, valid)
                                 for p in self._private)}

    def merge(self, a, b):
        shared = jax.tree.map(jnp.add, a["shared"], b["shared"])
        private = tuple(p.merge(x, y) for p, x, y in
                        zip(self._private, a["private"], b["private"]))
        return {"shared": shared, "private": private}

    def finalize(self, partial):
        out = []
        for p, (kind, ref) in zip(self.programs, self._roles):
            if kind == "shared":
                out.append(p.finalize_shared(partial["shared"][ref]))
            else:
                out.append(p.finalize(partial["private"][ref]))
        return tuple(out)

    def shared_fold_spec(self):
        # kernel-eligible iff the fusion is pure pool: no private member
        # folds alongside, and the pool is the kernel's fp32 accumulator
        if self._private or len(self._shared_groups) != 1:
            return None
        dt, names = self._shared_groups[0]
        if dt != "float32":
            return None
        return names

    def partial_from_shared(self, shared):
        dt, _ = self._shared_groups[0]
        return {"shared": {dt: dict(shared)}, "private": ()}


def grouped_shared_map_chunk(rows: jax.Array, gmask: jax.Array,
                             names: Tuple[str, ...], acc_dtype
                             ) -> Dict[str, jax.Array]:
    """Fold one chunk into per-group shared accumulators by segment-sum.

    ``gmask`` is the ``[G, eta]`` per-group row mask (rows of a chunk are
    partitioned across groups; invalid rows belong to no group).  Each raw
    power of ``x`` is materialized ONCE and contracted against the group
    weights in a single ``einsum`` — the grouped analogue of the CSE in
    :func:`shared_map_chunk`: G groups share one masked cast, one square,
    one cube, however many member statistics project from the pool.
    """
    out: Dict[str, jax.Array] = {}
    w = gmask.astype(acc_dtype)                      # [G, eta] 0/1 weights
    if "count" in names:
        out["count"] = w.sum(axis=1)
    if any(n in names for n in ("s1", "s2", "s3", "s4")):
        # zero rows no group claims BEFORE raising powers, exactly like the
        # ungrouped _masked path: a NaN/Inf payload in a masked-off row
        # must not poison the segment sums (0-weight × NaN is NaN)
        x = _masked(rows, gmask.any(axis=0), acc_dtype)  # [eta, ...]

        def seg(v):                                  # [G, ...] segment sums
            return jnp.einsum("ge,e...->g...", w, v)

        if "s1" in names:
            out["s1"] = seg(x)
        if any(n in names for n in ("s2", "s3", "s4")):
            x2 = x * x
            if "s2" in names:
                out["s2"] = seg(x2)
            if "s3" in names:
                out["s3"] = seg(x2 * x)
            if "s4" in names:
                out["s4"] = seg(x2 * x2)
    return out


@dataclasses.dataclass
class GroupedResult:
    """Per-group finalized statistics from a ``group_by`` plan.

    ``keys[g]`` labels row ``g`` of every leaf in ``values`` (leaves carry a
    leading group axis).  ``keys`` are the distinct group-key values among
    the selected rows, ascending — the same order ``np.unique`` gives a
    NumPy groupby oracle.
    """

    keys: np.ndarray               # [G] unique group-key values, sorted
    values: Any                    # result tree; leaves are [G, ...]

    def __len__(self) -> int:
        return len(self.keys)

    def index_of(self, key) -> int:
        pos = int(np.searchsorted(self.keys, key))
        if pos >= len(self.keys) or self.keys[pos] != key:
            raise KeyError(f"no group with key {key!r}")
        return pos

    def group(self, key) -> Any:
        """The result tree of one group (leaves indexed at its row)."""
        g = self.index_of(key)
        return jax.tree.map(lambda x: x[g], self.values)

    def asdict(self) -> Dict[Any, Any]:
        """``{group key: result tree}`` with native-Python scalar keys."""
        return {k.item() if hasattr(k, "item") else k: jax.tree.map(
            lambda x, g=g: x[g], self.values)
            for g, k in enumerate(self.keys)}


@dataclasses.dataclass(frozen=True)
class GroupedProgram(MapReduceProgram):
    """Group-aware lift of a statistic program: one fold, G answers.

    Wraps ``base`` (a single program or a :class:`FusedProgram`) so every
    accumulator gains a leading group axis.  ``map_chunk`` receives the
    ``[G, eta]`` per-group row mask the engine derives from the chunk's
    group ids:

    - members in the CSE pool fold through
      :func:`grouped_shared_map_chunk` — the raw power sums are segment-
      summed by group id, so each power is computed once per chunk however
      many groups or member statistics there are;
    - private members (histogram, the exact int32 count) ``vmap`` their own
      fold over the group masks.

    Additivity is inherited: a grouped additive program still merges by
    elementwise sum (now ``[G, ...]``-shaped), so the tree-reduce/psum merge
    path stays available.  ``finalize`` projects per-group results with the
    base program's own finalizers (``vmap`` over the group axis).
    """

    base: MapReduceProgram = None  # type: ignore[assignment]
    num_groups: int = 0

    def __post_init__(self):
        if self.base is None:
            raise ValueError("GroupedProgram needs a base program")
        if self.num_groups < 0:
            raise ValueError(f"num_groups must be >= 0, got {self.num_groups}")
        fused = (self.base if isinstance(self.base, FusedProgram)
                 else FusedProgram((self.base,)))
        object.__setattr__(self, "_fused", fused)
        object.__setattr__(self, "_single",
                           not isinstance(self.base, FusedProgram))
        object.__setattr__(self, "additive", fused.additive)

    def cache_key(self) -> Tuple:
        return ("Grouped", int(self.num_groups), self.base.cache_key())

    def zero(self, row_shape, dtype):
        G = self.num_groups
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (G,) + x.shape),
            self._fused.zero(row_shape, dtype))

    def map_chunk(self, rows, gmask):
        # gmask: [G, eta] bool — disjoint per-group row masks for the chunk
        shared = {dt: grouped_shared_map_chunk(rows, gmask, names,
                                               jnp.dtype(dt))
                  for dt, names in self._fused._shared_groups}
        private = tuple(
            jax.vmap(p.map_chunk, in_axes=(None, 0))(rows, gmask)
            for p in self._fused._private)
        return {"shared": shared, "private": private}

    def merge(self, a, b):
        if self.additive:
            return jax.tree.map(jnp.add, a, b)
        return jax.vmap(self._fused.merge)(a, b)

    def finalize(self, partial):
        out = jax.vmap(self._fused.finalize)(partial)
        return out[0] if self._single else out

    def shared_fold_spec(self):
        # the grouped partial is the fused partial with a leading group
        # axis on every leaf — exactly what the kernel's [G, F] pool is
        return self._fused.shared_fold_spec()

    def partial_from_shared(self, shared):
        return self._fused.partial_from_shared(shared)


@dataclasses.dataclass(frozen=True)
class HistogramProgram(MapReduceProgram):
    """Global intensity histogram with fixed bin edges (additive)."""

    lo: float = 0.0
    hi: float = 1.0
    bins: int = 64
    additive = True

    def zero(self, row_shape, dtype):
        return {"hist": jnp.zeros((self.bins,), jnp.float32)}

    def map_chunk(self, rows, valid):
        x = rows.reshape(rows.shape[0], -1)
        scaled = (x - self.lo) / (self.hi - self.lo) * self.bins
        idx = jnp.clip(scaled.astype(jnp.int32), 0, self.bins - 1)
        onehot = jax.nn.one_hot(idx, self.bins, dtype=jnp.float32)
        w = valid.astype(jnp.float32)[:, None, None]
        return {"hist": (onehot * w).sum(axis=(0, 1))}

    def merge(self, a, b):
        return jax.tree.map(jnp.add, a, b)

    def finalize(self, p):
        return p["hist"]
