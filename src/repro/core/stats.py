"""Summary-statistic MapReduce programs (§2.2's workload family).

The paper's exemplar is population-template construction: averaging 5,153
registered T1 volumes with ANTS ``AverageImages``.  That is a mean fold; this
module provides it plus the statistics a population study actually asks for
(variance via Chan/Welford parallel merge, higher moments, histograms), all as
:class:`~repro.core.mapreduce.MapReduceProgram` monoids so the same engine,
chunk model and table scheme apply.

Accumulation dtype defaults to float32 (TPU-native); pass ``acc_dtype=
jnp.float64`` on CPU for reference-grade accumulation.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.mapreduce import MapReduceProgram


def _masked(rows: jax.Array, valid: jax.Array, acc_dtype) -> jax.Array:
    """Zero out invalid rows and cast to the accumulator dtype."""
    v = valid.reshape(valid.shape + (1,) * (rows.ndim - 1))
    return jnp.where(v, rows, 0).astype(acc_dtype)


def _merge_leafwise(a: Any, b: Any, ops) -> Any:
    """Pairwise combine honoring per-leaf merge operators (``None`` = all
    sum) — the eager analogue of the engine's collective dispatch."""
    if ops is None:
        return jax.tree.map(jnp.add, a, b)
    leaves_a, treedef = jax.tree_util.tree_flatten(a)
    leaves_b = jax.tree_util.tree_leaves(b)
    return jax.tree_util.tree_unflatten(
        treedef, [jnp.maximum(x, y) if op == "max" else x + y
                  for x, y, op in zip(leaves_a, leaves_b, ops)])


# ---------------------------------------------------------------------------
# sketch support: deterministic seeded hashing (identical on host and device)
# ---------------------------------------------------------------------------

def _fmix32(h: jax.Array) -> jax.Array:
    """murmur3's 32-bit finalizer (full avalanche) on uint32 arrays.
    Pure uint32 arithmetic, so the jitted fold and the host-side estimate
    helpers hash bit-identically."""
    h = h ^ (h >> 16)
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> 13)
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> 16)
    return h


def _host_fmix32(h: np.ndarray) -> np.ndarray:
    h = h.astype(np.uint32)
    h = h ^ (h >> np.uint32(16))
    h = h * np.uint32(0x85EBCA6B)
    h = h ^ (h >> np.uint32(13))
    h = h * np.uint32(0xC2B2AE35)
    h = h ^ (h >> np.uint32(16))
    return h


def _derive_seeds(seed: int, n: int) -> Tuple[int, ...]:
    """``n`` decorrelated 32-bit hash seeds from one user seed (golden-ratio
    stepping + fmix32) — Python-int arithmetic mod 2^32, computed once per
    program instance so folds never pay for it."""
    out = []
    base = seed & 0xFFFFFFFF
    for i in range(n):
        h = (base + i * 0x9E3779B9) & 0xFFFFFFFF
        h ^= h >> 16
        h = (h * 0x85EBCA6B) & 0xFFFFFFFF
        h ^= h >> 13
        h = (h * 0xC2B2AE35) & 0xFFFFFFFF
        h ^= h >> 16
        out.append(h)
    return tuple(out)


def _element_keys(rows: jax.Array, valid: jax.Array
                  ) -> Tuple[jax.Array, jax.Array]:
    """Flatten a ``[eta, ...]`` chunk into per-element uint32 hash keys.

    Sketches treat every element of every valid row as one item of the
    distribution (the same element-level semantics as
    :class:`HistogramProgram`).  The key is the float32 bit pattern with
    ``-0.0`` canonicalized to ``+0.0``, so equal values always collide and
    the NumPy oracle (:mod:`repro.core.ref`) can reproduce the exact same
    item universe.  Invalid rows are zeroed before the bitcast — their keys
    are well-defined garbage that the returned element mask weights out.
    """
    x = rows.reshape(rows.shape[0], -1)                       # [eta, E]
    v = jnp.broadcast_to(valid.astype(bool)[:, None], x.shape)
    xf = jnp.where(v, x, 0).astype(jnp.float32)
    xf = jnp.where(xf == 0.0, 0.0, xf)                        # -0.0 -> +0.0
    keys = jax.lax.bitcast_convert_type(xf, jnp.uint32)
    return keys.reshape(-1), v.reshape(-1)


def host_element_keys(values: np.ndarray) -> np.ndarray:
    """Host mirror of the device key derivation: float32 bit patterns with
    ``-0.0`` canonicalized — the item identity both the sketch programs and
    the exact oracles share."""
    xf = np.asarray(values, np.float32).reshape(-1)
    xf = np.where(xf == 0.0, np.float32(0.0), xf)
    return xf.view(np.uint32)


#: The shared-accumulator vocabulary of the CSE protocol: the masked row
#: count and the elementwise raw power sums Σx..Σx⁴.  Every statistic that
#: is a projection of these (mean, variance, moments, ...) can declare
#: ``requires()`` and ride one shared fold inside a CSE'd FusedProgram.
SHARED_ACCUMULATORS: Tuple[str, ...] = ("count", "s1", "s2", "s3", "s4")


def shared_zero(names: Tuple[str, ...], row_shape, acc_dtype
                ) -> Dict[str, jax.Array]:
    z = jnp.zeros(row_shape, acc_dtype)
    return {n: (jnp.zeros((), acc_dtype) if n == "count" else z)
            for n in names}


def shared_map_chunk(rows: jax.Array, valid: jax.Array,
                     names: Tuple[str, ...], acc_dtype
                     ) -> Dict[str, jax.Array]:
    """Fold one chunk into exactly the requested shared accumulators.

    This is the CSE: the masked cast ``x`` and the square ``x²`` are each
    materialized once and reused across every moment that needs them,
    however many member programs asked.
    """
    out: Dict[str, jax.Array] = {}
    if "count" in names:
        out["count"] = valid.sum().astype(acc_dtype)
    if any(n in names for n in ("s1", "s2", "s3", "s4")):
        x = _masked(rows, valid, acc_dtype)
        if "s1" in names:
            out["s1"] = x.sum(axis=0)
        if any(n in names for n in ("s2", "s3", "s4")):
            x2 = x * x
            if "s2" in names:
                out["s2"] = x2.sum(axis=0)
            if "s3" in names:
                out["s3"] = (x2 * x).sum(axis=0)
            if "s4" in names:
                out["s4"] = (x2 * x2).sum(axis=0)
    return out


@dataclasses.dataclass(frozen=True)
class MeanProgram(MapReduceProgram):
    """ANTS AverageImages analogue: elementwise mean over the population."""

    acc_dtype: jnp.dtype = jnp.float32
    additive = True

    def zero(self, row_shape, dtype):
        return {
            "sum": jnp.zeros(row_shape, self.acc_dtype),
            "count": jnp.zeros((), self.acc_dtype),
        }

    def map_chunk(self, rows, valid):
        return {
            "sum": _masked(rows, valid, self.acc_dtype).sum(axis=0),
            "count": valid.sum().astype(self.acc_dtype),
        }

    def merge(self, a, b):
        return jax.tree.map(jnp.add, a, b)

    def finalize(self, p):
        return p["sum"] / jnp.maximum(p["count"], 1)

    def requires(self):
        return ("count", "s1")

    def finalize_shared(self, shared):
        return self.finalize({"sum": shared["s1"], "count": shared["count"]})

    def shared_fold_spec(self):
        if jnp.dtype(self.acc_dtype) != jnp.float32:
            return None
        return self.requires()

    def partial_from_shared(self, shared):
        return {"sum": shared["s1"], "count": shared["count"]}


@dataclasses.dataclass(frozen=True)
class VarianceProgram(MapReduceProgram):
    """Elementwise population mean/variance with Chan's parallel merge.

    Deliberately *non-additive* (partials carry running means), exercising the
    engine's all-gather + fold reduce path and demonstrating that arbitrary
    associative statistics ride the same colocation machinery.
    """

    acc_dtype: jnp.dtype = jnp.float32
    additive = False

    def zero(self, row_shape, dtype):
        return {
            "count": jnp.zeros((), self.acc_dtype),
            "mean": jnp.zeros(row_shape, self.acc_dtype),
            "m2": jnp.zeros(row_shape, self.acc_dtype),
        }

    def map_chunk(self, rows, valid):
        x = _masked(rows, valid, self.acc_dtype)
        n = valid.sum().astype(self.acc_dtype)
        safe_n = jnp.maximum(n, 1)
        mean = x.sum(axis=0) / safe_n
        v = valid.reshape(valid.shape + (1,) * (rows.ndim - 1))
        centered = jnp.where(v, x - mean, 0)
        m2 = (centered * centered).sum(axis=0)
        return {"count": n, "mean": mean, "m2": m2}

    def merge(self, a, b):
        na, nb = a["count"], b["count"]
        n = na + nb
        safe_n = jnp.maximum(n, 1)
        delta = b["mean"] - a["mean"]
        mean = a["mean"] + delta * (nb / safe_n)
        m2 = a["m2"] + b["m2"] + (delta * delta) * (na * nb / safe_n)
        # empty-side guards: merging with a zero partial must be identity
        mean = jnp.where(na == 0, b["mean"], jnp.where(nb == 0, a["mean"], mean))
        m2 = jnp.where(na == 0, b["m2"], jnp.where(nb == 0, a["m2"], m2))
        return {"count": n, "mean": mean, "m2": m2}

    def finalize(self, p):
        var = p["m2"] / jnp.maximum(p["count"], 1)
        return {"mean": p["mean"], "var": var, "count": p["count"]}

    def requires(self):
        # inside a CSE'd fusion the Chan partial gives way to the shared
        # raw sums (count, Σx, Σx²): same result up to float associativity,
        # and the shared path is additive — the fusion keeps the psum reduce
        return ("count", "s1", "s2")

    def finalize_shared(self, shared):
        n = jnp.maximum(shared["count"], 1)
        mean = shared["s1"] / n
        var = jnp.maximum(shared["s2"] / n - mean * mean, 0)
        return {"mean": mean, "var": var, "count": shared["count"]}

    def shared_fold_spec(self):
        if jnp.dtype(self.acc_dtype) != jnp.float32:
            return None
        return self.requires()

    def partial_from_shared(self, shared):
        # raw sums -> the Chan partial: mean = Σx/n, M2 = Σx² - n·mean²
        # (equal up to float associativity; merge stays the Chan merge)
        n = shared["count"]
        safe_n = jnp.maximum(n, 1)
        mean = shared["s1"] / safe_n
        m2 = jnp.maximum(shared["s2"] - mean * shared["s1"], 0)
        return {"count": n, "mean": mean, "m2": m2}


@dataclasses.dataclass(frozen=True)
class MomentsProgram(MapReduceProgram):
    """Raw moments 1..4 (additive) → mean/var/skew/kurtosis per voxel."""

    acc_dtype: jnp.dtype = jnp.float32
    additive = True

    def zero(self, row_shape, dtype):
        z = jnp.zeros(row_shape, self.acc_dtype)
        return {"count": jnp.zeros((), self.acc_dtype),
                "s1": z, "s2": z, "s3": z, "s4": z}

    def map_chunk(self, rows, valid):
        x = _masked(rows, valid, self.acc_dtype)
        x2 = x * x
        return {
            "count": valid.sum().astype(self.acc_dtype),
            "s1": x.sum(axis=0),
            "s2": x2.sum(axis=0),
            "s3": (x2 * x).sum(axis=0),
            "s4": (x2 * x2).sum(axis=0),
        }

    def merge(self, a, b):
        return jax.tree.map(jnp.add, a, b)

    def finalize(self, p):
        n = jnp.maximum(p["count"], 1)
        m = p["s1"] / n
        ex2 = p["s2"] / n
        var = jnp.maximum(ex2 - m * m, 0)
        std = jnp.sqrt(jnp.maximum(var, 1e-30))
        m3 = p["s3"] / n - 3 * m * ex2 + 2 * m**3
        m4 = (p["s4"] / n - 4 * m * (p["s3"] / n) + 6 * m * m * ex2 - 3 * m**4)
        return {
            "mean": m,
            "var": var,
            "skew": m3 / std**3,
            "kurtosis": m4 / jnp.maximum(var * var, 1e-30),
            "count": p["count"],
        }

    def requires(self):
        return ("count", "s1", "s2", "s3", "s4")

    def finalize_shared(self, shared):
        # the private partial IS the raw power sums — reuse finalize as-is
        return self.finalize(dict(shared))

    def shared_fold_spec(self):
        if jnp.dtype(self.acc_dtype) != jnp.float32:
            return None
        return self.requires()

    def partial_from_shared(self, shared):
        return dict(shared)


@dataclasses.dataclass(frozen=True)
class CountProgram(MapReduceProgram):
    """Row count (additive) — the cheapest statistic, and an end-to-end
    oracle: a fold over a block-assembled layout must count exactly the
    slots the scan's row mask selected, so the differential harness checks
    it against ``QueryStats.rows_selected`` (a mask/padding bug anywhere in
    the block plumbing shows up here first).

    Accumulates in int32 (``psum`` is exact on integers; int64 would need
    x64 mode), not the float32 the statistic programs default to — callers
    assert exact equality and float32 loses integer exactness past 2^24
    rows.  Deliberately NOT in the CSE pool: the shared ``count``
    accumulates in the pool's float dtype, which would re-lose that
    exactness — the private int32 fold is the whole point."""

    acc_dtype: jnp.dtype = jnp.int32
    additive = True

    def zero(self, row_shape, dtype):
        return {"count": jnp.zeros((), self.acc_dtype)}

    def map_chunk(self, rows, valid):
        return {"count": valid.sum().astype(self.acc_dtype)}

    def merge(self, a, b):
        return jax.tree.map(jnp.add, a, b)

    def finalize(self, p):
        return p["count"]


@dataclasses.dataclass(frozen=True)
class FusedProgram(MapReduceProgram):
    """The monoid product of N statistic programs — one pass, N answers.

    ``GridQuery`` fuses every ``.map(program)`` on a plan into one of these,
    so mean+variance+histogram share a single gather and a single engine
    pass.  With ``cse=True`` (the default) members that declare
    :meth:`~repro.core.mapreduce.MapReduceProgram.requires` pool their raw
    accumulators: each shared accumulator (count, Σx, Σx², ...) is folded
    ONCE per chunk — via :func:`shared_map_chunk`, which also reuses the
    masked cast and the square across moments — and ``finalize`` projects
    every member's result from the pool.  Members without ``requires()``
    (histogram, the exact int32 count) keep their private folds alongside.

    The partial is ``{"shared": {dtype: {name: acc}}, "private": (...)}``;
    shared accumulators merge by sum, so the fusion is additive (single
    ``psum``) unless a *private* member is non-additive.  ``cse=False``
    recovers the naive product (every member folds the chunk itself) — kept
    for the FLOP-comparison bench and as an escape hatch.
    """

    programs: Tuple[MapReduceProgram, ...] = ()
    cse: bool = True

    def __post_init__(self):
        if not self.programs:
            raise ValueError("FusedProgram needs at least one program")
        object.__setattr__(self, "programs", tuple(self.programs))
        # role per member: the index into the private tuple, or the shared
        # pool key (accumulator dtype) it projects from
        private: Tuple[MapReduceProgram, ...] = ()
        roles = []
        groups: Dict[str, Tuple[str, ...]] = {}
        for p in self.programs:
            req = p.requires() if self.cse else ()
            if req:
                dt = str(jnp.dtype(getattr(p, "acc_dtype", jnp.float32)))
                merged = set(groups.get(dt, ())) | set(req)
                groups[dt] = tuple(n for n in SHARED_ACCUMULATORS
                                   if n in merged)
                roles.append(("shared", dt))
            else:
                roles.append(("private", len(private)))
                private = private + (p,)
        object.__setattr__(self, "_roles", tuple(roles))
        object.__setattr__(self, "_private", private)
        object.__setattr__(self, "_shared_groups",
                           tuple(sorted(groups.items())))
        object.__setattr__(
            self, "additive", all(p.additive for p in private))

    def zero(self, row_shape, dtype):
        shared = {dt: shared_zero(names, row_shape, jnp.dtype(dt))
                  for dt, names in self._shared_groups}
        return {"shared": shared,
                "private": tuple(p.zero(row_shape, dtype)
                                 for p in self._private)}

    def map_chunk(self, rows, valid):
        shared = {dt: shared_map_chunk(rows, valid, names, jnp.dtype(dt))
                  for dt, names in self._shared_groups}
        return {"shared": shared,
                "private": tuple(p.map_chunk(rows, valid)
                                 for p in self._private)}

    def merge(self, a, b):
        shared = jax.tree.map(jnp.add, a["shared"], b["shared"])
        private = tuple(p.merge(x, y) for p, x, y in
                        zip(self._private, a["private"], b["private"]))
        return {"shared": shared, "private": private}

    def merge_ops_for(self, partial):
        # compose per-leaf operators member by member: shared pool leaves
        # always sum; each private member contributes its own declaration.
        # Leaf order follows tree_flatten of {"private": ..., "shared":
        # ...} — dict keys sort, so private leaves come first.
        member_ops = [p.merge_ops_for(q) for p, q in
                      zip(self._private, partial["private"])]
        if all(ops is None for ops in member_ops):
            return None
        flat = []
        for q, ops in zip(partial["private"], member_ops):
            flat.extend(ops if ops is not None
                        else ["sum"] * len(jax.tree_util.tree_leaves(q)))
        flat.extend(["sum"] * len(jax.tree_util.tree_leaves(
            partial["shared"])))
        return flat

    def finalize(self, partial):
        out = []
        for p, (kind, ref) in zip(self.programs, self._roles):
            if kind == "shared":
                out.append(p.finalize_shared(partial["shared"][ref]))
            else:
                out.append(p.finalize(partial["private"][ref]))
        return tuple(out)

    def shared_fold_spec(self):
        # kernel-eligible iff the fusion is pure pool: no private member
        # folds alongside, and the pool is the kernel's fp32 accumulator
        if self._private or len(self._shared_groups) != 1:
            return None
        dt, names = self._shared_groups[0]
        if dt != "float32":
            return None
        return names

    def partial_from_shared(self, shared):
        dt, _ = self._shared_groups[0]
        return {"shared": {dt: dict(shared)}, "private": ()}


def grouped_shared_map_chunk(rows: jax.Array, gmask: jax.Array,
                             names: Tuple[str, ...], acc_dtype
                             ) -> Dict[str, jax.Array]:
    """Fold one chunk into per-group shared accumulators by segment-sum.

    ``gmask`` is the ``[G, eta]`` per-group row mask (rows of a chunk are
    partitioned across groups; invalid rows belong to no group).  Each raw
    power of ``x`` is materialized ONCE and contracted against the group
    weights in a single ``einsum`` — the grouped analogue of the CSE in
    :func:`shared_map_chunk`: G groups share one masked cast, one square,
    one cube, however many member statistics project from the pool.
    """
    out: Dict[str, jax.Array] = {}
    w = gmask.astype(acc_dtype)                      # [G, eta] 0/1 weights
    if "count" in names:
        out["count"] = w.sum(axis=1)
    if any(n in names for n in ("s1", "s2", "s3", "s4")):
        # zero rows no group claims BEFORE raising powers, exactly like the
        # ungrouped _masked path: a NaN/Inf payload in a masked-off row
        # must not poison the segment sums (0-weight × NaN is NaN)
        x = _masked(rows, gmask.any(axis=0), acc_dtype)  # [eta, ...]

        def seg(v):                                  # [G, ...] segment sums
            return jnp.einsum("ge,e...->g...", w, v)

        if "s1" in names:
            out["s1"] = seg(x)
        if any(n in names for n in ("s2", "s3", "s4")):
            x2 = x * x
            if "s2" in names:
                out["s2"] = seg(x2)
            if "s3" in names:
                out["s3"] = seg(x2 * x)
            if "s4" in names:
                out["s4"] = seg(x2 * x2)
    return out


@dataclasses.dataclass
class GroupedResult:
    """Per-group finalized statistics from a ``group_by`` plan.

    ``keys[g]`` labels row ``g`` of every leaf in ``values`` (leaves carry a
    leading group axis).  ``keys`` are the distinct group-key values among
    the selected rows, ascending — the same order ``np.unique`` gives a
    NumPy groupby oracle.
    """

    keys: np.ndarray               # [G] unique group-key values, sorted
    values: Any                    # result tree; leaves are [G, ...]

    def __len__(self) -> int:
        return len(self.keys)

    def index_of(self, key) -> int:
        if self.keys.dtype == object:      # composite keys: tuple labels
            want = tuple(key)
            for g, k in enumerate(self.keys):
                if tuple(k) == want:
                    return g
            raise KeyError(f"no group with key {key!r}")
        pos = int(np.searchsorted(self.keys, key))
        if pos >= len(self.keys) or self.keys[pos] != key:
            raise KeyError(f"no group with key {key!r}")
        return pos

    def group(self, key) -> Any:
        """The result tree of one group (leaves indexed at its row)."""
        g = self.index_of(key)
        return jax.tree.map(lambda x: x[g], self.values)

    def asdict(self) -> Dict[Any, Any]:
        """``{group key: result tree}`` with native-Python scalar keys."""
        return {k.item() if hasattr(k, "item") else k: jax.tree.map(
            lambda x, g=g: x[g], self.values)
            for g, k in enumerate(self.keys)}


@dataclasses.dataclass(frozen=True)
class GroupedProgram(MapReduceProgram):
    """Group-aware lift of a statistic program: one fold, G answers.

    Wraps ``base`` (a single program or a :class:`FusedProgram`) so every
    accumulator gains a leading group axis.  ``map_chunk`` receives the
    ``[G, eta]`` per-group row mask the engine derives from the chunk's
    group ids:

    - members in the CSE pool fold through
      :func:`grouped_shared_map_chunk` — the raw power sums are segment-
      summed by group id, so each power is computed once per chunk however
      many groups or member statistics there are;
    - private members (histogram, the exact int32 count) ``vmap`` their own
      fold over the group masks.

    Additivity is inherited: a grouped additive program still merges by
    elementwise sum (now ``[G, ...]``-shaped), so the tree-reduce/psum merge
    path stays available.  ``finalize`` projects per-group results with the
    base program's own finalizers (``vmap`` over the group axis).
    """

    base: MapReduceProgram = None  # type: ignore[assignment]
    num_groups: int = 0

    def __post_init__(self):
        if self.base is None:
            raise ValueError("GroupedProgram needs a base program")
        if self.num_groups < 0:
            raise ValueError(f"num_groups must be >= 0, got {self.num_groups}")
        fused = (self.base if isinstance(self.base, FusedProgram)
                 else FusedProgram((self.base,)))
        object.__setattr__(self, "_fused", fused)
        object.__setattr__(self, "_single",
                           not isinstance(self.base, FusedProgram))
        object.__setattr__(self, "additive", fused.additive)

    def cache_key(self) -> Tuple:
        return ("Grouped", int(self.num_groups), self.base.cache_key())

    def zero(self, row_shape, dtype):
        G = self.num_groups
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (G,) + x.shape),
            self._fused.zero(row_shape, dtype))

    def map_chunk(self, rows, gmask):
        # gmask: [G, eta] bool — disjoint per-group row masks for the chunk
        shared = {dt: grouped_shared_map_chunk(rows, gmask, names,
                                               jnp.dtype(dt))
                  for dt, names in self._fused._shared_groups}
        private = tuple(
            jax.vmap(p.map_chunk, in_axes=(None, 0))(rows, gmask)
            for p in self._fused._private)
        return {"shared": shared, "private": private}

    def merge(self, a, b):
        if self.additive:
            # per-leaf sum/max per the fused declaration — the group axis
            # doesn't change leaf order or the elementwise operator
            return _merge_leafwise(a, b, self._fused.merge_ops_for(a))
        return jax.vmap(self._fused.merge)(a, b)

    def merge_ops_for(self, partial):
        # the grouped partial is the fused partial with a leading group
        # axis on every leaf: same treedef, same per-leaf operators
        return self._fused.merge_ops_for(partial)

    def finalize(self, partial):
        out = jax.vmap(self._fused.finalize)(partial)
        return out[0] if self._single else out

    def shared_fold_spec(self):
        # the grouped partial is the fused partial with a leading group
        # axis on every leaf — exactly what the kernel's [G, F] pool is
        return self._fused.shared_fold_spec()

    def partial_from_shared(self, shared):
        return self._fused.partial_from_shared(shared)


@dataclasses.dataclass(frozen=True)
class HistogramProgram(MapReduceProgram):
    """Global intensity histogram with fixed bin edges (additive)."""

    lo: float = 0.0
    hi: float = 1.0
    bins: int = 64
    additive = True

    def zero(self, row_shape, dtype):
        return {"hist": jnp.zeros((self.bins,), jnp.float32)}

    def map_chunk(self, rows, valid):
        x = rows.reshape(rows.shape[0], -1)
        scaled = (x - self.lo) / (self.hi - self.lo) * self.bins
        idx = jnp.clip(scaled.astype(jnp.int32), 0, self.bins - 1)
        onehot = jax.nn.one_hot(idx, self.bins, dtype=jnp.float32)
        w = valid.astype(jnp.float32)[:, None, None]
        return {"hist": (onehot * w).sum(axis=(0, 1))}

    def merge(self, a, b):
        return jax.tree.map(jnp.add, a, b)

    def finalize(self, p):
        return p["hist"]


# ---------------------------------------------------------------------------
# approximate sketches: mergeable programs with provable error bounds
# ---------------------------------------------------------------------------
#
# All three sketches below keep their state in fixed-shape int32 arrays whose
# merge is a per-leaf elementwise sum or max.  That buys two properties the
# exact monoids already enjoy, for free:
#
# - they ride every engine fast path (block-partial caching + .npz spill,
#   grouped lifting, the psum/pmax tree reduce, frontend coalescing);
# - their MERGE LAW is exact: integer sums and maxes are associative and
#   commutative with no rounding, so funnel vs tree, owner pre-merge or not,
#   any owner count — the merged sketch state is BIT-IDENTICAL, and every
#   finalized estimate (a deterministic function of that state) is too.
#
# Determinism: all hashing is seeded murmur-fmix32 over canonicalized
# float32 bit patterns (see _element_keys), identical on device and host.


@dataclasses.dataclass(frozen=True)
class CountMinProgram(MapReduceProgram):
    """Count-min frequency sketch (Cormode–Muthukrishnan) over the selected
    elements — the heavy-hitters program.

    ``depth`` hash rows × ``width`` int32 counters plus the exact item count
    ``n``.  Point estimates (:meth:`estimate`) never undercount, and
    overcount by at most ``(e / width) · n`` with probability
    ``1 - e^-depth`` per queried item (the classic ε–δ bound with
    ``ε = e / width``, ``δ = e^-depth``).  :meth:`heavy_hitters` screens a
    candidate set against a ``phi · n`` threshold: no true heavy hitter is
    ever missed (one-sided error)."""

    depth: int = 4
    width: int = 1024
    seed: int = 0
    additive = True

    def __post_init__(self):
        if self.depth < 1:
            raise ValueError(f"depth must be >= 1, got {self.depth}")
        if self.width < 2 or (self.width & (self.width - 1)):
            raise ValueError(
                f"width must be a power of two >= 2, got {self.width}")
        object.__setattr__(self, "_seeds",
                           _derive_seeds(self.seed, self.depth))

    def zero(self, row_shape, dtype):
        return {"cm": jnp.zeros((self.depth, self.width), jnp.int32),
                "n": jnp.zeros((), jnp.int32)}

    def map_chunk(self, rows, valid):
        keys, ok = _element_keys(rows, valid)
        w = ok.astype(jnp.int32)
        seeds = jnp.asarray(self._seeds, jnp.uint32)
        # all depth lanes in ONE flat scatter-add: lane d writes into the
        # [d*width, (d+1)*width) slice (identical counts to a per-lane
        # scatter — int32 adds — with depth× fewer device ops)
        idx = (_fmix32(keys[None, :] ^ seeds[:, None])
               & jnp.uint32(self.width - 1)).astype(jnp.int32)
        flat = (idx + jnp.arange(self.depth, dtype=jnp.int32)[:, None]
                * self.width).reshape(-1)
        wts = jnp.broadcast_to(w, (self.depth,) + w.shape).reshape(-1)
        cm = jnp.zeros((self.depth * self.width,), jnp.int32
                       ).at[flat].add(wts)
        return {"cm": cm.reshape(self.depth, self.width), "n": w.sum()}

    def merge(self, a, b):
        return jax.tree.map(jnp.add, a, b)

    def finalize(self, p):
        return {"cm": p["cm"], "n": p["n"]}

    # --- host-side query helpers (operate on the finalized result) -----

    def _host_indices(self, values) -> np.ndarray:
        keys = host_element_keys(values)                      # [M]
        seeds = np.asarray(self._seeds, np.uint32)[:, None]   # [depth, 1]
        return (_host_fmix32(keys[None, :] ^ seeds)
                & np.uint32(self.width - 1)).astype(np.int64)

    def estimate(self, result, values) -> np.ndarray:
        """Frequency upper-estimates for ``values`` — min over the depth
        rows; exact lower bound: ``estimate >= true frequency`` always."""
        cm = np.asarray(result["cm"])
        idx = self._host_indices(values)                      # [depth, M]
        rows = cm[np.arange(self.depth)[:, None], idx]
        return rows.min(axis=0).astype(np.int64)

    def heavy_hitters(self, result, values, phi: float):
        """``(value, estimate)`` pairs from ``values`` whose estimated
        frequency reaches ``phi * n``, descending.  One-sided: every true
        phi-heavy hitter in ``values`` is returned (estimates never
        undercount); false positives are bounded by the ε·n overcount."""
        vals = np.asarray(values, np.float32).reshape(-1)
        est = self.estimate(result, vals)
        thresh = float(phi) * float(np.asarray(result["n"]))
        keep = est >= thresh
        order = np.argsort(-est[keep], kind="stable")
        return [(float(v), int(e))
                for v, e in zip(vals[keep][order], est[keep][order])]

    def error_bound(self, n: int) -> Tuple[float, float]:
        """The documented (ε·n overcount, δ failure probability) pair for
        one point query against a sketch holding ``n`` items."""
        return (np.e / self.width) * float(n), float(np.exp(-self.depth))


@dataclasses.dataclass(frozen=True)
class HyperLogLogProgram(MapReduceProgram):
    """HyperLogLog distinct-count sketch (Flajolet et al.) over the selected
    elements' canonicalized float32 values.

    ``m = 2^p`` int32 registers; each item's hash picks a register with its
    top ``p`` bits and offers ``1 + leading-zeros`` of the rest.  Registers
    merge by elementwise MAX — declared through
    :meth:`~repro.core.mapreduce.MapReduceProgram.merge_ops_for`, so the
    engine's additive fast paths reduce with ``pmax`` / ``max(axis=0)``
    instead of sum while everything else (caching, spill, grouping, tree
    reduce) is inherited unchanged.  Relative standard error of the
    estimate is ``1.04 / sqrt(m)``; the linear-counting correction handles
    the small-cardinality regime."""

    p: int = 12
    seed: int = 0
    additive = True

    def __post_init__(self):
        if not 4 <= self.p <= 16:
            raise ValueError(f"p must be in [4, 16], got {self.p}")
        object.__setattr__(self, "_seed32", _derive_seeds(self.seed, 1)[0])

    def merge_ops_for(self, partial):
        return ["max"] * len(jax.tree_util.tree_leaves(partial))

    def zero(self, row_shape, dtype):
        return {"regs": jnp.zeros((1 << self.p,), jnp.int32)}

    def map_chunk(self, rows, valid):
        keys, ok = _element_keys(rows, valid)
        h = _fmix32(keys ^ jnp.uint32(self._seed32))
        m = 1 << self.p
        idx = (h >> jnp.uint32(32 - self.p)).astype(jnp.int32)
        tail = h << jnp.uint32(self.p)          # the 32-p low hash bits
        rank = jnp.minimum(jax.lax.clz(tail),
                           32 - self.p).astype(jnp.int32) + 1
        rank = jnp.where(ok, rank, 0)           # invalid items offer nothing
        return {"regs": jnp.zeros((m,), jnp.int32).at[idx].max(rank)}

    def merge(self, a, b):
        return jax.tree.map(jnp.maximum, a, b)

    def finalize(self, p_):
        m = 1 << self.p
        alpha = {16: 0.673, 32: 0.697, 64: 0.709}.get(
            m, 0.7213 / (1.0 + 1.079 / m))
        regs = p_["regs"]
        raw = (alpha * m * m
               / jnp.sum(jnp.exp2(-regs.astype(jnp.float32))))
        zeros = jnp.sum(regs == 0).astype(jnp.float32)
        small = m * jnp.log(m / jnp.maximum(zeros, 1.0))
        est = jnp.where((raw <= 2.5 * m) & (zeros > 0), small, raw)
        return {"estimate": est, "registers": regs}

    def std_error(self) -> float:
        """Documented relative standard error: ``1.04 / sqrt(m)``."""
        return 1.04 / float(np.sqrt(1 << self.p))


@dataclasses.dataclass(frozen=True)
class QuantileSketchProgram(MapReduceProgram):
    """Dyadic count-min rank/quantile sketch over a quantized universe.

    Values in ``[lo, hi)`` quantize to ``U = 2^log2_universe`` buckets; each
    item increments one count-min row per dyadic level (an item at bucket
    ``b`` lives in interval ``b >> lvl`` of level ``lvl``).  A rank query
    decomposes a prefix into at most ``log2_universe`` dyadic intervals and
    sums their count-min estimates; quantiles descend the dyadic trie with
    the same estimates.  All state is int32 counts, so — unlike a real
    KLL/t-digest, whose compactions make the result depend on merge order —
    the merged sketch is bit-identical under ANY merge tree, which is the
    engine's merge-law contract.

    **Dense fast path.**  Hashing into ``depth × width`` counters only pays
    off when the universe exceeds the table: for ``U <= depth * width`` the
    exact per-bucket counts fit in the SAME memory with strictly better
    accuracy (zero rank error) and a fold of one scatter entry per item
    instead of ``log2_universe · depth``.  Below that threshold the program
    keeps the exact ``[U]`` histogram (``dense`` is True,
    :meth:`rank_error_bound` returns 0); the count-min engages above it.
    Both modes share the quantized-universe semantics, the additive int32
    merge, and therefore the exact merge law.

    Error decomposition (documented, asserted in tests):

    - rank: dense mode is exact over the quantized items.  In count-min
      mode each lookup overcounts by at most ``(e / width) · n`` with
      probability ``1 - e^-depth``; a prefix sums at most
      ``log2_universe`` lookups, so the rank error is bounded by
      ``log2_universe · (e / width) · n`` w.h.p. (never an undercount —
      count-min is one-sided).
    - value: quantization adds at most one bucket width
      ``(hi - lo) / U`` to the returned quantile value (both modes).
    """

    lo: float = 0.0
    hi: float = 1.0
    log2_universe: int = 12
    depth: int = 4
    width: int = 2048
    probes: Tuple[float, ...] = (0.5,)
    seed: int = 0
    additive = True

    def __post_init__(self):
        if not self.hi > self.lo:
            raise ValueError(f"need hi > lo, got [{self.lo}, {self.hi})")
        if not 1 <= self.log2_universe <= 20:
            raise ValueError(
                f"log2_universe must be in [1, 20], got {self.log2_universe}")
        if self.depth < 1:
            raise ValueError(f"depth must be >= 1, got {self.depth}")
        if self.width < 2 or (self.width & (self.width - 1)):
            raise ValueError(
                f"width must be a power of two >= 2, got {self.width}")
        probes = tuple(float(q) for q in self.probes)
        if not probes or any(not 0.0 < q < 1.0 for q in probes):
            raise ValueError(f"probes must lie in (0, 1), got {probes}")
        object.__setattr__(self, "probes", probes)
        # exact dyadic counts beat the CM whenever they fit its memory
        object.__setattr__(self, "dense",
                           (1 << self.log2_universe)
                           <= self.depth * self.width)
        # one decorrelated seed per (level, depth-row)
        flat = _derive_seeds(self.seed, self.log2_universe * self.depth)
        object.__setattr__(
            self, "_seeds",
            tuple(flat[lvl * self.depth:(lvl + 1) * self.depth]
                  for lvl in range(self.log2_universe)))

    # --- shared bucket/hash arithmetic --------------------------------

    def _buckets(self, x, xp):
        """Quantize values to universe buckets (jnp or np namespace)."""
        U = 1 << self.log2_universe
        scaled = (x - self.lo) / (self.hi - self.lo) * U
        scaled = xp.nan_to_num(scaled, nan=0.0, posinf=float(U - 1),
                               neginf=0.0)
        return xp.clip(scaled.astype(xp.int32), 0, U - 1)

    def zero(self, row_shape, dtype):
        if self.dense:
            return {"cm": jnp.zeros((1 << self.log2_universe,), jnp.int32),
                    "n": jnp.zeros((), jnp.int32)}
        return {"cm": jnp.zeros((self.log2_universe, self.depth, self.width),
                                jnp.int32),
                "n": jnp.zeros((), jnp.int32)}

    def map_chunk(self, rows, valid):
        x = rows.reshape(rows.shape[0], -1)
        v = jnp.broadcast_to(valid.astype(bool)[:, None], x.shape)
        xf = jnp.where(v, x, self.lo).astype(jnp.float32)
        b = self._buckets(xf, jnp).reshape(-1)                # [M]
        w = v.reshape(-1).astype(jnp.int32)
        if self.dense:                     # exact bucket counts, 1 scatter
            U = 1 << self.log2_universe
            return {"cm": jnp.zeros((U,), jnp.int32).at[b].add(w),
                    "n": w.sum()}
        L, D = self.log2_universe, self.depth
        lvls = jnp.arange(L, dtype=jnp.int32)
        j = jnp.right_shift(b[None, :], lvls[:, None]).astype(jnp.uint32)
        seeds = jnp.asarray(self._seeds, jnp.uint32)          # [L, D]
        # every (level, depth-row) lane in ONE flat scatter-add — counts
        # identical to per-lane scatters, L·D× fewer device ops
        idx = (_fmix32(j[:, None, :] ^ seeds[:, :, None])
               & jnp.uint32(self.width - 1)).astype(jnp.int32)  # [L, D, M]
        lane = jnp.arange(L * D, dtype=jnp.int32).reshape(L, D, 1)
        flat = (idx + lane * self.width).reshape(-1)
        wts = jnp.broadcast_to(w, (L, D) + w.shape).reshape(-1)
        cm = jnp.zeros((L * D * self.width,), jnp.int32).at[flat].add(wts)
        return {"cm": cm.reshape(L, D, self.width), "n": w.sum()}

    def merge(self, a, b):
        return jax.tree.map(jnp.add, a, b)

    def _point_est(self, cm, lvl: int, j):
        """Count-min estimate for interval ``j`` of level ``lvl`` (traced)."""
        seeds = jnp.asarray(self._seeds[lvl], jnp.uint32)
        idx = (_fmix32(j.astype(jnp.uint32) ^ seeds)
               & jnp.uint32(self.width - 1)).astype(jnp.int32)
        return jnp.min(cm[lvl, jnp.arange(self.depth), idx])

    def finalize(self, p):
        """Estimate each probe quantile — dense mode reads the exact rank
        off the bucket cumsum; count-min mode descends the dyadic trie in
        ``log2_universe`` static steps of lookups.  Fully jittable;
        ``n == 0`` finalizes to NaN quantiles."""
        cm, n = p["cm"], p["n"]
        L = self.log2_universe
        U = 1 << L
        outs = []
        if self.dense:
            cum = jnp.cumsum(cm)
            for q in self.probes:
                r = jnp.maximum(
                    jnp.ceil(q * n.astype(jnp.float32)).astype(jnp.int32), 1)
                b = jnp.minimum(jnp.searchsorted(cum, r, side="left"),
                                U - 1).astype(jnp.int32)
                val = self.lo + (b.astype(jnp.float32) + 0.5) \
                    * (self.hi - self.lo) / U
                outs.append(jnp.where(n > 0, val, jnp.nan))
            return {"quantiles": jnp.stack(outs), "n": n, "cm": cm}
        for q in self.probes:
            r = jnp.maximum(
                jnp.ceil(q * n.astype(jnp.float32)).astype(jnp.int32), 1)
            b = jnp.zeros((), jnp.int32)
            cum = jnp.zeros((), jnp.int32)
            for lvl in range(L - 1, -1, -1):
                c = self._point_est(cm, lvl, b >> lvl)
                go_right = cum + c < r
                cum = jnp.where(go_right, cum + c, cum)
                b = jnp.where(go_right, b + (1 << lvl), b)
            val = self.lo + (b.astype(jnp.float32) + 0.5) \
                * (self.hi - self.lo) / U
            outs.append(jnp.where(n > 0, val, jnp.nan))
        return {"quantiles": jnp.stack(outs), "n": n, "cm": cm}

    # --- host-side query helpers (operate on the finalized result) -----

    def rank_estimate(self, result, values) -> np.ndarray:
        """Estimated rank (count of items strictly below each value's
        bucket) — exact in dense mode; in count-min mode the dyadic
        decomposition never undercounts and overcounts by at most
        ``log2_universe * (e/width) * n`` w.h.p."""
        cm = np.asarray(result["cm"])
        b = self._buckets(np.asarray(values, np.float32).reshape(-1), np)
        if self.dense:
            cum = np.cumsum(cm.astype(np.int64))
            return np.where(b > 0, cum[np.maximum(b, 1) - 1], 0)
        rank = np.zeros(b.shape, np.int64)
        for lvl in range(self.log2_universe):
            sel = (b >> lvl) & 1 == 1
            if not sel.any():
                continue
            j = ((b[sel].astype(np.int64) >> (lvl + 1)) << 1).astype(np.uint32)
            seeds = np.asarray(self._seeds[lvl], np.uint32)[:, None]
            idx = (_host_fmix32(j[None, :] ^ seeds)
                   & np.uint32(self.width - 1)).astype(np.int64)
            ests = cm[lvl][np.arange(self.depth)[:, None], idx].min(axis=0)
            rank[sel] += ests
        return rank

    def rank_error_bound(self, n: int) -> float:
        """Documented w.h.p. rank-error bound for one rank query — 0 in
        dense mode (exact over the quantized items)."""
        if self.dense:
            return 0.0
        return self.log2_universe * (np.e / self.width) * float(n)

    def value_resolution(self) -> float:
        """Quantization granularity: one universe bucket width."""
        return (self.hi - self.lo) / (1 << self.log2_universe)
