"""Summary-statistic MapReduce programs (§2.2's workload family).

The paper's exemplar is population-template construction: averaging 5,153
registered T1 volumes with ANTS ``AverageImages``.  That is a mean fold; this
module provides it plus the statistics a population study actually asks for
(variance via Chan/Welford parallel merge, higher moments, histograms), all as
:class:`~repro.core.mapreduce.MapReduceProgram` monoids so the same engine,
chunk model and table scheme apply.

Accumulation dtype defaults to float32 (TPU-native); pass ``acc_dtype=
jnp.float64`` on CPU for reference-grade accumulation.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.mapreduce import MapReduceProgram


def _masked(rows: jax.Array, valid: jax.Array, acc_dtype) -> jax.Array:
    """Zero out invalid rows and cast to the accumulator dtype."""
    v = valid.reshape(valid.shape + (1,) * (rows.ndim - 1))
    return jnp.where(v, rows, 0).astype(acc_dtype)


@dataclasses.dataclass(frozen=True)
class MeanProgram(MapReduceProgram):
    """ANTS AverageImages analogue: elementwise mean over the population."""

    acc_dtype: jnp.dtype = jnp.float32
    additive = True

    def zero(self, row_shape, dtype):
        return {
            "sum": jnp.zeros(row_shape, self.acc_dtype),
            "count": jnp.zeros((), self.acc_dtype),
        }

    def map_chunk(self, rows, valid):
        return {
            "sum": _masked(rows, valid, self.acc_dtype).sum(axis=0),
            "count": valid.sum().astype(self.acc_dtype),
        }

    def merge(self, a, b):
        return jax.tree.map(jnp.add, a, b)

    def finalize(self, p):
        return p["sum"] / jnp.maximum(p["count"], 1)


@dataclasses.dataclass(frozen=True)
class VarianceProgram(MapReduceProgram):
    """Elementwise population mean/variance with Chan's parallel merge.

    Deliberately *non-additive* (partials carry running means), exercising the
    engine's all-gather + fold reduce path and demonstrating that arbitrary
    associative statistics ride the same colocation machinery.
    """

    acc_dtype: jnp.dtype = jnp.float32
    additive = False

    def zero(self, row_shape, dtype):
        return {
            "count": jnp.zeros((), self.acc_dtype),
            "mean": jnp.zeros(row_shape, self.acc_dtype),
            "m2": jnp.zeros(row_shape, self.acc_dtype),
        }

    def map_chunk(self, rows, valid):
        x = _masked(rows, valid, self.acc_dtype)
        n = valid.sum().astype(self.acc_dtype)
        safe_n = jnp.maximum(n, 1)
        mean = x.sum(axis=0) / safe_n
        v = valid.reshape(valid.shape + (1,) * (rows.ndim - 1))
        centered = jnp.where(v, x - mean, 0)
        m2 = (centered * centered).sum(axis=0)
        return {"count": n, "mean": mean, "m2": m2}

    def merge(self, a, b):
        na, nb = a["count"], b["count"]
        n = na + nb
        safe_n = jnp.maximum(n, 1)
        delta = b["mean"] - a["mean"]
        mean = a["mean"] + delta * (nb / safe_n)
        m2 = a["m2"] + b["m2"] + (delta * delta) * (na * nb / safe_n)
        # empty-side guards: merging with a zero partial must be identity
        mean = jnp.where(na == 0, b["mean"], jnp.where(nb == 0, a["mean"], mean))
        m2 = jnp.where(na == 0, b["m2"], jnp.where(nb == 0, a["m2"], m2))
        return {"count": n, "mean": mean, "m2": m2}

    def finalize(self, p):
        var = p["m2"] / jnp.maximum(p["count"], 1)
        return {"mean": p["mean"], "var": var, "count": p["count"]}


@dataclasses.dataclass(frozen=True)
class MomentsProgram(MapReduceProgram):
    """Raw moments 1..4 (additive) → mean/var/skew/kurtosis per voxel."""

    acc_dtype: jnp.dtype = jnp.float32
    additive = True

    def zero(self, row_shape, dtype):
        z = jnp.zeros(row_shape, self.acc_dtype)
        return {"count": jnp.zeros((), self.acc_dtype),
                "s1": z, "s2": z, "s3": z, "s4": z}

    def map_chunk(self, rows, valid):
        x = _masked(rows, valid, self.acc_dtype)
        x2 = x * x
        return {
            "count": valid.sum().astype(self.acc_dtype),
            "s1": x.sum(axis=0),
            "s2": x2.sum(axis=0),
            "s3": (x2 * x).sum(axis=0),
            "s4": (x2 * x2).sum(axis=0),
        }

    def merge(self, a, b):
        return jax.tree.map(jnp.add, a, b)

    def finalize(self, p):
        n = jnp.maximum(p["count"], 1)
        m = p["s1"] / n
        ex2 = p["s2"] / n
        var = jnp.maximum(ex2 - m * m, 0)
        std = jnp.sqrt(jnp.maximum(var, 1e-30))
        m3 = p["s3"] / n - 3 * m * ex2 + 2 * m**3
        m4 = (p["s4"] / n - 4 * m * (p["s3"] / n) + 6 * m * m * ex2 - 3 * m**4)
        return {
            "mean": m,
            "var": var,
            "skew": m3 / std**3,
            "kurtosis": m4 / jnp.maximum(var * var, 1e-30),
            "count": p["count"],
        }


@dataclasses.dataclass(frozen=True)
class CountProgram(MapReduceProgram):
    """Row count (additive) — the cheapest statistic, and an end-to-end
    oracle: a fold over a block-assembled layout must count exactly the
    slots the scan's row mask selected, so the differential harness checks
    it against ``QueryStats.rows_selected`` (a mask/padding bug anywhere in
    the block plumbing shows up here first).

    Accumulates in int32 (``psum`` is exact on integers; int64 would need
    x64 mode), not the float32 the statistic programs default to — callers
    assert exact equality and float32 loses integer exactness past 2^24
    rows."""

    acc_dtype: jnp.dtype = jnp.int32
    additive = True

    def zero(self, row_shape, dtype):
        return {"count": jnp.zeros((), self.acc_dtype)}

    def map_chunk(self, rows, valid):
        return {"count": valid.sum().astype(self.acc_dtype)}

    def merge(self, a, b):
        return jax.tree.map(jnp.add, a, b)

    def finalize(self, p):
        return p["count"]


@dataclasses.dataclass(frozen=True)
class FusedProgram(MapReduceProgram):
    """The monoid product of N statistic programs — one pass, N answers.

    ``GridQuery`` fuses every ``.map(program)`` on a plan into one of these,
    so mean+variance+histogram share a single gather and a single
    ``shard_map`` fold: partials are tuples, merged component-wise.  The
    fused program is additive (single-``psum`` reduce) only when every
    component is; one non-additive member moves the whole tuple onto the
    all-gather path, which is still one executable and one data pass.
    """

    programs: Tuple[MapReduceProgram, ...] = ()

    def __post_init__(self):
        if not self.programs:
            raise ValueError("FusedProgram needs at least one program")
        object.__setattr__(self, "programs", tuple(self.programs))
        object.__setattr__(
            self, "additive", all(p.additive for p in self.programs))

    def zero(self, row_shape, dtype):
        return tuple(p.zero(row_shape, dtype) for p in self.programs)

    def map_chunk(self, rows, valid):
        return tuple(p.map_chunk(rows, valid) for p in self.programs)

    def merge(self, a, b):
        return tuple(p.merge(x, y) for p, x, y in zip(self.programs, a, b))

    def finalize(self, partial):
        return tuple(p.finalize(x) for p, x in zip(self.programs, partial))


@dataclasses.dataclass(frozen=True)
class HistogramProgram(MapReduceProgram):
    """Global intensity histogram with fixed bin edges (additive)."""

    lo: float = 0.0
    hi: float = 1.0
    bins: int = 64
    additive = True

    def zero(self, row_shape, dtype):
        return {"hist": jnp.zeros((self.bins,), jnp.float32)}

    def map_chunk(self, rows, valid):
        x = rows.reshape(rows.shape[0], -1)
        scaled = (x - self.lo) / (self.hi - self.lo) * self.bins
        idx = jnp.clip(scaled.astype(jnp.int32), 0, self.bins - 1)
        onehot = jax.nn.one_hot(idx, self.bins, dtype=jnp.float32)
        w = valid.astype(jnp.float32)[:, None, None]
        return {"hist": (onehot * w).sum(axis=(0, 1))}

    def merge(self, a, b):
        return jax.tree.map(jnp.add, a, b)

    def finalize(self, p):
        return p["hist"]
