"""Deterministic fault injection + retry/backoff for the whole grid stack.

The paper's premise is that the Hadoop/HBase substrate gives colocation
*plus* fault tolerance on a heterogeneous grid — tasks re-execute, region
servers fail over, corrupted files are re-read from replicas.  Our JAX
reproduction has no substrate underneath it, so this module supplies the
two halves the substrate provided:

1. :class:`FaultInjector` — a seeded, deterministic chaos harness.  A
   fault *plan* is a list of :class:`FaultRule`\\ s over named **sites**
   (the points where the stack touches something that can fail):

   ========================  ====================================================
   site                      where it fires
   ========================  ====================================================
   ``device_put``            :meth:`GridSession._put_block` host→device commits
   ``gather``                table reads feeding a block fetch
   ``fold``                  :meth:`MapReduceEngine.fold_block` dispatch
   ``spill_write``           BlockStore spill-file writes (blocks + partials)
   ``spill_read``            BlockStore spill-file reads (mmap / ``.npz``)
   ``dispatch``              :class:`GridFrontend` query-group dispatch
   ========================  ====================================================

   and **kinds**: ``transient`` (raises :class:`TransientFaultError` —
   retryable), ``device_lost`` (raises :class:`DeviceLostError` and marks
   the device permanently dead: every later fire against it re-raises),
   ``corrupt`` / ``truncate`` / ``delete`` (mangle the spill file at
   ``path`` — the CRC manifest detects it on read), and ``delay`` (a
   straggler sleep).  Rules fire by per-invocation probability (from one
   seeded PRNG, so a (seed, call-sequence) pair replays exactly) or at
   pinned invocation indices (``after``/``times``), and every fire is
   counted per site and kind.

2. :class:`RetryPolicy` — bounded attempts with exponential backoff and
   *deterministic* jitter (hash of (seed, key, attempt), not wall clock),
   so two runs of the same schedule sleep the same amounts and tests can
   assert exact retry counts.

Recovery semantics the rest of the stack builds on these primitives:
transient faults retry in place; permanent device loss quarantines the
owner and re-homes its regions through the balancer; lost or corrupt
spill files are dropped and losslessly re-derived from the table; the
frontend caps retries by the query deadline and surfaces the whole
attempt history as :class:`QueryFaultedError.chain`.
"""

from __future__ import annotations

import dataclasses
import hashlib
import os
import random
import threading
import time
from typing import Callable, Dict, Iterable, Optional, Sequence, Set, Tuple

# ----------------------------------------------------------------------
# sites and kinds
# ----------------------------------------------------------------------

SITE_DEVICE_PUT = "device_put"
SITE_GATHER = "gather"
SITE_FOLD = "fold"
SITE_SPILL_WRITE = "spill_write"
SITE_SPILL_READ = "spill_read"
SITE_DISPATCH = "dispatch"

SITES = frozenset({
    SITE_DEVICE_PUT, SITE_GATHER, SITE_FOLD,
    SITE_SPILL_WRITE, SITE_SPILL_READ, SITE_DISPATCH,
})

KIND_TRANSIENT = "transient"
KIND_DEVICE_LOST = "device_lost"
KIND_CORRUPT = "corrupt"
KIND_TRUNCATE = "truncate"
KIND_DELETE = "delete"
KIND_DELAY = "delay"

KINDS = frozenset({
    KIND_TRANSIENT, KIND_DEVICE_LOST, KIND_CORRUPT, KIND_TRUNCATE,
    KIND_DELETE, KIND_DELAY,
})

#: file-mangling kinds only make sense where a spill file is in play
_FILE_KINDS = frozenset({KIND_CORRUPT, KIND_TRUNCATE, KIND_DELETE})
_FILE_SITES = frozenset({SITE_SPILL_WRITE, SITE_SPILL_READ})


# ----------------------------------------------------------------------
# exceptions
# ----------------------------------------------------------------------


class FaultError(RuntimeError):
    """Base class for injected (and detected) faults."""


class TransientFaultError(FaultError):
    """A retryable failure: the operation may succeed if repeated."""


class DeviceLostError(FaultError):
    """Permanent loss of one owner device; never retried in place —
    the session quarantines the device and re-homes its regions."""

    def __init__(self, device: Optional[int], message: str = ""):
        super().__init__(
            message or f"device {device} lost (permanent)")
        self.device = device


class SpillCorruptionError(FaultError):
    """A spill file failed its CRC manifest check (or vanished)."""

    def __init__(self, path: str, reason: str = "checksum mismatch"):
        super().__init__(f"corrupt spill file {path}: {reason}")
        self.path = path


class QueryFaultedError(RuntimeError):
    """A frontend query exhausted its retries (or hit an open circuit
    breaker).  ``chain`` carries the per-attempt fault history, oldest
    first, so callers can see *what* kept failing."""

    def __init__(self, message: str,
                 chain: Sequence[BaseException | str] = ()):
        super().__init__(message)
        self.chain: Tuple = tuple(chain)

    def describe(self) -> str:
        steps = "; ".join(
            e if isinstance(e, str) else f"{type(e).__name__}: {e}"
            for e in self.chain)
        return f"{self}: [{steps}]" if self.chain else str(self)


# ----------------------------------------------------------------------
# fault rules / injector
# ----------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FaultRule:
    """One line of a fault plan.

    A rule is eligible on an invocation of its ``site`` when the site's
    call count exceeds ``after``, the rule has fired fewer than ``times``
    times, and (for device-scoped rules) the context device matches; an
    eligible rule then fires with probability ``p`` drawn from the
    injector's single seeded PRNG.  ``p=1.0, after=N, times=1`` pins a
    fault to exactly the (N+1)-th invocation — the deterministic form the
    acceptance walks use for one-shot events like a permanent device
    loss.
    """

    site: str
    kind: str
    p: float = 1.0                 # per-eligible-invocation probability
    after: int = 0                 # skip the first `after` site calls
    times: Optional[int] = None    # max fires (None = unlimited)
    device: Optional[int] = None   # only fire for this device index
    delay_s: float = 0.0           # sleep length for kind="delay"

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(f"unknown fault site {self.site!r}")
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.kind in _FILE_KINDS and self.site not in _FILE_SITES:
            raise ValueError(
                f"kind {self.kind!r} needs a spill site, got {self.site!r}")
        if self.kind == KIND_DEVICE_LOST and self.site in _FILE_SITES:
            raise ValueError("device_lost has no meaning at a spill site")
        if not (0.0 <= self.p <= 1.0):
            raise ValueError(f"p must be in [0, 1], got {self.p}")


def _mangle_file(path: Optional[str], kind: str) -> bool:
    """Apply one file fault in place; False when there is nothing to hit
    (no path / file already gone) so the rule does not count a fire."""
    if not path or not os.path.exists(path):
        return False
    try:
        if kind == KIND_DELETE:
            os.unlink(path)
            return True
        size = os.path.getsize(path)
        if size == 0:
            return False
        if kind == KIND_TRUNCATE:
            with open(path, "r+b") as f:
                f.truncate(max(1, size // 2))
            return True
        # corrupt: XOR a span in the middle so headers usually survive
        # and the CRC — not a parse error — is what catches it
        with open(path, "r+b") as f:
            f.seek(size // 2)
            buf = f.read(min(8, size - size // 2))
            f.seek(size // 2)
            f.write(bytes(b ^ 0xFF for b in buf))
        return True
    except OSError:
        return False


class FaultInjector:
    """Seeded, thread-safe fault firing over a plan of rules.

    Every instrumented operation calls :meth:`fire` with its site and
    context; matching rules raise, sleep, or mangle the spill file.  A
    permanent device loss is *sticky*: the device enters
    :attr:`lost_devices` and every later ``device_put``/``fold`` fire
    against it raises :class:`DeviceLostError` immediately, whatever the
    plan says — that is what "permanent" means.

    Determinism: one PRNG seeded at construction drives every
    probability draw under one lock, so a single-threaded run replays
    bit-for-bit from (seed, plan, call sequence).

    ``on_fire(site, kind)`` is an optional observer — the session wires
    it to the ``faults_injected`` stats counter.
    """

    def __init__(self, rules: Iterable[FaultRule] = (), seed: int = 0):
        self.rules: Tuple[FaultRule, ...] = tuple(rules)
        self._rng = random.Random(int(seed))
        self._lock = threading.Lock()
        self._site_calls: Dict[str, int] = {}
        self._rule_fires: Dict[int, int] = {}
        self.counts: Dict[str, int] = {}       # "site:kind" -> fires
        self.faults_injected = 0
        self.lost_devices: Set[int] = set()
        self.on_fire: Optional[Callable[[str, str], None]] = None

    # ------------------------------------------------------------------

    def _record(self, site: str, kind: str) -> None:
        self.faults_injected += 1
        k = f"{site}:{kind}"
        self.counts[k] = self.counts.get(k, 0) + 1

    def site_calls(self, site: str) -> int:
        with self._lock:
            return self._site_calls.get(site, 0)

    def snapshot(self) -> Dict[str, int]:
        with self._lock:
            return dict(self.counts)

    def fire(self, site: str, *, device: Optional[int] = None,
             path: Optional[str] = None) -> None:
        """One instrumented operation passed this site; maybe fault it.

        Raising kinds (transient, device loss) propagate to the caller,
        which owns the retry/quarantine response.  File kinds mangle
        ``path`` in place and return normally — the CRC manifest turns
        them into detected corruption at read time.  ``delay`` sleeps
        outside the lock.
        """
        if site not in SITES:
            raise ValueError(f"unknown fault site {site!r}")
        fired = []
        sticky_lost = False
        with self._lock:
            n = self._site_calls.get(site, 0) + 1
            self._site_calls[site] = n
            if (device is not None and device in self.lost_devices
                    and site in (SITE_DEVICE_PUT, SITE_FOLD)):
                sticky_lost = True
                self._record(site, KIND_DEVICE_LOST)
            else:
                for i, r in enumerate(self.rules):
                    if r.site != site:
                        continue
                    if r.device is not None and r.device != device:
                        continue
                    if n <= r.after:
                        continue
                    if (r.times is not None
                            and self._rule_fires.get(i, 0) >= r.times):
                        continue
                    if r.p < 1.0 and self._rng.random() >= r.p:
                        continue
                    if r.kind in _FILE_KINDS:
                        # only counts when there was a file to hit
                        if not _mangle_file(path, r.kind):
                            continue
                    self._rule_fires[i] = self._rule_fires.get(i, 0) + 1
                    self._record(site, r.kind)
                    if r.kind == KIND_DEVICE_LOST and device is not None:
                        self.lost_devices.add(device)
                    fired.append(r)
        observer = self.on_fire
        if observer is not None:
            if sticky_lost:
                observer(site, KIND_DEVICE_LOST)
            for r in fired:
                observer(site, r.kind)
        if sticky_lost:
            raise DeviceLostError(device)
        # non-raising kinds first (a delay plus a transient on the same
        # call should still sleep), then raise the most severe
        raise_kind: Optional[FaultRule] = None
        for r in fired:
            if r.kind == KIND_DELAY:
                time.sleep(r.delay_s)
            elif r.kind in (KIND_TRANSIENT, KIND_DEVICE_LOST):
                if raise_kind is None or r.kind == KIND_DEVICE_LOST:
                    raise_kind = r
        if raise_kind is not None:
            if raise_kind.kind == KIND_DEVICE_LOST:
                raise DeviceLostError(device)
            raise TransientFaultError(
                f"injected transient fault at {site}"
                + (f" (device {device})" if device is not None else ""))


# ----------------------------------------------------------------------
# retry policy
# ----------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded retries with exponential backoff and deterministic jitter.

    ``delay_s(attempt, key)`` grows ``base_delay_s * multiplier**attempt``
    and perturbs it by up to ±``jitter`` — the perturbation is a hash of
    ``(seed, key, attempt)``, not a clock or a shared PRNG, so concurrent
    retriers de-synchronize (no thundering herd on the shared table)
    while any single schedule replays exactly.
    """

    max_attempts: int = 3
    base_delay_s: float = 1e-3
    multiplier: float = 2.0
    jitter: float = 0.25
    seed: int = 0

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        if not (0.0 <= self.jitter <= 1.0):
            raise ValueError("jitter must be in [0, 1]")

    def delay_s(self, attempt: int, key: str = "") -> float:
        base = self.base_delay_s * (self.multiplier ** attempt)
        if self.jitter <= 0.0:
            return base
        h = hashlib.blake2b(f"{self.seed}:{key}:{attempt}".encode(),
                            digest_size=8).digest()
        frac = int.from_bytes(h, "little") / float(1 << 64)   # [0, 1)
        return base * (1.0 + self.jitter * (2.0 * frac - 1.0))

    def call(self, fn: Callable[[], "object"], *, key: str = "",
             retry_on: Tuple[type, ...] = (TransientFaultError,),
             on_retry: Optional[Callable[[BaseException, int], None]] = None,
             sleep: Callable[[float], None] = time.sleep):
        """Run ``fn``, retrying on ``retry_on`` up to ``max_attempts``
        total attempts; ``on_retry(exc, attempt)`` observes each retry
        (the stack wires it to the ``retries`` counters).  The final
        failure propagates unwrapped — callers distinguish exhausted
        transients from permanent faults by exception type."""
        attempt = 0
        while True:
            try:
                return fn()
            except retry_on as e:
                attempt += 1
                if attempt >= self.max_attempts:
                    raise
                if on_retry is not None:
                    on_retry(e, attempt)
                sleep(self.delay_s(attempt - 1, key))
