"""GridQuery — lazy scan→filter→map→reduce job plans over the grid.

The paper's criterion (3) is a rowkey/table scheme for *rapid* NoSQL query;
eager one-shot calls (``indexed_query``, ``GridSession.run_where``) could push
the predicate into the gather but still visited every region.  ``GridQuery``
makes the query a *plan*: nothing is scanned, gathered, or compiled until
``.collect()``/``.stats()``, which gives the planner room for three pushdowns
before any bytes move:

1. **Region pruning** — a rowkey prefix/range resolves against the region
   start keys (:meth:`RegionSet.prune`, two bisects), so regions outside the
   scan range are never scanned and their device blocks never gathered.
   ``QueryStats.regions_scanned``/``regions_pruned`` report the efficacy.
2. **Projection pushdown** — only the selected columns enter the device
   layout; index families are read exclusively by the predicate (and the
   ``group_by`` key column).
3. **Program fusion** — every ``.map(program)`` statistic joins one
   :class:`~repro.core.stats.FusedProgram`, so mean+variance+histogram run in
   a single engine pass over a single gather, sharing one compiled
   executable per block shape and one result-cache entry.  Members that
   declare shared accumulators (``requires()``) are CSE'd: count and the
   raw power sums fold once per chunk, however many statistics project from
   them.  With ``.select([c1, c2])`` the fused stack folds over EACH
   selected column; with ``.group_by(key)`` every block folds group-keyed
   partials (segment-summed by group id) and results come back per group.

Build plans through :meth:`GridSession.scan`::

    q = (session.scan(prefix=b"site-a/")
                .select("img:data")
                .where(age_sex_predicate(20, 40, 1), ["age", "sex"])
                .map(MeanProgram())
                .map(VarianceProgram())
                .reduce())
    (mean, var), report = q.collect()
    print(report.query.regions_pruned, "regions never touched")

Builder methods are pure — each returns a new plan, so a scan can be reused
as the base of several queries.  Results are memoized per (η, epoch) on the
plan object; across plan objects the session's content-addressed result and
partial caches make an equivalent re-execution fold zero payload rows.
"""

from __future__ import annotations

import dataclasses
from typing import (
    TYPE_CHECKING, Any, Dict, Optional, Sequence, Tuple, Union,
)

from repro.core.mapreduce import MapReduceProgram
from repro.core.query import Predicate
from repro.core.table import RowKey, _as_key

if TYPE_CHECKING:  # import cycle: grid builds plans, plans execute on grid
    from repro.core.grid import GridSession, RunReport


def prefix_range(prefix: RowKey) -> Tuple[bytes, Optional[bytes]]:
    """The half-open rowkey range ``[start, stop)`` matching a key prefix.

    ``stop`` is the prefix with its last non-``0xff`` byte incremented
    (trailing ``0xff`` bytes stripped first — ``b"a\\xff"`` rolls over to
    ``b"b"``); an empty or all-``0xff`` prefix has no upper bound (None,
    the keyspace's +inf sentinel).
    """
    p = _as_key(prefix)
    trimmed = p.rstrip(b"\xff")
    if not trimmed:
        return p, None
    stop = trimmed[:-1] + bytes([trimmed[-1] + 1])
    return p, stop


ColumnRef = Union[str, Tuple[str, str]]


def _parse_column(col: ColumnRef) -> Tuple[str, str]:
    """Accept ``"family:qualifier"`` or ``(family, qualifier)``."""
    if isinstance(col, str):
        fam, sep, qual = col.partition(":")
        if not sep or not fam or not qual:
            raise ValueError(
                f"column {col!r} must be 'family:qualifier' or a tuple")
        return fam, qual
    fam, qual = col
    return str(fam), str(qual)


@dataclasses.dataclass
class GridQuery:
    """One lazy scan→select→where→map→reduce plan bound to a session.

    Immutable by convention: builder methods return a *new* plan (the memo
    is dropped), so plans compose and fork freely.  Execution happens only
    in :meth:`collect`/:meth:`stats`, via the session's planner, which owns
    the pushdowns and the compiled-plan cache.
    """

    session: "GridSession"
    start: Optional[bytes] = None          # scan range, half-open
    stop: Optional[bytes] = None
    prefix: Optional[bytes] = None         # provenance only; folded into range
    columns: Tuple[Tuple[str, str], ...] = ()   # projection; () = payload col
    predicate: Optional[Predicate] = None
    index_qualifiers: Tuple[str, ...] = ()
    programs: Tuple[MapReduceProgram, ...] = ()
    # stratification columns: tuple of (family, qualifier), in key order
    group_key: Optional[Tuple[Tuple[str, str], ...]] = None
    # (eta, epoch) -> (results, report); dropped by every builder call
    _memo: Dict[Tuple[int, int], Tuple[Any, "RunReport"]] = dataclasses.field(
        default_factory=dict, repr=False, compare=False)

    # ------------------------------------------------------------------
    # builders (each returns a fresh plan)
    # ------------------------------------------------------------------

    def _fork(self, **changes) -> "GridQuery":
        changes.setdefault("_memo", {})
        return dataclasses.replace(self, **changes)

    def select(self, *columns) -> "GridQuery":
        """Projection pushdown: only these columns enter the layout.

        Accepts ``"family:qualifier"`` strings, ``(family, qualifier)``
        tuples, or a *list* of either to select several columns at once
        (``select(["img:data", "idx:age"])`` ≡ ``select("img:data",
        "idx:age")``).  Compute plans (any ``.map``) fold every mapped
        program over EACH selected column in one pass; plain ``.collect()``
        retrieves every selected column.  Default (no ``select``) is the
        session's payload column.
        """
        cols = []
        for c in columns:
            if isinstance(c, list):
                cols.extend(_parse_column(x) for x in c)
            else:
                cols.append(_parse_column(c))
        return self._fork(columns=tuple(cols))

    def group_by(self, column) -> "GridQuery":
        """Stratify every mapped statistic by one or more scalar key columns.

        ``column`` is a single column ref (e.g. ``"idx:site"``) or a *list*
        of refs for a composite key (``group_by(["idx:site",
        "idx:scanner"])``).  Key columns are read like index columns — a
        few bytes per row, never the payload.  Execution densifies the
        (combined) key to one dense group id per selected row, the
        per-block folds segment-sum group-keyed partials in the same single
        pass, and results come back as one
        :class:`~repro.core.stats.GroupedResult` per computed column.
        Single-column keys label groups with scalar key values; composite
        keys with tuples, ordered lexicographically by the listed columns
        (so ``["idx:site", "idx:scanner"]`` and ``["idx:scanner",
        "idx:site"]`` are distinct groupings with distinct cache
        identities).
        """
        if self.group_key is not None:
            raise ValueError("plan already grouped; pass the composite key "
                             "as one group_by([...]) list instead")
        cols = column if isinstance(column, list) else [column]
        if not cols:
            raise ValueError("group_by needs at least one key column")
        parsed = tuple(_parse_column(c) for c in cols)
        if len(set(parsed)) != len(parsed):
            raise ValueError(f"duplicate group_by key columns in {parsed}")
        return self._fork(group_key=parsed)

    def where(self, predicate: Predicate,
              index_qualifiers: Sequence[str]) -> "GridQuery":
        """Filter pushdown: ``predicate`` over the index family only."""
        if self.predicate is not None:
            raise ValueError("plan already has a predicate; compose them "
                             "into one callable instead")
        return self._fork(predicate=predicate,
                          index_qualifiers=tuple(index_qualifiers))

    def map(self, program: MapReduceProgram) -> "GridQuery":
        """Add a statistic; all mapped programs fuse into ONE engine pass."""
        return self._fork(programs=self.programs + (program,))

    def reduce(self) -> "GridQuery":
        """Finalize the plan (the programs are monoid folds, so the reduce
        is implied by their ``merge``/``finalize``; kept for call-site
        symmetry with the paper's map→reduce verbs).  Still lazy."""
        if not self.programs:
            raise ValueError("reduce() needs at least one map(program)")
        return self

    # ------------------------------------------------------------------
    # execution
    # ------------------------------------------------------------------

    def collect(self, eta: Optional[int] = None) -> Tuple[Any, "RunReport"]:
        """Compile + execute the plan; returns ``(results, RunReport)``.

        With programs, ``results`` follows map order (a bare value for a
        single program, a tuple for a fused set); grouped plans wrap each
        column's results in a :class:`~repro.core.stats.GroupedResult`, and
        multi-column compute plans return ``{"fam:qual": per-column
        results}``.  Without programs this is a pruned retrieve:
        ``results = (rowkeys, {"fam:qual": values})``.
        """
        eta_key = int(eta or self.session.default_eta)
        memo_key = (eta_key, self.session.epoch)
        if memo_key not in self._memo:
            self._memo.clear()      # stale epochs/etas have no consumers
            self._memo[memo_key] = self.session._execute_plan(self, eta=eta)
        return self._memo[memo_key]

    def stats(self, eta: Optional[int] = None) -> "RunReport":
        """Execute (memoized) and return just the accounting report."""
        _, report = self.collect(eta=eta)
        return report

    def explain(self) -> str:
        """Describe the physical plan WITHOUT moving bytes or compiling."""
        regions = self.session.table.regions
        pruned = regions.prune(self.start, self.stop)
        lo, hi = self.session.table.row_range(self.start, self.stop)
        cols = self.resolved_columns()
        lines = [
            f"GridQuery(epoch={self.session.epoch})",
            f"  scan    [{self.start!r}, {self.stop!r}) -> rows {lo}:{hi}, "
            f"regions {len(pruned)}/{len(regions)} "
            f"({len(regions) - len(pruned)} pruned)",
            f"  select  {', '.join(f'{f}:{q}' for f, q in cols)}",
            f"  where   {self.predicate!r} over idx{list(self.index_qualifiers)}"
            if self.predicate is not None else "  where   -",
            f"  group   "
            f"{', '.join(f'{f}:{q}' for f, q in self.group_key)}"
            if self.group_key is not None else "  group   -",
            f"  map     {len(self.programs)} program(s) fused: "
            f"{[type(p).__name__ for p in self.programs]}"
            f"{' x ' + str(len(cols)) + ' columns' if len(cols) > 1 else ''}"
            if self.programs else "  map     - (retrieve)",
        ]
        return "\n".join(lines)

    # ------------------------------------------------------------------
    # planner-facing helpers
    # ------------------------------------------------------------------

    def signature(self) -> Tuple:
        """Hashable identity of the plan's semantics — scan range,
        projection, predicate, fused program stack, grouping.  The
        frontend's single-flight registry keys on ``(signature, epoch)``
        to collapse concurrent identical queries into one execution.

        Predicates are callables compared by identity: two plans share a
        signature only when they share the predicate *object* (forks of
        one base scan, or one module-level predicate reused across
        clients) — exactly the repeat-query shape coalescing targets.
        The signature tuple holds a reference to the predicate, so an
        entry retained in a registry keeps its identity stable.
        """
        return (
            self.start, self.stop, self.resolved_columns(),
            self.predicate, self.index_qualifiers,
            tuple(p.cache_key() for p in self.programs),
            self.group_key,
        )

    def batch_signature(self) -> Tuple:
        """The plan signature *minus the program stack*.  Plans sharing
        this scan the same rows of the same columns under the same
        grouping, so their programs can fuse into one device pass per
        scheduler tick; results split back per plan by program count."""
        return (
            self.start, self.stop, self.resolved_columns(),
            self.predicate, self.index_qualifiers, self.group_key,
        )

    def resolved_columns(self) -> Tuple[Tuple[str, str], ...]:
        if self.columns:
            return self.columns
        return ((self.session.payload_family, self.session.payload_qualifier),)

    def compute_columns(self) -> Tuple[Tuple[str, str], ...]:
        """The columns a compute plan folds over (≥1; duplicates rejected —
        each column carries its own program stack in the one pass)."""
        cols = self.resolved_columns()
        if len(set(cols)) != len(cols):
            raise ValueError(f"duplicate compute columns in {cols}")
        return cols

    def compute_column(self) -> Tuple[str, str]:
        """Back-compat accessor for single-column compute plans."""
        cols = self.compute_columns()
        if len(cols) != 1:
            raise ValueError(
                f"compute plans fold over exactly one column, got {cols}")
        return cols[0]
