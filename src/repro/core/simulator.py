"""Discrete-event cluster simulator — reproduces the paper's experiments.

The paper's empirical platform is an in-house 224-core heterogeneous grid
(8 × 12 slow cores + 4 × 32 fast cores, §2.4/Fig. 3).  This container has one
CPU, so the *empirical* curves of Fig. 3/4/6 are reproduced by a
progress-based discrete-event simulation with max-min fair sharing of the
shared resources — the same modelling level the paper itself uses for its
"theoretical" curves, but with queueing and contention made explicit:

- each **node** has ``cores`` slots (a task holds one slot start-to-finish,
  which is what makes resource time = Σ task durations, the paper's metric),
  a disk read channel and a disk write channel (fair-shared among the node's
  concurrently-reading/writing tasks);
- the **network** is one shared full-duplex capacity, fair-shared among all
  active remote transfers (this is what saturates for SGE at small job
  lengths — Fig. 3A's flat region);
- a **task** runs READ → COMPUTE → WRITE; reads are disk-local when the
  executing node owns the input region, network otherwise; compute rate
  scales with the node's per-core MIPS.

Modes:
- ``hadoop``: tasks are queued on the node owning their input (data
  colocation); an idle node may steal from the longest queue, paying the
  network read — the paper's β rack-local fraction emerges from stealing.
- ``sge``: central storage; a single global FIFO, every read/write remote.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.balancer import NodeSpec

EPS = 1e-12


@dataclasses.dataclass
class SimTask:
    """One grid job (a map task, a compression job, ...)."""

    task_id: int
    input_bytes: float
    output_bytes: float
    work: float                      # seconds on a 1.0-MIPS core
    home_node: Optional[int] = None  # node owning the input region (None = central)
    sticky: bool = False             # if True, never stolen (strict locality)

    # -- filled by the simulator --
    exec_node: int = -1
    start: float = 0.0
    end: float = 0.0
    read_remote: bool = False
    write_remote: bool = False


@dataclasses.dataclass
class SimResult:
    wall_time: float
    resource_time: float              # Σ (end - start) over tasks — paper metric
    tasks: List[SimTask]
    remote_read_fraction: float
    node_busy: Dict[int, float]

    def summary(self) -> str:
        return (
            f"wall={self.wall_time:.1f}s resource={self.resource_time:.1f}s "
            f"tasks={len(self.tasks)} remote_reads={self.remote_read_fraction:.2f}"
        )


_PHASE_TOL = 1e-6  # units (bytes / work-seconds) below which a phase is done


class _Running:
    """A task in flight: phase ∈ {read, compute, write} with remaining units."""

    __slots__ = ("task", "node", "phase", "remaining")

    def __init__(self, task: SimTask, node: NodeSpec):
        self.task = task
        self.node = node
        self.phase = "read"
        self.remaining = max(task.input_bytes, 0.0)
        self._skip_empty()

    def _skip_empty(self) -> bool:
        """Advance through zero-length phases; True when the task is done."""
        while self.remaining <= _PHASE_TOL:
            if self.phase == "read":
                self.phase = "compute"
                self.remaining = max(self.task.work, 0.0)
            elif self.phase == "compute":
                self.phase = "write"
                self.remaining = max(self.task.output_bytes, 0.0)
            else:
                return True
        return False

    def advance(self, amount: float) -> bool:
        """Consume ``amount`` units; returns True when the task finished."""
        self.remaining -= amount
        return self._skip_empty()


class ClusterSim:
    def __init__(
        self,
        nodes: Sequence[NodeSpec],
        bandwidth: float = 70e6,
        allow_steal: bool = False,
    ):
        """``allow_steal=False`` is faithful to HBase MapReduce (map tasks are
        pinned to their region server — Fig. 3's starved fast nodes exist
        precisely because Hadoop does not steal).  ``allow_steal=True`` is
        ColoGrid's beyond-paper backlog-aware work stealing: an idle node may
        take from a victim whose queue exceeds one wave of its own cores,
        paying the remote read."""
        self.nodes = {n.node_id: n for n in nodes}
        self.bandwidth = bandwidth
        self.allow_steal = allow_steal

    # ------------------------------------------------------------------

    def run(self, tasks: Sequence[SimTask], mode: str = "hadoop") -> SimResult:
        if mode not in ("hadoop", "sge"):
            raise ValueError(f"unknown mode {mode!r}")
        tasks = [dataclasses.replace(t) for t in tasks]  # do not mutate input
        for t in tasks:
            t.write_remote = mode == "sge"

        queues: Dict[int, List[SimTask]] = {nid: [] for nid in self.nodes}
        global_queue: List[SimTask] = []
        if mode == "hadoop":
            for t in tasks:
                if t.home_node is not None and t.home_node in self.nodes:
                    queues[t.home_node].append(t)
                else:
                    global_queue.append(t)
        else:
            global_queue = list(tasks)

        free_slots: Dict[int, int] = {nid: n.cores for nid, n in self.nodes.items()}
        running: List[_Running] = []
        now = 0.0
        done: List[SimTask] = []
        node_busy: Dict[int, float] = {nid: 0.0 for nid in self.nodes}
        n_total = len(tasks)

        def schedule():
            for nid, node in self.nodes.items():
                while free_slots[nid] > 0:
                    task: Optional[SimTask] = None
                    if mode == "hadoop":
                        if queues[nid]:
                            task = queues[nid].pop(0)
                            task.read_remote = False
                        elif global_queue:
                            task = global_queue.pop(0)
                            task.read_remote = task.home_node != nid
                        elif self.allow_steal:
                            # backlog-aware: only steal from a victim whose
                            # queue exceeds one wave of its own cores
                            victims = [
                                q for q in queues
                                if q != nid
                                and len(queues[q]) > self.nodes[q].cores
                                and any(not t.sticky for t in queues[q])
                            ]
                            victim = max(victims, key=lambda q: len(queues[q]),
                                         default=None)
                            if victim is not None:
                                for i, cand in enumerate(queues[victim]):
                                    if not cand.sticky:
                                        task = queues[victim].pop(i)
                                        break
                                task.read_remote = True
                    else:  # sge: central storage, everything remote
                        if global_queue:
                            task = global_queue.pop(0)
                            task.read_remote = True
                    if task is None:
                        break
                    task.exec_node = nid
                    task.start = now
                    free_slots[nid] -= 1
                    running.append(_Running(task, node))

        schedule()
        while len(done) < n_total:
            if not running:
                raise RuntimeError("deadlock: tasks pending but none runnable")

            # --- max-min fair rates for every running phase ----------------
            net_users = sum(
                1 for r in running
                if (r.phase == "read" and r.task.read_remote)
                or (r.phase == "write" and r.task.write_remote)
            )
            disk_r_users: Dict[int, int] = {}
            disk_w_users: Dict[int, int] = {}
            for r in running:
                nid = r.node.node_id
                if r.phase == "read" and not r.task.read_remote:
                    disk_r_users[nid] = disk_r_users.get(nid, 0) + 1
                elif r.phase == "write" and not r.task.write_remote:
                    disk_w_users[nid] = disk_w_users.get(nid, 0) + 1

            rates: List[float] = []
            for r in running:
                nid = r.node.node_id
                if r.phase == "compute":
                    rate = r.node.mips  # work-seconds per second
                elif r.phase == "read":
                    rate = (
                        self.bandwidth / max(net_users, 1)
                        if r.task.read_remote
                        else r.node.disk_read_bps / max(disk_r_users.get(nid, 1), 1)
                    )
                else:  # write
                    rate = (
                        self.bandwidth / max(net_users, 1)
                        if r.task.write_remote
                        else r.node.disk_write_bps / max(disk_w_users.get(nid, 1), 1)
                    )
                rates.append(max(rate, EPS))

            # --- advance to the earliest phase completion -------------------
            dt = max(min(r.remaining / rate for r, rate in zip(running, rates)), 0.0)
            now += dt
            finished: List[_Running] = []
            for r, rate in zip(running, rates):
                if r.advance(rate * dt):
                    finished.append(r)
            for r in finished:
                running.remove(r)
                t = r.task
                t.end = now
                done.append(t)
                free_slots[r.node.node_id] += 1
                node_busy[r.node.node_id] += t.end - t.start
            if finished:
                schedule()

        resource = sum(t.end - t.start for t in done)
        remote = sum(1 for t in done if t.read_remote) / max(len(done), 1)
        return SimResult(
            wall_time=now,
            resource_time=resource,
            tasks=done,
            remote_read_fraction=remote,
            node_busy=node_busy,
        )


# ----------------------------------------------------------------------
# The paper's cluster (§2.4, Fig. 3 caption)
# ----------------------------------------------------------------------

def paper_cluster(slow_mips: float = 1.0, fast_mips: float = 1.6) -> List[NodeSpec]:
    """8 machines × 12 slow cores + 4 machines × 32 fast cores = 224 cores.

    MIPS ratio ~1:1.6 (older vs newer Xeons, measured by ``linux perf`` in the
    paper); absolute scale is irrelevant — only ratios move the allocation.
    """
    nodes = [
        NodeSpec(node_id=i, cores=12, mips=slow_mips, mem_bytes=48 << 30)
        for i in range(8)
    ]
    nodes += [
        NodeSpec(node_id=8 + i, cores=32, mips=fast_mips, mem_bytes=128 << 30)
        for i in range(4)
    ]
    return nodes


def mapreduce_job_tasks(
    n_img: int,
    eta: int,
    size_in: float,
    size_gen: float,
    avg_fn,
    placement_of_chunk,           # chunk index -> home node (or None)
    reference_mips: float = 1.0,
) -> Tuple[List[SimTask], SimTask]:
    """Build map tasks + the reduce task for a §2.2 averaging job.

    ``work`` is in reference-MIPS seconds so heterogeneous nodes run it at
    their own speed.  The reduce task averages the ⌊#img/η⌋ intermediates.
    """
    n_job = n_img // eta
    sizes = [eta] * n_job
    rem = n_img - n_job * eta
    if rem:
        sizes.append(rem)
    maps = [
        SimTask(
            task_id=i,
            input_bytes=sz * size_in,
            output_bytes=size_gen,
            work=avg_fn(sz) * reference_mips,
            home_node=placement_of_chunk(i),
        )
        for i, sz in enumerate(sizes)
    ]
    reduce_task = SimTask(
        task_id=len(maps),
        input_bytes=len(maps) * size_gen,
        output_bytes=size_gen,
        work=avg_fn(len(maps)) * reference_mips,
        home_node=None,
    )
    return maps, reduce_task
