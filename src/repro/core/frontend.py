"""GridFrontend — concurrent query serving with cross-query coalescing.

The paper's grid exists to serve *many simultaneous* analysis jobs against
colocated image data; everything below this module assumes one synchronous
caller.  ``GridFrontend`` is the serving layer on top of
:class:`~repro.core.grid.GridSession`:

- **Concurrent submission** — ``submit(plan) -> Future`` from any number of
  client threads, plus a synchronous ``query()`` convenience.  A bounded
  admission window (``max_pending``) rejects excess load with
  :class:`FrontendOverloadedError` instead of queueing unboundedly, and a
  per-query ``deadline`` fails queries that sat in the queue too long with
  :class:`QueryTimeoutError`.

- **Readers–writer epoch isolation** — queries execute under a shared read
  lock; the mutating verbs (``upload``/``remove``/``rebalance``) take the
  writer side, which *drains* every in-flight query, applies the mutation
  atomically (the session bumps its epoch), and releases.  No query ever
  observes a half-applied mutation; writer priority keeps mutations from
  starving under a steady query stream.

- **Query-level coalescing (single-flight)** — in-flight and recently
  completed executions are registered under the plan's semantic
  :meth:`~repro.core.plan.GridQuery.signature` + session epoch.  N clients
  asking the same question between two mutations share ONE execution: one
  leader runs, N-1 followers get futures chained off the leader's
  (``FrontendStats.coalesce_hits``).  Mutations clear the registry.

- **Batched device ticks** — distinct-program plans over the *same scan*
  (equal :meth:`~repro.core.plan.GridQuery.batch_signature`) that arrive
  within one ``tick_ms`` scheduler window merge their program stacks into a
  single fused plan: one scan resolution, one gather, one CSE'd fold pass
  answers them all, and results split back per plan by program count.  This
  is the continuous-batching-lite pattern from :mod:`repro.serve.engine`
  applied to analytics.

- **Partial-level coalescing (fold gate)** — *different* plans that need
  the same ``(block, program, mask-sig, group-sig)`` partial (overlapping
  range scans, a full-table plan racing a covering range plan) share one
  fold dispatch through a single-flight gate installed as
  ``session.fold_gate``, keyed on the BlockStore's content-addressed
  partial key.  Followers account the partial as reused.

Quickstart::

    with GridFrontend(session, workers=8, tick_ms=2.0) as fe:
        futs = [fe.submit(plan) for _ in range(16)]     # one execution
        results, report = futs[0].result()
        fe.upload(keys, data)                            # drains, then applies
        print(fe.stats.snapshot())
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
import time
from collections import deque
from concurrent.futures import Future, ThreadPoolExecutor
from concurrent.futures import TimeoutError as _FutureTimeout
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.blockstore import AtomicStats, LRUCache
from repro.core.faults import (
    DeviceLostError,
    QueryFaultedError,
    RetryPolicy,
    TransientFaultError,
)
from repro.core.grid import GridSession, RunReport
from repro.core.plan import GridQuery
from repro.core.stats import GroupedResult


class FrontendOverloadedError(RuntimeError):
    """Admission control: the frontend's open-query window is full."""


class QueryTimeoutError(TimeoutError):
    """The query's deadline passed before it could be served."""


@dataclasses.dataclass
class FrontendStats(AtomicStats):
    """Observable serving counters (atomic; read via ``snapshot()``).

    Latency percentiles come from a bounded reservoir of recent
    per-query service times — :meth:`latency_percentiles` — not from the
    dataclass fields, so ``snapshot()`` stays a cheap field copy.
    """

    submitted: int = 0          # submit() calls admitted
    served: int = 0             # futures resolved with a result
    failed: int = 0             # futures resolved with an error
    rejected: int = 0           # admission rejections (overload)
    timeouts: int = 0           # deadline expiries
    coalesce_hits: int = 0      # submissions served by another query's flight
    partial_coalesce_hits: int = 0  # block folds shared via the fold gate
    batch_merges: int = 0       # ticks that fused >= 2 plans into one pass
    batched_queries: int = 0    # queries answered through a merged pass
    ticks: int = 0              # scheduler windows that dispatched work
    mutations: int = 0          # write-side verbs applied
    queue_depth_peak: int = 0   # max tasks waiting in one tick window
    # --- fault tolerance ----------------------------------------------
    retries: int = 0            # dispatch-level query re-executions
    faults: int = 0             # fault-kind failures observed at dispatch
    breaker_opens: int = 0      # per-plan circuit breakers tripped open

    def __post_init__(self):
        super().__post_init__()
        object.__setattr__(self, "_lat", deque(maxlen=2048))
        object.__setattr__(self, "_lat_lock", threading.Lock())

    def record_latency(self, seconds: float) -> None:
        with self._lat_lock:
            self._lat.append(seconds)

    def latency_percentiles(self) -> Tuple[float, float]:
        """``(p50, p99)`` service latency in seconds over the reservoir."""
        with self._lat_lock:
            lat = sorted(self._lat)
        if not lat:
            return 0.0, 0.0
        return (lat[len(lat) // 2],
                lat[min(len(lat) - 1, (len(lat) * 99) // 100)])

    def reset_latencies(self) -> None:
        """Drop the reservoir (benches call this after warm-up so compile
        latencies don't pollute the steady-state percentiles)."""
        with self._lat_lock:
            self._lat.clear()


class _EpochRWLock:
    """Writer-priority readers–writer lock.

    Readers are executing queries; the writer is a mutating verb.  A
    waiting writer blocks NEW readers, so mutation latency is bounded by
    the in-flight queries it drains, not by the arrival stream.
    """

    def __init__(self):
        self._cond = threading.Condition()
        self._readers = 0
        self._writer = False
        self._writers_waiting = 0

    @contextlib.contextmanager
    def read(self):
        with self._cond:
            while self._writer or self._writers_waiting:
                self._cond.wait()
            self._readers += 1
        try:
            yield
        finally:
            with self._cond:
                self._readers -= 1
                if self._readers == 0:
                    self._cond.notify_all()

    @contextlib.contextmanager
    def write(self):
        with self._cond:
            self._writers_waiting += 1
            while self._writer or self._readers:
                self._cond.wait()
            self._writers_waiting -= 1
            self._writer = True
        try:
            yield
        finally:
            with self._cond:
                self._writer = False
                self._cond.notify_all()


class _GateEntry:
    """One in-flight fold behind the partial-level single-flight gate."""

    __slots__ = ("event", "result", "exc")

    def __init__(self):
        self.event = threading.Event()
        self.result: Any = None
        self.exc: Optional[BaseException] = None


class _Breaker:
    """Per-plan-signature circuit breaker state (guarded by the
    frontend's breaker lock)."""

    __slots__ = ("failures", "opened_until")

    def __init__(self):
        self.failures = 0
        self.opened_until = 0.0


@dataclasses.dataclass
class _Task:
    """One admitted query waiting for (or in) execution."""

    plan: GridQuery
    eta: Optional[int]
    deadline: Optional[float]      # monotonic absolute, None = no deadline
    future: Future
    t_submit: float
    flight_key: Optional[Tuple] = None
    breaker_key: Optional[Tuple] = None
    # resolution claim: exactly ONE of _finish / _fail / _abandon settles
    # the task (guarded by the frontend's open lock), so a sync caller
    # abandoning a timed-out query and the executor finishing the same
    # flight can race without double-counting or double-resolving
    done: bool = False


class GridFrontend:
    """Concurrent query server over one :class:`GridSession`.

    Parameters
    ----------
    session:
        The session to serve.  The frontend installs itself as the
        session's ``fold_gate`` (when ``coalesce=True``) and assumes it is
        the only concurrent entry point — don't call session verbs
        directly while the frontend is open.
    workers:
        Executor threads running query groups (distinct scans proceed in
        parallel; the device serializes where it must).
    tick_ms:
        The batching window: after the first arrival the scheduler waits
        this long for same-scan plans to accumulate before dispatching.
        0 dispatches immediately (no cross-query program fusion).
    max_pending:
        Admission bound on open (submitted, unresolved) queries.
    coalesce:
        ``False`` disables all three sharing layers (single-flight,
        tick merging, fold gate) — the control arm for benchmarks.
    retry_policy:
        Backoff schedule for dispatch-level retries of fault-kind
        failures (transient device faults, device loss already handled
        by the session's quarantine).  Defaults to the session's policy.
    breaker_threshold:
        Consecutive fault-kind failures of one plan signature before its
        circuit breaker opens (0 disables breakers).
    breaker_cooldown_s:
        How long an open breaker fast-fails submissions of that plan
        before letting a probe through.
    """

    def __init__(self, session: GridSession, *, workers: int = 4,
                 tick_ms: float = 2.0, max_pending: int = 256,
                 coalesce: bool = True,
                 retry_policy: Optional[RetryPolicy] = None,
                 breaker_threshold: int = 3,
                 breaker_cooldown_s: float = 1.0):
        self.session = session
        self.tick_ms = float(tick_ms)
        self.max_pending = int(max_pending)
        self.coalesce = bool(coalesce)
        self.stats = FrontendStats()
        self._retry = (retry_policy if retry_policy is not None
                       else session.retry_policy)
        self.breaker_threshold = int(breaker_threshold)
        self.breaker_cooldown_s = float(breaker_cooldown_s)
        # per-plan-signature circuit breakers (bounded: cold plans age out)
        self._breakers: LRUCache = LRUCache(512)
        self._breaker_lock = threading.Lock()

        self._rwlock = _EpochRWLock()
        self._pool = ThreadPoolExecutor(
            max_workers=max(1, int(workers)),
            thread_name_prefix="grid-frontend")
        # single-flight registry: (plan signature, eta, epoch) -> leader
        # Future.  Completed flights are RETAINED (bounded LRU) until the
        # next mutation, so repeat queries coalesce whether or not their
        # lifetimes overlap; mutation clears it wholesale.
        self._flights: LRUCache = LRUCache(512)
        self._flights_lock = threading.Lock()
        # partial-level single-flight: blockstore pkey -> _GateEntry
        self._gate_inflight: Dict[Tuple, _GateEntry] = {}
        self._gate_lock = threading.Lock()

        self._queue: List[_Task] = []
        self._queue_cond = threading.Condition()
        self._open = 0                     # admitted, not yet resolved
        self._open_lock = threading.Lock()
        self._closed = False
        # the task group an executor thread is currently serving — the
        # fold gate reads it to re-check deadlines mid-execution
        self._exec_tls = threading.local()

        # pin one bound-method object: attribute access mints a fresh
        # bound method each time, so install/uninstall must share it
        self._installed_gate = self._fold_gate
        if self.coalesce:
            session.fold_gate = self._installed_gate
        self._scheduler = threading.Thread(
            target=self._scheduler_loop, name="grid-frontend-tick",
            daemon=True)
        self._scheduler.start()

    # ------------------------------------------------------------------
    # client surface
    # ------------------------------------------------------------------

    def submit(self, plan: GridQuery, *, eta: Optional[int] = None,
               deadline: Optional[float] = None) -> Future:
        """Admit one plan; returns a Future of ``(results, RunReport)``.

        ``deadline`` is a relative budget in seconds, enforced while
        queued, at dispatch, and at every fold-gate entry during
        execution: an expired query resolves with
        :class:`QueryTimeoutError` instead of running to completion.
        Raises :class:`FrontendOverloadedError` when the open-query window
        (``max_pending``) is full.
        """
        return self._submit(plan, eta=eta, deadline=deadline).future

    def _submit(self, plan: GridQuery, *, eta: Optional[int],
                deadline: Optional[float]) -> _Task:
        if self._closed:
            raise RuntimeError("frontend is closed")
        bkey: Optional[Tuple] = None
        if self.breaker_threshold > 0:
            bkey = plan.signature()
            with self._breaker_lock:
                br = self._breakers.peek(bkey)
                open_until = 0.0 if br is None else br.opened_until
            if time.monotonic() < open_until:
                self.stats.inc(rejected=1)
                raise QueryFaultedError(
                    "circuit breaker open for this plan "
                    f"(cooldown {self.breaker_cooldown_s}s after "
                    f"{self.breaker_threshold} consecutive faults)")
        with self._open_lock:
            if self._open >= self.max_pending:
                self.stats.inc(rejected=1)
                raise FrontendOverloadedError(
                    f"{self._open} open queries >= max_pending="
                    f"{self.max_pending}")
            self._open += 1

        now = time.monotonic()
        fut: Future = Future()
        task = _Task(plan=plan, eta=eta,
                     deadline=None if deadline is None else now + deadline,
                     future=fut, t_submit=now, breaker_key=bkey)
        self.stats.inc(submitted=1)

        if self.coalesce:
            key = (plan.signature(), eta, self.session.epoch)
            task.flight_key = key
            with self._flights_lock:
                leader: Optional[Future] = self._flights.get(key)
                if leader is None:
                    self._flights.put(key, fut)
            if leader is not None:
                self.stats.inc(coalesce_hits=1)
                leader.add_done_callback(
                    lambda lf, t=task: self._resolve_from_leader(t, lf))
                return task

        with self._queue_cond:
            self._queue.append(task)
            depth = len(self._queue)
            self._queue_cond.notify()
        self.stats.imax(queue_depth_peak=depth)
        return task

    def query(self, plan: GridQuery, *, eta: Optional[int] = None,
              timeout: Optional[float] = None) -> Tuple[Any, RunReport]:
        """Synchronous convenience: ``submit`` + wait.

        A timed-out wait ABANDONS the task — it is resolved (once) with
        :class:`QueryTimeoutError`, counted as a timeout, its flight is
        released so later submissions re-execute instead of chaining onto
        a doomed leader, and an in-flight execution serving only this
        query aborts at its next fold-gate entry rather than running to
        completion."""
        task = self._submit(plan, eta=eta, deadline=timeout)
        try:
            return task.future.result(timeout=timeout)
        except _FutureTimeout:
            self._abandon(task)
            raise QueryTimeoutError(
                f"query not served within {timeout}s") from None

    def _abandon(self, task: _Task) -> None:
        """The client stopped waiting: settle the task as a timeout if
        nothing else settled it first (the claim in ``_fail`` makes the
        race with a concurrently finishing execution single-winner)."""
        with self._queue_cond:
            try:
                self._queue.remove(task)
            except ValueError:
                pass                  # already dispatched (or a follower)
        self._fail(task, QueryTimeoutError("abandoned by caller"),
                   timeout=True)

    # --- mutating verbs (writer side) ---------------------------------

    def upload(self, *args, **kwargs):
        """Drain in-flight queries, then ``session.upload`` atomically."""
        return self._mutate(self.session.upload, *args, **kwargs)

    def remove(self, *args, **kwargs):
        """Drain in-flight queries, then ``session.remove`` atomically."""
        return self._mutate(self.session.remove, *args, **kwargs)

    def rebalance(self, *args, **kwargs):
        """Drain in-flight queries, then ``session.rebalance``."""
        return self._mutate(self.session.rebalance, *args, **kwargs)

    def _mutate(self, verb: Callable, *args, **kwargs):
        with self._rwlock.write():
            # every flight answered (or will answer) at the old epoch;
            # post-mutation submissions must re-execute
            with self._flights_lock:
                self._flights.clear()
            out = verb(*args, **kwargs)
        self.stats.inc(mutations=1)
        return out

    # --- lifecycle ----------------------------------------------------

    def close(self) -> None:
        """Stop admitting, drain the queue, release the session hook."""
        if self._closed:
            return
        self._closed = True
        with self._queue_cond:
            self._queue_cond.notify_all()
        self._scheduler.join(timeout=10.0)
        self._pool.shutdown(wait=True)
        if self.session.fold_gate is self._installed_gate:
            self.session.fold_gate = None

    def __enter__(self) -> "GridFrontend":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------------
    # scheduler
    # ------------------------------------------------------------------

    def _scheduler_loop(self) -> None:
        while True:
            with self._queue_cond:
                while not self._queue and not self._closed:
                    self._queue_cond.wait()
                if self._closed and not self._queue:
                    return
            if self.tick_ms > 0:
                # accumulation window: let same-scan plans pile up
                time.sleep(self.tick_ms / 1000.0)
            with self._queue_cond:
                tasks, self._queue = self._queue, []
            if not tasks:
                continue
            self.stats.inc(ticks=1)
            for group in self._group_tasks(tasks):
                self._pool.submit(self._run_group, group)

    def _group_tasks(self, tasks: List[_Task]) -> List[List[_Task]]:
        """Partition one tick's tasks into mergeable groups.

        Compute plans sharing ``(batch_signature, eta)`` fuse; retrieves
        (no programs) and everything else run alone.  Coalescing off →
        every task is its own group.
        """
        if not self.coalesce:
            return [[t] for t in tasks]
        groups: Dict[Tuple, List[_Task]] = {}
        singles: List[List[_Task]] = []
        for t in tasks:
            if not t.plan.programs:
                singles.append([t])
                continue
            groups.setdefault(
                (t.plan.batch_signature(), t.eta), []).append(t)
        return list(groups.values()) + singles

    # ------------------------------------------------------------------
    # execution
    # ------------------------------------------------------------------

    def _run_group(self, tasks: List[_Task]) -> None:
        # dispatch-time deadline re-check: a query that expired while
        # queued (or was abandoned by its caller) must not start executing
        now = time.monotonic()
        live: List[_Task] = []
        for t in tasks:
            if t.done:
                continue               # abandoned while queued: settled
            if t.deadline is not None and now > t.deadline:
                self._fail(t, QueryTimeoutError(
                    "deadline passed while queued"), timeout=True)
            else:
                live.append(t)
        if not live:
            return
        self._exec_tls.tasks = live
        try:
            if len(live) == 1:
                t = live[0]
                out = self._execute_with_retries(
                    live, lambda: self._locked_exec(t.plan, t.eta))
                self._finish(t, out)
                return
            # merged tick: one fused pass answers every plan in the group
            offsets: List[Tuple[_Task, int, int]] = []
            programs: Tuple = ()
            for t in live:
                offsets.append((t, len(programs), len(t.plan.programs)))
                programs = programs + t.plan.programs
            merged = live[0].plan._fork(programs=programs)
            self.stats.inc(batch_merges=1, batched_queries=len(live))
            results, report = self._execute_with_retries(
                live, lambda: self._locked_exec(merged, live[0].eta))
            for t, off, k in offsets:
                self._finish(t, (self._split(results, off, k), report))
        except BaseException as e:     # noqa: BLE001 — resolve every future
            for t in live:
                self._fail(t, e)
        finally:
            self._exec_tls.tasks = None

    def _locked_exec(self, plan: GridQuery,
                     eta: Optional[int]) -> Tuple[Any, RunReport]:
        with self._rwlock.read():
            # one promotion sweep serves every coalesced member
            self.session.prefetch_plan(plan)
            return self.session._execute_plan(plan, eta=eta)

    def _execute_with_retries(self, live: List[_Task],
                              run: Callable[[], Tuple]) -> Tuple:
        """Run one execution attempt, retrying fault-kind failures.

        The session already degrades device→host→re-derive internally;
        what reaches here is a fault it could not absorb (an exhausted
        transient budget, or device loss surfacing mid-attempt before
        quarantine re-homing).  Each retry re-takes the read lock, so it
        executes against the freshly healed placement.  Retries stop at
        the policy's attempt budget or the group's last deadline,
        whichever is first; exhaustion raises :class:`QueryFaultedError`
        carrying the full fault chain for the client to inspect.
        """
        faults = self.session.faults
        chain: List[BaseException] = []
        attempt = 0
        while True:
            try:
                if faults is not None:
                    faults.fire("dispatch")
                return run()
            except (TransientFaultError, DeviceLostError) as e:
                chain.append(e)
                self.stats.inc(faults=1)
                attempt += 1
                delay = self._retry.delay_s(attempt - 1, key="dispatch")
                deadline = min(
                    (t.deadline for t in live
                     if not t.done and t.deadline is not None),
                    default=None)
                out_of_time = (deadline is not None
                               and time.monotonic() + delay > deadline)
                if attempt >= self._retry.max_attempts or out_of_time:
                    raise QueryFaultedError(
                        f"query faulted after {attempt} attempt(s)"
                        + (" (deadline reached)" if out_of_time else ""),
                        chain=tuple(chain)) from e
                self.stats.inc(retries=1)
                time.sleep(delay)

    def _check_deadline(self) -> None:
        """Mid-execution deadline gate, called from ``_fold_gate`` entry
        (i.e. between per-block folds): once EVERY task this thread is
        serving has expired or been abandoned, abort the execution with
        :class:`QueryTimeoutError` instead of running the remaining
        blocks for nobody.  While any member is still live, execution
        continues — expired members settle individually at resolution."""
        tasks = getattr(self._exec_tls, "tasks", None)
        if not tasks:
            return
        now = time.monotonic()
        for t in tasks:
            if t.done:
                continue
            if t.deadline is None or now <= t.deadline:
                return
        raise QueryTimeoutError("deadline passed during execution")

    @staticmethod
    def _split(results: Any, off: int, k: int) -> Any:
        """Project one member plan's results out of a merged pass.

        The merged plan has >= 2 programs, so each column's result is a
        tuple in program order (grouped columns wrap it in a
        :class:`GroupedResult`); a member with one program gets the bare
        element back, matching what its solo execution would return.
        """
        def one(val: Any) -> Any:
            if isinstance(val, GroupedResult):
                v = val.values
                sub = v[off] if k == 1 else tuple(v[off:off + k])
                return GroupedResult(keys=val.keys.copy(), values=sub)
            return val[off] if k == 1 else tuple(val[off:off + k])

        if isinstance(results, dict):
            return {col: one(v) for col, v in results.items()}
        return one(results)

    # --- future resolution --------------------------------------------

    def _claim(self, task: _Task) -> bool:
        """Settle-once guard: the first of finish / fail / abandon wins;
        everyone else observes ``done`` and walks away."""
        with self._open_lock:
            if task.done:
                return False
            task.done = True
            self._open -= 1
            return True

    def _finish(self, task: _Task, out: Tuple[Any, RunReport]) -> None:
        if not self._claim(task):
            return                # abandoned meanwhile: already settled
        self._breaker_ok(task)
        self.stats.record_latency(time.monotonic() - task.t_submit)
        self.stats.inc(served=1)
        task.future.set_result(out)

    def _fail(self, task: _Task, exc: BaseException,
              timeout: bool = False) -> None:
        if not self._claim(task):
            return
        # a failed flight must not be replayed to later submissions
        if task.flight_key is not None:
            with self._flights_lock:
                if self._flights.peek(task.flight_key) is task.future:
                    self._flights.pop(task.flight_key)
        if isinstance(exc, (QueryFaultedError, TransientFaultError,
                            DeviceLostError)):
            self._breaker_fault(task)
        timeout = timeout or isinstance(exc, QueryTimeoutError)
        self.stats.inc(failed=1, timeouts=1 if timeout else 0)
        task.future.set_exception(exc)

    # --- circuit breakers ---------------------------------------------

    def _breaker_ok(self, task: _Task) -> None:
        if task.breaker_key is None:
            return
        with self._breaker_lock:
            br = self._breakers.peek(task.breaker_key)
            if br is not None:
                br.failures = 0

    def _breaker_fault(self, task: _Task) -> None:
        """Count one fault-kind failure toward the plan's breaker; trip
        it open (cooldown fast-fail) at the threshold."""
        if task.breaker_key is None or self.breaker_threshold <= 0:
            return
        with self._breaker_lock:
            br = self._breakers.get(task.breaker_key)
            if br is None:
                br = _Breaker()
                self._breakers.put(task.breaker_key, br)
            br.failures += 1
            now = time.monotonic()
            if br.failures >= self.breaker_threshold and now >= br.opened_until:
                br.opened_until = now + self.breaker_cooldown_s
                br.failures = 0
                self.stats.inc(breaker_opens=1)

    def _resolve_from_leader(self, task: _Task, leader: Future) -> None:
        exc = leader.exception()
        if exc is not None:
            self._fail(task, exc)
        else:
            self._finish(task, leader.result())

    # ------------------------------------------------------------------
    # partial-level single-flight (installed as session.fold_gate)
    # ------------------------------------------------------------------

    def _fold_gate(self, pkey: Tuple,
                   fn: Callable[[], Tuple]) -> Tuple[Tuple, bool]:
        """Single-flight one block fold across concurrent queries.

        The first thread to miss on ``pkey`` runs ``fn`` (fetch + fold +
        put_partial); every thread that arrives while it runs blocks on
        the entry's event and receives the leader's result with
        ``coalesced=True`` — the session accounts those as partial
        reuses, so ``BlockStore.stats.folds`` counts each distinct
        partial exactly once however many queries needed it.

        The gate doubles as the mid-execution deadline checkpoint: it
        runs once per cold block, so an execution whose every consumer
        has expired (or abandoned) aborts here — between blocks, never
        mid-fold — instead of folding the rest of the table for nobody.
        """
        self._check_deadline()
        with self._gate_lock:
            entry = self._gate_inflight.get(pkey)
            leader = entry is None
            if leader:
                entry = _GateEntry()
                self._gate_inflight[pkey] = entry
        if leader:
            try:
                entry.result = fn()
            except BaseException as e:   # noqa: BLE001 — wake followers
                entry.exc = e
                raise
            finally:
                entry.event.set()
                with self._gate_lock:
                    self._gate_inflight.pop(pkey, None)
            return entry.result, False
        # follower: bounded waits so an expired query stops following a
        # slow leader instead of blocking past its own deadline
        while not entry.event.wait(timeout=0.05):
            self._check_deadline()
        if entry.exc is not None:
            raise entry.exc
        self.stats.inc(partial_coalesce_hits=1)
        return entry.result, True
