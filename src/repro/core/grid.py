"""GridSession — the paper's backend API behind one session object.

The paper's contribution is an *interface* (Table 1): Upload, Retrieve,
Remove, a heterogeneity-aware Load balancer, and MapReduce templates over
colocated storage.  The repo implements each piece as a standalone module
(:mod:`table`, :mod:`regions`, :mod:`balancer`, :mod:`placement`,
:mod:`mapreduce`, :mod:`query`); ``GridSession`` owns the whole
table → regions → blockstore → balancer → placement → mapreduce → query
lifecycle and exposes the five verbs:

- :meth:`upload`    — batch insert with split handling and incremental
  placement (split children inherit their parent's node, HBase-style);
- :meth:`retrieve`  — the Table-1 selector read path;
- :meth:`remove`    — row deletion with dirty-region invalidation;
- :meth:`rebalance` — the paper's offline #CPU×MIPS balancer, applied to the
  *current* allocation (minimum region moves); ``auto=True`` derives node
  powers from :meth:`observe_round` history through the wired
  :class:`GridScheduler` / ``powers_from_observations`` loop;
- :meth:`scan`      — the query surface: a lazy :class:`GridQuery` plan
  (``scan(...).select(...).where(...).map(...).reduce()``) that prunes
  regions, pushes the projection down, and fuses all mapped statistics into
  one engine pass when ``.collect()``/``.stats()`` executes it;
- :meth:`run` / :meth:`run_where` — thin wrappers over :meth:`scan` for the
  full table and the predicate-pushdown subset.

Beneath every executed plan sits the :class:`~repro.core.blockstore
.BlockStore`: a content-addressed, copy-on-write cache of per-region device
blocks keyed by ``(region signature, column, epoch-lineage)`` — and, stacked
on it, the **block-granular fold engine**.  Compute plans never assemble a
monolithic ``[D, C, ...]`` layout: each surviving block folds independently
on its owner device (:meth:`MapReduceEngine.fold_block`), the tiny partials
merge+finalize in one jitted reduce, and three content-addressed cache
levels make repeated compute collapse:

1. **Partial cache** (in the BlockStore).  Each block's fold result is
   cached under ``(block lineage, program, row-mask signature, η)``.  A
   mutation bumps only the touched regions' versions, so a repeat query
   re-folds exactly the dirty blocks and *merges* everything else; a repeat
   query at an unchanged table folds **zero payload rows**.  Mask
   signatures are content hashes — a range scan that exactly covers a
   region shares partials with the full-table plan, and two predicates
   selecting the same rows share partials too.
2. **Result cache.**  The finalized answer is memoized under the plan's
   full partial-key set: an identical re-execution returns without touching
   blocks, partials, or the engine.  Entries die eagerly when a member
   region's content changes and survive rebalances (the answer doesn't
   depend on which device folded it).
3. **Block cache.**  Blocks are fetched store-first only when a fold needs
   payload, so overlapping plans, later epochs, and retrieves ship each
   region's content once per (content, owner device).  The ``QueryStats``
   oracles (``blocks_*``, ``partials_*``, ``rows_folded``, ``gather_path``)
   make every level observable.

Pushdowns still run before any bytes move: region pruning (two bisects over
region start keys), index-family-only predicates (§2.3) folded through
per-block row masks, and projection.  Cold low-selectivity one-shot scans
take an **adaptive compact gather** (ship only the selected rows, cache
nothing) instead of whole-region blocks — the block path's shareability tax
is only paid where reuse can come (``compact_gather_threshold``).

Plans stratify and widen without extra passes: ``.select([c1, c2])`` folds
every mapped program over each selected column (per-column result-cache
entries, one scan resolution), and ``.group_by(key)`` lifts the fusion to
group-keyed partials (:class:`~repro.core.stats.GroupedProgram`) — each
block segment-sums all G strata in its one fold, so groups never multiply
gathers, folds, or compiles.

On multi-chip meshes each block commits to its owner via per-shard
``device_put`` and folds there — payload never crosses the interconnect;
only partials travel for the merge, which tree-reduces across the owner
devices (owner-local pre-merge, one ``psum`` over the data axis) for
additive programs and funnels to one device otherwise
(``QueryStats.merge_path``).  Meshes without a one-device-per-node
data axis fold host blocks on the default device (blocks still dedupe the
gathers).
"""

from __future__ import annotations

import dataclasses
import hashlib
import os
import re
import shutil
import tempfile
from typing import (
    Any, Callable, Dict, FrozenSet, List, Mapping, Optional, Sequence, Set,
    Tuple,
)

import numpy as np

import jax

from repro.core.balancer import (
    NodeSpec,
    allocation_imbalance,
    powers_from_observations,
    rebalance as rebalance_allocation,
)
from repro.core.blockstore import (
    AtomicStats, BlockStore, DeviceBlock, LRUCache,
)
from repro.core.chunk_model import TierCostModel
from repro.core.faults import (
    DeviceLostError,
    FaultInjector,
    RetryPolicy,
    TransientFaultError,
)
from repro.core.mapreduce import MapReduceEngine, MapReduceProgram, MapReduceStats
from repro.core.placement import Placement
from repro.core.plan import GridQuery, prefix_range
from repro.core.query import Predicate, QueryStats, indexed_query
from repro.core.regions import Region
from repro.core.scheduler import GridScheduler
from repro.core.stats import FusedProgram, GroupedProgram, GroupedResult
from repro.core.table import (
    DATA_FAMILY,
    INDEX_FAMILY,
    RowKey,
    TensorTable,
    _as_key,
)
from repro.utils import make_mesh

#: auto-named session spill dirs: grid-spill-<pid>-<hex session id>
_SPILL_DIR_RE = re.compile(r"^grid-spill-(\d+)-[0-9a-f]+$")


def _pid_alive(pid: int) -> bool:
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return False
    except OSError:       # e.g. EPERM: the pid exists, owned by another user
        return True
    return True


def sweep_stale_spill_dirs(root: Optional[str] = None) -> int:
    """Best-effort removal of spill dirs leaked by *dead* sessions.

    The ``atexit``/``close`` teardown covers normal exits, but a SIGKILL
    (OOM killer, job scheduler preemption — routine on the paper's shared
    grid) leaves ``grid-spill-<pid>-*`` dirs behind.  Every session
    startup sweeps its temp root for dirs whose embedded pid no longer
    runs; live sessions (including our own process) are never touched.
    Returns the number of directories removed.
    """
    root = root if root is not None else tempfile.gettempdir()
    try:
        names = os.listdir(root)
    except OSError:
        return 0
    swept = 0
    for name in names:
        m = _SPILL_DIR_RE.match(name)
        if m is None:
            continue
        pid = int(m.group(1))
        if pid == os.getpid() or _pid_alive(pid):
            continue
        path = os.path.join(root, name)
        if os.path.isdir(path):
            shutil.rmtree(path, ignore_errors=True)
            swept += 1
    return swept


@dataclasses.dataclass
class SessionMetrics(AtomicStats):
    """Observable counters for the session's incremental machinery.

    Updated through :meth:`~repro.core.blockstore.AtomicStats.inc` —
    concurrent frontend queries bump these from many threads, and a bare
    ``+=`` on a shared field loses updates.  Consistent multi-counter
    reads go through ``snapshot()``."""

    uploads: int = 0
    removes: int = 0
    rebalances: int = 0
    epochs: int = 0                 # mutation epochs advanced
    regions_dirtied: int = 0
    plan_hits: int = 0              # executions served whole from the result cache
    plan_misses: int = 0
    partials_folded: int = 0        # per-block folds executed (map tasks run)
    partials_reused: int = 0        # per-block partials served from the cache
    rows_folded: int = 0            # payload rows read by per-block folds
    rows_gathered: int = 0          # payload rows copied into device blocks
    pushdown_rows_gathered: int = 0  # payload rows gathered by pruned scans
    compact_scans: int = 0          # plans routed to the compacted one-shot gather
    scans: int = 0                  # GridQuery plans executed
    payload_gathers: int = 0        # payload gather passes (block or compact)
    programs_fused: int = 0         # programs that shared a fused engine pass
    # (session-lifetime block reuse counters live on BlockStore.stats —
    # hits/gathers/transfers/evictions/partial_hits/folds — not duplicated
    # here)


@dataclasses.dataclass(frozen=True)
class RunReport:
    """Accounting for one executed plan (``run``/``run_where``/``collect``)."""

    epoch: int
    eta: int
    plan_cache_hit: bool
    mapreduce: Optional[MapReduceStats]   # None for pure retrieve plans
    query: Optional[QueryStats] = None


class _SessionScheduler(GridScheduler):
    """The session-owned scheduler is observation/planning only.

    Node membership is pinned by the mesh (one device per node), and region
    moves must flow through :meth:`GridSession.rebalance` so mutation epochs
    invalidate cached layouts/plans — the fail/join verbs would mutate the
    shared placement behind the session's back, leaving stale device maps.
    """

    def handle_failure(self, dead_node_ids):
        raise NotImplementedError(
            "the session-owned scheduler cannot change node membership: the "
            "mesh pins one device per node; use GridSession.rebalance "
            "(optionally with refreshed NodeSpecs) for region moves")

    def handle_join(self, new_nodes):
        raise NotImplementedError(
            "the session-owned scheduler cannot change node membership: the "
            "mesh pins one device per node; use GridSession.rebalance "
            "(optionally with refreshed NodeSpecs) for region moves")


@dataclasses.dataclass
class _BlockAccount:
    """Per-execution block accounting, folded into ``QueryStats`` oracles."""

    total: int = 0
    reused: int = 0
    transferred: int = 0
    gathered: int = 0
    rows_gathered: int = 0
    bytes_transferred: int = 0

    def add(self, blk: DeviceBlock, reused: bool, gathered: bool) -> None:
        self.total += 1
        if reused:
            self.reused += 1
        else:
            self.transferred += 1
            # physical: the committed device copy may be fold-bucket padded
            self.bytes_transferred += blk.device_nbytes or blk.nbytes
        if gathered:
            self.gathered += 1
            self.rows_gathered += blk.rows

    @classmethod
    def all_reused(cls, n: int) -> "_BlockAccount":
        return cls(total=n, reused=n)

    def apply(self, qstats: QueryStats) -> QueryStats:
        return dataclasses.replace(
            qstats, blocks_total=self.total, blocks_reused=self.reused,
            blocks_transferred=self.transferred, gather_count=self.gathered,
            payload_bytes_transferred=self.bytes_transferred)


@dataclasses.dataclass
class _ResultEntry:
    """One cached query answer, content-addressed by its partial keys.

    The result cache closes the loop over the partial cache: a repeat
    execution whose every block lineage + row-mask signature is unchanged
    returns the finalized result without touching blocks, partials, or the
    engine.  Entries die eagerly when a mutation touches a member region
    (``_advance_epoch``) — a content change makes the key unmatchable
    forever — but survive rebalances: the answer does not depend on which
    device folded it.
    """

    result: Any
    partials_total: int        # foldable blocks the plan spanned
    blocks_total: int          # all blocks (incl. empty-selection regions)
    region_ids: FrozenSet[int] = frozenset()
    gather_path: str = "blocks"  # which path the miss execution took
    last_used: int = 0         # epoch of the last execution through this entry


@dataclasses.dataclass
class _RegionWork:
    """One surviving region's slice of a plan: owner device, positional
    row range (regions are contiguous in the sorted table), and the
    row-mask signature that content-addresses its partial."""

    region: Region
    owner: Optional[int]
    rows: slice
    mask_sig: str              # "full" | "empty" | digest of the bool mask
    selected: int              # mask-true rows (0 = nothing to fold)

    @property
    def n_rows(self) -> int:
        return self.rows.stop - self.rows.start


@dataclasses.dataclass
class _GroupInfo:
    """A plan's resolved stratification: the group-key column(s), the dense
    value→gid mapping over the *selected* rows, and the signature that
    content-addresses group-keyed partials (a gid assignment is only
    meaningful under the exact global mapping it was derived from).

    Composite keys (``group_by(["idx:site", "idx:scanner"])``) densify to
    ONE gid space: each column factorizes independently, the per-row codes
    combine lexicographically in listed-column order, and the observed
    combinations become gids 0..G-1 — so a stratified fold still segment-
    sums a single ``[G, ...]`` partial per block.  ``keys`` labels groups
    with scalar values for a single key column and with tuples (listed
    order) for composites.  The signature hashes the ordered column names
    with the mapping, so ``["site", "scanner"]`` and ``["scanner",
    "site"]`` address different partials.

    Only the distinct values (``keys`` — needed every execution for the
    result-cache key and the returned group labels) are materialized, and
    even they are memoized per plan lineage; per-row gids are derived
    lazily per region slice (:meth:`gids_for`), so result-cache hits and
    reused partials never pay a full-column densification."""

    columns: Tuple[Tuple[str, str], ...]  # (family, qualifier) per key col
    keys: np.ndarray           # [G] group labels: scalars or tuples
    per_col_keys: Tuple[np.ndarray, ...]  # per-column distinct values, asc
    combo_codes: np.ndarray    # [G] observed combined codes, ascending
    sig: str                   # digest of (ordered columns, mapping)
    row_nbytes: int            # per-row key bytes, all columns (accounting)

    @property
    def family(self) -> str:
        """Joined family label for gid-block cache addressing (the sig
        already pins the exact column set and order)."""
        return "|".join(f for f, _ in self.columns)

    @property
    def qualifier(self) -> str:
        return "|".join(q for _, q in self.columns)

    @property
    def num_groups(self) -> int:
        return len(self.combo_codes)

    def gids_for(self, values) -> np.ndarray:
        """Dense int32 group ids for one region's key-column rows —
        computed only when a block actually folds (partial-cache miss).
        ``values`` is one array (single key) or a tuple of per-column
        arrays (composite key, listed order), read from the table at call
        time (positions may shift under unrelated mutations; the mapping
        itself is pinned by the lineage-keyed memo).  Values outside the
        selected universe land on a clipped (valid but masked-off) gid."""
        cols = values if isinstance(values, (tuple, list)) else (values,)
        if len(cols) != len(self.columns):
            raise ValueError(
                f"expected {len(self.columns)} key column(s), got {len(cols)}")
        if not len(self.combo_codes):
            return np.zeros(len(cols[0]), np.int32)
        combined = np.zeros(len(cols[0]), np.int64)
        for vals, uniq in zip(cols, self.per_col_keys):
            code = np.searchsorted(uniq, vals).clip(
                0, max(len(uniq) - 1, 0))
            combined = combined * max(len(uniq), 1) + code
        return np.searchsorted(self.combo_codes, combined).clip(
            0, len(self.combo_codes) - 1).astype(np.int32)


@dataclasses.dataclass
class _ColumnOutcome:
    """One computed column's slice of a plan execution, combined by
    ``_run_fold`` into the plan-level ``QueryStats``/``RunReport``."""

    result: Any
    hit: bool                          # served whole from the result cache
    gather_path: str
    merge_path: str
    acct: _BlockAccount
    partials_total: int
    partials_reused: int
    rows_folded: int
    mr: MapReduceStats


class GridSession:
    """One object owning the grid lifecycle; the five-verb facade."""

    #: cached results untouched for this many epochs are evicted — a stale
    #: entry pins its finalized device arrays, so a long-lived mutating
    #: session must not keep it forever.
    RESULT_TTL_EPOCHS = 64

    def __init__(
        self,
        table: TensorTable,
        mesh: Optional[jax.sharding.Mesh] = None,
        nodes: Optional[Sequence[NodeSpec]] = None,
        strategy: str = "greedy",
        data_axis: str = "data",
        default_eta: int = 16,
        payload_family: str = DATA_FAMILY,
        payload_qualifier: str = "data",
        index_family: str = INDEX_FAMILY,
        plan_cache_cap: int = 64,
        block_cache_cap: Optional[int] = 256,
        partial_cache_cap: Optional[int] = 1024,
        compact_gather_threshold: float = 0.05,
        fold_impl: str = "pallas",
        fold_interpret: bool = False,
        device_budget: Optional[int] = None,
        host_budget: Optional[int] = None,
        disk_budget: Optional[int] = None,
        partial_budget: Optional[int] = None,
        spill_dir: Optional[str] = None,
        cost_model: Optional["TierCostModel"] = None,
        prefetch: bool = True,
        fault_injector: Optional[FaultInjector] = None,
        retry_policy: Optional[RetryPolicy] = None,
    ):
        self.table = table
        self.mesh = (mesh if mesh is not None
                     else make_mesh((jax.device_count(),), (data_axis,)))
        self.data_axis = data_axis
        D = self.mesh.shape[data_axis]
        if nodes is None:
            nodes = [NodeSpec(i) for i in range(D)]
        if len(nodes) != D:
            raise ValueError(
                f"{len(nodes)} nodes for mesh axis {data_axis!r} of size {D}")
        self.default_eta = int(default_eta)
        self.payload_family = payload_family
        self.payload_qualifier = payload_qualifier
        self.index_family = index_family
        #: cold scans below this selectivity (and with no cached blocks or
        #: partials to reuse) gather compacted selected rows instead of
        #: whole-region blocks — the adaptive one-shot path that recovers
        #: the pre-block cold cost where reuse never comes.  0 disables.
        self.compact_gather_threshold = float(compact_gather_threshold)

        self.placement = Placement.from_strategy(table, nodes, strategy)
        self.table.split_log.clear()  # from_strategy saw the current regions
        #: ``fold_impl="pallas"`` (default) streams CSE-eligible block
        #: folds through the fused Pallas kernel where the platform
        #: supports it, falling back per fold signature (see
        #: ``MapReduceEngine.fold_path``); ``"xla"`` forces the reference
        #: fold.  ``fold_interpret=True`` runs the kernel in interpret
        #: mode off-TPU (the test/bench harness on CPU).
        self.engine = MapReduceEngine(self.mesh, data_axis,
                                      fold_impl=fold_impl,
                                      fold_interpret=fold_interpret,
                                      fault_injector=fault_injector)
        self.metrics = SessionMetrics()
        #: chaos harness + recovery policy.  ``fault_injector`` (usually
        #: None outside tests/benches) fires injected faults at the named
        #: sites; ``retry_policy`` bounds the in-place retries wrapped
        #: around device transfers, table gathers, folds, and spill I/O.
        #: Owner devices that fail PERMANENTLY land in ``_quarantined``
        #: and their regions re-home through the balancer (see
        #: :meth:`_quarantine`).
        self.faults = fault_injector
        self.retry_policy = (retry_policy if retry_policy is not None
                             else RetryPolicy())
        self._quarantined: Set[int] = set()
        #: tiered storage (device HBM → host RAM → disk): any byte budget
        #: bounds its tier; ``spill_dir`` enables the disk tier (a
        #: session-private temp dir is created — and removed on
        #: :meth:`close` — when a host/disk budget is set without one).
        #: ``cost_model`` tunes the spill-vs-refetch-vs-refold oracle;
        #: ``prefetch`` runs the background promotion worker that overlaps
        #: ``device_put`` of lower-tier blocks with in-flight folds.
        tiering = any(b is not None for b in
                      (device_budget, host_budget, disk_budget,
                       partial_budget)) or spill_dir is not None
        if spill_dir is None and (host_budget is not None
                                  or disk_budget is not None):
            # a crashed predecessor can't clean up after itself: sweep its
            # leaked dirs before creating our own under the same root
            sweep_stale_spill_dirs()
            spill_dir = os.path.join(
                tempfile.gettempdir(),
                f"grid-spill-{os.getpid()}-{id(self):x}")
        self.blocks = BlockStore(
            cap=block_cache_cap, partial_cap=partial_cache_cap,
            device_budget=device_budget, host_budget=host_budget,
            disk_budget=disk_budget, partial_budget=partial_budget,
            spill_dir=spill_dir, cost_model=cost_model,
            prefetch_workers=1 if (prefetch and tiering) else 0,
            fault_injector=fault_injector, retry_policy=self.retry_policy)
        self._tiering = tiering
        if fault_injector is not None and fault_injector.on_fire is None:
            # mirror every observed fire into the store's counters so one
            # snapshot tells the whole fault story
            fault_injector.on_fire = (
                lambda site, kind: self.blocks.stats.inc(faults_injected=1))

        self._epoch = 0
        # content-addressed finalized results: (program, partial keys, ...)
        # -> _ResultEntry.  The only plan-level cache the fold engine needs —
        # bound layouts and per-plan gathered blocks are gone; partials (in
        # the BlockStore) carry all cross-plan, cross-epoch compute reuse.
        self._results: LRUCache = LRUCache(plan_cache_cap)
        # (epoch, work list) for full-table plans — see _run_fold
        self._full_work: Optional[Tuple[int, List[_RegionWork]]] = None
        # resolved group mappings keyed (column, plan lineage) — repeat
        # grouped queries skip the unique+hash over the selection
        self._groups: LRUCache = LRUCache(32)
        self._node_index = {n.node_id: d for d, n in enumerate(nodes)}
        # per-shard devices for block placement: available when the mesh is
        # exactly the 1-D data axis (one device per node); otherwise None
        # and layouts fall back to host-side assembly
        self._devices = (list(np.asarray(self.mesh.devices).flat)
                         if self.mesh.axis_names == (data_axis,) else None)
        # observed per-node round times (observe_round) -> auto-rebalance
        self._round_history: Dict[int, List[float]] = {
            n.node_id: [] for n in nodes
        }
        self._scheduler: Optional[GridScheduler] = None
        #: optional single-flight hook for cross-query partial coalescing
        #: (installed by :class:`repro.core.frontend.GridFrontend`).  Called
        #: as ``fold_gate(pkey, fn) -> (fn_result, coalesced)`` on every
        #: partial-cache miss: a leader runs ``fn`` (fetch + fold +
        #: put_partial) and followers blocked on the same ``pkey`` receive
        #: the leader's result with ``coalesced=True``, which this session
        #: accounts as a partial reuse rather than a second fold.
        self.fold_gate: Optional[Callable[[Tuple, Callable[[], Tuple]],
                                          Tuple[Tuple, bool]]] = None

    # ------------------------------------------------------------------
    # epoch / dirty tracking
    # ------------------------------------------------------------------

    @property
    def epoch(self) -> int:
        return self._epoch

    def _advance_epoch(self, dirty_rids: Set[int],
                       touch_blocks: bool = True,
                       dropped_rids: FrozenSet[int] = frozenset()) -> None:
        self._epoch += 1
        self.metrics.inc(epochs=1, regions_dirtied=len(dirty_rids))
        if touch_blocks:
            # copy-on-write: only the touched regions' blocks and partials
            # version-bump; every other block, partial, and cached result
            # over untouched regions survives the mutation structurally
            self.blocks.touch(dirty_rids, self._epoch)
            # results spanning a dirtied region — or a split parent whose
            # rid will never reappear (dropped_rids) — are keyed on dead
            # lineage and can never hit again: release their device arrays
            # now.  Rebalance epochs (touch_blocks=False) skip this: a
            # result does not depend on which devices folded it.
            doomed = set(dirty_rids) | set(dropped_rids)
            dead = [k for k, e in self._results.items()
                    if e.region_ids & doomed]
            for k in dead:
                self._results.pop(k)
        self._prune_caches()

    def _prune_caches(self) -> None:
        """Evict long-idle cached results — they pin finalized device
        arrays, so a long-lived mutating session must not keep them
        forever.  (The LRU cap bounds entry COUNT; this bounds idle
        LIFETIME across mutation epochs.)"""
        idle = [k for k, e in self._results.items()
                if self._epoch - e.last_used > self.RESULT_TTL_EPOCHS]
        for k in idle:
            self._results.pop(k)

    # ------------------------------------------------------------------
    # the five verbs
    # ------------------------------------------------------------------

    def upload(
        self,
        rowkeys: Sequence[RowKey],
        data: Mapping[str, Mapping[str, np.ndarray]],
        on_duplicate: str = "skip",
    ) -> int:
        """Table-1 Upload: batch insert with incremental placement.

        Splits triggered by the insert keep daughters on the parent's node
        (rebalancing is an explicit :meth:`rebalance` call, as in the paper);
        only the regions containing the uploaded keys are invalidated.
        """
        # under "skip", duplicates leave their rows untouched — only the keys
        # actually written may dirty a region, so snapshot existence first
        keys = np.array([_as_key(k) for k in rowkeys], dtype="S64")
        if on_duplicate == "skip" and len(keys):
            written_keys = keys[~self.table.existing_mask(rowkeys)]
        else:
            written_keys = keys
        written = self.table.upload(rowkeys, data, on_duplicate=on_duplicate)
        self.metrics.inc(uploads=1)
        if not written:
            self.table.split_log.clear()
            return 0
        # split parents' rids never reappear: forget their blocks (and evict
        # cached results spanning them) before apply_splits consumes the
        # log, or they'd pin payload until cap pressure (their region set
        # membership is gone for good)
        parents = frozenset(
            parent.rid for parent, _, _ in self.table.split_log)
        self.blocks.drop_regions(parents)
        self.placement.apply_splits()
        dirty = self.table.regions.regions_containing(
            [bytes(k) for k in written_keys])
        self._advance_epoch(dirty, dropped_rids=parents)
        return written

    def retrieve(
        self,
        family: str,
        qualifier: str,
        rowkey: Optional[RowKey] = None,
        start: Optional[RowKey] = None,
        stop: Optional[RowKey] = None,
        skip: Optional[Sequence[RowKey]] = None,
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Table-1 Retrieve: ``(rowkeys, values)`` for the selector."""
        return self.table.retrieve(family, qualifier, rowkey=rowkey,
                                   start=start, stop=stop, skip=skip)

    def remove(
        self,
        rowkey: Optional[RowKey] = None,
        start: Optional[RowKey] = None,
        stop: Optional[RowKey] = None,
        skip: Optional[Sequence[RowKey]] = None,
    ) -> int:
        """Table-1 Remove: delete rows, invalidating only their regions.

        Only the touched regions' block versions bump: every other region's
        device block is reused object-for-object by the next layout build
        (the block-identity tests pin this)."""
        doomed = [bytes(k) for k in
                  self.table.select_keys(rowkey, start, stop, skip)]
        removed = self.table.delete(rowkey=rowkey, start=start, stop=stop,
                                    skip=skip)
        self.metrics.inc(removes=1)
        if removed:
            self._advance_epoch(self.table.regions.regions_containing(doomed))
        return removed

    def observe_round(self, node_times: Mapping[int, float]) -> None:
        """Feed measured per-node round times (the runtime re-measurement of
        the paper's ``linux perf`` MIPS probe).

        Observations accumulate in the session AND drive the wired
        :class:`GridScheduler` (its EWMA powers back ``makespan_estimate``
        and the round ledger); :meth:`rebalance` with ``auto=True`` then
        derives node powers from this history via
        :func:`~repro.core.balancer.powers_from_observations` — no
        hand-supplied specs needed.
        """
        for nid, t in node_times.items():
            if nid in self._round_history and t > 0:
                hist = self._round_history[nid]
                hist.append(float(t))
                del hist[:-self.ROUND_HISTORY_CAP]
        self.scheduler.observe_round(node_times)

    #: round-time observations kept per node; the EWMA power fold saturates
    #: long before this, and an unbounded log would grow with session age
    ROUND_HISTORY_CAP = 64

    @property
    def scheduler(self) -> GridScheduler:
        """The session's passive :class:`GridScheduler` (observation ledger,
        makespan estimates).  Its auto-trigger threshold is infinite and its
        membership verbs are disabled — region moves stay under the
        session's explicit :meth:`rebalance`, which is what keeps
        epochs/dirty-tracking consistent."""
        if self._scheduler is None:
            self._scheduler = _SessionScheduler(
                self.placement, chunk_size=self.default_eta,
                rebalance_threshold=float("inf"))
        return self._scheduler

    def rebalance(
        self,
        tolerance: float = 0.05,
        nodes: Optional[Sequence[NodeSpec]] = None,
        auto: bool = False,
    ) -> List[int]:
        """The paper's offline balancer from the *current* allocation.

        ``nodes`` swaps in refreshed specs (elastic rescale, straggler
        deweighting via :func:`~repro.core.balancer.powers_from_observations`)
        — node ids must be the existing ones.  ``auto=True`` derives those
        specs from the round times fed to :meth:`observe_round` instead
        (no observations yet -> powers unchanged).  Returns moved region ids.

        Moves do NOT bump block content versions: a moved region's payload is
        unchanged, so its cached host block re-commits to the new owner
        device (one transfer, zero table re-reads) while unmoved regions'
        device blocks are reused as-is.
        """
        if auto:
            if nodes is not None:
                raise ValueError(
                    "auto=True derives nodes from observe_round history; "
                    "pass one or the other")
            if any(self._round_history.values()):
                nodes = powers_from_observations(
                    self._round_history, self.placement.nodes)
        if nodes is not None:
            if {n.node_id for n in nodes} != set(self._node_index):
                raise ValueError("rebalance nodes must keep the same node ids")
            order = sorted(nodes, key=lambda n: self._node_index[n.node_id])
            self.placement.nodes = tuple(order)
        old = dict(self.placement.alloc)
        new_alloc, moved = rebalance_allocation(
            old, self.table.region_bytes(), self.placement.nodes, tolerance)
        self.metrics.inc(rebalances=1)
        if moved:
            self.placement.alloc.clear()
            self.placement.alloc.update(new_alloc)
            self.placement.version += 1
            self._advance_epoch(set(moved), touch_blocks=False)
        return moved

    # ------------------------------------------------------------------
    # permanent owner failure: quarantine + re-home
    # ------------------------------------------------------------------

    @property
    def quarantined_devices(self) -> FrozenSet[int]:
        """Device indices permanently quarantined after a non-transient
        failure; their regions were re-homed onto the survivors."""
        return frozenset(self._quarantined)

    def _quarantine(self, owner: Optional[int]) -> None:
        """Permanent owner failure: mark the device dead and re-home its
        regions through the balancer.  Idempotent per device; the first
        call counts one ``quarantines`` and pays one re-home epoch."""
        if owner is None or owner in self._quarantined:
            return
        self._quarantined.add(owner)
        if self.faults is not None:
            # keep the injector's sticky lost-set consistent even when the
            # loss was detected (a real device_put error), not injected
            self.faults.lost_devices.add(owner)
        self.blocks.stats.inc(quarantines=1)
        self._rehome_quarantined()

    def _rehome_quarantined(self) -> List[int]:
        """Drain every quarantined device's regions onto the survivors.

        This is the paper's region-server failover expressed through the
        offline balancer: dead nodes are simply *absent* from the node
        list handed to :func:`~repro.core.balancer.rebalance`, so their
        regions are treated as homeless and re-assigned first, and the
        survivors rebalance around the new load.  Like any rebalance, the
        move bumps the placement version and advances a
        ``touch_blocks=False`` epoch — block content versions are
        untouched, so every still-resident host/disk block and cached
        partial survives and a moved region re-commits to its new owner
        with one ``device_put`` and ZERO table re-reads.  With no live
        node left the session keeps serving host-degraded (folds run on
        host copies; nothing is re-homed)."""
        live = [n for d, n in enumerate(self.placement.nodes)
                if d not in self._quarantined]
        if not live:
            return []
        old = dict(self.placement.alloc)
        new_alloc, moved = rebalance_allocation(
            old, self.table.region_bytes(), live, tolerance=0.05)
        self.metrics.inc(rebalances=1)
        if moved:
            self.placement.alloc.clear()
            self.placement.alloc.update(new_alloc)
            self.placement.version += 1
            self._advance_epoch(set(moved), touch_blocks=False)
        return moved

    # ------------------------------------------------------------------
    # GridQuery: lazy scan -> filter -> map -> reduce plans
    # ------------------------------------------------------------------

    def scan(
        self,
        prefix: Optional[RowKey] = None,
        start: Optional[RowKey] = None,
        stop: Optional[RowKey] = None,
    ) -> GridQuery:
        """Open a lazy :class:`GridQuery` plan over a rowkey range.

        ``prefix`` is sugar for the half-open range of keys sharing it
        (mutually exclusive with ``start``/``stop``).  Nothing is scanned,
        gathered, or compiled until ``.collect()``/``.stats()`` — the
        planner prunes regions, pushes the projection down, and fuses every
        ``.map`` program into one engine pass first.
        """
        if prefix is not None:
            if start is not None or stop is not None:
                raise ValueError("prefix is exclusive with start/stop")
            p, (start_b, stop_b) = _as_key(prefix), prefix_range(prefix)
            return GridQuery(self, start=start_b, stop=stop_b, prefix=p)
        return GridQuery(
            self,
            start=None if start is None else _as_key(start),
            stop=None if stop is None else _as_key(stop),
        )

    def run(
        self,
        program: MapReduceProgram,
        eta: Optional[int] = None,
        family: Optional[str] = None,
        qualifier: Optional[str] = None,
        impl: Optional[str] = None,
    ) -> Tuple[Any, RunReport]:
        """MapReduce over the whole table — a full-range one-program plan.

        ``impl="pallas"`` swaps a sum/count-family program for its Pallas
        ``streaming_stats``-backed map phase (see
        :func:`repro.kernels.streaming_stats.ops.kernel_map_program`);
        ``impl="ref"``/``None`` keeps the jnp reference fold.  The kernel
        program has its own cache identity, so ref and pallas runs keep
        separate partials and can be compared side by side.

        Orthogonally, the *fold phase itself* runs on the fused Pallas
        fold kernel whenever the session-level ``fold_impl="pallas"``
        switch is on and the fold signature is eligible (see
        ``MapReduceEngine.fold_path``) — that path needs no per-call
        opt-in here.
        """
        if impl is not None and impl != "ref":
            from repro.kernels.streaming_stats.ops import kernel_map_program
            program = kernel_map_program(program, impl=impl)
        q = self.scan().select(
            (family or self.payload_family,
             qualifier or self.payload_qualifier)).map(program)
        return q.collect(eta=eta)

    def run_where(
        self,
        predicate: Predicate,
        program: MapReduceProgram,
        index_qualifiers: Sequence[str],
        eta: Optional[int] = None,
        family: Optional[str] = None,
        qualifier: Optional[str] = None,
    ) -> Tuple[Any, RunReport]:
        """Predicate-pushdown MapReduce (§2.3 unified with §2.2) — a
        full-range ``.where`` plan.

        The predicate runs over the index family only; the fold then reads
        *just the selected payload slots* through per-block row masks
        (locality preserved because index and payload share rowkeys and
        placement), so ``QueryStats.payload_bytes_moved`` covers exactly
        the selected rows — never the full table.

        Physical transfer is adaptive: by default a selective query ships
        the surviving regions' whole blocks (observable via
        ``payload_bytes_transferred``), which lets every later plan — any
        predicate, any overlapping range, any later epoch — reuse blocks
        AND per-block fold partials without re-shipping or re-folding.  A
        COLD query below ``compact_gather_threshold`` selectivity with no
        cached state to reuse ships only the compacted selected rows
        instead (``QueryStats.gather_path == "compact"``).
        """
        q = (self.scan()
             .select((family or self.payload_family,
                      qualifier or self.payload_qualifier))
             .where(predicate, index_qualifiers)
             .map(program))
        return q.collect(eta=eta)

    # ------------------------------------------------------------------
    # the planner/executor behind GridQuery
    # ------------------------------------------------------------------

    def _execute_plan(
        self, plan: GridQuery, eta: Optional[int] = None
    ) -> Tuple[Any, RunReport]:
        """Compile + execute a :class:`GridQuery` with all three pushdowns."""
        eta = int(eta or self.default_eta)
        self.metrics.inc(scans=1)
        if not plan.programs:
            if plan.group_key is not None:
                raise ValueError(
                    "group_by needs at least one map(program); a grouped "
                    "retrieve has no statistic to stratify")
            return self._collect_rows(plan, eta)
        program: MapReduceProgram
        if len(plan.programs) == 1:
            program = plan.programs[0]
        else:
            program = FusedProgram(plan.programs)
            self.metrics.inc(programs_fused=len(plan.programs))
        return self._run_fold(plan, program, eta)

    @staticmethod
    def _mask_sig(mask_slice: np.ndarray) -> str:
        """Content signature of one region's selected-row mask.

        ``"full"`` and ``"empty"`` are canonical — a range scan that exactly
        covers a region shares partials with the full-table plan; anything
        else hashes the packed mask bits plus the length (packbits pads to
        byte boundaries, so the length disambiguates).
        """
        if mask_slice.all():
            return "full"
        if not mask_slice.any():
            return "empty"
        h = hashlib.blake2b(digest_size=12)
        h.update(len(mask_slice).to_bytes(8, "little"))
        h.update(np.packbits(mask_slice).tobytes())
        return h.hexdigest()

    def _plan_work(
        self, mask: Optional[np.ndarray], regions: Sequence[Region]
    ) -> List[_RegionWork]:
        """Per-region work items, in start-key order: owner device,
        positional row range, and the partial-addressing mask signature.
        This runs on EVERY execution (it builds the result-cache key), so
        it stays allocation-light: slices, not index arrays."""
        work = []
        keys = self.table.keys
        alloc = self.placement.alloc
        for region in regions:
            owner = self._node_index.get(alloc.get(region.rid))
            if owner is not None and owner in self._quarantined:
                # permanently lost owner whose regions could not re-home
                # (no live node left): serve host-degraded
                owner = None
            rows = region.row_slice(keys)
            n = rows.stop - rows.start
            if n == 0:
                sig, sel = "empty", 0
            elif mask is None:
                sig, sel = "full", n
            else:
                sub = mask[rows]
                sig = self._mask_sig(sub)
                sel = int(sub.sum())
            work.append(_RegionWork(region, owner, rows, sig, sel))
        return work

    def _group_info(self, plan: GridQuery, mask: Optional[np.ndarray],
                    work_sig: Tuple) -> _GroupInfo:
        """Resolve a plan's ``group_by`` key to a dense gid mapping.

        The key column is read like an index column (a few bytes per row,
        never the payload); the distinct values among the *selected* rows
        become group ids 0..G-1 in ascending value order — exactly the
        grouping a NumPy ``np.unique``-based oracle produces.  The mapping
        signature content-addresses every group-keyed partial: a selection
        whose value universe differs folds under a different signature.

        The resolved info is memoized on ``(column, work_sig)`` — the
        plan's region lineage + row-mask signatures pin the selected key
        values exactly, so a repeat grouped query costs an LRU lookup, not
        an O(N log N) unique+hash over the selection.
        """
        key_cols = plan.group_key
        memo_key = (key_cols, work_sig)
        cached = self._groups.get(memo_key)
        if cached is not None:
            return cached
        row_nbytes = 0
        per_col_vals = []
        h = hashlib.blake2b(digest_size=12)
        for gf, gq in key_cols:
            spec = self.table.column_spec(gf, gq)
            if spec.shape != ():
                raise ValueError(
                    f"group_by column {gf}:{gq} must be scalar per row, "
                    f"got shape {spec.shape}")
            row_nbytes += spec.row_nbytes
            col = self.table.column(gf, gq)
            per_col_vals.append(col if mask is None else col[mask])
        per_col_keys = []
        combined = np.zeros(len(per_col_vals[0]), np.int64)
        for (gf, gq), vals in zip(key_cols, per_col_vals):
            uniq, inv = np.unique(vals, return_inverse=True)
            per_col_keys.append(uniq)
            combined = combined * max(len(uniq), 1) + inv.reshape(-1)
            # ordered column identity + per-column universe: the sig
            # distinguishes ["site","scanner"] from ["scanner","site"]
            h.update(f"{gf}:{gq}:{uniq.dtype.str}:{len(uniq)};".encode())
            h.update(uniq.tobytes())
        combo_codes = np.unique(combined)
        h.update(combo_codes.tobytes())
        if len(key_cols) == 1:
            keys = per_col_keys[0]
        else:
            # decode each observed combination back to a tuple label, in
            # listed-column (lexicographic) order
            keys = np.empty(len(combo_codes), object)
            for g, code in enumerate(combo_codes):
                parts = []
                rem = int(code)
                for uniq in reversed(per_col_keys):
                    rem, idx = divmod(rem, max(len(uniq), 1))
                    parts.append(uniq[idx].item()
                                 if hasattr(uniq[idx], "item")
                                 else uniq[idx])
                keys[g] = tuple(reversed(parts))
        info = _GroupInfo(tuple(key_cols), keys, tuple(per_col_keys),
                          combo_codes, h.hexdigest(), row_nbytes)
        self._groups.put(memo_key, info)
        return info

    def _run_fold(
        self, plan: GridQuery, program: MapReduceProgram, eta: int
    ) -> Tuple[Any, RunReport]:
        """The block-granular fold behind every compute plan.

        One scan resolution (range pruning + predicate mask + group-key
        mapping) feeds every computed column; each column then resolves
        independently through (1) the content-addressed result cache — a
        repeat query at unchanged block lineage returns the finalized
        answer and folds zero rows; (2) the adaptive compact gather for
        cold low-selectivity ungrouped one-shots; (3) block-at-a-time
        folding with the partial cache — only blocks whose partial is
        missing are fetched and folded, so a mutation re-folds exactly the
        dirty regions.  Grouped plans fold group-keyed partials (leaves
        gain a leading group axis) in the same single pass per block —
        grouping never multiplies gathers or folds.
        """
        cols = plan.compute_columns()
        full = (plan.start is None and plan.stop is None
                and plan.predicate is None)
        if full:
            mask = None
            # the full-table work list is a pure function of the epoch
            # (regions, row slices, owners, versions all mutate only
            # through _advance_epoch), so the repeat-query hot path skips
            # the per-region bisects entirely
            fw = self._full_work
            if fw is None or fw[0] != self._epoch:
                fw = (self._epoch,
                      self._plan_work(None, tuple(self.table.regions.regions)))
                self._full_work = fw
            work = fw[1]
            n = self.table.num_rows
            qstats = QueryStats(
                rows_scanned=n, index_bytes_scanned=0,
                payload_bytes_traversed=0, rows_selected=n,
                regions_scanned=len(work), regions_pruned=0)
        else:
            mask, qstats, regions = self._scan_mask(plan)
            work = self._plan_work(mask, regions)

        # the plan's lineage signature: region content versions + row-mask
        # signatures — shared by the group-mapping memo and every column's
        # result-cache key
        work_sig = tuple(
            (w.region.signature, self.blocks.version_of(w.region.rid),
             w.mask_sig) for w in work)

        group: Optional[_GroupInfo] = None
        if plan.group_key is not None:
            group = self._group_info(plan, mask, work_sig)
            program = GroupedProgram(program, group.num_groups)
            # the key column is scanned like any index column
            qstats = dataclasses.replace(
                qstats, num_groups=group.num_groups,
                index_bytes_scanned=qstats.index_bytes_scanned
                + qstats.rows_scanned * group.row_nbytes)
        per_row = sum(self.table.column_spec(f, q).row_nbytes
                      for f, q in cols)
        qstats = dataclasses.replace(
            qstats, payload_bytes_moved=qstats.rows_selected * per_row)

        outcomes = [
            self._fold_column(program, eta, mask, work, work_sig, f, q,
                              group)
            for f, q in cols
        ]

        # --- combine per-column outcomes into the plan-level report -------
        acct = _BlockAccount()
        for o in outcomes:
            a = o.acct
            acct.total += a.total
            acct.reused += a.reused
            acct.transferred += a.transferred
            acct.gathered += a.gathered
            acct.rows_gathered += a.rows_gathered
            acct.bytes_transferred += a.bytes_transferred

        def _combine_paths(paths) -> str:
            named = {p for p in paths if p}
            if not named:
                return ""
            return named.pop() if len(named) == 1 else "mixed"

        hit = all(o.hit for o in outcomes)
        if hit:
            self.metrics.inc(plan_hits=1)
        else:
            self.metrics.inc(plan_misses=1)
        qstats = dataclasses.replace(
            acct.apply(qstats),
            gather_path=_combine_paths(o.gather_path for o in outcomes),
            merge_path=_combine_paths(o.merge_path for o in outcomes),
            partials_total=sum(o.partials_total for o in outcomes),
            partials_reused=sum(o.partials_reused for o in outcomes),
            rows_folded=sum(o.rows_folded for o in outcomes))
        mr = MapReduceStats(
            local_rows_read=sum(o.mr.local_rows_read for o in outcomes),
            local_bytes_read=sum(o.mr.local_bytes_read for o in outcomes),
            shuffle_bytes=sum(o.mr.shuffle_bytes for o in outcomes),
            rounds=max(o.mr.rounds for o in outcomes),
            chunks=sum(o.mr.chunks for o in outcomes),
            chunk_size=eta)

        def _wrap(o: _ColumnOutcome) -> Any:
            if group is not None:
                return GroupedResult(keys=group.keys.copy(), values=o.result)
            return o.result

        if len(cols) == 1:
            results: Any = _wrap(outcomes[0])
        else:
            results = {f"{f}:{q}": _wrap(o)
                       for (f, q), o in zip(cols, outcomes)}
        return results, RunReport(epoch=self._epoch, eta=eta,
                                  plan_cache_hit=hit, mapreduce=mr,
                                  query=qstats)

    def _fold_column(
        self, program: MapReduceProgram, eta: int,
        mask: Optional[np.ndarray], work: Sequence[_RegionWork],
        work_sig: Tuple, family: str, qualifier: str,
        group: Optional[_GroupInfo],
    ) -> _ColumnOutcome:
        """Resolve one computed column: result cache → compact → blockwise."""
        spec = self.table.column_spec(family, qualifier)
        result_key = (
            "fold", program.cache_key(), family, qualifier, int(eta),
            self._mesh_shape(), group.sig if group is not None else "",
            work_sig,
        )
        entry = self._results.get(result_key)
        if entry is not None:
            entry.last_used = self._epoch
            self.metrics.inc(partials_reused=entry.partials_total)
            # zero-work execution: nothing was read, folded, or shuffled
            return _ColumnOutcome(
                result=entry.result, hit=True,
                gather_path=entry.gather_path, merge_path="",
                acct=_BlockAccount.all_reused(entry.blocks_total),
                partials_total=entry.partials_total,
                partials_reused=entry.partials_total, rows_folded=0,
                mr=MapReduceStats(0, 0, 0, 0, 0, eta))
        if (mask is not None and group is None
                and self._should_compact(work, family, qualifier)):
            return self._run_compact(program, eta, mask, work,
                                     family, qualifier, spec, result_key)
        return self._run_blockwise(program, eta, mask, work,
                                   family, qualifier, spec, result_key,
                                   group)

    def _should_compact(self, work: Sequence[_RegionWork],
                        family: str, qualifier: str) -> bool:
        """Adaptive cold-scan gather: take the compacted one-shot path when
        selectivity is below the threshold AND no reuse is in flight (no
        resident current-version block or partial for any surviving
        region).  Block granularity deliberately ships whole regions to
        make them shareable; a cold selective scan that will never share
        shouldn't pay for that."""
        thr = self.compact_gather_threshold
        if thr <= 0:
            return False
        in_range = sum(w.n_rows for w in work)
        sel = sum(w.selected for w in work)
        if sel == 0 or in_range == 0 or sel / in_range >= thr:
            return False
        for w in work:
            if w.selected == 0:
                continue
            if self.blocks.peek(w.region, family, qualifier) is not None:
                return False
            if self.blocks.has_partials(w.region.rid):
                return False
        return True

    def _run_compact(
        self, program: MapReduceProgram, eta: int, mask: np.ndarray,
        work: Sequence[_RegionWork],
        family: str, qualifier: str, spec, result_key: Tuple,
    ) -> _ColumnOutcome:
        """One-shot compacted gather: ONLY the selected rows ship, grouped
        by owner device (locality preserved), folded layout-at-a-time via
        the shard_map engine.  Nothing enters the block or partial caches —
        this path exists precisely because no payload reuse is expected —
        but the tiny finalized RESULT is still memoized, so an identical
        repeat query pays nothing at all."""
        D = len(self.placement.nodes)
        sel_per_dev: List[List[np.ndarray]] = [[] for _ in range(D)]
        for w in work:
            if w.selected == 0 or w.owner is None:
                continue
            sel_per_dev[w.owner].append(
                np.nonzero(mask[w.rows])[0] + w.rows.start)
        rows_per_dev = [int(sum(len(x) for x in lst)) for lst in sel_per_dev]
        # capacity rounds up to a power-of-two chunk count so compact scans
        # of drifting selectivity share a few engine executables
        cap = self._capacity_for(rows_per_dev, eta)
        cap = eta * (1 << (max(1, cap // eta) - 1).bit_length())
        col = self.table.column(family, qualifier)
        host = np.zeros((D, cap) + tuple(spec.shape), spec.dtype)
        valid = np.zeros((D, cap), dtype=bool)
        for d in range(D):
            off = 0
            for sub in sel_per_dev[d]:
                host[d, off: off + len(sub)] = col[sub]
                off += len(sub)
            valid[d, :off] = True
        sh = Placement.data_sharding(self.mesh, self.data_axis)
        result, mr = self.engine.run(
            program, jax.device_put(host, sh), jax.device_put(valid, sh),
            eta)
        sel = sum(rows_per_dev)
        self.metrics.inc(compact_scans=1, pushdown_rows_gathered=sel,
                         payload_gathers=1, rows_folded=sel)
        self._results.put(result_key, _ResultEntry(
            result=result, partials_total=0, blocks_total=0,
            region_ids=frozenset(w.region.rid for w in work),
            gather_path="compact", last_used=self._epoch))
        acct = _BlockAccount()
        acct.bytes_transferred = sel * spec.row_nbytes
        return _ColumnOutcome(
            result=result, hit=False, gather_path="compact", merge_path="",
            acct=acct, partials_total=0, partials_reused=0,
            rows_folded=sel, mr=mr)

    def _run_blockwise(
        self, program: MapReduceProgram, eta: int,
        mask: Optional[np.ndarray], work: Sequence[_RegionWork],
        family: str, qualifier: str, spec, result_key: Tuple,
        group: Optional[_GroupInfo] = None,
    ) -> _ColumnOutcome:
        """Block-at-a-time map phase + one merge/finalize reduce.

        Per foldable block: partial-cache lookup first; on a miss the block
        is fetched store-first (reused / transferred / gathered classified
        by the BlockStore) and folded ON ITS OWNER DEVICE, and the partial
        is cached under the block's lineage.  Blocks with no selected rows
        contribute the monoid identity — neither payload nor partial is
        ever touched for them.  Grouped plans fold group-keyed partials in
        the same one pass per block: group ids ride beside the row mask, so
        G strata never multiply gathers, folds, or partials.
        """
        prog_key = program.cache_key()
        gsig = group.sig if group is not None else ""
        n_groups = group.num_groups if group is not None else 0
        # Partials from the fused Pallas fold and the XLA fold agree only
        # to fp32 accumulation tolerance, so they must not share cache
        # slots.  The path is deterministic per (program, dtype, G) —
        # resolve it once and key partials on it ("" keeps xla keys
        # identical to pre-kernel sessions).
        fold_impl = self.engine.fold_path(program, spec.dtype, n_groups)
        impl_sig = fold_impl if fold_impl != "xla" else ""
        acct = _BlockAccount()
        if (self._tiering and self._devices is not None
                and self.blocks.prefetch_enabled):
            # overlap host→device promotion of upcoming cold blocks with
            # the folds of earlier ones: every work item whose partial
            # isn't servable and whose block sits in a lower tier gets a
            # background device_put; the fold loop below claims each
            # completed promotion with its original classification
            for w in work:
                if w.selected == 0 or w.owner is None:
                    continue
                pk = self.blocks.partial_key(
                    w.region, family, qualifier, prog_key, w.mask_sig,
                    eta, group_sig=gsig, impl=impl_sig)
                if self.blocks.peek_partial(pk):
                    continue
                self.blocks.prefetch(w.region, family, qualifier, w.owner,
                                     self._put_block)
        partials: List[Any] = []
        owners: List[Optional[int]] = []
        p_total = p_reused = rows_folded = local_rows = chunks = 0
        rounds: Dict[Optional[int], int] = {}
        for w in work:
            if w.selected == 0:
                acct.total += 1
                acct.reused += 1
                continue
            p_total += 1
            pkey = self.blocks.partial_key(
                w.region, family, qualifier, prog_key, w.mask_sig, eta,
                group_sig=gsig, impl=impl_sig)
            partial = self.blocks.get_partial(pkey)
            if partial is not None:
                p_reused += 1
                acct.total += 1
                acct.reused += 1
            else:
                gate = self.fold_gate
                if gate is None:
                    folded, coalesced = self._fold_cold(
                        program, eta, mask, w, family, qualifier, spec,
                        group, n_groups, pkey), False
                else:
                    folded, coalesced = gate(pkey, lambda: self._fold_cold(
                        program, eta, mask, w, family, qualifier, spec,
                        group, n_groups, pkey))
                partial = folded[0]
                if coalesced:
                    # a concurrent query's leader fold produced this
                    # partial while we waited — account it as a reuse, not
                    # a second fetch + fold
                    p_reused += 1
                    acct.total += 1
                    acct.reused += 1
                else:
                    _, blk, reused, gathered = folded
                    acct.add(blk, reused, gathered)
                    rows_folded += blk.rows
                    local_rows += w.selected
                    c = -(-blk.rows // eta)
                    chunks += c
                    rounds[w.owner] = rounds.get(w.owner, 0) + c
            partials.append(partial)
            owners.append(w.owner)
        result = self.engine.merge_finalize(program, partials,
                                            spec.shape, spec.dtype,
                                            owners=owners)
        self._results.put(result_key, _ResultEntry(
            result=result, partials_total=p_total, blocks_total=acct.total,
            region_ids=frozenset(w.region.rid for w in work),
            last_used=self._epoch))

        self.metrics.inc(
            partials_folded=p_total - p_reused, partials_reused=p_reused,
            rows_folded=rows_folded, rows_gathered=acct.rows_gathered,
            pushdown_rows_gathered=(acct.rows_gathered
                                    if mask is not None else 0),
            payload_gathers=1 if acct.gathered else 0)

        pb = self.engine.partial_nbytes(program, spec.shape, spec.dtype)
        # local_* use the layout path's logical convention (selected rows ×
        # row bytes); the PHYSICAL rows the folds traversed are the
        # rows_folded oracle on QueryStats
        mr = MapReduceStats(
            local_rows_read=local_rows,
            local_bytes_read=local_rows * spec.row_nbytes,
            shuffle_bytes=pb * len(partials),
            rounds=max(rounds.values(), default=0),
            chunks=chunks,
            chunk_size=eta)
        return _ColumnOutcome(
            result=result, hit=False, gather_path="blocks",
            merge_path=self.engine.last_merge_path, acct=acct,
            partials_total=p_total, partials_reused=p_reused,
            rows_folded=rows_folded, mr=mr)

    def _fold_cold(
        self, program: MapReduceProgram, eta: int,
        mask: Optional[np.ndarray], w: _RegionWork,
        family: str, qualifier: str, spec,
        group: Optional[_GroupInfo], n_groups: int, pkey: Tuple,
    ) -> Tuple[Any, DeviceBlock, bool, bool]:
        """Fetch one region's block, fold it on its owner device, and cache
        the partial under ``pkey``.  Returns ``(partial, block, reused,
        gathered)`` so the caller (or a coalescing fold gate's followers)
        can account the fetch classification exactly once."""
        blk, reused, gathered = self._fetch_block(
            w.region, family, qualifier, owner=w.owner)
        base_mask = None if w.mask_sig == "full" else mask[w.rows]
        gid_base = None
        if group is not None:
            # Densified gid blocks depend only on (region lineage,
            # mapping), not on the program — cache them so dirty-region
            # re-folds across plans skip the factorize pass.
            gid_base = self.blocks.get_gids(
                w.region, group.family, group.qualifier, group.sig)
            if gid_base is None:
                gid_base = group.gids_for(tuple(
                    self.table.column(f, q)[w.rows]
                    for f, q in group.columns))
                self.blocks.put_gids(
                    w.region, group.family, group.qualifier,
                    group.sig, gid_base)

        def fold_with(b: DeviceBlock, force_host: bool = False):
            # mask/gid padding is keyed off the actual source shape — the
            # committed device copy is pre-padded to the fold bucket, a
            # host-degraded copy is not.  ``force_host`` ignores a device
            # copy outright: after a quarantine it lives on dead silicon
            use_device = b.device is not None and not force_host
            src = b.device if use_device else b.host
            bmask, gid_arr = base_mask, gid_base
            src_rows = int(src.shape[0])
            if src_rows != b.rows:
                # committed pre-padded to the fold bucket: extend the
                # (tiny) mask/gid arrays host-side to match
                m = np.zeros(src_rows, bool)
                m[:b.rows] = True if bmask is None else bmask
                bmask = m
                if gid_arr is not None:
                    g2 = np.zeros(src_rows, np.int32)
                    g2[:b.rows] = gid_arr
                    gid_arr = g2
            return self.engine.fold_block(
                program, src, bmask, eta, spec.shape, spec.dtype,
                gids=gid_arr, num_groups=n_groups,
                owner=w.owner if use_device else None)

        def run(b: DeviceBlock, force_host: bool = False):
            if self.faults is None:
                return fold_with(b, force_host)
            return self.retry_policy.call(
                lambda: fold_with(b, force_host),
                key=f"fold:{w.region.rid}",
                on_retry=lambda e, a: self.blocks.stats.inc(retries=1))

        try:
            partial = run(blk)
        except DeviceLostError as e:
            # the owner died mid-fold: quarantine it (re-homing its
            # regions for later plans) and re-fold this block's host copy
            # — still resident in the store, so no table re-read unless
            # the host tier, too, was lost
            self._quarantine(e.device if e.device is not None else w.owner)
            hblk, regath = self.blocks.fetch_host(
                w.region, family, qualifier,
                gather_host=self._gather_fn(w.region, family, qualifier))
            gathered = gathered or regath
            blk = hblk
            partial = run(hblk, force_host=True)
        self.blocks.put_partial(pkey, partial)
        return partial, blk, reused, gathered

    def _scan_mask(
        self, plan: GridQuery
    ) -> Tuple[np.ndarray, QueryStats, Tuple[Region, ...]]:
        """Selected-row mask + accounting for a plan's scan stage, plus the
        pruned region set so downstream stages consume the SAME range
        resolution they were keyed on (range clipping itself lives in the
        mask — blocks keep whole regions).

        With a predicate this is :func:`indexed_query` over the scan range
        (index family only); without one, every row in range is selected and
        zero index bytes move.  Region stats always reflect the pruning.
        """
        regions = self.table.regions.prune(plan.start, plan.stop)
        pruned_count = len(self.table.regions) - len(regions)
        lo, hi = self.table.row_range(plan.start, plan.stop)
        if plan.predicate is not None:
            mask, qstats = indexed_query(
                self.table, plan.predicate, plan.index_qualifiers,
                index_family=self.index_family,
                start=plan.start, stop=plan.stop)
        else:
            mask = np.zeros(self.table.num_rows, dtype=bool)
            mask[lo:hi] = True
            qstats = QueryStats(
                rows_scanned=hi - lo, index_bytes_scanned=0,
                payload_bytes_traversed=0, rows_selected=hi - lo,
                regions_scanned=len(regions), regions_pruned=pruned_count)
        return mask, qstats, regions

    def _collect_rows(
        self, plan: GridQuery, eta: int
    ) -> Tuple[Tuple[np.ndarray, Dict[str, np.ndarray]], RunReport]:
        """Program-less plans are pruned retrieves: host-side rowkeys plus
        every selected column's values, charging only the selected rows.

        Retrieves route through the BlockStore's host blocks
        (:meth:`BlockStore.fetch_host`): each surviving region's column is
        read from the table once per content version, so retrieve-heavy
        workloads — and later folds over the same regions — share one
        gather.  In the accounting, ``reused`` is a content hit and
        ``transferred``/``gather_count`` a fresh table read (host-side;
        nothing ships to a device on this path).
        """
        mask, qstats, regions = self._scan_mask(plan)
        sel = np.nonzero(mask)[0]
        acct = _BlockAccount()
        cols: Dict[str, np.ndarray] = {}
        for f, q in plan.resolved_columns():
            spec = self.table.column_spec(f, q)
            parts = []
            for region in regions:
                rows = self.table.region_rows(region)
                if rows.stop <= rows.start:
                    continue
                sub = mask[rows]
                if not sub.any():
                    continue
                blk, gathered = self.blocks.fetch_host(
                    region, f, q,
                    gather_host=lambda r=region, fa=f, qu=q:
                        self.table.region_column(r, fa, qu))
                acct.add(blk, not gathered, gathered)
                parts.append(blk.host[sub])
            cols[f"{f}:{q}"] = (
                np.concatenate(parts) if parts
                else np.empty((0,) + tuple(spec.shape), spec.dtype))
        per_row = sum(self.table.column_spec(f, q).row_nbytes
                      for f, q in plan.resolved_columns())
        qstats = dataclasses.replace(
            acct.apply(qstats), gather_path="retrieve",
            payload_bytes_moved=len(sel) * per_row)
        report = RunReport(epoch=self._epoch, eta=eta, plan_cache_hit=False,
                           mapreduce=None, query=qstats)
        return (self.table.keys[sel].copy(), cols), report

    # ------------------------------------------------------------------
    # block fetch (the BlockStore plumbing)
    # ------------------------------------------------------------------

    @staticmethod
    def _capacity_for(rows_per_dev: List[int], chunk: int) -> int:
        """Slots per device: the busiest device's rows rounded up to a
        chunk multiple, at least one chunk (SPMD needs equal shards)."""
        need = max(rows_per_dev, default=0)
        return max(chunk, -(-max(need, 1) // chunk) * chunk)

    def _gather_fn(self, region: Region, family: str,
                   qualifier: str) -> Callable[[], np.ndarray]:
        """The table-read thunk handed to the BlockStore, wrapped (when a
        fault injector is live) so transient gather faults retry in place
        before the store ever sees an exception."""
        def base() -> np.ndarray:
            return self.table.region_column(region, family, qualifier)
        if self.faults is None:
            return base

        def attempt() -> np.ndarray:
            self.faults.fire("gather")
            return base()

        return lambda: self.retry_policy.call(
            attempt, key=f"gather:{region.rid}",
            on_retry=lambda e, a: self.blocks.stats.inc(retries=1))

    def _fetch_block(
        self, region: Region, family: str, qualifier: str,
        owner: Optional[int],
    ) -> Tuple[DeviceBlock, bool, bool]:
        """Store-first block access; ``owner`` is the region's device index
        (derived once per plan in ``_plan_work``, not re-derived per
        block).

        Degradation ladder on faults: transient ``device_put`` failures
        already retried inside :meth:`_put_block`; a PERMANENT owner loss
        quarantines the device (re-homing its regions for every later
        plan) and this fetch falls back to the host tier — the content is
        served without device commitment, so the query completes with the
        payload folding host-side instead of raising."""
        if owner is not None and owner in self._quarantined:
            owner = None       # stale work item from before a re-home
        gather = self._gather_fn(region, family, qualifier)
        to_device = None if self._devices is None else self._put_block
        try:
            return self.blocks.fetch(region, family, qualifier, owner,
                                     gather_host=gather,
                                     to_device=to_device)
        except DeviceLostError as e:
            self._quarantine(e.device if e.device is not None else owner)
        except TransientFaultError:
            pass               # retries exhausted: degrade below
        # device commitment failed for good: serve the host tier (the
        # store's cached copy, or one verified table re-read)
        blk, gathered = self.blocks.fetch_host(region, family, qualifier,
                                               gather_host=gather)
        return blk, False, gathered

    def _put_block(self, host: np.ndarray, owner_index: Optional[int]):
        """Commit one block to its owner shard's device (the per-shard
        ``device_put`` half of the multi-chip transfer path; the per-block
        fold then runs where the committed array lives).

        The committed copy is padded to the engine's bucketed row count
        (next power of two), so every later fold hits an exact-shape
        executable with NO per-fold pad copy — the pad memcpy is paid once
        per gather, where it amortizes.  The block's ``host`` array and
        ``rows`` stay logical; ``_run_blockwise`` extends row masks/gids to
        the padded shape host-side (tiny bool/int32 arrays).

        Transient injected transfer faults retry here under the session
        policy; :class:`DeviceLostError` propagates to
        :meth:`_fetch_block`, which owns quarantine + host degrade."""
        bucket = self.engine.bucket_rows(len(host))
        if bucket != len(host):
            host = np.concatenate(
                [host, np.zeros((bucket - len(host),) + host.shape[1:],
                                host.dtype)])
        dev = None if owner_index is None else self._devices[owner_index]
        if self.faults is None:
            return jax.device_put(host, dev)

        def attempt():
            self.faults.fire("device_put", device=owner_index)
            return jax.device_put(host, dev)

        return self.retry_policy.call(
            attempt, key=f"device_put:{owner_index}",
            on_retry=lambda e, a: self.blocks.stats.inc(retries=1))

    # ------------------------------------------------------------------
    # helpers / diagnostics
    # ------------------------------------------------------------------

    def _mesh_shape(self) -> Tuple[Tuple[str, int], ...]:
        return tuple((a, self.mesh.shape[a]) for a in self.mesh.axis_names)

    def close(self) -> None:
        """Release tier resources (the prefetch worker, every spill file,
        and the session-owned spill dir).  The session stays usable for
        in-memory work afterwards; cached lower-tier content re-gathers
        from the table on next use."""
        self.blocks.close()

    def __enter__(self) -> "GridSession":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def prefetch_plan(self, plan: GridQuery) -> int:
        """Kick background device promotion for the blocks a plan is about
        to fold; returns the number of promotions enqueued.

        Promotion-only and best-effort: a region whose block was demoted
        out of the device tier (or never committed) gets its
        ``device_put`` overlapped with the folds of earlier blocks in the
        same pass; regions that still have cached partials for their
        current content are skipped (a warm query folds nothing, so
        promoting its payload would waste HBM).  A no-op unless tiering is
        configured — flat unbounded sessions already keep every block
        device-resident.  Callers must hold whatever epoch isolation they
        run queries under (the frontend calls this inside its read lock).
        """
        if (not self._tiering or self._devices is None
                or not self.blocks.prefetch_enabled):
            return 0
        columns = plan.columns or ((self.payload_family,
                                    self.payload_qualifier),)
        regions = self.table.regions.prune(plan.start, plan.stop)
        alloc = self.placement.alloc
        issued = 0
        for region in regions:
            if self.blocks.has_partials(region.rid):
                continue
            owner = self._node_index.get(alloc.get(region.rid))
            if owner is None:
                continue
            for family, qualifier in columns:
                if self.blocks.prefetch(region, family, qualifier, owner,
                                        self._put_block):
                    issued += 1
        return issued

    def imbalance(self) -> float:
        """Max relative deviation of node work from #CPU×MIPS-proportional."""
        return allocation_imbalance(
            self.placement.alloc, self.table.region_bytes(),
            self.placement.nodes)

    def token_dataset(self, global_batch: int,
                      batch_axes: Sequence[str] = ("data",), seed: int = 0):
        """A :class:`ColocatedTokenDataset` sharing this session's placement
        (training batches ride the same region→device map the verbs maintain).
        """
        from repro.data.pipeline import ColocatedTokenDataset
        return ColocatedTokenDataset(
            self.table, self.mesh, global_batch, data_axis=self.data_axis,
            batch_axes=batch_axes, placement=self.placement, seed=seed)

    def describe(self) -> str:
        m = self.metrics
        lines = [
            f"GridSession(table={self.table.name!r}, epoch={self._epoch}, "
            f"eta={self.default_eta}, imbalance={self.imbalance():.3f})",
            self.placement.describe(),
            f"  results: {m.plan_hits} hits / {m.plan_misses} misses; "
            f"engine compiles: {self.engine.compile_count}",
            f"  folds: {m.partials_folded} block partials folded "
            f"({m.rows_folded} rows), {m.partials_reused} reused, "
            f"{m.compact_scans} compact one-shots",
            f"  blocks: {self.blocks.describe()}",
            f"  queries: {m.scans} plans executed, {m.programs_fused} "
            f"programs fused, {m.payload_gathers} payload gather passes "
            f"({m.rows_gathered} rows gathered, "
            f"{m.pushdown_rows_gathered} pushdown rows)",
        ]
        return "\n".join(lines)
