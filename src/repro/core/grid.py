"""GridSession — the paper's backend API behind one session object.

The paper's contribution is an *interface* (Table 1): Upload, Retrieve,
Remove, a heterogeneity-aware Load balancer, and MapReduce templates over
colocated storage.  The repo implements each piece as a standalone module
(:mod:`table`, :mod:`regions`, :mod:`balancer`, :mod:`placement`,
:mod:`mapreduce`, :mod:`query`); ``GridSession`` owns the whole
table → regions → balancer → placement → mapreduce → query lifecycle and
exposes the five verbs:

- :meth:`upload`    — batch insert with split handling and incremental
  placement (split children inherit their parent's node, HBase-style);
- :meth:`retrieve`  — the Table-1 selector read path;
- :meth:`remove`    — row deletion with dirty-region invalidation;
- :meth:`rebalance` — the paper's offline #CPU×MIPS balancer, applied to the
  *current* allocation (minimum region moves);
- :meth:`run` / :meth:`run_where` — MapReduce over the full table or a
  predicate-pushdown subset.

Three properties make mutation cheap and repeated compute fast:

1. **Mutation epochs + dirty regions.**  Every mutation advances an epoch and
   records which regions (hence which nodes) it touched.  Device layouts are
   cached per column; a stale layout re-gathers payload *only for the dirty
   nodes* and reuses every other device's block — an upload into one region
   costs one device's gather, not a rebuild of the world.
2. **Compiled-plan cache.**  Plans are keyed by ``(program, mesh shape, η,
   table epoch)``.  A repeat ``run`` at the same epoch is a pure cache hit;
   across epochs the bound data refreshes but the jitted ``shard_map``
   executable (shape-keyed inside :class:`MapReduceEngine`) is reused, so no
   recompile happens unless the layout's shape actually changed.
3. **Predicate pushdown.**  ``run_where`` evaluates the predicate on the
   index family only (§2.3), then gathers *just the selected payload rows*
   per device — locality preserved because index and payload share rowkeys
   and placement — and reports ``payload_bytes_moved`` covering only those
   rows.  The mask path (materialize everything, fold a subset) is gone.
"""

from __future__ import annotations

import dataclasses
from typing import (
    Any, Dict, FrozenSet, List, Mapping, Optional, Sequence, Set, Tuple,
)

import numpy as np

import jax

from repro.core.balancer import (
    NodeSpec,
    allocation_imbalance,
    rebalance as rebalance_allocation,
)
from repro.core.mapreduce import MapReduceEngine, MapReduceProgram, MapReduceStats
from repro.core.placement import Placement
from repro.core.query import Predicate, QueryStats, indexed_query
from repro.core.table import (
    DATA_FAMILY,
    INDEX_FAMILY,
    RowKey,
    TensorTable,
    _as_key,
)
from repro.utils import make_mesh


@dataclasses.dataclass
class SessionMetrics:
    """Observable counters for the session's incremental machinery."""

    uploads: int = 0
    removes: int = 0
    rebalances: int = 0
    epochs: int = 0                 # mutation epochs advanced
    regions_dirtied: int = 0
    plan_hits: int = 0              # run() served from the plan cache
    plan_misses: int = 0
    layout_full_builds: int = 0     # gather-everything rebuilds
    layout_refreshes: int = 0       # incremental dirty-node refreshes
    devices_regathered: int = 0     # device blocks whose payload was re-read
    devices_reused: int = 0         # device blocks kept across a mutation
    rows_gathered: int = 0          # payload rows copied into layouts
    pushdown_rows_gathered: int = 0  # payload rows moved by run_where


@dataclasses.dataclass(frozen=True)
class RunReport:
    """Accounting for one ``run``/``run_where`` call."""

    epoch: int
    eta: int
    plan_cache_hit: bool
    mapreduce: MapReduceStats
    query: Optional[QueryStats] = None


@dataclasses.dataclass
class _Layout:
    """One column materialized in colocated ``[D, C, ...]`` device layout."""

    epoch: int
    chunk: int
    capacity: int
    row_ids: np.ndarray        # [D, C] positional indices into the table
    valid: np.ndarray          # [D, C] real-slot mask (host)
    host_values: np.ndarray    # [D, C, ...] gathered payload (host cache)
    values: Any                # device copy of host_values
    dvalid: Any                # device copy of valid
    last_used: int = 0         # epoch of the last run using this layout


class GridSession:
    """One object owning the grid lifecycle; the five-verb facade."""

    #: layouts untouched for this many epochs are evicted — a stale layout
    #: pins a full host payload copy AND the dirty-log floor, so a
    #: long-lived mutating session must not keep it forever.
    LAYOUT_TTL_EPOCHS = 64

    def __init__(
        self,
        table: TensorTable,
        mesh: Optional[jax.sharding.Mesh] = None,
        nodes: Optional[Sequence[NodeSpec]] = None,
        strategy: str = "greedy",
        data_axis: str = "data",
        default_eta: int = 16,
        payload_family: str = DATA_FAMILY,
        payload_qualifier: str = "data",
        index_family: str = INDEX_FAMILY,
    ):
        self.table = table
        self.mesh = (mesh if mesh is not None
                     else make_mesh((jax.device_count(),), (data_axis,)))
        self.data_axis = data_axis
        D = self.mesh.shape[data_axis]
        if nodes is None:
            nodes = [NodeSpec(i) for i in range(D)]
        if len(nodes) != D:
            raise ValueError(
                f"{len(nodes)} nodes for mesh axis {data_axis!r} of size {D}")
        self.default_eta = int(default_eta)
        self.payload_family = payload_family
        self.payload_qualifier = payload_qualifier
        self.index_family = index_family

        self.placement = Placement.from_strategy(table, nodes, strategy)
        self.table.split_log.clear()  # from_strategy saw the current regions
        self.engine = MapReduceEngine(self.mesh, data_axis)
        self.metrics = SessionMetrics()

        self._epoch = 0
        # (epoch, dirty node ids) per mutation; consumed by layout refresh
        self._dirty_log: List[Tuple[int, FrozenSet[int]]] = []
        self._layouts: Dict[Tuple[str, str, int], _Layout] = {}
        # (program, mesh shape, eta, column, epoch) -> layout key
        self._plans: Dict[Tuple, Tuple[str, str, int]] = {}
        self._node_index = {n.node_id: d for d, n in enumerate(nodes)}

    # ------------------------------------------------------------------
    # epoch / dirty tracking
    # ------------------------------------------------------------------

    @property
    def epoch(self) -> int:
        return self._epoch

    def _advance_epoch(self, dirty_rids: Set[int],
                       extra_dirty_nodes: Set[int] = frozenset()) -> None:
        self._epoch += 1
        self.metrics.epochs += 1
        self.metrics.regions_dirtied += len(dirty_rids)
        owners = {
            self.placement.alloc[rid]
            for rid in dirty_rids if rid in self.placement.alloc
        } | set(extra_dirty_nodes)
        self._dirty_log.append((self._epoch, frozenset(owners)))
        # plans are epoch-keyed; everything cached is now stale
        self._plans.clear()
        self._prune_caches()

    def _prune_caches(self) -> None:
        """Evict long-unused layouts, then drop dirty entries no survivor
        can still consume — keeps a mutating session's memory bounded."""
        self._layouts = {
            k: l for k, l in self._layouts.items()
            if self._epoch - l.last_used <= self.LAYOUT_TTL_EPOCHS
        }
        floor = min((l.epoch for l in self._layouts.values()),
                    default=self._epoch)
        self._dirty_log = [(e, ns) for e, ns in self._dirty_log if e > floor]

    # ------------------------------------------------------------------
    # the five verbs
    # ------------------------------------------------------------------

    def upload(
        self,
        rowkeys: Sequence[RowKey],
        data: Mapping[str, Mapping[str, np.ndarray]],
        on_duplicate: str = "skip",
    ) -> int:
        """Table-1 Upload: batch insert with incremental placement.

        Splits triggered by the insert keep daughters on the parent's node
        (rebalancing is an explicit :meth:`rebalance` call, as in the paper);
        only the regions containing the uploaded keys are invalidated.
        """
        # under "skip", duplicates leave their rows untouched — only the keys
        # actually written may dirty a region, so snapshot existence first
        keys = np.array([_as_key(k) for k in rowkeys], dtype="S64")
        if on_duplicate == "skip" and len(keys):
            written_keys = keys[~self.table.existing_mask(rowkeys)]
        else:
            written_keys = keys
        written = self.table.upload(rowkeys, data, on_duplicate=on_duplicate)
        self.metrics.uploads += 1
        if not written:
            self.table.split_log.clear()
            return 0
        self.placement.apply_splits()
        dirty = self.table.regions.regions_containing(
            [bytes(k) for k in written_keys])
        self._advance_epoch(dirty)
        return written

    def retrieve(
        self,
        family: str,
        qualifier: str,
        rowkey: Optional[RowKey] = None,
        start: Optional[RowKey] = None,
        stop: Optional[RowKey] = None,
        skip: Optional[Sequence[RowKey]] = None,
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Table-1 Retrieve: ``(rowkeys, values)`` for the selector."""
        return self.table.retrieve(family, qualifier, rowkey=rowkey,
                                   start=start, stop=stop, skip=skip)

    def remove(
        self,
        rowkey: Optional[RowKey] = None,
        start: Optional[RowKey] = None,
        stop: Optional[RowKey] = None,
        skip: Optional[Sequence[RowKey]] = None,
    ) -> int:
        """Table-1 Remove: delete rows, invalidating only their regions."""
        doomed = [bytes(k) for k in
                  self.table.select_keys(rowkey, start, stop, skip)]
        removed = self.table.delete(rowkey=rowkey, start=start, stop=stop,
                                    skip=skip)
        self.metrics.removes += 1
        if removed:
            self._advance_epoch(self.table.regions.regions_containing(doomed))
        return removed

    def rebalance(
        self,
        tolerance: float = 0.05,
        nodes: Optional[Sequence[NodeSpec]] = None,
    ) -> List[int]:
        """The paper's offline balancer from the *current* allocation.

        ``nodes`` swaps in refreshed specs (elastic rescale, straggler
        deweighting via :func:`~repro.core.balancer.powers_from_observations`)
        — node ids must be the existing ones.  Returns moved region ids.
        """
        if nodes is not None:
            if {n.node_id for n in nodes} != set(self._node_index):
                raise ValueError("rebalance nodes must keep the same node ids")
            order = sorted(nodes, key=lambda n: self._node_index[n.node_id])
            self.placement.nodes = tuple(order)
        old = dict(self.placement.alloc)
        new_alloc, moved = rebalance_allocation(
            old, self.table.region_bytes(), self.placement.nodes, tolerance)
        self.metrics.rebalances += 1
        if moved:
            self.placement.alloc.clear()
            self.placement.alloc.update(new_alloc)
            self.placement.version += 1
            dirty_nodes = ({old[rid] for rid in moved if rid in old}
                           | {new_alloc[rid] for rid in moved})
            self._advance_epoch(set(moved), extra_dirty_nodes=dirty_nodes)
        return moved

    def run(
        self,
        program: MapReduceProgram,
        eta: Optional[int] = None,
        family: Optional[str] = None,
        qualifier: Optional[str] = None,
    ) -> Tuple[Any, RunReport]:
        """MapReduce over the whole table, through the compiled-plan cache."""
        family = family or self.payload_family
        qualifier = qualifier or self.payload_qualifier
        eta = int(eta or self.default_eta)
        plan_key = (self._program_key(program), self._mesh_shape(), eta,
                    family, qualifier, self._epoch)
        hit = plan_key in self._plans
        if hit:
            self.metrics.plan_hits += 1
            layout = self._layouts[self._plans[plan_key]]
        else:
            self.metrics.plan_misses += 1
            layout = self._layout(family, qualifier, eta)
            self._plans[plan_key] = (family, qualifier, eta)
        result, mr = self.engine.run(program, layout.values, layout.dvalid,
                                     eta)
        return result, RunReport(epoch=self._epoch, eta=eta,
                                 plan_cache_hit=hit, mapreduce=mr)

    def run_where(
        self,
        predicate: Predicate,
        program: MapReduceProgram,
        index_qualifiers: Sequence[str],
        eta: Optional[int] = None,
        family: Optional[str] = None,
        qualifier: Optional[str] = None,
    ) -> Tuple[Any, RunReport]:
        """Predicate-pushdown MapReduce (§2.3 unified with §2.2).

        The predicate runs over the index family only; each device then
        gathers *just its own selected* payload rows (compacted, locality
        preserved), so the returned ``QueryStats.payload_bytes_moved`` covers
        exactly the selected rows — never the full table.
        """
        family = family or self.payload_family
        qualifier = qualifier or self.payload_qualifier
        eta = int(eta or self.default_eta)
        mask, qstats = indexed_query(self.table, predicate, index_qualifiers,
                                     index_family=self.index_family)
        per_dev = self._per_device_rows()
        selected = [rows[mask[rows]] for rows in per_dev]
        n_sel = int(sum(len(s) for s in selected))
        need = max((len(s) for s in selected), default=0)
        cap = max(eta, -(-max(need, 1) // eta) * eta)

        col = self.table.column(family, qualifier)
        D = len(per_dev)
        host = np.zeros((D, cap) + col.shape[1:], col.dtype)
        valid = np.zeros((D, cap), dtype=bool)
        for d, rows in enumerate(selected):
            host[d, : len(rows)] = col[rows]
            valid[d, : len(rows)] = True
        sh = Placement.data_sharding(self.mesh, self.data_axis)
        values = jax.device_put(host, sh)
        dvalid = jax.device_put(valid, sh)

        result, mr = self.engine.run(program, values, dvalid, eta)
        row_nbytes = self.table.column_spec(family, qualifier).row_nbytes
        qstats = dataclasses.replace(
            qstats, payload_bytes_moved=n_sel * row_nbytes)
        self.metrics.pushdown_rows_gathered += n_sel
        return result, RunReport(epoch=self._epoch, eta=eta,
                                 plan_cache_hit=False, mapreduce=mr,
                                 query=qstats)

    # ------------------------------------------------------------------
    # layouts (incremental placement materialization)
    # ------------------------------------------------------------------

    def _per_device_rows(self) -> List[np.ndarray]:
        return [self.placement.rows_for_node(n.node_id)
                for n in self.placement.nodes]

    def _layout(self, family: str, qualifier: str, chunk: int) -> _Layout:
        key = (family, qualifier, int(chunk))
        lay = self._layouts.get(key)
        if lay is not None and lay.epoch == self._epoch:
            lay.last_used = self._epoch
            return lay

        per_dev = self._per_device_rows()
        D = len(per_dev)
        need = max((len(r) for r in per_dev), default=0)
        cap_needed = max(chunk, -(-max(need, 1) // chunk) * chunk)
        col = self.table.column(family, qualifier)

        if lay is None or cap_needed > lay.capacity:
            cap = cap_needed
            row_ids = np.zeros((D, cap), dtype=np.int64)
            valid = np.zeros((D, cap), dtype=bool)
            host = np.zeros((D, cap) + col.shape[1:], col.dtype)
            for d, rows in enumerate(per_dev):
                row_ids[d, : len(rows)] = rows
                valid[d, : len(rows)] = True
                host[d, : len(rows)] = col[rows]
            self.metrics.layout_full_builds += 1
            self.metrics.devices_regathered += D
            self.metrics.rows_gathered += int(sum(len(r) for r in per_dev))
        else:
            # incremental refresh: payload re-gathered ONLY for nodes dirtied
            # since this layout's epoch; row indices are recomputed for all
            # (cheap — positions shift under inserts) but clean devices keep
            # their payload blocks byte-for-byte.
            cap = lay.capacity
            dirty_nodes: Set[int] = set()
            for e, ns in self._dirty_log:
                if e > lay.epoch:
                    dirty_nodes |= set(ns)
            dirty_devs = {self._node_index[nid] for nid in dirty_nodes
                          if nid in self._node_index}
            row_ids, valid, host = lay.row_ids, lay.valid, lay.host_values
            for d, rows in enumerate(per_dev):
                row_ids[d] = 0
                valid[d] = False
                row_ids[d, : len(rows)] = rows
                valid[d, : len(rows)] = True
                if d in dirty_devs:
                    host[d] = 0
                    host[d, : len(rows)] = col[rows]
                    self.metrics.devices_regathered += 1
                    self.metrics.rows_gathered += len(rows)
                else:
                    self.metrics.devices_reused += 1
            self.metrics.layout_refreshes += 1

        sh = Placement.data_sharding(self.mesh, self.data_axis)
        lay = _Layout(
            epoch=self._epoch, chunk=int(chunk), capacity=cap,
            row_ids=row_ids, valid=valid, host_values=host,
            values=jax.device_put(host, sh), dvalid=jax.device_put(valid, sh),
            last_used=self._epoch,
        )
        self._layouts[key] = lay
        return lay

    # ------------------------------------------------------------------
    # helpers / diagnostics
    # ------------------------------------------------------------------

    @staticmethod
    def _program_key(program: MapReduceProgram) -> Tuple[str, str]:
        return (type(program).__name__, repr(program))

    def _mesh_shape(self) -> Tuple[Tuple[str, int], ...]:
        return tuple((a, self.mesh.shape[a]) for a in self.mesh.axis_names)

    def imbalance(self) -> float:
        """Max relative deviation of node work from #CPU×MIPS-proportional."""
        return allocation_imbalance(
            self.placement.alloc, self.table.region_bytes(),
            self.placement.nodes)

    def token_dataset(self, global_batch: int,
                      batch_axes: Sequence[str] = ("data",), seed: int = 0):
        """A :class:`ColocatedTokenDataset` sharing this session's placement
        (training batches ride the same region→device map the verbs maintain).
        """
        from repro.data.pipeline import ColocatedTokenDataset
        return ColocatedTokenDataset(
            self.table, self.mesh, global_batch, data_axis=self.data_axis,
            batch_axes=batch_axes, placement=self.placement, seed=seed)

    def describe(self) -> str:
        m = self.metrics
        lines = [
            f"GridSession(table={self.table.name!r}, epoch={self._epoch}, "
            f"eta={self.default_eta}, imbalance={self.imbalance():.3f})",
            self.placement.describe(),
            f"  plans: {m.plan_hits} hits / {m.plan_misses} misses; "
            f"engine compiles: {self.engine.compile_count}",
            f"  layouts: {m.layout_full_builds} full builds, "
            f"{m.layout_refreshes} refreshes "
            f"({m.devices_regathered} regathered / {m.devices_reused} reused "
            f"device blocks, {m.rows_gathered} rows gathered)",
        ]
        return "\n".join(lines)
