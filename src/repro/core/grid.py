"""GridSession — the paper's backend API behind one session object.

The paper's contribution is an *interface* (Table 1): Upload, Retrieve,
Remove, a heterogeneity-aware Load balancer, and MapReduce templates over
colocated storage.  The repo implements each piece as a standalone module
(:mod:`table`, :mod:`regions`, :mod:`balancer`, :mod:`placement`,
:mod:`mapreduce`, :mod:`query`); ``GridSession`` owns the whole
table → regions → blockstore → balancer → placement → mapreduce → query
lifecycle and exposes the five verbs:

- :meth:`upload`    — batch insert with split handling and incremental
  placement (split children inherit their parent's node, HBase-style);
- :meth:`retrieve`  — the Table-1 selector read path;
- :meth:`remove`    — row deletion with dirty-region invalidation;
- :meth:`rebalance` — the paper's offline #CPU×MIPS balancer, applied to the
  *current* allocation (minimum region moves); ``auto=True`` derives node
  powers from :meth:`observe_round` history through the wired
  :class:`GridScheduler` / ``powers_from_observations`` loop;
- :meth:`scan`      — the query surface: a lazy :class:`GridQuery` plan
  (``scan(...).select(...).where(...).map(...).reduce()``) that prunes
  regions, pushes the projection down, and fuses all mapped statistics into
  one engine pass when ``.collect()``/``.stats()`` executes it;
- :meth:`run` / :meth:`run_where` — thin wrappers over :meth:`scan` for the
  full table and the predicate-pushdown subset.

Beneath every executed plan sits the :class:`~repro.core.blockstore
.BlockStore`: a content-addressed, copy-on-write cache of per-region device
blocks keyed by ``(region signature, column, epoch-lineage)``.  Four
properties make mutation cheap and repeated compute fast:

1. **Mutation epochs + block lineage.**  Every mutation advances an epoch
   and bumps *only the touched regions'* block versions.  A layout for epoch
   N+1 structurally shares every clean region's block with epoch N — no
   re-pad, no re-``device_put``; an upload into one region re-gathers one
   region's block and re-assembles one device's shard, not the world.
2. **Cross-plan block sharing.**  Pruned-scan plans look blocks up in the
   store before gathering, so two overlapping plans (same region subset,
   different predicates or ranges) ship the shared regions once.  The
   ``QueryStats`` oracles ``blocks_reused`` / ``blocks_transferred`` /
   ``gather_count`` make both reuse paths observable.
3. **Compiled-plan caches.**  Whole-table plans are keyed by ``(program,
   mesh shape, η, epoch)``; pruned plans by the block lineage of their
   region subset, so they *survive* mutations that touch other regions.
   Either way the jitted ``shard_map`` executable (shape-keyed inside
   :class:`MapReduceEngine`) is reused unless the layout's shape changed.
   All three caches (plans, blocks, executables) are LRU-capped so
   long-lived sessions stay memory-bounded.
4. **Pushdowns.**  Region pruning (two bisects over region start keys)
   excludes non-matching regions before any bytes move; ``where`` plans
   evaluate the predicate on the index family only (§2.3) and the fold
   reads just the selected slots through a device-side row mask;
   projection keeps unselected columns out of the layout entirely.

On multi-chip meshes, dirty blocks transfer via per-shard ``device_put`` +
``jax.make_array_from_single_device_arrays`` — the interconnect never
carries clean blocks.  Meshes without a one-device-per-node data axis fall
back to host-side assembly of the whole layout (blocks still dedupe the
host gathers).
"""

from __future__ import annotations

import dataclasses
from typing import (
    Any, Dict, FrozenSet, List, Mapping, Optional, Sequence, Set, Tuple,
)

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.balancer import (
    NodeSpec,
    allocation_imbalance,
    powers_from_observations,
    rebalance as rebalance_allocation,
)
from repro.core.blockstore import BlockStore, DeviceBlock, LRUCache
from repro.core.mapreduce import MapReduceEngine, MapReduceProgram, MapReduceStats
from repro.core.placement import Placement
from repro.core.plan import GridQuery, prefix_range
from repro.core.query import Predicate, QueryStats, indexed_query
from repro.core.regions import Region
from repro.core.scheduler import GridScheduler
from repro.core.stats import FusedProgram
from repro.core.table import (
    DATA_FAMILY,
    INDEX_FAMILY,
    RowKey,
    TensorTable,
    _as_key,
)
from repro.utils import make_mesh


@dataclasses.dataclass
class SessionMetrics:
    """Observable counters for the session's incremental machinery."""

    uploads: int = 0
    removes: int = 0
    rebalances: int = 0
    epochs: int = 0                 # mutation epochs advanced
    regions_dirtied: int = 0
    plan_hits: int = 0              # run() served from the plan cache
    plan_misses: int = 0
    layout_full_builds: int = 0     # assemble-every-shard builds
    layout_refreshes: int = 0       # incremental dirty-shard refreshes
    devices_regathered: int = 0     # device shards re-assembled from blocks
    devices_reused: int = 0         # device shards kept across a mutation
    rows_gathered: int = 0          # payload rows copied into layout blocks
    pushdown_rows_gathered: int = 0  # payload rows gathered by pruned scans
    scans: int = 0                  # GridQuery plans executed
    payload_gathers: int = 0        # payload gather passes (full, refresh, pruned)
    programs_fused: int = 0         # programs that shared a fused engine pass
    # (session-lifetime block reuse counters live on BlockStore.stats —
    # hits/gathers/transfers/evictions — not duplicated here)


@dataclasses.dataclass(frozen=True)
class RunReport:
    """Accounting for one executed plan (``run``/``run_where``/``collect``)."""

    epoch: int
    eta: int
    plan_cache_hit: bool
    mapreduce: Optional[MapReduceStats]   # None for pure retrieve plans
    query: Optional[QueryStats] = None


class _SessionScheduler(GridScheduler):
    """The session-owned scheduler is observation/planning only.

    Node membership is pinned by the mesh (one device per node), and region
    moves must flow through :meth:`GridSession.rebalance` so mutation epochs
    invalidate cached layouts/plans — the fail/join verbs would mutate the
    shared placement behind the session's back, leaving stale device maps.
    """

    def handle_failure(self, dead_node_ids):
        raise NotImplementedError(
            "the session-owned scheduler cannot change node membership: the "
            "mesh pins one device per node; use GridSession.rebalance "
            "(optionally with refreshed NodeSpecs) for region moves")

    def handle_join(self, new_nodes):
        raise NotImplementedError(
            "the session-owned scheduler cannot change node membership: the "
            "mesh pins one device per node; use GridSession.rebalance "
            "(optionally with refreshed NodeSpecs) for region moves")


@dataclasses.dataclass
class _BlockAccount:
    """Per-execution block accounting, folded into ``QueryStats`` oracles."""

    total: int = 0
    reused: int = 0
    transferred: int = 0
    gathered: int = 0
    rows_gathered: int = 0
    bytes_transferred: int = 0

    def add(self, blk: DeviceBlock, reused: bool, gathered: bool) -> None:
        self.total += 1
        if reused:
            self.reused += 1
        else:
            self.transferred += 1
            self.bytes_transferred += blk.nbytes
        if gathered:
            self.gathered += 1
            self.rows_gathered += blk.rows

    @classmethod
    def all_reused(cls, n: int) -> "_BlockAccount":
        return cls(total=n, reused=n)

    def apply(self, qstats: QueryStats) -> QueryStats:
        return dataclasses.replace(
            qstats, blocks_total=self.total, blocks_reused=self.reused,
            blocks_transferred=self.transferred, gather_count=self.gathered,
            payload_bytes_transferred=self.bytes_transferred)


@dataclasses.dataclass
class _ScanPlan:
    """A bound pruned-scan layout: one ``GridQuery`` plan's device blocks,
    assembled, reusable until a mutation touches one of its regions.

    ``predicate`` pins the predicate object so its ``id()`` (part of the
    plan signature) cannot be recycled while this entry lives; ``blocks``
    pins the (COW) device blocks against LRU eviction so the assembled
    ``values`` stay backed.  Every cache hit re-verifies predicate identity.
    """

    predicate: Optional[Predicate]
    values: Any                # device [D, C, ...] assembled region blocks
    dvalid: Any                # device [D, C] real-slot mask
    row_mask: Any              # device [D, C] selected-slot mask
    qstats: QueryStats         # scan accounting sans per-execution blocks
    blocks: Tuple[DeviceBlock, ...]
    # staleness probes: a mutation touching a member region, or a move of
    # one (owner binding changed), makes the entry's signature unmatchable
    # forever — _advance_epoch evicts it eagerly instead of letting dead
    # device arrays ride the LRU.  Moves of OTHER regions leave it bound.
    region_ids: FrozenSet[int] = frozenset()
    owners: Tuple[Tuple[int, Optional[int]], ...] = ()
    last_used: int = 0         # epoch of the last execution through this entry


@dataclasses.dataclass
class _Layout:
    """One column materialized in colocated ``[D, C, ...]`` device layout,
    assembled per shard from the BlockStore's per-region device blocks."""

    epoch: int
    chunk: int
    capacity: int
    valid: np.ndarray          # [D, C] real-slot mask (host)
    values: Any                # global [D, C, ...] device array
    dvalid: Any                # device copy of valid
    # per-device tuple of (rid, version) — the shard's block lineage; a
    # shard whose composition is unchanged is reused object-for-object
    composition: Tuple[Tuple[Tuple[int, int], ...], ...]
    shards: Optional[List[Any]]  # per-device [1, C, ...] committed arrays
    n_blocks: int
    last_used: int = 0         # epoch of the last run using this layout


class GridSession:
    """One object owning the grid lifecycle; the five-verb facade."""

    #: layouts untouched for this many epochs are evicted — a stale layout
    #: pins its device shards, so a long-lived mutating session must not
    #: keep it forever.
    LAYOUT_TTL_EPOCHS = 64

    def __init__(
        self,
        table: TensorTable,
        mesh: Optional[jax.sharding.Mesh] = None,
        nodes: Optional[Sequence[NodeSpec]] = None,
        strategy: str = "greedy",
        data_axis: str = "data",
        default_eta: int = 16,
        payload_family: str = DATA_FAMILY,
        payload_qualifier: str = "data",
        index_family: str = INDEX_FAMILY,
        plan_cache_cap: int = 64,
        block_cache_cap: int = 256,
    ):
        self.table = table
        self.mesh = (mesh if mesh is not None
                     else make_mesh((jax.device_count(),), (data_axis,)))
        self.data_axis = data_axis
        D = self.mesh.shape[data_axis]
        if nodes is None:
            nodes = [NodeSpec(i) for i in range(D)]
        if len(nodes) != D:
            raise ValueError(
                f"{len(nodes)} nodes for mesh axis {data_axis!r} of size {D}")
        self.default_eta = int(default_eta)
        self.payload_family = payload_family
        self.payload_qualifier = payload_qualifier
        self.index_family = index_family

        self.placement = Placement.from_strategy(table, nodes, strategy)
        self.table.split_log.clear()  # from_strategy saw the current regions
        self.engine = MapReduceEngine(self.mesh, data_axis)
        self.metrics = SessionMetrics()
        self.blocks = BlockStore(cap=block_cache_cap)

        self._epoch = 0
        self._layouts: Dict[Tuple[str, str, int], _Layout] = {}
        # (programs, mesh shape, eta, column, epoch) -> layout key
        self._plans: LRUCache = LRUCache(plan_cache_cap)
        # GridQuery plan signature (block lineage) -> bound pruned-scan layout
        self._scan_plans: LRUCache = LRUCache(plan_cache_cap)
        self._node_index = {n.node_id: d for d, n in enumerate(nodes)}
        # per-shard devices for block placement: available when the mesh is
        # exactly the 1-D data axis (one device per node); otherwise None
        # and layouts fall back to host-side assembly
        self._devices = (list(np.asarray(self.mesh.devices).flat)
                         if self.mesh.axis_names == (data_axis,) else None)
        # observed per-node round times (observe_round) -> auto-rebalance
        self._round_history: Dict[int, List[float]] = {
            n.node_id: [] for n in nodes
        }
        self._scheduler: Optional[GridScheduler] = None

    # ------------------------------------------------------------------
    # epoch / dirty tracking
    # ------------------------------------------------------------------

    @property
    def epoch(self) -> int:
        return self._epoch

    def _advance_epoch(self, dirty_rids: Set[int],
                       touch_blocks: bool = True) -> None:
        self._epoch += 1
        self.metrics.epochs += 1
        self.metrics.regions_dirtied += len(dirty_rids)
        if touch_blocks:
            # copy-on-write: only the touched regions' blocks version-bump;
            # every other block — and every pruned-scan plan over untouched
            # regions — survives the mutation structurally intact
            self.blocks.touch(dirty_rids, self._epoch)
        # whole-table plans are epoch-keyed and can never hit again
        self._plans.clear()
        # bound pruned plans whose lineage or owner binding just changed
        # are unmatchable forever — release their device layouts now
        alloc = self.placement.alloc
        dead = [sig for sig, e in self._scan_plans.items()
                if (e.region_ids & dirty_rids)
                or any(alloc.get(rid) != owner for rid, owner in e.owners)]
        for sig in dead:
            self._scan_plans.pop(sig)
        self._prune_caches()

    def _prune_caches(self) -> None:
        """Evict long-unused layouts and bound scan plans — both pin
        assembled device arrays, so a long-lived mutating session must not
        keep idle ones forever.  (The LRU caps bound entry COUNT; this
        bounds idle LIFETIME across mutation epochs.)"""
        self._layouts = {
            k: l for k, l in self._layouts.items()
            if self._epoch - l.last_used <= self.LAYOUT_TTL_EPOCHS
        }
        idle = [sig for sig, e in self._scan_plans.items()
                if self._epoch - e.last_used > self.LAYOUT_TTL_EPOCHS]
        for sig in idle:
            self._scan_plans.pop(sig)

    # ------------------------------------------------------------------
    # the five verbs
    # ------------------------------------------------------------------

    def upload(
        self,
        rowkeys: Sequence[RowKey],
        data: Mapping[str, Mapping[str, np.ndarray]],
        on_duplicate: str = "skip",
    ) -> int:
        """Table-1 Upload: batch insert with incremental placement.

        Splits triggered by the insert keep daughters on the parent's node
        (rebalancing is an explicit :meth:`rebalance` call, as in the paper);
        only the regions containing the uploaded keys are invalidated.
        """
        # under "skip", duplicates leave their rows untouched — only the keys
        # actually written may dirty a region, so snapshot existence first
        keys = np.array([_as_key(k) for k in rowkeys], dtype="S64")
        if on_duplicate == "skip" and len(keys):
            written_keys = keys[~self.table.existing_mask(rowkeys)]
        else:
            written_keys = keys
        written = self.table.upload(rowkeys, data, on_duplicate=on_duplicate)
        self.metrics.uploads += 1
        if not written:
            self.table.split_log.clear()
            return 0
        # split parents' rids never reappear: forget their blocks before
        # apply_splits consumes the log, or they'd pin payload until cap
        # pressure (their region set membership is gone for good)
        self.blocks.drop_regions(
            parent.rid for parent, _, _ in self.table.split_log)
        self.placement.apply_splits()
        dirty = self.table.regions.regions_containing(
            [bytes(k) for k in written_keys])
        self._advance_epoch(dirty)
        return written

    def retrieve(
        self,
        family: str,
        qualifier: str,
        rowkey: Optional[RowKey] = None,
        start: Optional[RowKey] = None,
        stop: Optional[RowKey] = None,
        skip: Optional[Sequence[RowKey]] = None,
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Table-1 Retrieve: ``(rowkeys, values)`` for the selector."""
        return self.table.retrieve(family, qualifier, rowkey=rowkey,
                                   start=start, stop=stop, skip=skip)

    def remove(
        self,
        rowkey: Optional[RowKey] = None,
        start: Optional[RowKey] = None,
        stop: Optional[RowKey] = None,
        skip: Optional[Sequence[RowKey]] = None,
    ) -> int:
        """Table-1 Remove: delete rows, invalidating only their regions.

        Only the touched regions' block versions bump: every other region's
        device block is reused object-for-object by the next layout build
        (the block-identity tests pin this)."""
        doomed = [bytes(k) for k in
                  self.table.select_keys(rowkey, start, stop, skip)]
        removed = self.table.delete(rowkey=rowkey, start=start, stop=stop,
                                    skip=skip)
        self.metrics.removes += 1
        if removed:
            self._advance_epoch(self.table.regions.regions_containing(doomed))
        return removed

    def observe_round(self, node_times: Mapping[int, float]) -> None:
        """Feed measured per-node round times (the runtime re-measurement of
        the paper's ``linux perf`` MIPS probe).

        Observations accumulate in the session AND drive the wired
        :class:`GridScheduler` (its EWMA powers back ``makespan_estimate``
        and the round ledger); :meth:`rebalance` with ``auto=True`` then
        derives node powers from this history via
        :func:`~repro.core.balancer.powers_from_observations` — no
        hand-supplied specs needed.
        """
        for nid, t in node_times.items():
            if nid in self._round_history and t > 0:
                hist = self._round_history[nid]
                hist.append(float(t))
                del hist[:-self.ROUND_HISTORY_CAP]
        self.scheduler.observe_round(node_times)

    #: round-time observations kept per node; the EWMA power fold saturates
    #: long before this, and an unbounded log would grow with session age
    ROUND_HISTORY_CAP = 64

    @property
    def scheduler(self) -> GridScheduler:
        """The session's passive :class:`GridScheduler` (observation ledger,
        makespan estimates).  Its auto-trigger threshold is infinite and its
        membership verbs are disabled — region moves stay under the
        session's explicit :meth:`rebalance`, which is what keeps
        epochs/dirty-tracking consistent."""
        if self._scheduler is None:
            self._scheduler = _SessionScheduler(
                self.placement, chunk_size=self.default_eta,
                rebalance_threshold=float("inf"))
        return self._scheduler

    def rebalance(
        self,
        tolerance: float = 0.05,
        nodes: Optional[Sequence[NodeSpec]] = None,
        auto: bool = False,
    ) -> List[int]:
        """The paper's offline balancer from the *current* allocation.

        ``nodes`` swaps in refreshed specs (elastic rescale, straggler
        deweighting via :func:`~repro.core.balancer.powers_from_observations`)
        — node ids must be the existing ones.  ``auto=True`` derives those
        specs from the round times fed to :meth:`observe_round` instead
        (no observations yet -> powers unchanged).  Returns moved region ids.

        Moves do NOT bump block content versions: a moved region's payload is
        unchanged, so its cached host block re-commits to the new owner
        device (one transfer, zero table re-reads) while unmoved regions'
        device blocks are reused as-is.
        """
        if auto:
            if nodes is not None:
                raise ValueError(
                    "auto=True derives nodes from observe_round history; "
                    "pass one or the other")
            if any(self._round_history.values()):
                nodes = powers_from_observations(
                    self._round_history, self.placement.nodes)
        if nodes is not None:
            if {n.node_id for n in nodes} != set(self._node_index):
                raise ValueError("rebalance nodes must keep the same node ids")
            order = sorted(nodes, key=lambda n: self._node_index[n.node_id])
            self.placement.nodes = tuple(order)
        old = dict(self.placement.alloc)
        new_alloc, moved = rebalance_allocation(
            old, self.table.region_bytes(), self.placement.nodes, tolerance)
        self.metrics.rebalances += 1
        if moved:
            self.placement.alloc.clear()
            self.placement.alloc.update(new_alloc)
            self.placement.version += 1
            self._advance_epoch(set(moved), touch_blocks=False)
        return moved

    # ------------------------------------------------------------------
    # GridQuery: lazy scan -> filter -> map -> reduce plans
    # ------------------------------------------------------------------

    def scan(
        self,
        prefix: Optional[RowKey] = None,
        start: Optional[RowKey] = None,
        stop: Optional[RowKey] = None,
    ) -> GridQuery:
        """Open a lazy :class:`GridQuery` plan over a rowkey range.

        ``prefix`` is sugar for the half-open range of keys sharing it
        (mutually exclusive with ``start``/``stop``).  Nothing is scanned,
        gathered, or compiled until ``.collect()``/``.stats()`` — the
        planner prunes regions, pushes the projection down, and fuses every
        ``.map`` program into one engine pass first.
        """
        if prefix is not None:
            if start is not None or stop is not None:
                raise ValueError("prefix is exclusive with start/stop")
            p, (start_b, stop_b) = _as_key(prefix), prefix_range(prefix)
            return GridQuery(self, start=start_b, stop=stop_b, prefix=p)
        return GridQuery(
            self,
            start=None if start is None else _as_key(start),
            stop=None if stop is None else _as_key(stop),
        )

    def run(
        self,
        program: MapReduceProgram,
        eta: Optional[int] = None,
        family: Optional[str] = None,
        qualifier: Optional[str] = None,
    ) -> Tuple[Any, RunReport]:
        """MapReduce over the whole table — a full-range one-program plan."""
        q = self.scan().select(
            (family or self.payload_family,
             qualifier or self.payload_qualifier)).map(program)
        return q.collect(eta=eta)

    def run_where(
        self,
        predicate: Predicate,
        program: MapReduceProgram,
        index_qualifiers: Sequence[str],
        eta: Optional[int] = None,
        family: Optional[str] = None,
        qualifier: Optional[str] = None,
    ) -> Tuple[Any, RunReport]:
        """Predicate-pushdown MapReduce (§2.3 unified with §2.2) — a
        full-range ``.where`` plan.

        The predicate runs over the index family only; the fold then reads
        *just the selected payload slots* through a device-side row mask
        (locality preserved because index and payload share rowkeys and
        placement), so ``QueryStats.payload_bytes_moved`` covers exactly
        the selected rows — never the full table.

        Physical transfer is block-granular: a COLD selective query ships
        the surviving regions' whole blocks (observable via
        ``payload_bytes_transferred``), which is what lets every later
        plan — any predicate, any overlapping range, any later epoch —
        reuse them without re-shipping.  Region pruning (``scan`` with a
        range, then ``.where``) is the tool for keeping cold transfers
        small too.
        """
        q = (self.scan()
             .select((family or self.payload_family,
                      qualifier or self.payload_qualifier))
             .where(predicate, index_qualifiers)
             .map(program))
        return q.collect(eta=eta)

    # ------------------------------------------------------------------
    # the planner/executor behind GridQuery
    # ------------------------------------------------------------------

    def _execute_plan(
        self, plan: GridQuery, eta: Optional[int] = None
    ) -> Tuple[Any, RunReport]:
        """Compile + execute a :class:`GridQuery` with all three pushdowns."""
        eta = int(eta or self.default_eta)
        self.metrics.scans += 1
        if not plan.programs:
            return self._collect_rows(plan, eta)
        program: MapReduceProgram
        if len(plan.programs) == 1:
            program = plan.programs[0]
        else:
            program = FusedProgram(plan.programs)
            self.metrics.programs_fused += len(plan.programs)
        if (plan.start is None and plan.stop is None
                and plan.predicate is None):
            return self._run_full(plan, program, eta)
        return self._run_pruned(plan, program, eta)

    def _run_full(
        self, plan: GridQuery, program: MapReduceProgram, eta: int
    ) -> Tuple[Any, RunReport]:
        """Whole-table plans ride the incremental layout machinery: a repeat
        run is a plan-cache hit; across epochs only dirty regions' blocks are
        re-gathered and only their shards re-assembled."""
        family, qualifier = plan.compute_column()
        plan_key = (tuple(p.cache_key() for p in plan.programs),
                    self._mesh_shape(), eta, family, qualifier, self._epoch)
        layout_key = self._plans.get(plan_key)
        hit = (layout_key is not None
               and self._layouts.get(layout_key) is not None)
        if hit:
            self.metrics.plan_hits += 1
            layout = self._layouts[layout_key]
            layout.last_used = self._epoch
            acct = _BlockAccount.all_reused(layout.n_blocks)
        else:
            self.metrics.plan_misses += 1
            layout, acct = self._layout(family, qualifier, eta)
            self._plans.put(plan_key, (family, qualifier, eta))
        result, mr = self.engine.run(program, layout.values, layout.dvalid,
                                     eta)
        n = self.table.num_rows
        row_nbytes = self.table.column_spec(family, qualifier).row_nbytes
        # payload_bytes_moved is the LOGICAL quantity (selected rows × row
        # bytes, here the whole table) on every path; physical transfer
        # lives in the block oracles acct.apply fills in
        qstats = acct.apply(QueryStats(
            rows_scanned=n, index_bytes_scanned=0, payload_bytes_traversed=0,
            rows_selected=n,
            payload_bytes_moved=n * row_nbytes,
            regions_scanned=len(self.table.regions), regions_pruned=0))
        return result, RunReport(epoch=self._epoch, eta=eta,
                                 plan_cache_hit=hit, mapreduce=mr,
                                 query=qstats)

    def _run_pruned(
        self, plan: GridQuery, program: MapReduceProgram, eta: int
    ) -> Tuple[Any, RunReport]:
        """Range/predicate plans: prune regions first, then assemble the
        surviving regions' blocks into a layout (store-first, so blocks
        shared with earlier plans or epochs never re-gather) and fold only
        the selected slots through a device-side row mask."""
        sig = plan.plan_signature(eta)
        entry = self._scan_plans.get(sig)
        hit = entry is not None and entry.predicate is plan.predicate
        if hit:
            self.metrics.plan_hits += 1
            acct = _BlockAccount.all_reused(len(entry.blocks))
        else:
            self.metrics.plan_misses += 1
            entry, acct = self._gather_pruned(plan, eta)
            self._scan_plans.put(sig, entry)
        entry.last_used = self._epoch
        result, mr = self.engine.run(program, entry.values, entry.dvalid, eta,
                                     row_mask=entry.row_mask)
        return result, RunReport(epoch=self._epoch, eta=eta,
                                 plan_cache_hit=hit, mapreduce=mr,
                                 query=acct.apply(entry.qstats))

    def _scan_mask(
        self, plan: GridQuery
    ) -> Tuple[np.ndarray, QueryStats, Tuple[Region, ...]]:
        """Selected-row mask + accounting for a plan's scan stage, plus the
        pruned region set so downstream stages consume the SAME range
        resolution they were keyed on (range clipping itself lives in the
        mask — blocks keep whole regions).

        With a predicate this is :func:`indexed_query` over the scan range
        (index family only); without one, every row in range is selected and
        zero index bytes move.  Region stats always reflect the pruning.
        """
        regions = self.table.regions.prune(plan.start, plan.stop)
        pruned_count = len(self.table.regions) - len(regions)
        lo, hi = self.table.row_range(plan.start, plan.stop)
        if plan.predicate is not None:
            mask, qstats = indexed_query(
                self.table, plan.predicate, plan.index_qualifiers,
                index_family=self.index_family,
                start=plan.start, stop=plan.stop)
        else:
            mask = np.zeros(self.table.num_rows, dtype=bool)
            mask[lo:hi] = True
            qstats = QueryStats(
                rows_scanned=hi - lo, index_bytes_scanned=0,
                payload_bytes_traversed=0, rows_selected=hi - lo,
                regions_scanned=len(regions), regions_pruned=pruned_count)
        return mask, qstats, regions

    def _gather_pruned(
        self, plan: GridQuery, eta: int
    ) -> Tuple[_ScanPlan, _BlockAccount]:
        """One store-first assembly pass: per device, ITS OWN surviving
        regions' blocks — pruned regions untouched, shared blocks reused."""
        family, qualifier = plan.compute_column()
        # range clipping lives entirely in the row mask below — blocks keep
        # whole regions so the payload stays shareable across ranges
        mask, qstats, regions = self._scan_mask(plan)
        per_dev = self._per_device_regions(regions)
        blocks_per_dev, acct = self._fetch_blocks(per_dev, family, qualifier)

        spec = self.table.column_spec(family, qualifier)
        rows_per_dev = [sum(b.rows for b in blks) for blks in blocks_per_dev]
        cap = self._capacity_for(rows_per_dev, eta)
        values, valid, _ = self._assemble(blocks_per_dev, rows_per_dev, cap,
                                          spec.shape, spec.dtype)
        # slot-level selection: real slot AND in scan range AND predicate —
        # blocks hold whole regions, so range edges and predicates both land
        # in the mask, never in the (shared, reusable) payload
        row_mask = np.zeros_like(valid)
        for d, regs in enumerate(per_dev):
            if regs:
                rows = np.concatenate(
                    [self.table.region_positions(r) for r in regs])
                row_mask[d, : len(rows)] = mask[rows]
        sh = Placement.data_sharding(self.mesh, self.data_axis)
        qstats = dataclasses.replace(
            qstats,
            payload_bytes_moved=qstats.rows_selected * spec.row_nbytes)
        self.metrics.pushdown_rows_gathered += acct.rows_gathered
        if acct.gathered:
            self.metrics.payload_gathers += 1
        entry = _ScanPlan(
            predicate=plan.predicate, values=values,
            dvalid=jax.device_put(valid, sh),
            row_mask=jax.device_put(row_mask, sh), qstats=qstats,
            blocks=tuple(b for blks in blocks_per_dev for b in blks),
            region_ids=frozenset(r.rid for r in regions),
            owners=tuple((r.rid, self.placement.alloc.get(r.rid))
                         for r in regions))
        return entry, acct

    def _collect_rows(
        self, plan: GridQuery, eta: int
    ) -> Tuple[Tuple[np.ndarray, Dict[str, np.ndarray]], RunReport]:
        """Program-less plans are pruned retrieves: host-side rowkeys plus
        every selected column's values, charging only the selected rows."""
        mask, qstats, _ = self._scan_mask(plan)
        sel = np.nonzero(mask)[0]
        cols = {
            f"{f}:{q}": self.table.column(f, q)[sel].copy()
            for f, q in plan.resolved_columns()
        }
        per_row = sum(self.table.column_spec(f, q).row_nbytes
                      for f, q in plan.resolved_columns())
        qstats = dataclasses.replace(
            qstats, payload_bytes_moved=len(sel) * per_row)
        report = RunReport(epoch=self._epoch, eta=eta, plan_cache_hit=False,
                           mapreduce=None, query=qstats)
        return (self.table.keys[sel].copy(), cols), report

    # ------------------------------------------------------------------
    # block fetch + layout assembly (the BlockStore plumbing)
    # ------------------------------------------------------------------

    def _per_device_regions(
        self, regions: Sequence[Region]
    ) -> List[List[Region]]:
        """Group regions by owning device, preserving start-key order (so a
        shard's slots are ascending in rowkey, exactly as placement's
        ``rows_for_node`` orders them)."""
        per: List[List[Region]] = [[] for _ in self.placement.nodes]
        for region in regions:
            d = self._node_index.get(self.placement.alloc.get(region.rid))
            if d is not None:
                per[d].append(region)
        return per

    @staticmethod
    def _capacity_for(rows_per_dev: List[int], chunk: int) -> int:
        """Slots per device: the busiest device's rows rounded up to a
        chunk multiple, at least one chunk (SPMD needs equal shards)."""
        need = max(rows_per_dev, default=0)
        return max(chunk, -(-max(need, 1) // chunk) * chunk)

    def _fetch_blocks(
        self,
        per_dev: List[List[Region]],
        family: str,
        qualifier: str,
        skip: Optional[List[bool]] = None,
    ) -> Tuple[List[List[DeviceBlock]], _BlockAccount]:
        """Store-first fetch of every listed region's block, grouped per
        device, with one account covering the whole pass.

        ``skip[d]`` marks devices whose assembled shard will be reused
        as-is: their regions are accounted as reused without touching the
        store (no fetch, no LRU churn) and their block list stays empty.
        """
        acct = _BlockAccount()
        blocks_per_dev: List[List[DeviceBlock]] = []
        for d, regs in enumerate(per_dev):
            if skip is not None and skip[d]:
                acct.total += len(regs)
                acct.reused += len(regs)
                blocks_per_dev.append([])
                continue
            blks = []
            for region in regs:
                blk, reused, gathered = self._fetch_block(
                    region, family, qualifier, owner=d)
                acct.add(blk, reused, gathered)
                blks.append(blk)
            blocks_per_dev.append(blks)
        return blocks_per_dev, acct

    def _fetch_block(
        self, region: Region, family: str, qualifier: str, owner: int
    ) -> Tuple[DeviceBlock, bool, bool]:
        """Store-first block access; ``owner`` is the region's device index
        (the _per_device_regions group the caller is filling — derived once
        there, not re-derived per block)."""
        blk, reused, gathered = self.blocks.fetch(
            region, family, qualifier, owner,
            gather_host=lambda: self.table.region_column(
                region, family, qualifier),
            to_device=None if self._devices is None else self._put_block,
        )
        return blk, reused, gathered

    def _put_block(self, host: np.ndarray, owner_index: Optional[int]):
        """Commit one block to its owner shard's device (the per-shard
        ``device_put`` half of the multi-chip transfer path)."""
        dev = None if owner_index is None else self._devices[owner_index]
        return jax.device_put(host, dev)

    def _assemble(
        self,
        blocks_per_dev: List[List[DeviceBlock]],
        rows_per_dev: List[int],
        cap: int,
        row_shape: Tuple[int, ...],
        dtype,
        reuse: Optional[List[Optional[Any]]] = None,
    ) -> Tuple[Any, np.ndarray, Optional[List[Any]]]:
        """Blocks → ``(global [D, cap, ...] device array, host validity,
        per-device shards)``.

        Per-shard path (1-D data mesh): each device's blocks are already
        resident on it, so assembly is an on-device concat + pad and the
        global array is stitched with
        ``jax.make_array_from_single_device_arrays`` — clean blocks never
        re-cross the host↔device boundary.  ``reuse[d]`` (a prior build's
        shard whose composition is unchanged) skips even the concat, and
        its block list may be empty.  Fallback (exotic meshes): host concat
        + one sharded ``device_put``, shards ``None``.
        """
        D = len(blocks_per_dev)
        valid = np.zeros((D, cap), dtype=bool)
        for d, n in enumerate(rows_per_dev):
            valid[d, :n] = True
        sh = Placement.data_sharding(self.mesh, self.data_axis)
        global_shape = (D, cap) + tuple(row_shape)
        if self._devices is None:
            host = np.zeros(global_shape, dtype)
            for d, blks in enumerate(blocks_per_dev):
                off = 0
                for b in blks:
                    host[d, off: off + b.rows] = b.host
                    off += b.rows
            return jax.device_put(host, sh), valid, None
        shards = [
            reuse[d] if reuse is not None and reuse[d] is not None
            else self._assemble_shard(blks, cap, row_shape, dtype, d)
            for d, blks in enumerate(blocks_per_dev)
        ]
        values = jax.make_array_from_single_device_arrays(
            global_shape, sh, shards)
        return values, valid, shards

    def _assemble_shard(
        self,
        blks: List[DeviceBlock],
        cap: int,
        row_shape: Tuple[int, ...],
        dtype,
        d: int,
    ):
        """One device's ``[1, cap, ...]`` shard from its resident blocks."""
        parts = [b.device for b in blks if b.rows]
        n = sum(b.rows for b in blks)
        if not parts:
            shard = jax.device_put(
                np.zeros((cap,) + tuple(row_shape), dtype), self._devices[d])
        else:
            shard = parts[0] if len(parts) == 1 else jnp.concatenate(parts)
            if n < cap:
                shard = jnp.pad(
                    shard, [(0, cap - n)] + [(0, 0)] * len(row_shape))
        return shard.reshape((1, cap) + tuple(row_shape))

    # ------------------------------------------------------------------
    # layouts (incremental placement materialization over blocks)
    # ------------------------------------------------------------------

    def _layout(
        self, family: str, qualifier: str, chunk: int
    ) -> Tuple[_Layout, _BlockAccount]:
        key = (family, qualifier, int(chunk))
        lay = self._layouts.get(key)
        if lay is not None and lay.epoch == self._epoch:
            lay.last_used = self._epoch
            return lay, _BlockAccount.all_reused(lay.n_blocks)

        per_dev = self._per_device_regions(self.table.regions.regions)
        D = len(per_dev)
        keys = self.table.keys
        rows_per_dev = [sum(r.num_rows(keys) for r in regs)
                        for regs in per_dev]
        # composition comes from lineage alone — deciding which shards to
        # reuse must not touch the store, or clean shards' blocks would be
        # re-fetched (and under cap pressure re-gathered) just to be
        # discarded by the reuse path
        composition = tuple(self.blocks.lineage(regs) for regs in per_dev)

        cap_needed = self._capacity_for(rows_per_dev, chunk)
        spec = self.table.column_spec(family, qualifier)
        full = lay is None or cap_needed > lay.capacity
        cap = cap_needed if full else lay.capacity

        # a shard whose block composition (and capacity) is unchanged is
        # reused object-for-object — no concat, no pad, no device_put,
        # and its blocks are never pulled through the store
        reuse: Optional[List[Optional[Any]]] = None
        if not full and lay.shards is not None:
            reuse = [lay.shards[d] if composition[d] == lay.composition[d]
                     else None for d in range(D)]
        skip = None if reuse is None else [r is not None for r in reuse]
        blocks_per_dev, acct = self._fetch_blocks(per_dev, family, qualifier,
                                                  skip=skip)
        values, valid, shards = self._assemble(
            blocks_per_dev, rows_per_dev, cap, spec.shape, spec.dtype,
            reuse=reuse)
        kept = sum(1 for r in reuse if r is not None) if reuse else 0
        self.metrics.devices_reused += kept
        self.metrics.devices_regathered += D - kept

        if full:
            self.metrics.layout_full_builds += 1
        else:
            self.metrics.layout_refreshes += 1
        self.metrics.rows_gathered += acct.rows_gathered
        if acct.gathered:
            self.metrics.payload_gathers += 1

        sh = Placement.data_sharding(self.mesh, self.data_axis)
        lay = _Layout(
            epoch=self._epoch, chunk=int(chunk), capacity=cap,
            valid=valid, values=values,
            dvalid=jax.device_put(valid, sh),
            composition=composition, shards=shards,
            n_blocks=acct.total, last_used=self._epoch,
        )
        self._layouts[key] = lay
        return lay, acct

    # ------------------------------------------------------------------
    # helpers / diagnostics
    # ------------------------------------------------------------------

    def _mesh_shape(self) -> Tuple[Tuple[str, int], ...]:
        return tuple((a, self.mesh.shape[a]) for a in self.mesh.axis_names)

    def imbalance(self) -> float:
        """Max relative deviation of node work from #CPU×MIPS-proportional."""
        return allocation_imbalance(
            self.placement.alloc, self.table.region_bytes(),
            self.placement.nodes)

    def token_dataset(self, global_batch: int,
                      batch_axes: Sequence[str] = ("data",), seed: int = 0):
        """A :class:`ColocatedTokenDataset` sharing this session's placement
        (training batches ride the same region→device map the verbs maintain).
        """
        from repro.data.pipeline import ColocatedTokenDataset
        return ColocatedTokenDataset(
            self.table, self.mesh, global_batch, data_axis=self.data_axis,
            batch_axes=batch_axes, placement=self.placement, seed=seed)

    def describe(self) -> str:
        m = self.metrics
        lines = [
            f"GridSession(table={self.table.name!r}, epoch={self._epoch}, "
            f"eta={self.default_eta}, imbalance={self.imbalance():.3f})",
            self.placement.describe(),
            f"  plans: {m.plan_hits} hits / {m.plan_misses} misses; "
            f"engine compiles: {self.engine.compile_count}",
            f"  layouts: {m.layout_full_builds} full builds, "
            f"{m.layout_refreshes} refreshes "
            f"({m.devices_regathered} reassembled / {m.devices_reused} reused "
            f"device shards, {m.rows_gathered} rows gathered)",
            f"  blocks: {self.blocks.describe()}",
            f"  queries: {m.scans} plans executed, {m.programs_fused} "
            f"programs fused, {m.payload_gathers} payload gather passes "
            f"({m.pushdown_rows_gathered} pushdown rows)",
        ]
        return "\n".join(lines)
