"""GridSession — the paper's backend API behind one session object.

The paper's contribution is an *interface* (Table 1): Upload, Retrieve,
Remove, a heterogeneity-aware Load balancer, and MapReduce templates over
colocated storage.  The repo implements each piece as a standalone module
(:mod:`table`, :mod:`regions`, :mod:`balancer`, :mod:`placement`,
:mod:`mapreduce`, :mod:`query`); ``GridSession`` owns the whole
table → regions → balancer → placement → mapreduce → query lifecycle and
exposes the five verbs:

- :meth:`upload`    — batch insert with split handling and incremental
  placement (split children inherit their parent's node, HBase-style);
- :meth:`retrieve`  — the Table-1 selector read path;
- :meth:`remove`    — row deletion with dirty-region invalidation;
- :meth:`rebalance` — the paper's offline #CPU×MIPS balancer, applied to the
  *current* allocation (minimum region moves); ``auto=True`` derives node
  powers from :meth:`observe_round` history through the wired
  :class:`GridScheduler` / ``powers_from_observations`` loop;
- :meth:`scan`      — the query surface: a lazy :class:`GridQuery` plan
  (``scan(...).select(...).where(...).map(...).reduce()``) that prunes
  regions, pushes the projection down, and fuses all mapped statistics into
  one engine pass when ``.collect()``/``.stats()`` executes it;
- :meth:`run` / :meth:`run_where` — thin wrappers over :meth:`scan` for the
  full table and the predicate-pushdown subset.

Three properties make mutation cheap and repeated compute fast:

1. **Mutation epochs + dirty regions.**  Every mutation advances an epoch and
   records which regions (hence which nodes) it touched.  Device layouts are
   cached per column; a stale layout re-gathers payload *only for the dirty
   nodes* and reuses every other device's block — an upload into one region
   costs one device's gather, not a rebuild of the world.
2. **Compiled-plan cache.**  Plans are keyed by ``(program, mesh shape, η,
   table epoch)``.  A repeat ``run`` at the same epoch is a pure cache hit;
   across epochs the bound data refreshes but the jitted ``shard_map``
   executable (shape-keyed inside :class:`MapReduceEngine`) is reused, so no
   recompile happens unless the layout's shape actually changed.
3. **Predicate pushdown.**  ``where`` plans evaluate the predicate on the
   index family only (§2.3), then gather *just the selected payload rows*
   per device — locality preserved because index and payload share rowkeys
   and placement — and report ``payload_bytes_moved`` covering only those
   rows.  The mask path (materialize everything, fold a subset) is gone.
4. **Region pruning.**  A rowkey prefix/range scan intersects the
   :class:`RegionSet` intervals *before* any bytes move (two bisects over
   region start keys): non-matching regions are never scanned and their
   device blocks never gathered.  ``QueryStats.regions_scanned`` /
   ``regions_pruned`` make the efficacy observable.
"""

from __future__ import annotations

import dataclasses
from typing import (
    Any, Dict, FrozenSet, List, Mapping, Optional, Sequence, Set, Tuple,
)

import numpy as np

import jax

from repro.core.balancer import (
    NodeSpec,
    allocation_imbalance,
    powers_from_observations,
    rebalance as rebalance_allocation,
)
from repro.core.mapreduce import MapReduceEngine, MapReduceProgram, MapReduceStats
from repro.core.placement import Placement
from repro.core.plan import GridQuery, prefix_range
from repro.core.query import Predicate, QueryStats, indexed_query
from repro.core.regions import Region
from repro.core.scheduler import GridScheduler
from repro.core.stats import FusedProgram
from repro.core.table import (
    DATA_FAMILY,
    INDEX_FAMILY,
    RowKey,
    TensorTable,
    _as_key,
)
from repro.utils import make_mesh


@dataclasses.dataclass
class SessionMetrics:
    """Observable counters for the session's incremental machinery."""

    uploads: int = 0
    removes: int = 0
    rebalances: int = 0
    epochs: int = 0                 # mutation epochs advanced
    regions_dirtied: int = 0
    plan_hits: int = 0              # run() served from the plan cache
    plan_misses: int = 0
    layout_full_builds: int = 0     # gather-everything rebuilds
    layout_refreshes: int = 0       # incremental dirty-node refreshes
    devices_regathered: int = 0     # device blocks whose payload was re-read
    devices_reused: int = 0         # device blocks kept across a mutation
    rows_gathered: int = 0          # payload rows copied into layouts
    pushdown_rows_gathered: int = 0  # payload rows moved by pruned/where scans
    scans: int = 0                  # GridQuery plans executed
    payload_gathers: int = 0        # payload gather passes (full, refresh, pruned)
    programs_fused: int = 0         # programs that shared a fused engine pass


@dataclasses.dataclass(frozen=True)
class RunReport:
    """Accounting for one executed plan (``run``/``run_where``/``collect``)."""

    epoch: int
    eta: int
    plan_cache_hit: bool
    mapreduce: Optional[MapReduceStats]   # None for pure retrieve plans
    query: Optional[QueryStats] = None


class _SessionScheduler(GridScheduler):
    """The session-owned scheduler is observation/planning only.

    Node membership is pinned by the mesh (one device per node), and region
    moves must flow through :meth:`GridSession.rebalance` so mutation epochs
    invalidate cached layouts/plans — the fail/join verbs would mutate the
    shared placement behind the session's back, leaving stale device maps.
    """

    def handle_failure(self, dead_node_ids):
        raise NotImplementedError(
            "the session-owned scheduler cannot change node membership: the "
            "mesh pins one device per node; use GridSession.rebalance "
            "(optionally with refreshed NodeSpecs) for region moves")

    def handle_join(self, new_nodes):
        raise NotImplementedError(
            "the session-owned scheduler cannot change node membership: the "
            "mesh pins one device per node; use GridSession.rebalance "
            "(optionally with refreshed NodeSpecs) for region moves")


@dataclasses.dataclass
class _ScanPlan:
    """A bound pruned-scan layout: the gathered device blocks of one
    ``GridQuery`` plan, reusable until the next mutation epoch.

    ``predicate`` pins the predicate object so its ``id()`` (part of the
    plan signature) cannot be recycled while this entry lives; every cache
    hit re-verifies identity.
    """

    predicate: Optional[Predicate]
    values: Any                # device [D, C, ...] of the selected rows
    dvalid: Any                # device [D, C] validity
    qstats: QueryStats


@dataclasses.dataclass
class _Layout:
    """One column materialized in colocated ``[D, C, ...]`` device layout."""

    epoch: int
    chunk: int
    capacity: int
    row_ids: np.ndarray        # [D, C] positional indices into the table
    valid: np.ndarray          # [D, C] real-slot mask (host)
    host_values: np.ndarray    # [D, C, ...] gathered payload (host cache)
    values: Any                # device copy of host_values
    dvalid: Any                # device copy of valid
    last_used: int = 0         # epoch of the last run using this layout


class GridSession:
    """One object owning the grid lifecycle; the five-verb facade."""

    #: layouts untouched for this many epochs are evicted — a stale layout
    #: pins a full host payload copy AND the dirty-log floor, so a
    #: long-lived mutating session must not keep it forever.
    LAYOUT_TTL_EPOCHS = 64

    def __init__(
        self,
        table: TensorTable,
        mesh: Optional[jax.sharding.Mesh] = None,
        nodes: Optional[Sequence[NodeSpec]] = None,
        strategy: str = "greedy",
        data_axis: str = "data",
        default_eta: int = 16,
        payload_family: str = DATA_FAMILY,
        payload_qualifier: str = "data",
        index_family: str = INDEX_FAMILY,
    ):
        self.table = table
        self.mesh = (mesh if mesh is not None
                     else make_mesh((jax.device_count(),), (data_axis,)))
        self.data_axis = data_axis
        D = self.mesh.shape[data_axis]
        if nodes is None:
            nodes = [NodeSpec(i) for i in range(D)]
        if len(nodes) != D:
            raise ValueError(
                f"{len(nodes)} nodes for mesh axis {data_axis!r} of size {D}")
        self.default_eta = int(default_eta)
        self.payload_family = payload_family
        self.payload_qualifier = payload_qualifier
        self.index_family = index_family

        self.placement = Placement.from_strategy(table, nodes, strategy)
        self.table.split_log.clear()  # from_strategy saw the current regions
        self.engine = MapReduceEngine(self.mesh, data_axis)
        self.metrics = SessionMetrics()

        self._epoch = 0
        # (epoch, dirty node ids) per mutation; consumed by layout refresh
        self._dirty_log: List[Tuple[int, FrozenSet[int]]] = []
        self._layouts: Dict[Tuple[str, str, int], _Layout] = {}
        # (programs, mesh shape, eta, column, epoch) -> layout key
        self._plans: Dict[Tuple, Tuple[str, str, int]] = {}
        # GridQuery plan signature -> bound pruned-scan layout
        self._scan_plans: Dict[Tuple, _ScanPlan] = {}
        self._node_index = {n.node_id: d for d, n in enumerate(nodes)}
        # observed per-node round times (observe_round) -> auto-rebalance
        self._round_history: Dict[int, List[float]] = {
            n.node_id: [] for n in nodes
        }
        self._scheduler: Optional[GridScheduler] = None

    # ------------------------------------------------------------------
    # epoch / dirty tracking
    # ------------------------------------------------------------------

    @property
    def epoch(self) -> int:
        return self._epoch

    def _advance_epoch(self, dirty_rids: Set[int],
                       extra_dirty_nodes: Set[int] = frozenset()) -> None:
        self._epoch += 1
        self.metrics.epochs += 1
        self.metrics.regions_dirtied += len(dirty_rids)
        owners = {
            self.placement.alloc[rid]
            for rid in dirty_rids if rid in self.placement.alloc
        } | set(extra_dirty_nodes)
        self._dirty_log.append((self._epoch, frozenset(owners)))
        # plans are epoch-keyed; everything cached is now stale
        self._plans.clear()
        self._scan_plans.clear()
        self._prune_caches()

    def _prune_caches(self) -> None:
        """Evict long-unused layouts, then drop dirty entries no survivor
        can still consume — keeps a mutating session's memory bounded."""
        self._layouts = {
            k: l for k, l in self._layouts.items()
            if self._epoch - l.last_used <= self.LAYOUT_TTL_EPOCHS
        }
        floor = min((l.epoch for l in self._layouts.values()),
                    default=self._epoch)
        self._dirty_log = [(e, ns) for e, ns in self._dirty_log if e > floor]

    # ------------------------------------------------------------------
    # the five verbs
    # ------------------------------------------------------------------

    def upload(
        self,
        rowkeys: Sequence[RowKey],
        data: Mapping[str, Mapping[str, np.ndarray]],
        on_duplicate: str = "skip",
    ) -> int:
        """Table-1 Upload: batch insert with incremental placement.

        Splits triggered by the insert keep daughters on the parent's node
        (rebalancing is an explicit :meth:`rebalance` call, as in the paper);
        only the regions containing the uploaded keys are invalidated.
        """
        # under "skip", duplicates leave their rows untouched — only the keys
        # actually written may dirty a region, so snapshot existence first
        keys = np.array([_as_key(k) for k in rowkeys], dtype="S64")
        if on_duplicate == "skip" and len(keys):
            written_keys = keys[~self.table.existing_mask(rowkeys)]
        else:
            written_keys = keys
        written = self.table.upload(rowkeys, data, on_duplicate=on_duplicate)
        self.metrics.uploads += 1
        if not written:
            self.table.split_log.clear()
            return 0
        self.placement.apply_splits()
        dirty = self.table.regions.regions_containing(
            [bytes(k) for k in written_keys])
        self._advance_epoch(dirty)
        return written

    def retrieve(
        self,
        family: str,
        qualifier: str,
        rowkey: Optional[RowKey] = None,
        start: Optional[RowKey] = None,
        stop: Optional[RowKey] = None,
        skip: Optional[Sequence[RowKey]] = None,
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Table-1 Retrieve: ``(rowkeys, values)`` for the selector."""
        return self.table.retrieve(family, qualifier, rowkey=rowkey,
                                   start=start, stop=stop, skip=skip)

    def remove(
        self,
        rowkey: Optional[RowKey] = None,
        start: Optional[RowKey] = None,
        stop: Optional[RowKey] = None,
        skip: Optional[Sequence[RowKey]] = None,
    ) -> int:
        """Table-1 Remove: delete rows, invalidating only their regions."""
        doomed = [bytes(k) for k in
                  self.table.select_keys(rowkey, start, stop, skip)]
        removed = self.table.delete(rowkey=rowkey, start=start, stop=stop,
                                    skip=skip)
        self.metrics.removes += 1
        if removed:
            self._advance_epoch(self.table.regions.regions_containing(doomed))
        return removed

    def observe_round(self, node_times: Mapping[int, float]) -> None:
        """Feed measured per-node round times (the runtime re-measurement of
        the paper's ``linux perf`` MIPS probe).

        Observations accumulate in the session AND drive the wired
        :class:`GridScheduler` (its EWMA powers back ``makespan_estimate``
        and the round ledger); :meth:`rebalance` with ``auto=True`` then
        derives node powers from this history via
        :func:`~repro.core.balancer.powers_from_observations` — no
        hand-supplied specs needed.
        """
        for nid, t in node_times.items():
            if nid in self._round_history and t > 0:
                hist = self._round_history[nid]
                hist.append(float(t))
                del hist[:-self.ROUND_HISTORY_CAP]
        self.scheduler.observe_round(node_times)

    #: round-time observations kept per node; the EWMA power fold saturates
    #: long before this, and an unbounded log would grow with session age
    ROUND_HISTORY_CAP = 64

    @property
    def scheduler(self) -> GridScheduler:
        """The session's passive :class:`GridScheduler` (observation ledger,
        makespan estimates).  Its auto-trigger threshold is infinite and its
        membership verbs are disabled — region moves stay under the
        session's explicit :meth:`rebalance`, which is what keeps
        epochs/dirty-tracking consistent."""
        if self._scheduler is None:
            self._scheduler = _SessionScheduler(
                self.placement, chunk_size=self.default_eta,
                rebalance_threshold=float("inf"))
        return self._scheduler

    def rebalance(
        self,
        tolerance: float = 0.05,
        nodes: Optional[Sequence[NodeSpec]] = None,
        auto: bool = False,
    ) -> List[int]:
        """The paper's offline balancer from the *current* allocation.

        ``nodes`` swaps in refreshed specs (elastic rescale, straggler
        deweighting via :func:`~repro.core.balancer.powers_from_observations`)
        — node ids must be the existing ones.  ``auto=True`` derives those
        specs from the round times fed to :meth:`observe_round` instead
        (no observations yet -> powers unchanged).  Returns moved region ids.
        """
        if auto:
            if nodes is not None:
                raise ValueError(
                    "auto=True derives nodes from observe_round history; "
                    "pass one or the other")
            if any(self._round_history.values()):
                nodes = powers_from_observations(
                    self._round_history, self.placement.nodes)
        if nodes is not None:
            if {n.node_id for n in nodes} != set(self._node_index):
                raise ValueError("rebalance nodes must keep the same node ids")
            order = sorted(nodes, key=lambda n: self._node_index[n.node_id])
            self.placement.nodes = tuple(order)
        old = dict(self.placement.alloc)
        new_alloc, moved = rebalance_allocation(
            old, self.table.region_bytes(), self.placement.nodes, tolerance)
        self.metrics.rebalances += 1
        if moved:
            self.placement.alloc.clear()
            self.placement.alloc.update(new_alloc)
            self.placement.version += 1
            dirty_nodes = ({old[rid] for rid in moved if rid in old}
                           | {new_alloc[rid] for rid in moved})
            self._advance_epoch(set(moved), extra_dirty_nodes=dirty_nodes)
        return moved

    # ------------------------------------------------------------------
    # GridQuery: lazy scan -> filter -> map -> reduce plans
    # ------------------------------------------------------------------

    def scan(
        self,
        prefix: Optional[RowKey] = None,
        start: Optional[RowKey] = None,
        stop: Optional[RowKey] = None,
    ) -> GridQuery:
        """Open a lazy :class:`GridQuery` plan over a rowkey range.

        ``prefix`` is sugar for the half-open range of keys sharing it
        (mutually exclusive with ``start``/``stop``).  Nothing is scanned,
        gathered, or compiled until ``.collect()``/``.stats()`` — the
        planner prunes regions, pushes the projection down, and fuses every
        ``.map`` program into one engine pass first.
        """
        if prefix is not None:
            if start is not None or stop is not None:
                raise ValueError("prefix is exclusive with start/stop")
            p, (start_b, stop_b) = _as_key(prefix), prefix_range(prefix)
            return GridQuery(self, start=start_b, stop=stop_b, prefix=p)
        return GridQuery(
            self,
            start=None if start is None else _as_key(start),
            stop=None if stop is None else _as_key(stop),
        )

    def run(
        self,
        program: MapReduceProgram,
        eta: Optional[int] = None,
        family: Optional[str] = None,
        qualifier: Optional[str] = None,
    ) -> Tuple[Any, RunReport]:
        """MapReduce over the whole table — a full-range one-program plan."""
        q = self.scan().select(
            (family or self.payload_family,
             qualifier or self.payload_qualifier)).map(program)
        return q.collect(eta=eta)

    def run_where(
        self,
        predicate: Predicate,
        program: MapReduceProgram,
        index_qualifiers: Sequence[str],
        eta: Optional[int] = None,
        family: Optional[str] = None,
        qualifier: Optional[str] = None,
    ) -> Tuple[Any, RunReport]:
        """Predicate-pushdown MapReduce (§2.3 unified with §2.2) — a
        full-range ``.where`` plan.

        The predicate runs over the index family only; each device then
        gathers *just its own selected* payload rows (compacted, locality
        preserved), so the returned ``QueryStats.payload_bytes_moved`` covers
        exactly the selected rows — never the full table.
        """
        q = (self.scan()
             .select((family or self.payload_family,
                      qualifier or self.payload_qualifier))
             .where(predicate, index_qualifiers)
             .map(program))
        return q.collect(eta=eta)

    # ------------------------------------------------------------------
    # the planner/executor behind GridQuery
    # ------------------------------------------------------------------

    #: bound pruned-scan layouts kept per epoch; oldest evicted beyond this
    SCAN_PLAN_CAP = 32

    def _execute_plan(
        self, plan: GridQuery, eta: Optional[int] = None
    ) -> Tuple[Any, RunReport]:
        """Compile + execute a :class:`GridQuery` with all three pushdowns."""
        eta = int(eta or self.default_eta)
        self.metrics.scans += 1
        if not plan.programs:
            return self._collect_rows(plan, eta)
        program: MapReduceProgram
        if len(plan.programs) == 1:
            program = plan.programs[0]
        else:
            program = FusedProgram(plan.programs)
            self.metrics.programs_fused += len(plan.programs)
        if (plan.start is None and plan.stop is None
                and plan.predicate is None):
            return self._run_full(plan, program, eta)
        return self._run_pruned(plan, program, eta)

    def _run_full(
        self, plan: GridQuery, program: MapReduceProgram, eta: int
    ) -> Tuple[Any, RunReport]:
        """Whole-table plans ride the incremental layout machinery: a repeat
        run is a plan-cache hit; across epochs only dirty device blocks are
        re-gathered."""
        family, qualifier = plan.compute_column()
        plan_key = (tuple(p.cache_key() for p in plan.programs),
                    self._mesh_shape(), eta, family, qualifier, self._epoch)
        hit = plan_key in self._plans
        rows_before = self.metrics.rows_gathered
        if hit:
            self.metrics.plan_hits += 1
            layout = self._layouts[self._plans[plan_key]]
        else:
            self.metrics.plan_misses += 1
            layout = self._layout(family, qualifier, eta)
            self._plans[plan_key] = (family, qualifier, eta)
        result, mr = self.engine.run(program, layout.values, layout.dvalid,
                                     eta)
        n = self.table.num_rows
        row_nbytes = self.table.column_spec(family, qualifier).row_nbytes
        qstats = QueryStats(
            rows_scanned=n, index_bytes_scanned=0, payload_bytes_traversed=0,
            rows_selected=n,
            payload_bytes_moved=(self.metrics.rows_gathered - rows_before)
            * row_nbytes,
            regions_scanned=len(self.table.regions), regions_pruned=0)
        return result, RunReport(epoch=self._epoch, eta=eta,
                                 plan_cache_hit=hit, mapreduce=mr,
                                 query=qstats)

    def _run_pruned(
        self, plan: GridQuery, program: MapReduceProgram, eta: int
    ) -> Tuple[Any, RunReport]:
        """Range/predicate plans: prune regions first, then gather only the
        selected rows of the surviving regions into a compact layout."""
        sig = plan.plan_signature(eta)
        entry = self._scan_plans.get(sig)
        hit = entry is not None and entry.predicate is plan.predicate
        if hit:
            self.metrics.plan_hits += 1
        else:
            self.metrics.plan_misses += 1
            entry = self._gather_pruned(plan, eta)
            while len(self._scan_plans) >= self.SCAN_PLAN_CAP:
                self._scan_plans.pop(next(iter(self._scan_plans)))
            self._scan_plans[sig] = entry
        result, mr = self.engine.run(program, entry.values, entry.dvalid, eta)
        return result, RunReport(epoch=self._epoch, eta=eta,
                                 plan_cache_hit=hit, mapreduce=mr,
                                 query=entry.qstats)

    def _scan_mask(
        self, plan: GridQuery
    ) -> Tuple[np.ndarray, QueryStats, Tuple[Region, ...], int, int]:
        """Selected-row mask + accounting for a plan's scan stage, plus the
        resolved ``(regions, lo, hi)`` so downstream stages consume the SAME
        range resolution they were keyed on.

        With a predicate this is :func:`indexed_query` over the scan range
        (index family only); without one, every row in range is selected and
        zero index bytes move.  Region stats always reflect the pruning.
        """
        regions = self.table.regions.prune(plan.start, plan.stop)
        pruned_count = len(self.table.regions) - len(regions)
        lo, hi = self.table.row_range(plan.start, plan.stop)
        if plan.predicate is not None:
            mask, qstats = indexed_query(
                self.table, plan.predicate, plan.index_qualifiers,
                index_family=self.index_family,
                start=plan.start, stop=plan.stop)
        else:
            mask = np.zeros(self.table.num_rows, dtype=bool)
            mask[lo:hi] = True
            qstats = QueryStats(
                rows_scanned=hi - lo, index_bytes_scanned=0,
                payload_bytes_traversed=0, rows_selected=hi - lo,
                regions_scanned=len(regions), regions_pruned=pruned_count)
        return mask, qstats, regions, lo, hi

    def _gather_pruned(self, plan: GridQuery, eta: int) -> _ScanPlan:
        """One gather pass: per device, only ITS OWN selected rows from the
        surviving regions — locality preserved, pruned regions untouched."""
        family, qualifier = plan.compute_column()
        mask, qstats, regions, lo, hi = self._scan_mask(plan)
        per_dev = self._per_device_rows_pruned(regions, lo, hi)
        selected = [rows[mask[rows]] for rows in per_dev]
        n_sel = int(sum(len(s) for s in selected))
        need = max((len(s) for s in selected), default=0)
        cap = max(eta, -(-max(need, 1) // eta) * eta)

        col = self.table.column(family, qualifier)
        D = len(per_dev)
        host = np.zeros((D, cap) + col.shape[1:], col.dtype)
        valid = np.zeros((D, cap), dtype=bool)
        for d, rows in enumerate(selected):
            host[d, : len(rows)] = col[rows]
            valid[d, : len(rows)] = True
        sh = Placement.data_sharding(self.mesh, self.data_axis)
        row_nbytes = self.table.column_spec(family, qualifier).row_nbytes
        qstats = dataclasses.replace(
            qstats, payload_bytes_moved=n_sel * row_nbytes)
        self.metrics.pushdown_rows_gathered += n_sel
        self.metrics.payload_gathers += 1
        return _ScanPlan(predicate=plan.predicate,
                         values=jax.device_put(host, sh),
                         dvalid=jax.device_put(valid, sh), qstats=qstats)

    def _collect_rows(
        self, plan: GridQuery, eta: int
    ) -> Tuple[Tuple[np.ndarray, Dict[str, np.ndarray]], RunReport]:
        """Program-less plans are pruned retrieves: host-side rowkeys plus
        every selected column's values, charging only the selected rows."""
        mask, qstats, _, _, _ = self._scan_mask(plan)
        sel = np.nonzero(mask)[0]
        cols = {
            f"{f}:{q}": self.table.column(f, q)[sel].copy()
            for f, q in plan.resolved_columns()
        }
        per_row = sum(self.table.column_spec(f, q).row_nbytes
                      for f, q in plan.resolved_columns())
        qstats = dataclasses.replace(
            qstats, payload_bytes_moved=len(sel) * per_row)
        report = RunReport(epoch=self._epoch, eta=eta, plan_cache_hit=False,
                           mapreduce=None, query=qstats)
        return (self.table.keys[sel].copy(), cols), report

    # ------------------------------------------------------------------
    # layouts (incremental placement materialization)
    # ------------------------------------------------------------------

    def _per_device_rows(self) -> List[np.ndarray]:
        return [self.placement.rows_for_node(n.node_id)
                for n in self.placement.nodes]

    def _per_device_rows_pruned(
        self, regions: Sequence[Region], lo: int, hi: int
    ) -> List[np.ndarray]:
        """Per-device positional rows restricted to the surviving regions,
        clipped to the scan range — O(|pruned regions|), never a walk over
        regions the scan excluded."""
        keys = self.table.keys
        per: List[List[np.ndarray]] = [[] for _ in self.placement.nodes]
        for region in regions:
            d = self._node_index.get(self.placement.alloc.get(region.rid))
            if d is None:
                continue
            s = region.row_slice(keys)
            a, b = max(s.start, lo), min(s.stop, hi)
            if a < b:
                per[d].append(np.arange(a, b, dtype=np.int64))
        return [np.sort(np.concatenate(p)) if p
                else np.empty((0,), dtype=np.int64) for p in per]

    def _layout(self, family: str, qualifier: str, chunk: int) -> _Layout:
        key = (family, qualifier, int(chunk))
        lay = self._layouts.get(key)
        if lay is not None and lay.epoch == self._epoch:
            lay.last_used = self._epoch
            return lay

        per_dev = self._per_device_rows()
        D = len(per_dev)
        need = max((len(r) for r in per_dev), default=0)
        cap_needed = max(chunk, -(-max(need, 1) // chunk) * chunk)
        col = self.table.column(family, qualifier)

        if lay is None or cap_needed > lay.capacity:
            cap = cap_needed
            row_ids = np.zeros((D, cap), dtype=np.int64)
            valid = np.zeros((D, cap), dtype=bool)
            host = np.zeros((D, cap) + col.shape[1:], col.dtype)
            for d, rows in enumerate(per_dev):
                row_ids[d, : len(rows)] = rows
                valid[d, : len(rows)] = True
                host[d, : len(rows)] = col[rows]
            self.metrics.layout_full_builds += 1
            self.metrics.payload_gathers += 1
            self.metrics.devices_regathered += D
            self.metrics.rows_gathered += int(sum(len(r) for r in per_dev))
        else:
            # incremental refresh: payload re-gathered ONLY for nodes dirtied
            # since this layout's epoch; row indices are recomputed for all
            # (cheap — positions shift under inserts) but clean devices keep
            # their payload blocks byte-for-byte.
            cap = lay.capacity
            dirty_nodes: Set[int] = set()
            for e, ns in self._dirty_log:
                if e > lay.epoch:
                    dirty_nodes |= set(ns)
            dirty_devs = {self._node_index[nid] for nid in dirty_nodes
                          if nid in self._node_index}
            row_ids, valid, host = lay.row_ids, lay.valid, lay.host_values
            for d, rows in enumerate(per_dev):
                row_ids[d] = 0
                valid[d] = False
                row_ids[d, : len(rows)] = rows
                valid[d, : len(rows)] = True
                if d in dirty_devs:
                    host[d] = 0
                    host[d, : len(rows)] = col[rows]
                    self.metrics.devices_regathered += 1
                    self.metrics.rows_gathered += len(rows)
                else:
                    self.metrics.devices_reused += 1
            self.metrics.layout_refreshes += 1
            if dirty_devs:
                self.metrics.payload_gathers += 1

        sh = Placement.data_sharding(self.mesh, self.data_axis)
        lay = _Layout(
            epoch=self._epoch, chunk=int(chunk), capacity=cap,
            row_ids=row_ids, valid=valid, host_values=host,
            values=jax.device_put(host, sh), dvalid=jax.device_put(valid, sh),
            last_used=self._epoch,
        )
        self._layouts[key] = lay
        return lay

    # ------------------------------------------------------------------
    # helpers / diagnostics
    # ------------------------------------------------------------------

    def _mesh_shape(self) -> Tuple[Tuple[str, int], ...]:
        return tuple((a, self.mesh.shape[a]) for a in self.mesh.axis_names)

    def imbalance(self) -> float:
        """Max relative deviation of node work from #CPU×MIPS-proportional."""
        return allocation_imbalance(
            self.placement.alloc, self.table.region_bytes(),
            self.placement.nodes)

    def token_dataset(self, global_batch: int,
                      batch_axes: Sequence[str] = ("data",), seed: int = 0):
        """A :class:`ColocatedTokenDataset` sharing this session's placement
        (training batches ride the same region→device map the verbs maintain).
        """
        from repro.data.pipeline import ColocatedTokenDataset
        return ColocatedTokenDataset(
            self.table, self.mesh, global_batch, data_axis=self.data_axis,
            batch_axes=batch_axes, placement=self.placement, seed=seed)

    def describe(self) -> str:
        m = self.metrics
        lines = [
            f"GridSession(table={self.table.name!r}, epoch={self._epoch}, "
            f"eta={self.default_eta}, imbalance={self.imbalance():.3f})",
            self.placement.describe(),
            f"  plans: {m.plan_hits} hits / {m.plan_misses} misses; "
            f"engine compiles: {self.engine.compile_count}",
            f"  layouts: {m.layout_full_builds} full builds, "
            f"{m.layout_refreshes} refreshes "
            f"({m.devices_regathered} regathered / {m.devices_reused} reused "
            f"device blocks, {m.rows_gathered} rows gathered)",
            f"  queries: {m.scans} plans executed, {m.programs_fused} "
            f"programs fused, {m.payload_gathers} payload gather passes "
            f"({m.pushdown_rows_gathered} pushdown rows)",
        ]
        return "\n".join(lines)
