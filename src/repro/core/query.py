"""Rapid NoSQL query — the paper's §2.3 table scheme, with byte accounting.

The proposed scheme puts small covariate indexes (age, sex, size, ...) in a
column family **separate** from the image payloads.  A subset query ("average
all female brains aged 20-40") then:

1. scans only the index family to build a rowkey mask — bytes touched are a
   few per row, not megabytes (``indexed_query``);
2. hands the mask to the MapReduce engine, where each map task gathers the
   selected payload rows *from its own shard* — the two families share rowkeys
   and placement, so locality survives the filter.

The naïve scheme (everything in one family) cannot evaluate the predicate
without dragging the payload bytes through the read path (HBase materializes
the row's store files around the cells it returns); ``naive_query`` returns
the *same mask* but charges the full row bytes — the 7× of Fig. 6 comes from
exactly this difference, and the simulator turns these byte counts into time.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core.table import (
    DATA_FAMILY,
    INDEX_FAMILY,
    TensorTable,
)

# A predicate maps {qualifier: column array} -> boolean row mask.
Predicate = Callable[[Mapping[str, np.ndarray]], np.ndarray]


@dataclasses.dataclass(frozen=True)
class QueryStats:
    """What the query *touched* — the quantity the table scheme optimizes."""

    rows_scanned: int             # rows whose cells were visited
    index_bytes_scanned: int      # small-column bytes read for the predicate
    payload_bytes_traversed: int  # payload bytes forced through the read path
    rows_selected: int
    # logical payload bytes the pushdown admitted into the fold: selected
    # rows × row bytes, the quantity the §2.3 scheme exists to minimize.
    # (Physical transfer is now block-granular and reported separately
    # below — a repeat plan can select many rows yet transfer nothing.)
    payload_bytes_moved: int = 0
    # region pruning efficacy: how many regions the scan range resolved to
    # vs how many the rowkey-range pushdown excluded outright (their device
    # blocks are never gathered).  scanned + pruned == total regions.
    regions_scanned: int = 0
    regions_pruned: int = 0
    # --- BlockStore oracles (copy-on-write block reuse observability) ----
    # The plan's layout is assembled from per-region device blocks; every
    # block it needed is exactly one of reused / transferred:
    blocks_total: int = 0         # blocks the plan's surviving regions span
    blocks_reused: int = 0        # already resident on the right device
    blocks_transferred: int = 0   # crossed host→device for this execution
    gather_count: int = 0         # blocks whose host payload was re-read
    payload_bytes_transferred: int = 0  # physical bytes of the transfers
    # --- fold-engine oracles (block-granular partial caching) ------------
    # The fold is block-at-a-time: each surviving block with selected rows
    # is one *partial*, either served from the partial cache or re-folded:
    partials_total: int = 0       # foldable (selected-row) blocks the plan spans
    partials_reused: int = 0      # partials served from the cache (zero rows read)
    rows_folded: int = 0          # payload rows the map phase actually read
    # which physical gather/fold path the planner chose for this execution:
    # "blocks" (block-granular fold), "compact" (one-shot compacted gather),
    # "retrieve" (host-side collect), "" for pre-fold stats objects.
    gather_path: str = ""
    # --- grouped-analytics oracles ---------------------------------------
    # distinct group-key values among the selected rows (0 = ungrouped);
    # grouping must never multiply gathers or folds — the per-block fold
    # segment-sums all G groups in its one pass.
    num_groups: int = 0
    # which physical reduce combined the partials: "tree" (psum over the
    # mesh's data axis, owner-local pre-merge) or "funnel" (single-device
    # jitted merge); "" when no merge ran (result-cache hit, compact path,
    # retrieve).
    merge_path: str = ""

    @property
    def total_bytes_scanned(self) -> int:
        return self.index_bytes_scanned + self.payload_bytes_traversed

    def check_block_invariant(self) -> None:
        """Every needed block is exactly one of reused / transferred, and a
        table re-read implies a transfer (the differential harness asserts
        this after every executed plan)."""
        assert self.blocks_reused + self.blocks_transferred == \
            self.blocks_total, self
        assert 0 <= self.gather_count <= self.blocks_transferred, self

    def check_partial_invariant(self) -> None:
        """Partial-cache consistency: a fully-reused plan folds zero rows,
        any fold implies a non-reused partial, and the compact path never
        touches blocks or partials (the differential harness asserts this
        after every executed plan)."""
        assert 0 <= self.partials_reused <= self.partials_total, self
        if self.partials_total and self.partials_reused == self.partials_total:
            assert self.rows_folded == 0, self
        if self.gather_path == "blocks" and self.rows_folded > 0:
            assert self.partials_reused < self.partials_total, self
        if self.gather_path == "compact":
            assert self.partials_total == 0 and self.blocks_total == 0, self


def _scan_range(
    table: TensorTable,
    start: Optional[bytes],
    stop: Optional[bytes],
) -> np.ndarray:
    lo, hi = table.row_range(start, stop)
    return np.arange(lo, hi, dtype=np.int64)


def _region_stats(
    table: TensorTable,
    start: Optional[bytes],
    stop: Optional[bytes],
) -> Tuple[int, int]:
    """``(regions_scanned, regions_pruned)`` for a scan range."""
    scanned = len(table.regions.prune(start, stop))
    return scanned, len(table.regions) - scanned


def indexed_query(
    table: TensorTable,
    predicate: Predicate,
    index_qualifiers: Sequence[str],
    index_family: str = INDEX_FAMILY,
    start: Optional[bytes] = None,
    stop: Optional[bytes] = None,
) -> Tuple[np.ndarray, QueryStats]:
    """Proposed scheme: evaluate ``predicate`` touching ONLY the index family.

    Returns a full-table boolean row mask plus byte accounting.
    """
    rows = _scan_range(table, start, stop)
    cols: Dict[str, np.ndarray] = {}
    idx_bytes = 0
    for q in index_qualifiers:
        col = table.column(index_family, q)
        cols[q] = col[rows]
        idx_bytes += len(rows) * table.column_spec(index_family, q).row_nbytes
    sel = np.asarray(predicate(cols), dtype=bool)
    if sel.shape != rows.shape:
        raise ValueError("predicate must return one bool per scanned row")
    mask = np.zeros(table.num_rows, dtype=bool)
    mask[rows[sel]] = True
    scanned, pruned = _region_stats(table, start, stop)
    return mask, QueryStats(
        rows_scanned=len(rows),
        index_bytes_scanned=idx_bytes,
        payload_bytes_traversed=0,
        rows_selected=int(sel.sum()),
        regions_scanned=scanned,
        regions_pruned=pruned,
    )


def naive_query(
    table: TensorTable,
    predicate: Predicate,
    index_qualifiers: Sequence[str],
    family: str = DATA_FAMILY,
    start: Optional[bytes] = None,
    stop: Optional[bytes] = None,
) -> Tuple[np.ndarray, QueryStats]:
    """Naïve scheme: indexes share the payload family, so every scanned row
    traverses its image bytes (the paper's Fig. 1C failure mode)."""
    rows = _scan_range(table, start, stop)
    cols: Dict[str, np.ndarray] = {}
    idx_bytes = 0
    for q in index_qualifiers:
        col = table.column(family, q)
        cols[q] = col[rows]
        idx_bytes += len(rows) * table.column_spec(family, q).row_nbytes
    sel = np.asarray(predicate(cols), dtype=bool)
    mask = np.zeros(table.num_rows, dtype=bool)
    mask[rows[sel]] = True
    # logical payload bytes of every row in the scan range — the traversal cost
    payload = int(table.row_bytes()[rows].sum())
    scanned, pruned = _region_stats(table, start, stop)
    return mask, QueryStats(
        rows_scanned=len(rows),
        index_bytes_scanned=idx_bytes,
        payload_bytes_traversed=payload,
        rows_selected=int(sel.sum()),
        regions_scanned=scanned,
        regions_pruned=pruned,
    )


def mask_to_device_layout(
    mask: np.ndarray, row_ids: np.ndarray, valid: np.ndarray
) -> np.ndarray:
    """Re-layout a full-table row mask to the ``[D, C]`` device layout so the
    MapReduce engine can apply it shard-locally."""
    return np.asarray(mask)[row_ids] & valid


def age_sex_predicate(
    age_lo: Optional[float] = None,
    age_hi: Optional[float] = None,
    sex: Optional[int] = None,
) -> Predicate:
    """The paper's Table-3 subset selector (age window × sex)."""

    def pred(cols: Mapping[str, np.ndarray]) -> np.ndarray:
        m = np.ones(len(cols["age"]), dtype=bool)
        if age_lo is not None:
            m &= cols["age"] >= age_lo
        if age_hi is not None:
            m &= cols["age"] < age_hi
        if sex is not None:
            m &= cols["sex"] == sex
        return m

    return pred
