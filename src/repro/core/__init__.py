"""ColoGrid core — the paper's contribution as a composable JAX library.

The subpackage mirrors HadoopBase-MIP's backend (Bao et al., 2017):

- :mod:`repro.core.table`       — HBase-analogue columnar ``TensorTable``.
- :mod:`repro.core.regions`     — region abstraction + split policies.
- :mod:`repro.core.balancer`    — data-allocation strategies (HBase-default
  balanced, the paper's greedy ``#CPU x MIPS`` balancer, SGE central store).
- :mod:`repro.core.placement`   — region->device placement realized as JAX
  sharded layouts + per-device task schedules.
- :mod:`repro.core.mapreduce`   — ``shard_map`` MapReduce engine over the mesh.
- :mod:`repro.core.chunk_model` — the paper's eq. (1)-(8) wall/resource-time
  model and the chunk-size (eta) optimizer.
- :mod:`repro.core.stats`       — summary-statistic MapReduce programs.
- :mod:`repro.core.query`       — index-family predicate pushdown vs naive scan.
- :mod:`repro.core.plan`        — :class:`GridQuery`, lazy scan→filter→map→
  reduce job plans with region pruning, projection pushdown, program fusion.
- :mod:`repro.core.simulator`   — discrete-event cluster simulator (Hadoop/SGE).
- :mod:`repro.core.scheduler`   — grid scheduler: rounds, stragglers, failures.
- :mod:`repro.core.blockstore`  — :class:`BlockStore`, content-addressed
  copy-on-write per-region device blocks shared across epochs and plans.
- :mod:`repro.core.grid`        — :class:`GridSession`, the five-verb facade
  (upload / retrieve / remove / rebalance / run) with mutation epochs,
  incremental placement, and a compiled-plan cache.
- :mod:`repro.core.frontend`    — :class:`GridFrontend`, concurrent query
  serving: single-flight coalescing, batched device ticks, epoch-isolated
  mutation, admission control.
"""

from repro.core.table import TensorTable, ColumnFamily, ColumnSpec
from repro.core.regions import (
    Region,
    RegionSet,
    ConstantSizeSplitPolicy,
    HierarchicalSplitPolicy,
)
from repro.core.balancer import (
    NodeSpec,
    assign_new_regions,
    balanced_allocation,
    greedy_allocation,
    central_allocation,
    rebalance,
    allocation_imbalance,
)
from repro.core.placement import Placement
from repro.core.chunk_model import (
    ChunkModelParams,
    ChunkModel,
    PAPER_PARAMS,
    TPU_V5E_PARAMS,
)
from repro.core.mapreduce import MapReduceEngine, MapReduceProgram
from repro.core.stats import (
    CountProgram,
    MeanProgram,
    VarianceProgram,
    MomentsProgram,
    HistogramProgram,
    FusedProgram,
    GroupedProgram,
    GroupedResult,
)
from repro.core.query import indexed_query, naive_query, QueryStats
from repro.core.plan import GridQuery, prefix_range
from repro.core.blockstore import BlockStore, DeviceBlock, LRUCache
from repro.core.grid import GridSession, RunReport, SessionMetrics
from repro.core.frontend import (
    FrontendOverloadedError,
    FrontendStats,
    GridFrontend,
    QueryTimeoutError,
)

__all__ = [
    "GridSession", "RunReport", "SessionMetrics",
    "GridFrontend", "FrontendStats",
    "FrontendOverloadedError", "QueryTimeoutError",
    "TensorTable", "ColumnFamily", "ColumnSpec",
    "Region", "RegionSet", "ConstantSizeSplitPolicy", "HierarchicalSplitPolicy",
    "NodeSpec", "assign_new_regions", "balanced_allocation",
    "greedy_allocation", "central_allocation",
    "rebalance", "allocation_imbalance",
    "Placement",
    "ChunkModelParams", "ChunkModel", "PAPER_PARAMS", "TPU_V5E_PARAMS",
    "MapReduceEngine", "MapReduceProgram",
    "CountProgram", "MeanProgram", "VarianceProgram", "MomentsProgram",
    "HistogramProgram", "FusedProgram", "GroupedProgram", "GroupedResult",
    "indexed_query", "naive_query", "QueryStats",
    "GridQuery", "prefix_range",
    "BlockStore", "DeviceBlock", "LRUCache",
]
