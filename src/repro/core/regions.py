"""Regions — contiguous rowkey ranges of a TensorTable, with split policies.

HBase splits a table into *regions*: half-open rowkey ranges ``[start, stop)``
that are the unit of placement and of map-task locality.  A region whose byte
size exceeds a policy threshold is split into two children.  The paper uses two
policies (Table 1, "Region split policy"):

- the *default* policy splits at the median rowkey of the region, and
- the *hierarchical* policy (ref. [2, 17] of the paper) uses the per-row size
  index column to pick the split point that balances **bytes**, which matters
  for medical images whose sizes vary 6-20 MB.

Regions here are pure values over ``(sorted rowkeys, per-row byte sizes)``
arrays owned by the table; they never hold data themselves.
"""

from __future__ import annotations

import bisect
import dataclasses
from typing import Iterable, Iterator, List, Optional, Sequence, Set, Tuple

import numpy as np

# Sentinels for the open ends of the keyspace, mirroring HBase's empty
# start/stop keys.  All real rowkeys compare strictly inside these.
KEY_MIN: bytes = b""           # inclusive lower bound of the keyspace
KEY_MAX: Optional[bytes] = None  # exclusive upper bound (None == +inf)


def _key_lt(a: bytes, b: Optional[bytes]) -> bool:
    """a < b with b possibly the +inf sentinel."""
    return b is None or a < b


@dataclasses.dataclass(frozen=True)
class Region:
    """A half-open rowkey range ``[start, stop)`` with a stable id."""

    rid: int
    start: bytes                  # inclusive; KEY_MIN for the first region
    stop: Optional[bytes]         # exclusive; None (KEY_MAX) for the last

    @property
    def signature(self) -> Tuple[int, bytes, Optional[bytes]]:
        """Stable identity for content-addressed consumers (the BlockStore's
        block keys).  rids are never reused, but carrying the key range makes
        a block's address self-describing and collision-proof by
        construction."""
        return (self.rid, self.start, self.stop)

    def contains(self, key: bytes) -> bool:
        return self.start <= key and _key_lt(key, self.stop)

    def row_slice(self, sorted_keys: np.ndarray) -> slice:
        """Resolve to a positional slice into the table's sorted row order."""
        lo = int(np.searchsorted(sorted_keys, self.start, side="left"))
        if self.stop is None:
            hi = len(sorted_keys)
        else:
            hi = int(np.searchsorted(sorted_keys, self.stop, side="left"))
        return slice(lo, hi)

    def num_rows(self, sorted_keys: np.ndarray) -> int:
        s = self.row_slice(sorted_keys)
        return s.stop - s.start

    def num_bytes(self, sorted_keys: np.ndarray, row_bytes: np.ndarray) -> int:
        s = self.row_slice(sorted_keys)
        return int(row_bytes[s.start:s.stop].sum())


class SplitPolicy:
    """Decides whether and where to split an over-threshold region."""

    def __init__(self, max_region_bytes: int):
        if max_region_bytes <= 0:
            raise ValueError("max_region_bytes must be positive")
        self.max_region_bytes = int(max_region_bytes)

    def should_split(self, region: Region, sorted_keys: np.ndarray,
                     row_bytes: np.ndarray) -> bool:
        return (region.num_rows(sorted_keys) >= 2
                and region.num_bytes(sorted_keys, row_bytes) > self.max_region_bytes)

    def split_key(self, region: Region, sorted_keys: np.ndarray,
                  row_bytes: np.ndarray) -> Optional[bytes]:
        raise NotImplementedError


class ConstantSizeSplitPolicy(SplitPolicy):
    """HBase default-like: split at the median *row* of the region."""

    def split_key(self, region, sorted_keys, row_bytes):
        s = region.row_slice(sorted_keys)
        n = s.stop - s.start
        if n < 2:
            return None
        mid = s.start + n // 2
        key = bytes(sorted_keys[mid])
        # The split key must strictly separate the two halves.
        if key == region.start:
            return None
        return key

    def __repr__(self):
        return f"ConstantSizeSplitPolicy(max_region_bytes={self.max_region_bytes})"


class HierarchicalSplitPolicy(SplitPolicy):
    """The paper's scheme: use the size index column to balance *bytes*.

    Picks the rowkey at which the cumulative byte count crosses half the
    region's total, so children carry near-equal data volume even when row
    sizes are skewed (6-20 MB NiFTI images).
    """

    def split_key(self, region, sorted_keys, row_bytes):
        s = region.row_slice(sorted_keys)
        n = s.stop - s.start
        if n < 2:
            return None
        sizes = row_bytes[s.start:s.stop].astype(np.int64)
        half = sizes.sum() / 2.0
        cum = np.cumsum(sizes)
        # first row index whose prefix sum reaches half; clamp inside (0, n)
        pos = int(np.searchsorted(cum, half, side="left")) + 1
        pos = max(1, min(pos, n - 1))
        key = bytes(sorted_keys[s.start + pos])
        if key == region.start:
            return None
        return key

    def __repr__(self):
        return f"HierarchicalSplitPolicy(max_region_bytes={self.max_region_bytes})"


class RegionSet:
    """A sorted, contiguous partition of the keyspace into regions.

    Invariants (checked by :meth:`check_invariants` and the property tests):
      * regions are sorted by ``start`` and tile the keyspace exactly:
        first.start == KEY_MIN, last.stop is KEY_MAX, and every adjacent pair
        satisfies ``regions[i].stop == regions[i+1].start``;
      * region ids are unique and never reused.
    """

    def __init__(self, policy: SplitPolicy):
        self.policy = policy
        self._regions: List[Region] = [Region(0, KEY_MIN, KEY_MAX)]
        self._next_rid = 1
        # sorted region start keys, maintained in lockstep with _regions so
        # every containment/overlap question is a bisect, never a rebuild
        self._starts: List[bytes] = [KEY_MIN]

    # -- accessors ---------------------------------------------------------

    @property
    def regions(self) -> Tuple[Region, ...]:
        return tuple(self._regions)

    def __len__(self) -> int:
        return len(self._regions)

    def __iter__(self) -> Iterator[Region]:
        return iter(self._regions)

    def regions_containing(self, keys: Iterable[bytes]) -> Set[int]:
        """Region ids whose key ranges contain any of ``keys``.

        The dirty-region primitive: a mutation touching ``keys`` invalidates
        exactly these regions' placements, nothing else.  O(m log n) — one
        bisect over the maintained start-key list per key, no linear walk.
        """
        starts = self._starts
        return {
            self._regions[bisect.bisect_right(starts, k) - 1].rid
            for k in keys
        }

    def region_for(self, key: bytes) -> Region:
        return self._regions[bisect.bisect_right(self._starts, key) - 1]

    def prune(self, start: Optional[bytes] = None,
              stop: Optional[bytes] = None) -> Tuple[Region, ...]:
        """Regions overlapping the half-open scan range ``[start, stop)``.

        The scan-pruning primitive (§2.3's rowkey scheme): a rowkey
        prefix/range predicate resolves to the regions it can possibly touch,
        so non-matching regions are never scanned and their device blocks
        never gathered.  ``None`` bounds mean the open keyspace ends.  Two
        bisects over the start-key list — O(log n) plus the output size.
        """
        if stop is not None and start is not None and start >= stop:
            return ()
        lo = 0
        if start is not None and start > KEY_MIN:
            lo = bisect.bisect_right(self._starts, start) - 1
        hi = len(self._regions)
        if stop is not None:
            # regions with r.start >= stop cannot overlap [start, stop)
            hi = bisect.bisect_left(self._starts, stop)
        return tuple(self._regions[lo:hi])

    # -- mutation ----------------------------------------------------------

    def pre_split(self, split_keys: Sequence[bytes]) -> None:
        """Pre-split the (single, empty) keyspace at the given keys.

        Mirrors the Upload interface's ``pre-split`` option: only valid on a
        fresh table.
        """
        if len(self._regions) != 1:
            raise ValueError("pre_split is only valid on an unsplit table")
        keys = sorted(set(split_keys))
        regions: List[Region] = []
        prev: bytes = KEY_MIN
        for k in keys:
            if k == prev:
                continue
            regions.append(Region(self._next_rid, prev, k))
            self._next_rid += 1
            prev = k
        regions.append(Region(self._next_rid, prev, KEY_MAX))
        self._next_rid += 1
        self._regions = regions
        self._starts = [r.start for r in regions]

    def maybe_split(self, sorted_keys: np.ndarray, row_bytes: np.ndarray
                    ) -> List[Tuple[Region, Region, Region]]:
        """Split every over-threshold region (repeatedly, as HBase would).

        Returns the list of ``(parent, left_child, right_child)`` splits that
        happened, so Placement can remap parents to children in place.
        """
        events: List[Tuple[Region, Region, Region]] = []
        i = 0
        while i < len(self._regions):
            region = self._regions[i]
            if self.policy.should_split(region, sorted_keys, row_bytes):
                key = self.policy.split_key(region, sorted_keys, row_bytes)
                if key is not None and region.contains(key) and key != region.start:
                    left = Region(self._next_rid, region.start, key)
                    right = Region(self._next_rid + 1, key, region.stop)
                    self._next_rid += 2
                    self._regions[i:i + 1] = [left, right]
                    self._starts[i:i + 1] = [left.start, right.start]
                    events.append((region, left, right))
                    continue  # re-examine children at the same index
            i += 1
        return events

    # -- validation --------------------------------------------------------

    def check_invariants(self) -> None:
        rs = self._regions
        assert rs, "RegionSet must never be empty"
        assert rs[0].start == KEY_MIN, "first region must start the keyspace"
        assert rs[-1].stop is None, "last region must end the keyspace"
        for a, b in zip(rs, rs[1:]):
            assert a.stop == b.start, f"gap/overlap between {a} and {b}"
            assert a.stop is not None
        rids = [r.rid for r in rs]
        assert len(set(rids)) == len(rids), "region ids must be unique"
        assert self._starts == [r.start for r in rs], \
            "start-key index out of sync with regions"
