"""Data-allocation strategies — the paper's heterogeneity-aware load balancer.

HBase's built-in balancer equalizes the *number of regions* per server, which
on a heterogeneous cluster starves fast machines and overloads slow ones
(Fig. 1A).  The paper's contribution (Table 1, "Load Balancer") is an offline
greedy re-allocation so that each node's **data share matches its compute
share**:

    share(node)  ∝  #CPU(node) × MIPS(node)

with MIPS measured by ``linux perf``.  On TPU the analogue of MIPS is the
per-device effective FLOP/s (mixed-generation slices, DCN-attached pods, or
observed step throughput under straggling); the arithmetic is identical.

Three allocators (all pure functions over ``{region_id: bytes}``):

- :func:`balanced_allocation` — HBase default (equal region count) — the
  paper's *baseline*;
- :func:`greedy_allocation`   — the paper's #CPU×MIPS-proportional greedy
  allocation (LPT-style) from scratch;
- :func:`central_allocation`  — the SGE comparison: all data on one storage
  node, every task pulls over the network.

plus :func:`rebalance`, the faithful *offline* form ("first find all regions
... second, moving images based on region") that starts from the current
placement and moves the fewest regions needed to restore proportionality —
this is also ColoGrid's elastic-rescale and straggler-mitigation primitive.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

Allocation = Dict[int, int]  # region id -> node id


@dataclasses.dataclass(frozen=True)
class NodeSpec:
    """One machine (or TPU device / device group) of the grid."""

    node_id: int
    cores: int = 1
    mips: float = 1.0           # per-core throughput (MIPS / effective FLOP/s)
    mem_bytes: int = 4 << 30    # paper: 4 GB per job slot
    disk_read_bps: float = 100e6   # paper §2.4: 100 MB/s
    disk_write_bps: float = 65e6   # paper §2.4: 65 MB/s

    @property
    def power(self) -> float:
        """The paper's allocation weight: #CPU × MIPS."""
        return self.cores * self.mips


def _targets(total_bytes: float, nodes: Sequence[NodeSpec]) -> Dict[int, float]:
    """Per-node target byte shares ∝ #CPU×MIPS."""
    total_power = sum(n.power for n in nodes)
    if total_power <= 0:
        raise ValueError("total node power must be positive")
    return {n.node_id: total_bytes * n.power / total_power for n in nodes}


def balanced_allocation(
    region_bytes: Mapping[int, int], nodes: Sequence[NodeSpec]
) -> Allocation:
    """HBase default balancer: equalize region COUNT per node (baseline).

    Region sizes and node speeds are ignored — exactly the behaviour the
    paper shows degrading heterogeneous-cluster wall time (Fig. 3).
    """
    alloc: Allocation = {}
    node_ids = [n.node_id for n in nodes]
    for i, rid in enumerate(sorted(region_bytes)):
        alloc[rid] = node_ids[i % len(node_ids)]
    return alloc


def greedy_allocation(
    region_bytes: Mapping[int, int], nodes: Sequence[NodeSpec]
) -> Allocation:
    """The paper's allocator: greedy placement to #CPU×MIPS-proportional shares.

    Largest-region-first into the node with the largest remaining *deficit*
    relative to its target share (classic LPT shape; optimal within one region
    size of the proportional target).
    """
    total = float(sum(region_bytes.values()))
    targets = _targets(total, nodes)
    assigned = {n.node_id: 0.0 for n in nodes}
    # heap keyed by -(deficit) so the neediest node pops first
    heap: List[Tuple[float, int]] = [(-targets[n.node_id], n.node_id) for n in nodes]
    heapq.heapify(heap)
    alloc: Allocation = {}
    for rid in sorted(region_bytes, key=lambda r: (-region_bytes[r], r)):
        neg_deficit, nid = heapq.heappop(heap)
        alloc[rid] = nid
        assigned[nid] += region_bytes[rid]
        heapq.heappush(heap, (assigned[nid] - targets[nid], nid))
    return alloc


def central_allocation(
    region_bytes: Mapping[int, int], nodes: Sequence[NodeSpec],
    storage_node: Optional[int] = None,
) -> Allocation:
    """SGE-style central storage: every region on one node; all reads remote."""
    nid = nodes[0].node_id if storage_node is None else storage_node
    return {rid: nid for rid in region_bytes}


def node_loads(
    alloc: Allocation, region_bytes: Mapping[int, int], nodes: Sequence[NodeSpec]
) -> Dict[int, float]:
    loads = {n.node_id: 0.0 for n in nodes}
    for rid, nid in alloc.items():
        loads[nid] += region_bytes[rid]
    return loads


def allocation_imbalance(
    alloc: Allocation, region_bytes: Mapping[int, int], nodes: Sequence[NodeSpec]
) -> float:
    """Max relative deviation of a node's *work* from proportional.

    Work on a node ≙ bytes/power (time-to-process proxy).  0.0 is perfectly
    proportional; the paper's Fig. 3 "before" corresponds to the default
    balancer's large value on a heterogeneous cluster.
    """
    total = float(sum(region_bytes.values()))
    if total == 0:
        return 0.0
    total_power = sum(n.power for n in nodes)
    loads = node_loads(alloc, region_bytes, nodes)
    # ideal makespan: every node finishes together
    ideal = total / total_power
    worst = max(loads[n.node_id] / n.power for n in nodes)
    return worst / ideal - 1.0


def rebalance(
    current: Allocation,
    region_bytes: Mapping[int, int],
    nodes: Sequence[NodeSpec],
    tolerance: float = 0.05,
) -> Tuple[Allocation, List[int]]:
    """The paper's offline balancer: move regions until shares ≈ #CPU×MIPS.

    Starts from ``current`` and greedily moves the largest useful region from
    the most-overloaded node (by surplus bytes over its target) to the
    neediest node, stopping when every node is within ``tolerance`` of its
    target or no move improves.  Returns ``(new_allocation, moved_region_ids)``
    — the move list is what an operator (or the elastic-rescale path) actually
    executes, so minimizing it matters.

    Dead/removed nodes: regions currently mapped to a node not in ``nodes``
    are treated as homeless and re-assigned first (failure handling).
    """
    live = {n.node_id for n in nodes}
    total = float(sum(region_bytes.values()))
    targets = _targets(total, nodes)
    alloc = dict(current)
    if total == 0:
        return alloc, []

    # Phase 1 (keep): each live node keeps its current regions,
    # largest-first, while staying within target·(1+tolerance); the rest are
    # evicted.  Orphans on dead nodes are evicted by construction.
    per_node: Dict[int, List[int]] = {nid: [] for nid in live}
    evicted: List[int] = []
    for rid in sorted(region_bytes, key=lambda r: (-region_bytes[r], r)):
        nid = alloc.get(rid)
        if nid in live:
            per_node[nid].append(rid)
        else:
            evicted.append(rid)
    loads = {nid: 0.0 for nid in live}
    for nid, rids in per_node.items():
        cap = targets[nid] * (1.0 + tolerance)
        for rid in rids:  # already largest-first
            b = region_bytes[rid]
            if loads[nid] + b <= cap:
                loads[nid] += b
            else:
                evicted.append(rid)

    # Phase 2 (place): greedy deficit-heap assignment of evicted regions,
    # largest-first — the same LPT shape as greedy_allocation.
    heap: List[Tuple[float, int]] = [
        (loads[nid] - targets[nid], nid) for nid in live
    ]
    heapq.heapify(heap)
    moved: List[int] = []
    for rid in sorted(evicted, key=lambda r: (-region_bytes[r], r)):
        _, nid = heapq.heappop(heap)
        if alloc.get(rid) != nid:
            moved.append(rid)
        alloc[rid] = nid
        loads[nid] += region_bytes[rid]
        heapq.heappush(heap, (loads[nid] - targets[nid], nid))
    return alloc, moved


def assign_new_regions(
    current: Allocation,
    region_bytes: Mapping[int, int],
    nodes: Sequence[NodeSpec],
) -> Allocation:
    """Adopt regions absent from ``current`` without moving existing ones.

    The incremental complement of :func:`rebalance`: each unassigned region
    (largest-first) goes to the node with the largest remaining deficit vs
    its #CPU×MIPS-proportional target, and every existing assignment stays
    put — this is what keeps an incremental upload cheap between full
    balancer runs.  Returns ONLY the new assignments.
    """
    new = [rid for rid in region_bytes if rid not in current]
    if not new:
        return {}
    targets = _targets(float(sum(region_bytes.values())), nodes)
    loads = {n.node_id: 0.0 for n in nodes}
    for rid, nid in current.items():
        if nid in loads and rid in region_bytes:
            loads[nid] += region_bytes[rid]
    heap: List[Tuple[float, int]] = [
        (loads[n.node_id] - targets[n.node_id], n.node_id) for n in nodes
    ]
    heapq.heapify(heap)
    out: Allocation = {}
    for rid in sorted(new, key=lambda r: (-region_bytes[r], r)):
        deficit, nid = heapq.heappop(heap)
        out[rid] = nid
        heapq.heappush(heap, (deficit + region_bytes[rid], nid))
    return out


def powers_from_observations(
    round_times: Mapping[int, Sequence[float]],
    nodes: Sequence[NodeSpec],
    ewma: float = 0.5,
) -> List[NodeSpec]:
    """Straggler mitigation: refresh node powers from observed round times.

    A node that keeps finishing its (equal-work) rounds slower than the mean
    gets its effective MIPS deweighted, so the next :func:`rebalance` shifts
    regions away from it — the runtime analogue of re-running ``linux perf``.
    """
    out: List[NodeSpec] = []
    for n in nodes:
        times = list(round_times.get(n.node_id, []))
        if not times:
            out.append(n)
            continue
        # observed throughput ∝ 1/time; EWMA over the sequence
        thr = 1.0 / max(times[0], 1e-9)
        for t in times[1:]:
            thr = (1 - ewma) * thr + ewma / max(t, 1e-9)
        out.append(dataclasses.replace(n, mips=thr / max(n.cores, 1)))
    return out
