"""MapReduce engine over the device mesh — the paper's §2.2 on TPU.

The paper's Map phase sends each chunk of η images to the node holding them;
the Reduce phase combines the per-chunk intermediates on one node.  On a TPU
mesh this becomes:

- **Map**: a ``shard_map`` body on the ``data`` axis.  Each device scans its
  *local* table shard (placed by :mod:`repro.core.placement`, so no input
  bytes cross the interconnect) in chunks of η rows, folding each chunk into a
  running partial with the program's ``map_chunk``/``merge``.  Devices with
  fewer real rows run the same number of lockstep rounds with masked-out
  chunks — the SPMD analogue of idle cores waiting on the longest map task
  (eq. 2's worst-case term).
- **Shuffle/Reduce**: only the tiny partials move.  Additive programs reduce
  with a single ``psum`` (an all-reduce the ICI does in hardware); general
  associative merges use an ``all_gather`` of partials followed by a fold.
  Either way the network carries ``O(#job · |partial|)`` bytes — the colocation
  win over SGE, which must move ``O(#img · SizeBig)``.

Programs are associative-merge folds (monoids), which is exactly the structure
the paper's ANTS AverageImages use case has, and what makes chunk size η a
free *performance* parameter with no effect on the result (a property test
asserts chunk-size invariance up to float associativity).

Two execution granularities share the program interface:

- :meth:`MapReduceEngine.run` — the layout-at-a-time path: one ``shard_map``
  fold over an assembled ``[D, C, ...]`` array (used by standalone layouts
  and the compact one-shot gather path);
- :meth:`MapReduceEngine.fold_block` + :meth:`MapReduceEngine.merge_finalize`
  — the block-at-a-time path :class:`~repro.core.grid.GridSession` drives:
  each region's device block folds independently on its owner device (the
  jitted fold runs where the committed block lives — the map phase), then
  the tiny partials reduce.  Additive programs on a 1-D data mesh
  **tree-reduce**: each owner pre-merges its own partials locally and one
  ``psum`` over the data axis joins them (the ICI's hardware all-reduce);
  everything else funnels to one device for a single jitted merge+finalize.
  Because partials are per-block, they are cacheable per block lineage in
  the :class:`~repro.core.blockstore.BlockStore` — a repeat query merges
  cached partials and folds zero payload rows.  Fold executables are keyed
  by block rows padded to the next power of two and funnel merges by the
  pow2-bucketed partial count, so drifting region sizes and block counts
  share a handful of compiles.  Grouped folds (``gids``/``num_groups``,
  see :class:`~repro.core.stats.GroupedProgram`) produce group-keyed
  partials in the same single pass.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable, List, Mapping, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.blockstore import LRUCache
from repro.utils import shard_map_compat

PyTree = Any


def partial_to_host(partial: PyTree) -> Tuple[List[np.ndarray], Any]:
    """Flatten a fold partial into host numpy leaves + its treedef — the
    serialization half of the BlockStore's partial spill tier.  Device
    leaves are pulled to host; the treedef round-trips the pytree shape
    through :func:`partial_from_host` without pickling the structure."""
    leaves, treedef = jax.tree_util.tree_flatten(partial)
    return [np.asarray(leaf) for leaf in leaves], treedef


def partial_from_host(leaves: Sequence[np.ndarray], treedef: Any) -> PyTree:
    """Rebuild a spilled fold partial from its host leaves.  Leaves stay
    numpy — the merge paths accept host arrays and JAX converts on first
    use, so promotion costs no eager ``device_put``."""
    return jax.tree_util.tree_unflatten(treedef, list(leaves))


class MapReduceProgram:
    """An associative summary-statistic program (a commutative monoid).

    Subclasses define:
      * ``zero(row_shape, dtype)``  — identity partial;
      * ``map_chunk(rows, valid)``  — fold a ``[eta, ...]`` chunk (with a
        ``[eta]`` validity mask) into a partial;
      * ``merge(a, b)``             — associative combine of partials;
      * ``finalize(partial)``       — partial -> user-facing result.

    ``additive`` marks programs whose partials combine by a per-leaf
    elementwise operator — sum by default, or the operator named by
    :meth:`merge_ops_for` — enabling the single-collective reduce path
    (``psum``/``pmax``).

    Programs whose statistic is a projection of the raw power sums may also
    declare :meth:`requires` / :meth:`finalize_shared`; a CSE'd
    :class:`~repro.core.stats.FusedProgram` then computes each shared
    accumulator once per chunk and projects per-member results, instead of
    re-folding the chunk once per member.
    """

    additive: bool = False

    def cache_key(self) -> Tuple[str, str]:
        """Stable identity for executable/plan caches.

        Default: type name + repr — correct for the frozen-dataclass
        programs in :mod:`repro.core.stats` (repr encodes every parameter).
        Programs with unhashable/unstable reprs should override.
        """
        return (type(self).__name__, repr(self))

    def zero(self, row_shape: Tuple[int, ...], dtype) -> PyTree:
        raise NotImplementedError

    def map_chunk(self, rows: jax.Array, valid: jax.Array) -> PyTree:
        raise NotImplementedError

    def merge(self, a: PyTree, b: PyTree) -> PyTree:
        raise NotImplementedError

    def merge_ops_for(self, partial: PyTree) -> Optional[List[str]]:
        """Per-leaf merge operators for an ``additive`` program, aligned
        with ``jax.tree.leaves(partial)``: each entry is ``"sum"`` or
        ``"max"``.  ``None`` (the default) means every leaf merges by
        elementwise sum — the classic additive monoid.

        This is how a max-merge sketch (HyperLogLog registers) rides the
        engine's additive fast paths: the tree reduce issues ``pmax``
        instead of ``psum`` for ``"max"`` leaves, and the stacked funnel /
        owner pre-merge reduce with ``max(axis=0)`` instead of
        ``sum(axis=0)``.  Contract: ``zero()`` must be the identity of
        each leaf's operator (0 works for both sum and max over
        non-negative registers), and ``merge`` must agree leafwise with
        the declared operators.  Only consulted when ``additive``; the
        argument may be a tracer — implementations may inspect only its
        tree structure, never its values."""
        return None

    def finalize(self, partial: PyTree) -> PyTree:
        raise NotImplementedError

    # --- common-subexpression sharing protocol (optional) -------------

    def requires(self) -> Tuple[str, ...]:
        """Raw shared accumulators this program's result projects from
        (a subset of ``repro.core.stats.SHARED_ACCUMULATORS``: ``count``,
        ``s1`` .. ``s4``).  Empty (the default) means the program folds its
        own private accumulator even inside a CSE'd fusion."""
        return ()

    def finalize_shared(self, shared: Mapping[str, jax.Array]) -> PyTree:
        """Project the user-facing result from the shared accumulators
        named by :meth:`requires`.  Must agree with
        ``finalize(own fold)`` up to float associativity."""
        raise NotImplementedError

    # --- fused-kernel fold protocol (optional) ------------------------

    def shared_fold_spec(self) -> Optional[Tuple[str, ...]]:
        """The shared-accumulator names whose fp32 pool fully determines
        this program's partial, or ``None`` if the partial needs anything
        outside the pool (private accumulators, non-fp32 pools).  Non-None
        makes the program eligible for the engine's fused Pallas fold
        (``fold_impl="pallas"``): the kernel emits the pool in one HBM pass
        and :meth:`partial_from_shared` shapes it into the program's
        native partial — bitwise-compatible with the XLA fold up to fp32
        accumulation order."""
        return None

    def partial_from_shared(self, shared: Mapping[str, jax.Array]) -> PyTree:
        """Build this program's partial from the kernel-folded shared
        pool (``{name: acc}``; grouped folds carry a leading group axis on
        every leaf).  Must merge/finalize identically to a partial the
        program folded itself, up to float associativity."""
        raise NotImplementedError


def _checked_merge_ops(program: MapReduceProgram,
                       partial: PyTree) -> Optional[List[str]]:
    """The program's per-leaf merge operators, validated against the
    partial's actual leaf count — ``None`` for the all-sum common case."""
    ops = program.merge_ops_for(partial)
    if ops is None:
        return None
    n_leaves = len(jax.tree_util.tree_leaves(partial))
    if len(ops) != n_leaves:
        raise ValueError(
            f"{type(program).__name__}.merge_ops_for returned {len(ops)} "
            f"operators for a partial with {n_leaves} leaves")
    bad = sorted(set(ops) - {"sum", "max"})
    if bad:
        raise ValueError(f"unknown merge operators {bad}; "
                         "expected 'sum' or 'max'")
    return ops


def _combine_leafwise(partial_like: PyTree, ops: Optional[List[str]],
                      sum_fn: Callable[[Any], Any],
                      max_fn: Callable[[Any], Any]) -> PyTree:
    """Apply ``sum_fn`` / ``max_fn`` leaf-by-leaf per the operator list
    (``None`` = all sum) and rebuild the tree."""
    if ops is None:
        return jax.tree.map(sum_fn, partial_like)
    leaves, treedef = jax.tree_util.tree_flatten(partial_like)
    out = [max_fn(leaf) if op == "max" else sum_fn(leaf)
           for leaf, op in zip(leaves, ops)]
    return jax.tree_util.tree_unflatten(treedef, out)


@dataclasses.dataclass
class MapReduceStats:
    """Byte accounting for one run (feeds EXPERIMENTS.md and the simulator
    cross-check)."""

    local_rows_read: int          # rows folded on their home device
    local_bytes_read: int         # physical payload bytes read from HBM
    shuffle_bytes: int            # partial bytes crossing the interconnect
    rounds: int                   # lockstep map rounds (wall-clock proxy)
    chunks: int                   # Σ real chunks (#job; resource proxy)
    chunk_size: int


class MapReduceEngine:
    """Executes MapReduce programs over ``[D, C, ...]`` colocated layouts."""

    def __init__(self, mesh: Mesh, data_axis: str = "data",
                 executable_cache_cap: int = 64,
                 block_pad: str = "pow2",
                 merge_strategy: str = "auto",
                 fold_impl: str = "pallas",
                 fold_interpret: bool = False,
                 fault_injector=None):
        self.mesh = mesh
        self.data_axis = data_axis
        #: optional chaos harness (repro.core.faults.FaultInjector): every
        #: block-fold dispatch fires its "fold" site with the owner device,
        #: so injected fold faults (transient, permanent owner loss,
        #: straggler delay) surface here and the session's retry/quarantine
        #: wrapper around fold_block owns the response
        self.fault_injector = fault_injector
        # LRU-capped: one entry per (program, row signature, eta, C); an
        # evicted executable rebuilds on next use (compile_count bumps again)
        self._compiled = LRUCache(executable_cache_cap)
        # partial byte sizes per (program, row signature): plain dict — tiny
        # ints, not executables, so no cap and no compile_count coupling
        self._partial_bytes: dict = {}
        # builds of new executables (the recompile oracle GridSession's plan
        # cache is tested against): bumped only on an executable-cache miss.
        self.compile_count = 0
        #: per-block fold executables are shape-keyed; "pow2" pads block rows
        #: up to the next power of two before the jitted fold, so the key
        #: space stays O(log max_rows) however many distinct region sizes a
        #: (grouped) workload produces.  "none" keys on exact row counts.
        if block_pad not in ("pow2", "none"):
            raise ValueError(f"unknown block_pad policy {block_pad!r}")
        self.block_pad = block_pad
        #: "auto" tree-reduces additive merges across owner devices when the
        #: mesh allows it; "funnel" forces the single-device reduce (the
        #: comparison baseline the merge bench uses).
        if merge_strategy not in ("auto", "funnel"):
            raise ValueError(f"unknown merge_strategy {merge_strategy!r}")
        self.merge_strategy = merge_strategy
        #: "pallas" streams each CSE-eligible block fold through the fused
        #: Pallas kernel (one HBM pass emits the whole grouped accumulator
        #: pool); "xla" forces the reference scan-of-chunks fold.  The
        #: pallas setting falls back per fold signature — see
        #: :meth:`fold_path` — so it is always safe to leave on.
        if fold_impl not in ("pallas", "xla"):
            raise ValueError(f"unknown fold_impl {fold_impl!r}")
        self.fold_impl = fold_impl
        #: run the Pallas kernel in interpret mode off-TPU (tests/benches on
        #: the CPU container).  Off by default: without it, non-TPU
        #: platforms take the XLA fold — interpret mode is a correctness
        #: harness, not a fast path.
        self.fold_interpret = bool(fold_interpret)
        #: folds dispatched per implementation (observability + tests);
        #: bumped under ``_count_lock`` — concurrent frontend queries fold
        #: from many threads and the barrier tests assert EXACT counts
        self.fold_path_counts: dict = {"pallas": 0, "xla": 0}
        self.merge_path_counts: dict = {"tree": 0, "funnel": 0}
        # executable builds are serialized (two threads missing the same
        # key must not compile twice and double-bump compile_count); the
        # dispatch of an already-built executable stays lock-free
        self._build_lock = threading.RLock()
        self._count_lock = threading.Lock()
        # the last merge path is per-thread: concurrent queries must each
        # read the path of THEIR merge, not whichever finished last
        self._tls = threading.local()
        # the mesh's data-axis devices, in shard order — available only when
        # the mesh is exactly the 1-D data axis (same condition the session
        # uses for per-shard block placement); None disables the tree reduce
        devs = np.asarray(mesh.devices).flat
        self._axis_devices = (list(devs)
                              if mesh.axis_names == (data_axis,) else None)

    @property
    def last_merge_path(self) -> str:
        """Which physical reduce the CALLING THREAD's last
        :meth:`merge_finalize` took ("tree" / "funnel"; "" before any
        merge on this thread).  Thread-local so concurrent queries each
        observe their own merge, not whichever finished last."""
        return getattr(self._tls, "last_merge_path", "")

    @last_merge_path.setter
    def last_merge_path(self, value: str) -> None:
        self._tls.last_merge_path = value

    # ------------------------------------------------------------------

    def _build(self, program: MapReduceProgram, row_shape, dtype, eta: int):
        """Build the jitted shard_map fold for a given row signature."""
        data_axis = self.data_axis
        mesh = self.mesh
        rep_axes = tuple(a for a in mesh.axis_names if a != data_axis)

        def local_fold(values: jax.Array, valid: jax.Array) -> PyTree:
            # values: [1, C, ...] local shard; valid: [1, C]
            v = values[0]
            m = valid[0]
            C = v.shape[0]
            n_chunks = C // eta
            v = v.reshape((n_chunks, eta) + v.shape[1:])
            m = m.reshape((n_chunks, eta))

            def body(carry, xs):
                chunk, mask = xs
                return program.merge(carry, program.map_chunk(chunk, mask)), None

            init = program.zero(row_shape, dtype)
            partial, _ = jax.lax.scan(body, init, (v, m))
            return partial

        if program.additive:
            def mapper(values, valid):
                partial = local_fold(values, valid)
                # per-leaf collective: psum for sum leaves, pmax for max
                # leaves (HLL registers) — one hardware all-reduce either way
                ops = _checked_merge_ops(program, partial)
                return _combine_leafwise(
                    partial, ops,
                    lambda x: jax.lax.psum(x, axis_name=data_axis),
                    lambda x: jax.lax.pmax(x, axis_name=data_axis))
        else:
            def mapper(values, valid):
                partial = local_fold(values, valid)
                gathered = jax.tree.map(
                    lambda x: jax.lax.all_gather(x, axis_name=data_axis), partial
                )
                D = mesh.shape[data_axis]

                def fold(i, acc):
                    piece = jax.tree.map(lambda g: g[i], gathered)
                    return program.merge(acc, piece)

                first = jax.tree.map(lambda g: g[0], gathered)
                return jax.lax.fori_loop(1, D, fold, first)

        in_specs = (P(data_axis), P(data_axis))
        out_specs = jax.tree.map(lambda _: P(), program.zero(row_shape, dtype))

        fn = shard_map_compat(
            mapper, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check=False,
        )

        def run(values, valid):
            partial = fn(values, valid)
            return program.finalize(partial)

        return jax.jit(run)

    # ------------------------------------------------------------------
    # block-at-a-time path: per-block folds + one merge/finalize reduce
    # ------------------------------------------------------------------

    @property
    def _merge_device(self):
        """Where partials meet for the reduce phase (the paper's "combine on
        one node"): the mesh's first device.  Only ``O(#blocks · |partial|)``
        bytes ever travel here."""
        return list(np.asarray(self.mesh.devices).flat)[0]

    def _get_or_build(self, key, build: Callable[[], Any]):
        fn = self._compiled.get(key)
        if fn is None:
            with self._build_lock:
                # double-check under the lock: a racing thread may have
                # built it while we waited — compile once, count once
                fn = self._compiled.get(key)
                if fn is None:
                    self.compile_count += 1
                    fn = build()
                    self._compiled.put(key, fn)
        return fn

    @staticmethod
    def _next_pow2(n: int) -> int:
        return 1 << max(0, int(n) - 1).bit_length()

    def bucket_rows(self, rows: int) -> int:
        """The padded row count a block folds at: the next power of two
        under the "pow2" policy (bounding the executable key space to
        O(log max_rows) however many distinct region sizes exist), the
        exact count under "none".  ``GridSession`` commits device blocks
        pre-padded to this bucket, so the per-fold hot path never pays a
        pad copy — only freshly-shaped raw arrays do."""
        if self.block_pad == "pow2":
            return self._next_pow2(rows)
        return rows

    def fold_path(self, program: MapReduceProgram, dtype,
                  num_groups: int = 0) -> str:
        """Which implementation :meth:`fold_block` takes for this fold
        signature: ``"pallas"`` (the fused one-HBM-pass kernel) or
        ``"xla"`` (the reference scan of chunks).  Deterministic per
        (engine config, program, dtype, G), so the session can key cached
        partials on it.  Falls back to XLA when:

        - the program needs accumulators outside the fp32 CSE pool
          (``shared_fold_spec() is None`` — private members, int32 count,
          histograms, non-fp32 pools);
        - the platform lacks Pallas support and interpret mode was not
          requested (``fold_interpret`` covers CPU tests);
        - the payload dtype is not real-valued;
        - G exceeds the VMEM-budget threshold from the chunk model
          (``fused_fold.ops.max_groups_for_vmem``).
        """
        if self.fold_impl != "pallas":
            return "xla"
        if not (self.fold_interpret or jax.default_backend() == "tpu"):
            return "xla"
        names = program.shared_fold_spec()
        if not names:
            return "xla"
        dt = jnp.dtype(dtype)
        if not (jnp.issubdtype(dt, jnp.floating)
                or jnp.issubdtype(dt, jnp.integer)
                or dt == jnp.dtype(bool)):
            return "xla"
        from repro.kernels.fused_fold.ops import max_groups_for_vmem
        if max(1, int(num_groups)) > max_groups_for_vmem(names=names):
            return "xla"
        return "pallas"

    def _pallas_fold_fn(self, program: MapReduceProgram, rows: int,
                        row_shape, dtype, masked: bool, groups: int = 0):
        """The jitted fused-kernel fold for one block signature.  One
        streaming pass emits the whole shared pool; ``eta`` does not enter
        the executable key — the kernel is chunk-free, so every chunk size
        shares one compile per (bucketed rows, G).  Tile sizes divide the
        pow2 row bucket (both are powers of two), so executables stay
        keyed on ``bucket_rows`` exactly like the XLA path."""
        from repro.kernels.fused_fold.ops import fused_fold

        names = program.shared_fold_spec()
        grouped = groups > 0
        G = max(1, groups)
        interpret = self.fold_interpret or jax.default_backend() != "tpu"

        def fold(block, mask, gids):
            shared = fused_fold(
                block, mask, gids, num_groups=G, names=names,
                interpret=interpret)
            if not grouped:   # ungrouped folds are the G=1 degenerate case
                shared = {n: a[0] for n, a in shared.items()}
            return program.partial_from_shared(shared)

        if grouped:
            if masked:
                return jax.jit(fold)
            return jax.jit(lambda block, gids: fold(block, None, gids))
        if masked:
            return jax.jit(lambda block, mask: fold(block, mask, None))
        return jax.jit(lambda block: fold(block, None, None))

    def _block_fold_fn(self, program: MapReduceProgram, rows: int,
                       row_shape, dtype, eta: int, masked: bool,
                       groups: int = 0):
        """The jitted fold for one block signature ``(rows, row_shape,
        dtype, η[, groups])``.  Padding to a chunk multiple happens inside
        the jit, so a committed device block folds on its own device with no
        host trip.  Executables are shape-keyed: blocks of equal (bucketed)
        row count share one compile.

        With ``groups > 0`` the program is a
        :class:`~repro.core.stats.GroupedProgram`: the fold additionally
        takes ``[rows]`` int32 group ids, and each chunk's ``[G, eta]``
        group mask (disjoint segment membership × validity) feeds the
        grouped ``map_chunk`` — one pass produces G partials.
        """
        pad = -rows % eta
        n_chunks = (rows + pad) // eta
        shape = tuple(row_shape)

        def fold(block, mask, gids):
            m = (jnp.ones((rows,), bool) if mask is None
                 else mask.astype(bool))
            v = block
            if pad:
                v = jnp.pad(v, [(0, pad)] + [(0, 0)] * len(shape))
                m = jnp.pad(m, [(0, pad)])
                if groups:
                    gids = jnp.pad(gids, [(0, pad)])
            v = v.reshape((n_chunks, eta) + shape)
            m = m.reshape((n_chunks, eta))
            init = program.zero(shape, dtype)

            if groups:
                g = gids.astype(jnp.int32).reshape((n_chunks, eta))

                def gbody(carry, xs):
                    chunk, cm, cg = xs
                    gm = (cg[None, :] == jnp.arange(groups)[:, None]) \
                        & cm[None, :]
                    return program.merge(carry,
                                         program.map_chunk(chunk, gm)), None

                partial, _ = jax.lax.scan(gbody, init, (v, m, g))
                return partial

            def body(carry, xs):
                chunk, cm = xs
                return program.merge(carry, program.map_chunk(chunk, cm)), None

            partial, _ = jax.lax.scan(body, init, (v, m))
            return partial

        if groups:
            if masked:
                return jax.jit(fold)
            return jax.jit(lambda block, gids: fold(block, None, gids))
        if masked:
            return jax.jit(lambda block, mask: fold(block, mask, None))
        return jax.jit(lambda block: fold(block, None, None))

    def fold_block(
        self,
        program: MapReduceProgram,
        block: Any,                      # [rows, ...] device or host array
        mask: Optional[Any],             # [rows] bool; None = every row
        eta: int,
        row_shape: Tuple[int, ...],
        dtype,
        gids: Optional[Any] = None,      # [rows] int32 group ids (grouped)
        num_groups: int = 0,
        owner: Optional[int] = None,     # fault context: owning device index
    ) -> PyTree:
        """Fold one block into a partial — the map phase at block granularity.

        ``block`` committed to a device keeps the fold there (jit follows
        committed inputs), which is the colocation property: the block's
        payload bytes never leave its owner; only the partial will.

        Blocks are padded to the bucketed row count *outside* the jit (pad
        rows masked off), so two regions of 9 and 12 rows share the 16-row
        executable instead of compiling twice.  With ``gids``/``num_groups``
        the fold is group-aware: the partial's leaves carry a leading group
        axis (see :class:`~repro.core.stats.GroupedProgram`).
        """
        if self.fault_injector is not None:
            # fired before any padding/compile work so an injected fold
            # fault costs the caller nothing but the retry itself
            self.fault_injector.fire("fold", device=owner)
        rows = int(block.shape[0])
        grouped = num_groups > 0
        if grouped and gids is None:
            raise ValueError("grouped fold needs per-row group ids")
        bucket = self.bucket_rows(rows)
        if bucket != rows:
            padw = [(0, bucket - rows)]
            block = jnp.pad(block, padw + [(0, 0)] * (block.ndim - 1))
            mask = jnp.pad(jnp.ones((rows,), bool) if mask is None
                           else jnp.asarray(mask, bool), padw)
            if grouped:
                gids = jnp.pad(jnp.asarray(gids, jnp.int32), padw)
        impl = self.fold_path(program, dtype, num_groups)
        with self._count_lock:
            self.fold_path_counts[impl] += 1
        if impl == "pallas":
            # chunk-free: eta is absent from the key — every η shares the
            # one fused-kernel executable per (bucket, G) signature
            key = ("pfold", program.cache_key(), bucket, tuple(row_shape),
                   str(dtype), mask is not None, int(num_groups))
            fn = self._get_or_build(
                key, lambda: self._pallas_fold_fn(
                    program, bucket, row_shape, dtype, mask is not None,
                    groups=int(num_groups)))
        else:
            key = ("bfold", program.cache_key(), bucket, tuple(row_shape),
                   str(dtype), int(eta), mask is not None, int(num_groups))
            fn = self._get_or_build(
                key, lambda: self._block_fold_fn(
                    program, bucket, row_shape, dtype, eta, mask is not None,
                    groups=int(num_groups)))
        if grouped:
            gids = jnp.asarray(gids, jnp.int32)
            return fn(block, mask, gids) if mask is not None \
                else fn(block, gids)
        return fn(block, mask) if mask is not None else fn(block)

    def merge_finalize(
        self,
        program: MapReduceProgram,
        partials: Sequence[PyTree],
        row_shape: Tuple[int, ...],
        dtype,
        owners: Optional[Sequence[Optional[int]]] = None,
    ) -> PyTree:
        """Reduce phase: combine the per-block partials and finalize.

        Two physical reduces share this entry point:

        - **tree** — additive programs on a 1-D data mesh with ``owners``
          given: each owner device pre-merges its own partials locally (no
          payload crosses the interconnect), the D per-device sums join via
          one ``psum`` over the data axis (the ICI's hardware all-reduce —
          log-depth, all links busy), and finalize runs replicated.  The
          merge wall stops scaling with #blocks-on-one-device.
        - **funnel** — the fallback (non-additive merges, single device,
          exotic meshes, ``merge_strategy="funnel"``): partials move to one
          device and a jitted merge+finalize reduces them there.

        Zero partials finalize the monoid identity (the empty-selection
        result).  Funnel executables are keyed by the partial count rounded
        up to a power of two (identity-padded), so drifting block counts
        don't multiply compiles.
        """
        if self._tree_merge_ok(program, partials, owners):
            self.last_merge_path = "tree"
            with self._count_lock:
                self.merge_path_counts["tree"] += 1
            return self._merge_tree(program, partials, owners,
                                    row_shape, dtype)
        self.last_merge_path = "funnel"
        with self._count_lock:
            self.merge_path_counts["funnel"] += 1
        return self._merge_funnel(program, partials, row_shape, dtype)

    def _tree_merge_ok(self, program, partials, owners) -> bool:
        return (self.merge_strategy == "auto"
                and program.additive
                and self._axis_devices is not None
                and len(self._axis_devices) > 1
                and owners is not None
                and len(owners) == len(partials)
                and len(partials) > 1
                and all(o is not None and 0 <= o < len(self._axis_devices)
                        for o in owners))

    def _presum_fn(self, program, count: int, row_shape, dtype):
        """One jitted per-device sum over ``count`` stacked partials — the
        owner-local pre-merge of the tree reduce.  Keyed by the pow2-
        bucketed partial count (identity-padded), so drifting per-owner
        block counts share a handful of compiles instead of dispatching a
        Python loop of adds per partial."""
        key = ("bpresum", program.cache_key(), int(count), tuple(row_shape),
               str(dtype))

        def build():
            def presum(*ps):
                ops = _checked_merge_ops(program, ps[0])
                stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *ps)
                return _combine_leafwise(stacked, ops,
                                         lambda s: s.sum(axis=0),
                                         lambda s: s.max(axis=0))

            return jax.jit(presum)

        return self._get_or_build(key, build)

    def _merge_tree(self, program, partials, owners, row_shape, dtype):
        """psum-over-mesh reduce: owner-local pre-merge, one all-reduce."""
        D = len(self._axis_devices)
        by_owner: List[List[PyTree]] = [[] for _ in range(D)]
        for p, o in zip(partials, owners):
            by_owner[o].append(p)
        identity = None

        def ident(dev):
            nonlocal identity
            if identity is None:
                identity = program.zero(tuple(row_shape), dtype)
            return jax.device_put(identity, dev)

        shards = []
        for d, ps in enumerate(by_owner):
            dev = self._axis_devices[d]
            if not ps:
                acc = ident(dev)
            elif len(ps) == 1:
                # partials folded this execution already live on device d;
                # cached partials from a pre-rebalance owner re-home here
                # (tiny — a partial, never a payload block)
                acc = jax.device_put(ps[0], dev)
            else:
                # one jitted stack+sum per owner (tree path ⇒ additive),
                # identity-padded to the pow2 count bucket
                moved = [jax.device_put(p, dev) for p in ps]
                bucket = self._next_pow2(len(moved))
                moved.extend([ident(dev)] * (bucket - len(moved)))
                acc = self._presum_fn(program, bucket, row_shape,
                                      dtype)(*moved)
            shards.append(jax.tree.map(lambda x: x[None], acc))

        sharding = NamedSharding(self.mesh, P(self.data_axis))

        def assemble(*leaves):
            shape = (D,) + tuple(leaves[0].shape[1:])
            return jax.make_array_from_single_device_arrays(
                shape, sharding, list(leaves))

        stacked = jax.tree.map(assemble, *shards)

        key = ("btree", program.cache_key(), tuple(row_shape), str(dtype))

        def build():
            def local(t):
                ops = _checked_merge_ops(program, t)
                return _combine_leafwise(
                    t, ops,
                    lambda x: jax.lax.psum(x[0], self.data_axis),
                    lambda x: jax.lax.pmax(x[0], self.data_axis))

            reduce_fn = shard_map_compat(
                local, mesh=self.mesh, in_specs=P(self.data_axis),
                out_specs=P(), check=False)
            return jax.jit(lambda t: program.finalize(reduce_fn(t)))

        return self._get_or_build(key, build)(stacked)

    def _merge_funnel(self, program, partials, row_shape, dtype):
        """Single-device reduce: partials meet on the merge device and one
        jitted merge+finalize combines them (count bucketed to a power of
        two with identity partials, so the executable key space stays
        narrow as block counts drift)."""
        n = len(partials)
        bucket = n if n <= 1 else self._next_pow2(n)
        key = ("bmerge", program.cache_key(), bucket, tuple(row_shape),
               str(dtype))

        def build():
            shape = tuple(row_shape)

            def mf(*ps):
                if not ps:
                    acc = program.zero(shape, dtype)
                elif program.additive and len(ps) > 1:
                    ops = _checked_merge_ops(program, ps[0])
                    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *ps)
                    acc = _combine_leafwise(stacked, ops,
                                            lambda s: s.sum(axis=0),
                                            lambda s: s.max(axis=0))
                else:
                    items: List[PyTree] = list(ps)
                    while len(items) > 1:
                        items = [
                            program.merge(items[i], items[i + 1])
                            if i + 1 < len(items) else items[i]
                            for i in range(0, len(items), 2)
                        ]
                    acc = items[0]
                return program.finalize(acc)

            return jax.jit(mf)

        fn = self._get_or_build(key, build)
        dev = self._merge_device
        moved = [jax.device_put(p, dev) for p in partials]
        if bucket > n:
            identity = jax.device_put(
                program.zero(tuple(row_shape), dtype), dev)
            moved.extend([identity] * (bucket - n))
        return fn(*moved)

    def partial_nbytes(self, program: MapReduceProgram,
                       row_shape: Tuple[int, ...], dtype) -> int:
        """Bytes of one partial (the unit of reduce-phase shuffle traffic).
        Cached outside the executable LRU — shape arithmetic is not a
        compile, so it must not move ``compile_count``."""
        key = (program.cache_key(), tuple(row_shape), str(dtype))
        nbytes = self._partial_bytes.get(key)
        if nbytes is None:
            tree = jax.eval_shape(
                lambda: program.zero(tuple(row_shape), dtype))
            nbytes = sum(
                int(np.prod(x.shape, dtype=np.int64)) * x.dtype.itemsize
                for x in jax.tree.leaves(tree))
            self._partial_bytes[key] = nbytes
        return nbytes

    def fold_cost(
        self,
        program: MapReduceProgram,
        rows: int,
        row_shape: Tuple[int, ...],
        dtype,
        eta: int,
        masked: bool = False,
        groups: int = 0,
    ) -> Mapping[str, float]:
        """XLA ``cost_analysis`` of the per-block fold executable (FLOPs /
        bytes accessed) — the oracle the CSE bench and property test use to
        show shared accumulators are computed once per chunk, and the
        measured bytes-read the fused-kernel bench compares its one-pass
        analytic bytes against (grouped folds via ``groups > 0``)."""
        fn = self._block_fold_fn(program, rows, row_shape, dtype, eta,
                                 masked, groups=int(groups))
        args = [jax.ShapeDtypeStruct((rows,) + tuple(row_shape),
                                     jnp.dtype(dtype))]
        if masked:
            args.append(jax.ShapeDtypeStruct((rows,), jnp.dtype(bool)))
        if groups:
            args.append(jax.ShapeDtypeStruct((rows,), jnp.dtype(jnp.int32)))
        cost = fn.lower(*args).compile().cost_analysis()
        if isinstance(cost, (list, tuple)):   # JAX 0.4.x wraps it in a list
            cost = cost[0] if cost else {}
        return {"flops": float(cost.get("flops", 0.0)),
                "bytes": float(cost.get("bytes accessed", 0.0))}

    # ------------------------------------------------------------------

    def run(
        self,
        program: MapReduceProgram,
        values: jax.Array,
        valid: jax.Array,
        chunk_size: int,
        row_mask: Optional[jax.Array] = None,
    ) -> Tuple[PyTree, MapReduceStats]:
        """Run ``program`` over a colocated ``[D, C, ...]`` layout.

        ``row_mask`` (``[D, C]`` bool) restricts the fold to a query subset
        (the §2.3 path: the mask comes from index columns, and the payload
        rows it deselects are never read by the fold — locality preserved
        because mask and payload share the row layout).
        """
        D, C = values.shape[0], values.shape[1]
        if C % chunk_size != 0:
            pad = -C % chunk_size
            values = jnp.pad(values, [(0, 0), (0, pad)] + [(0, 0)] * (values.ndim - 2))
            valid = jnp.pad(valid, [(0, 0), (0, pad)])
            if row_mask is not None:
                row_mask = jnp.pad(row_mask, [(0, 0), (0, pad)])
            C += pad
        mask = valid if row_mask is None else (valid & row_mask)

        row_shape = tuple(values.shape[2:])
        dtype = values.dtype
        key = (program.cache_key(), row_shape, str(dtype), chunk_size, C)
        fn = self._get_or_build(
            key, lambda: self._build(program, row_shape, dtype, chunk_size))
        result = fn(values, mask)

        # --- byte accounting (host-side; mask is tiny) -------------------
        mask_np = np.asarray(jax.device_get(mask))
        per_dev_rows = mask_np.sum(axis=1)
        row_nbytes = int(np.prod(row_shape, dtype=np.int64)) * np.dtype(dtype).itemsize
        partial = program.zero(row_shape, dtype)
        partial_bytes = sum(
            int(np.prod(jnp.shape(x), dtype=np.int64)) * jnp.result_type(x).itemsize
            for x in jax.tree.leaves(partial)
        )
        chunks_per_dev = np.ceil(per_dev_rows / chunk_size).astype(np.int64)
        shuffle = partial_bytes * (D if program.additive else D * D)  # psum vs all_gather
        stats = MapReduceStats(
            local_rows_read=int(per_dev_rows.sum()),
            local_bytes_read=int(per_dev_rows.sum()) * row_nbytes,
            shuffle_bytes=int(shuffle),
            rounds=C // chunk_size,
            chunks=int(chunks_per_dev.sum()),
            chunk_size=chunk_size,
        )
        return result, stats
