"""MapReduce engine over the device mesh — the paper's §2.2 on TPU.

The paper's Map phase sends each chunk of η images to the node holding them;
the Reduce phase combines the per-chunk intermediates on one node.  On a TPU
mesh this becomes:

- **Map**: a ``shard_map`` body on the ``data`` axis.  Each device scans its
  *local* table shard (placed by :mod:`repro.core.placement`, so no input
  bytes cross the interconnect) in chunks of η rows, folding each chunk into a
  running partial with the program's ``map_chunk``/``merge``.  Devices with
  fewer real rows run the same number of lockstep rounds with masked-out
  chunks — the SPMD analogue of idle cores waiting on the longest map task
  (eq. 2's worst-case term).
- **Shuffle/Reduce**: only the tiny partials move.  Additive programs reduce
  with a single ``psum`` (an all-reduce the ICI does in hardware); general
  associative merges use an ``all_gather`` of partials followed by a fold.
  Either way the network carries ``O(#job · |partial|)`` bytes — the colocation
  win over SGE, which must move ``O(#img · SizeBig)``.

Programs are associative-merge folds (monoids), which is exactly the structure
the paper's ANTS AverageImages use case has, and what makes chunk size η a
free *performance* parameter with no effect on the result (a property test
asserts chunk-size invariance up to float associativity).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.blockstore import LRUCache
from repro.utils import shard_map_compat

PyTree = Any


class MapReduceProgram:
    """An associative summary-statistic program (a commutative monoid).

    Subclasses define:
      * ``zero(row_shape, dtype)``  — identity partial;
      * ``map_chunk(rows, valid)``  — fold a ``[eta, ...]`` chunk (with a
        ``[eta]`` validity mask) into a partial;
      * ``merge(a, b)``             — associative combine of partials;
      * ``finalize(partial)``       — partial -> user-facing result.

    ``additive`` marks programs whose partials combine by elementwise sum,
    enabling the single-``psum`` reduce path.
    """

    additive: bool = False

    def cache_key(self) -> Tuple[str, str]:
        """Stable identity for executable/plan caches.

        Default: type name + repr — correct for the frozen-dataclass
        programs in :mod:`repro.core.stats` (repr encodes every parameter).
        Programs with unhashable/unstable reprs should override.
        """
        return (type(self).__name__, repr(self))

    def zero(self, row_shape: Tuple[int, ...], dtype) -> PyTree:
        raise NotImplementedError

    def map_chunk(self, rows: jax.Array, valid: jax.Array) -> PyTree:
        raise NotImplementedError

    def merge(self, a: PyTree, b: PyTree) -> PyTree:
        raise NotImplementedError

    def finalize(self, partial: PyTree) -> PyTree:
        raise NotImplementedError


@dataclasses.dataclass
class MapReduceStats:
    """Byte accounting for one run (feeds EXPERIMENTS.md and the simulator
    cross-check)."""

    local_rows_read: int          # rows folded on their home device
    local_bytes_read: int         # physical payload bytes read from HBM
    shuffle_bytes: int            # partial bytes crossing the interconnect
    rounds: int                   # lockstep map rounds (wall-clock proxy)
    chunks: int                   # Σ real chunks (#job; resource proxy)
    chunk_size: int


class MapReduceEngine:
    """Executes MapReduce programs over ``[D, C, ...]`` colocated layouts."""

    def __init__(self, mesh: Mesh, data_axis: str = "data",
                 executable_cache_cap: int = 64):
        self.mesh = mesh
        self.data_axis = data_axis
        # LRU-capped: one entry per (program, row signature, eta, C); an
        # evicted executable rebuilds on next use (compile_count bumps again)
        self._compiled = LRUCache(executable_cache_cap)
        # builds of new executables (the recompile oracle GridSession's plan
        # cache is tested against): bumped only on an executable-cache miss.
        self.compile_count = 0

    # ------------------------------------------------------------------

    def _build(self, program: MapReduceProgram, row_shape, dtype, eta: int):
        """Build the jitted shard_map fold for a given row signature."""
        data_axis = self.data_axis
        mesh = self.mesh
        rep_axes = tuple(a for a in mesh.axis_names if a != data_axis)

        def local_fold(values: jax.Array, valid: jax.Array) -> PyTree:
            # values: [1, C, ...] local shard; valid: [1, C]
            v = values[0]
            m = valid[0]
            C = v.shape[0]
            n_chunks = C // eta
            v = v.reshape((n_chunks, eta) + v.shape[1:])
            m = m.reshape((n_chunks, eta))

            def body(carry, xs):
                chunk, mask = xs
                return program.merge(carry, program.map_chunk(chunk, mask)), None

            init = program.zero(row_shape, dtype)
            partial, _ = jax.lax.scan(body, init, (v, m))
            return partial

        if program.additive:
            def mapper(values, valid):
                partial = local_fold(values, valid)
                total = jax.tree.map(
                    lambda x: jax.lax.psum(x, axis_name=data_axis), partial
                )
                return total
        else:
            def mapper(values, valid):
                partial = local_fold(values, valid)
                gathered = jax.tree.map(
                    lambda x: jax.lax.all_gather(x, axis_name=data_axis), partial
                )
                D = mesh.shape[data_axis]

                def fold(i, acc):
                    piece = jax.tree.map(lambda g: g[i], gathered)
                    return program.merge(acc, piece)

                first = jax.tree.map(lambda g: g[0], gathered)
                return jax.lax.fori_loop(1, D, fold, first)

        in_specs = (P(data_axis), P(data_axis))
        out_specs = jax.tree.map(lambda _: P(), program.zero(row_shape, dtype))

        fn = shard_map_compat(
            mapper, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check=False,
        )

        def run(values, valid):
            partial = fn(values, valid)
            return program.finalize(partial)

        return jax.jit(run)

    # ------------------------------------------------------------------

    def run(
        self,
        program: MapReduceProgram,
        values: jax.Array,
        valid: jax.Array,
        chunk_size: int,
        row_mask: Optional[jax.Array] = None,
    ) -> Tuple[PyTree, MapReduceStats]:
        """Run ``program`` over a colocated ``[D, C, ...]`` layout.

        ``row_mask`` (``[D, C]`` bool) restricts the fold to a query subset
        (the §2.3 path: the mask comes from index columns, and the payload
        rows it deselects are never read by the fold — locality preserved
        because mask and payload share the row layout).
        """
        D, C = values.shape[0], values.shape[1]
        if C % chunk_size != 0:
            pad = -C % chunk_size
            values = jnp.pad(values, [(0, 0), (0, pad)] + [(0, 0)] * (values.ndim - 2))
            valid = jnp.pad(valid, [(0, 0), (0, pad)])
            if row_mask is not None:
                row_mask = jnp.pad(row_mask, [(0, 0), (0, pad)])
            C += pad
        mask = valid if row_mask is None else (valid & row_mask)

        row_shape = tuple(values.shape[2:])
        dtype = values.dtype
        key = (program.cache_key(), row_shape, str(dtype), chunk_size, C)
        fn = self._compiled.get(key)
        if fn is None:
            self.compile_count += 1
            fn = self._build(program, row_shape, dtype, chunk_size)
            self._compiled.put(key, fn)
        result = fn(values, mask)

        # --- byte accounting (host-side; mask is tiny) -------------------
        mask_np = np.asarray(jax.device_get(mask))
        per_dev_rows = mask_np.sum(axis=1)
        row_nbytes = int(np.prod(row_shape, dtype=np.int64)) * np.dtype(dtype).itemsize
        partial = program.zero(row_shape, dtype)
        partial_bytes = sum(
            int(np.prod(jnp.shape(x), dtype=np.int64)) * jnp.result_type(x).itemsize
            for x in jax.tree.leaves(partial)
        )
        chunks_per_dev = np.ceil(per_dev_rows / chunk_size).astype(np.int64)
        shuffle = partial_bytes * (D if program.additive else D * D)  # psum vs all_gather
        stats = MapReduceStats(
            local_rows_read=int(per_dev_rows.sum()),
            local_bytes_read=int(per_dev_rows.sum()) * row_nbytes,
            shuffle_bytes=int(shuffle),
            rounds=C // chunk_size,
            chunks=int(chunks_per_dev.sum()),
            chunk_size=chunk_size,
        )
        return result, stats
