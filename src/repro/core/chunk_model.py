"""The paper's chunk-size model — eq. (1)-(8) of §2.2, faithfully.

Predicts wall-clock time (what the user experiences) and resource time
(Σ busy time across nodes) for a MapReduce summary-statistic job as a function
of the map-task chunk size η (images per map task), and finds the optimal η
inside the validity window

    η ∈ [ max(#img·SizeSmall/mem, #img/core),  mem/SizeBig ]          (paper §2.2)

(lower bound: one map round across all cores + reduce-phase memory; upper
bound: a chunk must fit in one machine's memory).

Two parameterizations ship:

- :data:`PAPER_PARAMS` — the paper's cluster (§2.4: 70 MB/s network, 100/65
  MB/s disk R/W, 224 cores, SizeBig/Small/Gen = 20/6/21 MB, 5,153 images,
  ``avgANTS(η) = 0.4η + 5`` s).  With these constants the model reproduces the
  reported optimum η* in [50, 60] and the Fig. 4C/D trends.
- :data:`TPU_V5E_PARAMS` — the TPU translation: disk→HBM (819 GB/s), network→
  ICI (~50 GB/s/link), machine→chip (16 GB HBM); the compute kernel is
  memory-bound streaming mean rather than ANTS.  This drives ColoGrid's chunk
  auto-tuner at runtime.

Notes on constants the paper leaves implicit:

- ``alpha`` (unbuffered-map-output ratio) is never given a value; we default
  to 0.25, which places the predicted optimum at η*≈59, inside the reported
  [50, 60] band (any α∈[0,0.6] keeps η*∈[56,63] — the model is flat there).
- ``mem`` is set to 3.2 GB so that the upper bound mem/SizeBig equals the 160
  the paper assesses (their "4 GB per job" is a scheduler grant, not the
  model's machine memory).
- ``wt_init + wt_end`` (MapReduce job setup/teardown) defaults to 30 s, the
  Hadoop-typical overhead visible as the Fig. 3 intercept.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Optional, Tuple

MB = 1e6
GB = 1e9

#: Per-core VMEM of the TPU translation (~16 MB of on-chip vector memory
#: feeding the compute units).  The fused fold kernel sizes its grouped
#: accumulator pool against a fraction of this — the G threshold above
#: which the engine falls back to the XLA fold (see
#: ``repro.kernels.fused_fold.ops.max_groups_for_vmem``).
VMEM_BYTES = 16 * MB


@dataclasses.dataclass(frozen=True)
class ChunkModelParams:
    """Table 2 of the paper, as a value type.  Sizes in bytes, rates in B/s."""

    n_img: int                    # #img
    size_big: float               # SizeBig  — max input file size (worst case)
    size_small: float             # SizeSmall — min input file size (η bounds)
    size_gen: float               # SizeGen  — max intermediate/output size
    bandwidth: float              # cluster network bandwidth
    v_disc_r: float               # local disk read B/s
    v_disc_w: float               # local disk write B/s
    mem: float                    # memory of one machine
    core: int                     # total CPU cores of the cluster
    alpha: float = 0.25           # unbuffered ratio of map outputs (spilled)
    beta: float = 0.9             # rack-local (network-loaded) map-task ratio
    wt_init: float = 15.0         # job initialization (s)
    wt_end: float = 15.0          # job conclusion (s)
    # avg_fn(η) — seconds to average η images on one core.  The paper's
    # empirical worst case for ANTS AverageImages is 0.4η + 5.
    avg_fn: Callable[[float], float] = lambda eta: 0.4 * eta + 5.0

    # -- helper functions of Table 2 ------------------------------------

    def disc_r(self, x: float) -> float:
        return x / self.v_disc_r

    def disc_w(self, x: float) -> float:
        return x / self.v_disc_w

    def bdw(self, x: float) -> float:
        return x / self.bandwidth


class ChunkModel:
    """Evaluates eq. (1)-(8) and optimizes η."""

    def __init__(self, params: ChunkModelParams):
        self.p = params

    # ------------------------------------------------------------------
    # validity window (§2.2)
    # ------------------------------------------------------------------

    def eta_bounds(self) -> Tuple[int, int]:
        p = self.p
        lo = max(p.n_img * p.size_small / p.mem, p.n_img / p.core)
        hi = p.mem / p.size_big
        lo_i, hi_i = int(math.ceil(lo)), int(math.floor(hi))
        if lo_i > hi_i:
            raise ValueError(
                f"empty η window [{lo:.1f}, {hi:.1f}] — cluster cannot run "
                f"this dataset in one wave; add nodes or memory"
            )
        return lo_i, hi_i

    # ------------------------------------------------------------------
    # wall-clock time, eq. (1)-(4)
    # ------------------------------------------------------------------

    def wall_time(self, eta: int) -> Dict[str, float]:
        p = self.p
        n_job = p.n_img // eta                       # ⌊#img/η⌋ as in the paper

        # eq. (2): the longest map task (worst case: all-big-image chunk;
        # read local, possibly network-loaded, write intermediate, compute)
        wt_map = (
            p.disc_r(p.size_big * eta)
            + p.bdw(p.size_big * eta)
            + p.disc_w(p.size_big * eta)
            + p.avg_fn(eta)
        )
        # eq. (3): worst-case shuffle — unbuffered outputs from disk, over
        # the wire, spilled at the reducer
        wt_shuffle = (
            p.disc_r(p.size_gen)
            + p.bdw(p.alpha * n_job * p.size_gen)
            + p.disc_w(n_job * p.size_gen)
        )
        # eq. (4): reduce = average the #job intermediates + final I/O
        wt_reduce = p.avg_fn(n_job) + p.disc_r(p.size_gen) + p.disc_w(p.size_gen)

        total = p.wt_init + wt_map + wt_shuffle + wt_reduce + p.wt_end
        return {
            "init": p.wt_init, "map": wt_map, "shuffle": wt_shuffle,
            "reduce": wt_reduce, "end": p.wt_end, "total": total,
        }

    # ------------------------------------------------------------------
    # resource time, eq. (5)-(8)
    # ------------------------------------------------------------------

    def resource_time(self, eta: int) -> Dict[str, float]:
        p = self.p
        n_job = p.n_img // eta

        # eq. (6): every image read+written once somewhere, the β rack-local
        # fraction also crossing the network, plus all map computations
        rt_map = (
            p.disc_r(p.n_img * p.size_big)
            + p.disc_w(p.n_img * p.size_big)
            + p.bdw(p.beta * n_job * eta * p.size_big)
            + n_job * p.avg_fn(eta)
        )
        # eq. (7): spills on both sides + full intermediate transfer + sink
        rt_shuffle = (
            p.alpha * n_job * (p.disc_w(p.size_gen) + p.disc_r(p.size_gen))
            + p.bdw(n_job * p.size_gen)
            + p.disc_w(n_job * p.size_gen)
        )
        # eq. (8) == eq. (4)
        rt_reduce = p.avg_fn(n_job) + p.disc_r(p.size_gen) + p.disc_w(p.size_gen)

        total = rt_map + rt_shuffle + rt_reduce
        return {
            "map": rt_map, "shuffle": rt_shuffle, "reduce": rt_reduce,
            "total": total,
        }

    # ------------------------------------------------------------------
    # optimizer
    # ------------------------------------------------------------------

    def optimal_eta(
        self,
        metric: str = "wall",
        step: int = 1,
        bounds: Optional[Tuple[int, int]] = None,
    ) -> Tuple[int, float]:
        """argmin over the validity window; returns ``(η*, predicted_time)``."""
        lo, hi = bounds if bounds is not None else self.eta_bounds()
        fn = self.wall_time if metric == "wall" else self.resource_time
        best_eta, best_t = lo, float("inf")
        for eta in range(lo, hi + 1, step):
            t = fn(eta)["total"]
            if t < best_t:
                best_eta, best_t = eta, t
        return best_eta, best_t

    def sweep(self, etas) -> Dict[int, Dict[str, float]]:
        return {
            int(e): {
                "wall": self.wall_time(int(e))["total"],
                "resource": self.resource_time(int(e))["total"],
            }
            for e in etas
        }


# ----------------------------------------------------------------------
# Shipped parameterizations
# ----------------------------------------------------------------------

#: The paper's cluster (§2.4) — reproduces Fig. 4C/D and η* ∈ [50, 60].
PAPER_PARAMS = ChunkModelParams(
    n_img=5153,
    size_big=20 * MB,
    size_small=6 * MB,
    size_gen=21 * MB,
    bandwidth=70 * MB,
    v_disc_r=100 * MB,
    v_disc_w=65 * MB,
    mem=3.2 * GB,                 # makes mem/SizeBig = 160, the paper's bound
    core=224,
)


def tpu_chunk_params(
    n_img: int,
    row_bytes: float,
    n_devices: int,
    hbm_bytes: float = 16 * GB,
    hbm_bw: float = 819e9,
    ici_bw: float = 50e9,
    flops: float = 197e12,
) -> ChunkModelParams:
    """TPU v5e translation of Table 2 (see DESIGN.md §2).

    disk → HBM, network → ICI, machine → chip.  The per-chunk compute is a
    memory-bound streaming mean: ``avg(η) ≈ η·row_bytes / HBM_bw`` plus a
    fixed kernel-dispatch overhead; the MXU term is negligible for adds.
    """
    dispatch = 5e-6  # per-chunk kernel launch/loop overhead (s)

    def avg_fn(eta: float) -> float:
        return eta * row_bytes / hbm_bw + dispatch

    return ChunkModelParams(
        n_img=n_img,
        size_big=row_bytes,
        size_small=row_bytes,
        size_gen=row_bytes,
        bandwidth=ici_bw,
        v_disc_r=hbm_bw,
        v_disc_w=hbm_bw,
        mem=hbm_bytes * 0.5,      # stats may only claim half of HBM
        core=n_devices,
        alpha=0.0,                # no spill: partials live in HBM
        beta=0.0,                 # colocated: no network loads in map
        wt_init=1e-3,             # dispatch, not a JVM job launch
        wt_end=1e-3,
        avg_fn=avg_fn,
    )


#: A representative TPU parameterization (5,153 rows of 20 MB on 256 chips).
TPU_V5E_PARAMS = tpu_chunk_params(n_img=5153, row_bytes=20 * MB, n_devices=256)
