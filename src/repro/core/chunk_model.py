"""The paper's chunk-size model — eq. (1)-(8) of §2.2, faithfully.

Predicts wall-clock time (what the user experiences) and resource time
(Σ busy time across nodes) for a MapReduce summary-statistic job as a function
of the map-task chunk size η (images per map task), and finds the optimal η
inside the validity window

    η ∈ [ max(#img·SizeSmall/mem, #img/core),  mem/SizeBig ]          (paper §2.2)

(lower bound: one map round across all cores + reduce-phase memory; upper
bound: a chunk must fit in one machine's memory).

Two parameterizations ship:

- :data:`PAPER_PARAMS` — the paper's cluster (§2.4: 70 MB/s network, 100/65
  MB/s disk R/W, 224 cores, SizeBig/Small/Gen = 20/6/21 MB, 5,153 images,
  ``avgANTS(η) = 0.4η + 5`` s).  With these constants the model reproduces the
  reported optimum η* in [50, 60] and the Fig. 4C/D trends.
- :data:`TPU_V5E_PARAMS` — the TPU translation: disk→HBM (819 GB/s), network→
  ICI (~50 GB/s/link), machine→chip (16 GB HBM); the compute kernel is
  memory-bound streaming mean rather than ANTS.  This drives ColoGrid's chunk
  auto-tuner at runtime.

Notes on constants the paper leaves implicit:

- ``alpha`` (unbuffered-map-output ratio) is never given a value; we default
  to 0.25, which places the predicted optimum at η*≈59, inside the reported
  [50, 60] band (any α∈[0,0.6] keeps η*∈[56,63] — the model is flat there).
- ``mem`` is set to 3.2 GB so that the upper bound mem/SizeBig equals the 160
  the paper assesses (their "4 GB per job" is a scheduler grant, not the
  model's machine memory).
- ``wt_init + wt_end`` (MapReduce job setup/teardown) defaults to 30 s, the
  Hadoop-typical overhead visible as the Fig. 3 intercept.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Optional, Tuple

MB = 1e6
GB = 1e9

#: Per-core VMEM of the TPU translation (~16 MB of on-chip vector memory
#: feeding the compute units).  The fused fold kernel sizes its grouped
#: accumulator pool against a fraction of this — the G threshold above
#: which the engine falls back to the XLA fold (see
#: ``repro.kernels.fused_fold.ops.max_groups_for_vmem``).
VMEM_BYTES = 16 * MB


@dataclasses.dataclass(frozen=True)
class ChunkModelParams:
    """Table 2 of the paper, as a value type.  Sizes in bytes, rates in B/s."""

    n_img: int                    # #img
    size_big: float               # SizeBig  — max input file size (worst case)
    size_small: float             # SizeSmall — min input file size (η bounds)
    size_gen: float               # SizeGen  — max intermediate/output size
    bandwidth: float              # cluster network bandwidth
    v_disc_r: float               # local disk read B/s
    v_disc_w: float               # local disk write B/s
    mem: float                    # memory of one machine
    core: int                     # total CPU cores of the cluster
    alpha: float = 0.25           # unbuffered ratio of map outputs (spilled)
    beta: float = 0.9             # rack-local (network-loaded) map-task ratio
    wt_init: float = 15.0         # job initialization (s)
    wt_end: float = 15.0          # job conclusion (s)
    # avg_fn(η) — seconds to average η images on one core.  The paper's
    # empirical worst case for ANTS AverageImages is 0.4η + 5.
    avg_fn: Callable[[float], float] = lambda eta: 0.4 * eta + 5.0

    # -- helper functions of Table 2 ------------------------------------

    def disc_r(self, x: float) -> float:
        return x / self.v_disc_r

    def disc_w(self, x: float) -> float:
        return x / self.v_disc_w

    def bdw(self, x: float) -> float:
        return x / self.bandwidth


class ChunkModel:
    """Evaluates eq. (1)-(8) and optimizes η."""

    def __init__(self, params: ChunkModelParams):
        self.p = params

    # ------------------------------------------------------------------
    # validity window (§2.2)
    # ------------------------------------------------------------------

    def eta_bounds(self) -> Tuple[int, int]:
        p = self.p
        lo = max(p.n_img * p.size_small / p.mem, p.n_img / p.core)
        hi = p.mem / p.size_big
        lo_i, hi_i = int(math.ceil(lo)), int(math.floor(hi))
        if lo_i > hi_i:
            raise ValueError(
                f"empty η window [{lo:.1f}, {hi:.1f}] — cluster cannot run "
                f"this dataset in one wave; add nodes or memory"
            )
        return lo_i, hi_i

    # ------------------------------------------------------------------
    # wall-clock time, eq. (1)-(4)
    # ------------------------------------------------------------------

    def wall_time(self, eta: int) -> Dict[str, float]:
        p = self.p
        n_job = p.n_img // eta                       # ⌊#img/η⌋ as in the paper

        # eq. (2): the longest map task (worst case: all-big-image chunk;
        # read local, possibly network-loaded, write intermediate, compute)
        wt_map = (
            p.disc_r(p.size_big * eta)
            + p.bdw(p.size_big * eta)
            + p.disc_w(p.size_big * eta)
            + p.avg_fn(eta)
        )
        # eq. (3): worst-case shuffle — unbuffered outputs from disk, over
        # the wire, spilled at the reducer
        wt_shuffle = (
            p.disc_r(p.size_gen)
            + p.bdw(p.alpha * n_job * p.size_gen)
            + p.disc_w(n_job * p.size_gen)
        )
        # eq. (4): reduce = average the #job intermediates + final I/O
        wt_reduce = p.avg_fn(n_job) + p.disc_r(p.size_gen) + p.disc_w(p.size_gen)

        total = p.wt_init + wt_map + wt_shuffle + wt_reduce + p.wt_end
        return {
            "init": p.wt_init, "map": wt_map, "shuffle": wt_shuffle,
            "reduce": wt_reduce, "end": p.wt_end, "total": total,
        }

    # ------------------------------------------------------------------
    # resource time, eq. (5)-(8)
    # ------------------------------------------------------------------

    def resource_time(self, eta: int) -> Dict[str, float]:
        p = self.p
        n_job = p.n_img // eta

        # eq. (6): every image read+written once somewhere, the β rack-local
        # fraction also crossing the network, plus all map computations
        rt_map = (
            p.disc_r(p.n_img * p.size_big)
            + p.disc_w(p.n_img * p.size_big)
            + p.bdw(p.beta * n_job * eta * p.size_big)
            + n_job * p.avg_fn(eta)
        )
        # eq. (7): spills on both sides + full intermediate transfer + sink
        rt_shuffle = (
            p.alpha * n_job * (p.disc_w(p.size_gen) + p.disc_r(p.size_gen))
            + p.bdw(n_job * p.size_gen)
            + p.disc_w(n_job * p.size_gen)
        )
        # eq. (8) == eq. (4)
        rt_reduce = p.avg_fn(n_job) + p.disc_r(p.size_gen) + p.disc_w(p.size_gen)

        total = rt_map + rt_shuffle + rt_reduce
        return {
            "map": rt_map, "shuffle": rt_shuffle, "reduce": rt_reduce,
            "total": total,
        }

    # ------------------------------------------------------------------
    # optimizer
    # ------------------------------------------------------------------

    def optimal_eta(
        self,
        metric: str = "wall",
        step: int = 1,
        bounds: Optional[Tuple[int, int]] = None,
    ) -> Tuple[int, float]:
        """argmin over the validity window; returns ``(η*, predicted_time)``."""
        lo, hi = bounds if bounds is not None else self.eta_bounds()
        fn = self.wall_time if metric == "wall" else self.resource_time
        best_eta, best_t = lo, float("inf")
        for eta in range(lo, hi + 1, step):
            t = fn(eta)["total"]
            if t < best_t:
                best_eta, best_t = eta, t
        return best_eta, best_t

    def sweep(self, etas) -> Dict[int, Dict[str, float]]:
        return {
            int(e): {
                "wall": self.wall_time(int(e))["total"],
                "resource": self.resource_time(int(e))["total"],
            }
            for e in etas
        }


# ----------------------------------------------------------------------
# Shipped parameterizations
# ----------------------------------------------------------------------

#: The paper's cluster (§2.4) — reproduces Fig. 4C/D and η* ∈ [50, 60].
PAPER_PARAMS = ChunkModelParams(
    n_img=5153,
    size_big=20 * MB,
    size_small=6 * MB,
    size_gen=21 * MB,
    bandwidth=70 * MB,
    v_disc_r=100 * MB,
    v_disc_w=65 * MB,
    mem=3.2 * GB,                 # makes mem/SizeBig = 160, the paper's bound
    core=224,
)


def tpu_chunk_params(
    n_img: int,
    row_bytes: float,
    n_devices: int,
    hbm_bytes: float = 16 * GB,
    hbm_bw: float = 819e9,
    ici_bw: float = 50e9,
    flops: float = 197e12,
    disk_bw_r: Optional[float] = None,
    disk_bw_w: Optional[float] = None,
) -> ChunkModelParams:
    """TPU v5e translation of Table 2 (see DESIGN.md §2).

    disk → HBM, network → ICI, machine → chip.  The per-chunk compute is a
    memory-bound streaming mean: ``avg(η) ≈ η·row_bytes / HBM_bw`` plus a
    fixed kernel-dispatch overhead; the MXU term is negligible for adds.

    The spill term: ``alpha`` (the paper's unbuffered-output ratio) is the
    fraction of the dataset that does NOT fit in the fleet's stats budget
    (``mem × n_devices``) — 0 exactly when everything is resident, which is
    what the old hard-coded ``alpha=0.0`` silently assumed.  When the
    spilled fraction is nonzero, reads/writes of spilled data go to real
    disk, so ``v_disc_r/w`` become the harmonic blend of HBM and disk
    bandwidth weighted by the spilled fraction (``disk_bw_r/w`` default to
    HBM speed for backwards compatibility, i.e. an infinitely fast spill
    device).
    """
    dispatch = 5e-6  # per-chunk kernel launch/loop overhead (s)

    def avg_fn(eta: float) -> float:
        return eta * row_bytes / hbm_bw + dispatch

    mem = hbm_bytes * 0.5         # stats may only claim half of HBM
    dataset = float(n_img) * float(row_bytes)
    capacity = mem * n_devices
    spilled = 0.0 if dataset <= 0 else max(0.0, 1.0 - capacity / dataset)

    def _blend(disk_bw: Optional[float]) -> float:
        if disk_bw is None or spilled <= 0.0:
            return hbm_bw
        return 1.0 / ((1.0 - spilled) / hbm_bw + spilled / disk_bw)

    return ChunkModelParams(
        n_img=n_img,
        size_big=row_bytes,
        size_small=row_bytes,
        size_gen=row_bytes,
        bandwidth=ici_bw,
        v_disc_r=_blend(disk_bw_r),
        v_disc_w=_blend(disk_bw_w if disk_bw_w is not None else disk_bw_r),
        mem=mem,
        core=n_devices,
        alpha=spilled,            # real spill term: the non-resident fraction
        beta=0.0,                 # colocated: no network loads in map
        wt_init=1e-3,             # dispatch, not a JVM job launch
        wt_end=1e-3,
        avg_fn=avg_fn,
    )


# ----------------------------------------------------------------------
# Tier-placement cost oracle (BlockStore device → host → disk chain)
# ----------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TierCostModel:
    """Cost oracle for the BlockStore's tier chain: should an evicted
    payload be demoted to the next tier, or dropped and re-derived later?

    Three re-acquisition paths compete, all in seconds per access:

    - **disk read** — ``nbytes / disk_bw_r`` (an mmap'd ``.npy`` page-in);
      paying ``nbytes / disk_bw_w`` once up front to write the spill file;
    - **re-fetch** — re-reading the content from the backing table, which
      in the paper's grid crosses the storage fabric: ``nbytes /
      refetch_bw`` (the paper's 70 MB/s cluster network by default);
    - **re-fold** — for partials: stream the whole source block again at
      ``fold_bw`` plus a dispatch overhead (and re-acquire the block first
      if it, too, was evicted).

    Rates default to the paper's cluster (§2.4) for the fabric and a
    commodity local SSD for spill; sessions built from
    :func:`tpu_chunk_params` pass their own.
    """

    disk_bw_r: float = 300 * MB    # local spill-file read (mmap page-in)
    disk_bw_w: float = 200 * MB    # local spill-file write
    refetch_bw: float = 70 * MB    # backing-table re-read (paper's network)
    fold_bw: float = 819e9         # fold streaming rate (HBM-bound compute)
    fold_overhead: float = 5e-6    # per-fold kernel dispatch (s)
    # fault-adjusted re-fetch: on a lossy fabric a table re-read is not
    # one transfer but an expected-attempts multiple of it (a capped
    # geometric: each attempt independently fails with this probability
    # and is retried up to ``max_refetch_attempts`` times), plus the
    # retry policy's mean backoff between attempts.  Defaults keep the
    # fault-free arithmetic bit-identical.
    refetch_fault_rate: float = 0.0   # per-attempt failure probability
    retry_backoff_s: float = 0.0      # mean sleep between attempts (s)
    max_refetch_attempts: int = 3

    def disk_read_s(self, nbytes: int) -> float:
        return nbytes / self.disk_bw_r

    def disk_write_s(self, nbytes: int) -> float:
        return nbytes / self.disk_bw_w

    def refetch_s(self, nbytes: int) -> float:
        return nbytes / self.refetch_bw

    def expected_attempts(self) -> float:
        """Mean number of table-read attempts under the fault rate: the
        expectation of a geometric capped at ``max_refetch_attempts``,
        ``(1 - p^k) / (1 - p)``.  Exactly 1.0 when the rate is zero."""
        p = min(max(self.refetch_fault_rate, 0.0), 0.999999)
        if p <= 0.0:
            return 1.0
        return (1.0 - p ** self.max_refetch_attempts) / (1.0 - p)

    def expected_refetch_s(self, nbytes: int) -> float:
        """Fault-adjusted cost of re-deriving content from the table:
        expected attempts × transfer time, plus the backoff slept between
        the extra attempts.  Collapses to :meth:`refetch_s` fault-free."""
        n = self.expected_attempts()
        return n * self.refetch_s(nbytes) + (n - 1.0) * self.retry_backoff_s

    def refold_s(self, block_nbytes: int) -> float:
        """Re-deriving a lost partial: worst case re-acquires the source
        block over the fabric, then streams it through the fold."""
        return (self.expected_refetch_s(block_nbytes)
                + block_nbytes / self.fold_bw + self.fold_overhead)

    def should_spill_block(self, nbytes: int) -> bool:
        """Spill a host payload iff the write amortizes within two future
        accesses — i.e. ``write + read <= 2 × expected refetch``.  With
        default rates local disk beats the storage fabric, so blocks
        spill; a deployment whose table is faster than its scratch disk
        drops the payload and re-gathers instead.  A non-zero
        ``refetch_fault_rate`` inflates the re-fetch side, biasing
        placement toward the (checksummed, locally verifiable) spill
        tier exactly when the fabric is unreliable."""
        if nbytes <= 0:
            return False
        return (self.disk_write_s(nbytes) + self.disk_read_s(nbytes)
                <= 2.0 * self.expected_refetch_s(nbytes))

    def should_spill_partial(self, partial_nbytes: int,
                             block_nbytes: int) -> bool:
        """Spill an evicted partial iff its disk round-trip undercuts
        re-folding the source block (partials are tiny accumulators, so
        this is almost always a win)."""
        if partial_nbytes <= 0:
            return False
        return (self.disk_write_s(partial_nbytes)
                + self.disk_read_s(partial_nbytes)
                <= self.refold_s(max(block_nbytes, partial_nbytes)))

    @classmethod
    def from_params(cls, params: ChunkModelParams,
                    disk_bw_r: float = 300 * MB,
                    disk_bw_w: float = 200 * MB) -> "TierCostModel":
        """Derive the oracle from a chunk-model parameterization: the
        table re-read crosses ``params.bandwidth`` (network for the
        paper's cluster, ICI for the TPU translation); folds stream at the
        model's read rate."""
        return cls(disk_bw_r=disk_bw_r, disk_bw_w=disk_bw_w,
                   refetch_bw=params.bandwidth, fold_bw=params.v_disc_r)


#: A representative TPU parameterization (5,153 rows of 20 MB on 256 chips).
TPU_V5E_PARAMS = tpu_chunk_params(n_img=5153, row_bytes=20 * MB, n_devices=256)
