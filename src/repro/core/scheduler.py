"""Grid scheduler — rounds, straggler mitigation, failure handling.

The paper's balancer is *offline*: measure MIPS once with ``linux perf``,
allocate, run.  At 1000+-node scale the measurement must be continuous —
effective device throughput drifts (thermal throttling, DCN congestion,
co-tenant noise) and devices fail outright.  ``GridScheduler`` closes the
loop:

1. every round it hands each node its chunk quota (from the placement);
2. observed per-node round times update effective powers (EWMA — the runtime
   re-measurement of "MIPS");
3. when the predicted makespan gain of re-balancing exceeds a threshold, it
   runs the paper's offline greedy :func:`~repro.core.balancer.rebalance`
   (move-minimizing) and emits the move list;
4. a failed node's regions are orphaned and adopted by the same rebalance
   call — fault tolerance *is* the balancer, run with a shrunken node list.

The scheduler is deliberately host-side and pure (no device state): it plans;
the MapReduce engine / training loop executes.  That keeps it testable with
injected timings and reusable across the stats path and the data pipeline.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from repro.core.balancer import (
    NodeSpec,
    allocation_imbalance,
    rebalance,
)
from repro.core.placement import Placement


@dataclasses.dataclass
class RebalanceEvent:
    round_index: int
    reason: str                   # "straggler" | "failure" | "elastic"
    moved_regions: List[int]
    imbalance_before: float
    imbalance_after: float


class GridScheduler:
    def __init__(
        self,
        placement: Placement,
        chunk_size: int,
        rebalance_threshold: float = 0.20,
        ewma: float = 0.5,
        min_rounds_between_rebalance: int = 3,
    ):
        self.placement = placement
        self.chunk_size = chunk_size
        self.rebalance_threshold = rebalance_threshold
        self.ewma = ewma
        self.min_gap = min_rounds_between_rebalance
        self.round_index = 0
        self._last_rebalance = -(10**9)
        # effective throughput per node (chunks/s), EWMA-updated
        self._eff_power: Dict[int, float] = {
            n.node_id: n.power for n in placement.nodes
        }
        self.events: List[RebalanceEvent] = []

    # ------------------------------------------------------------------
    # planning
    # ------------------------------------------------------------------

    def plan_round(self) -> Dict[int, int]:
        """Chunk quota per node for the next lockstep round."""
        counts = self.placement.node_row_counts()
        rounds = max(self.placement.rounds(self.chunk_size), 1)
        return {
            nid: -(-c // self.chunk_size) // rounds
            + (1 if (-(-c // self.chunk_size)) % rounds > 0 else 0)
            for nid, c in counts.items()
        }

    def makespan_estimate(self) -> float:
        """Predicted wall time of draining all chunks at current powers."""
        counts = self.placement.node_row_counts()
        return max(
            (-(-c // self.chunk_size)) / max(self._eff_power[nid], 1e-9)
            for nid, c in counts.items()
        )

    # ------------------------------------------------------------------
    # observation / adaptation
    # ------------------------------------------------------------------

    def observe_round(self, node_times: Mapping[int, float]) -> Optional[RebalanceEvent]:
        """Feed measured per-node round times; maybe rebalance.

        ``node_times[nid]`` is the wall time node ``nid`` took for its quota
        this round.  Throughput = quota/time updates the node's effective
        power; a sustained straggler shifts the allocation away from itself.
        """
        self.round_index += 1
        quotas = self.plan_round()
        for nid, t in node_times.items():
            if nid not in self._eff_power or t <= 0:
                continue
            thr = max(quotas.get(nid, 1), 1) / t
            self._eff_power[nid] = (
                (1 - self.ewma) * self._eff_power[nid] + self.ewma * thr
            )
        return self._maybe_rebalance(reason="straggler")

    def handle_failure(self, dead_node_ids: Sequence[int]) -> RebalanceEvent:
        """Remove nodes; their regions are orphaned and re-adopted."""
        dead = set(dead_node_ids)
        survivors = tuple(n for n in self.placement.nodes if n.node_id not in dead)
        if not survivors:
            raise RuntimeError("all nodes failed")
        for nid in dead:
            self._eff_power.pop(nid, None)
        self.placement.nodes = survivors
        return self._force_rebalance(reason="failure")

    def handle_join(self, new_nodes: Sequence[NodeSpec]) -> RebalanceEvent:
        """Elastic scale-up: add nodes and shift regions onto them."""
        self.placement.nodes = tuple(self.placement.nodes) + tuple(new_nodes)
        for n in new_nodes:
            self._eff_power[n.node_id] = n.power
        return self._force_rebalance(reason="elastic")

    # ------------------------------------------------------------------

    def effective_nodes(self) -> List[NodeSpec]:
        """Node specs with MIPS refreshed from the scheduler's EWMA powers.

        The hand-off point for callers owning the rebalance decision: pass
        the result to ``GridSession.rebalance(nodes=...)`` to apply this
        scheduler's view of node speeds with the session's epoch machinery
        intact.  (``rebalance(auto=True)`` instead folds the session's own
        raw round-time history via :func:`powers_from_observations` —
        unbiased by quota estimates, per the paper's offline probe.)"""
        return self._current_nodes()

    def _current_nodes(self) -> List[NodeSpec]:
        """Node specs with MIPS refreshed from observed effective powers."""
        return [
            dataclasses.replace(
                n, mips=self._eff_power[n.node_id] / max(n.cores, 1)
            )
            for n in self.placement.nodes
        ]

    def _maybe_rebalance(self, reason: str) -> Optional[RebalanceEvent]:
        if self.round_index - self._last_rebalance < self.min_gap:
            return None
        nodes = self._current_nodes()
        region_bytes = self.placement.table.region_bytes()
        imb = allocation_imbalance(self.placement.alloc, region_bytes, nodes)
        if imb <= self.rebalance_threshold:
            return None
        return self._do_rebalance(nodes, region_bytes, imb, reason)

    def _force_rebalance(self, reason: str) -> RebalanceEvent:
        nodes = self._current_nodes()
        region_bytes = self.placement.table.region_bytes()
        imb = allocation_imbalance(
            {r: n for r, n in self.placement.alloc.items()
             if n in {x.node_id for x in nodes}},
            {r: b for r, b in region_bytes.items()
             if self.placement.alloc.get(r) in {x.node_id for x in nodes}}
            or region_bytes,
            nodes,
        ) if region_bytes else 0.0
        return self._do_rebalance(nodes, region_bytes, imb, reason)

    def _do_rebalance(self, nodes, region_bytes, imb_before, reason) -> RebalanceEvent:
        new_alloc, moved = rebalance(self.placement.alloc, region_bytes, nodes)
        self.placement.alloc = new_alloc
        self.placement.nodes = tuple(nodes)
        imb_after = allocation_imbalance(new_alloc, region_bytes, nodes)
        self._last_rebalance = self.round_index
        ev = RebalanceEvent(
            round_index=self.round_index,
            reason=reason,
            moved_regions=moved,
            imbalance_before=imb_before,
            imbalance_after=imb_after,
        )
        self.events.append(ev)
        return ev
