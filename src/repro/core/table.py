"""TensorTable — an HBase-analogue columnar tensor store.

Follows HBase's simplified hierarchy from the paper (§2.1):

    Table -> Column family -> Column qualifier -> data

Each row has a unique ``rowkey`` (bytes; the paper uses the image file's unique
name).  Rows are kept **rowkey-sorted**, regions partition the keyspace, and a
split policy keeps region sizes bounded — exactly the structure the balancer
and the MapReduce engine rely on for locality.

The paper's recommended *table scheme* (§2.3) maps to: bulky tensor payloads in
one column family (e.g. ``img:data``) and small per-row indexes (age, sex,
file-size, ...) in a **separate** family (e.g. ``idx:age``), so predicates are
evaluated without touching the payloads (see :mod:`repro.core.query`).

Storage is host-side numpy (the mutable source of truth); device placement and
sharded layouts are produced by :mod:`repro.core.placement`.  Byte accounting
distinguishes *physical* bytes (what the arrays occupy here) from *logical*
bytes (the medical-image sizes the paper's time models consume), carried by the
``idx:size`` column when present — this is what lets the reproduction run the
paper's 77.4 GB workload on a laptop-scale container while keeping every time
model faithful.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.regions import (
    ConstantSizeSplitPolicy,
    Region,
    RegionSet,
    SplitPolicy,
)

RowKey = Union[bytes, str]

# The conventional families of the paper's proposed scheme.
DATA_FAMILY = "img"
INDEX_FAMILY = "idx"
SIZE_QUALIFIER = "size"


def _as_key(k: RowKey) -> bytes:
    return k.encode() if isinstance(k, str) else bytes(k)


@dataclasses.dataclass(frozen=True)
class ColumnSpec:
    """Schema of one column qualifier: fixed per-row shape and dtype."""

    qualifier: str
    shape: Tuple[int, ...] = ()
    dtype: np.dtype = dataclasses.field(default_factory=lambda: np.dtype(np.float32))

    def __post_init__(self):
        object.__setattr__(self, "shape", tuple(int(s) for s in self.shape))
        object.__setattr__(self, "dtype", np.dtype(self.dtype))

    @property
    def row_nbytes(self) -> int:
        return int(np.prod(self.shape, dtype=np.int64)) * self.dtype.itemsize


@dataclasses.dataclass(frozen=True)
class ColumnFamily:
    name: str
    columns: Tuple[ColumnSpec, ...]

    def spec(self, qualifier: str) -> ColumnSpec:
        for c in self.columns:
            if c.qualifier == qualifier:
                return c
        raise KeyError(f"unknown qualifier {self.name}:{qualifier}")


class TensorTable:
    """Rowkey-sorted columnar store with column families and regions."""

    def __init__(
        self,
        name: str,
        families: Sequence[ColumnFamily],
        split_policy: Optional[SplitPolicy] = None,
        presplit_keys: Optional[Sequence[RowKey]] = None,
    ):
        self.name = name
        self.families: Dict[str, ColumnFamily] = {f.name: f for f in families}
        if len(self.families) != len(families):
            raise ValueError("duplicate column family names")
        self.split_policy = split_policy or ConstantSizeSplitPolicy(1 << 62)
        self.regions = RegionSet(self.split_policy)
        if presplit_keys:
            self.regions.pre_split([_as_key(k) for k in presplit_keys])

        self._keys = np.empty((0,), dtype="S64")
        self._data: Dict[Tuple[str, str], np.ndarray] = {}
        for fam in families:
            for col in fam.columns:
                self._data[(fam.name, col.qualifier)] = np.empty(
                    (0,) + col.shape, dtype=col.dtype
                )
        # split events observed (parent, left, right) — consumed by Placement.
        self.split_log: List[Tuple[Region, Region, Region]] = []
        # bumped on every row-changing upload/delete; cheap cache-invalidation
        # signal for consumers holding positional indices (data pipeline).
        self.mutation_count = 0

    # ------------------------------------------------------------------
    # schema / introspection
    # ------------------------------------------------------------------

    @property
    def num_rows(self) -> int:
        return len(self._keys)

    @property
    def keys(self) -> np.ndarray:
        """Sorted rowkeys (read-only view)."""
        v = self._keys.view()
        v.flags.writeable = False
        return v

    def has_column(self, family: str, qualifier: str) -> bool:
        return (family, qualifier) in self._data

    def column(self, family: str, qualifier: str) -> np.ndarray:
        """Full column in row order (read-only view)."""
        v = self._data[(family, qualifier)].view()
        v.flags.writeable = False
        return v

    def column_spec(self, family: str, qualifier: str) -> ColumnSpec:
        return self.families[family].spec(qualifier)

    def physical_row_nbytes(self, families: Optional[Iterable[str]] = None) -> int:
        fams = self.families.keys() if families is None else families
        return sum(
            c.row_nbytes for f in fams for c in self.families[f].columns
        )

    def row_bytes(self) -> np.ndarray:
        """Per-row *logical* byte sizes.

        Uses the ``idx:size`` column when present (the paper's size index,
        which also feeds the hierarchical split policy); falls back to the
        physical row footprint otherwise.
        """
        if self.has_column(INDEX_FAMILY, SIZE_QUALIFIER):
            return self._data[(INDEX_FAMILY, SIZE_QUALIFIER)].astype(np.int64)
        # naive scheme: the size qualifier lives inside the payload family
        for fam in self.families:
            if self.has_column(fam, SIZE_QUALIFIER):
                return self._data[(fam, SIZE_QUALIFIER)].astype(np.int64)
        return np.full((self.num_rows,), self.physical_row_nbytes(), dtype=np.int64)

    def total_bytes(self) -> int:
        return int(self.row_bytes().sum()) if self.num_rows else 0

    # ------------------------------------------------------------------
    # selectors
    # ------------------------------------------------------------------

    def row_range(self, start: Optional[RowKey] = None,
                  stop: Optional[RowKey] = None) -> Tuple[int, int]:
        """Positional bounds ``(lo, hi)`` of the rowkey range ``[start, stop)``.

        The scan primitive every range consumer (selectors, queries, the
        GridQuery planner) shares: two binary searches over the sorted keys,
        never a linear walk.  ``hi`` is clamped so ``hi >= lo`` always.
        """
        lo = 0
        if start is not None:
            lo = int(np.searchsorted(self._keys, _as_key(start), side="left"))
        hi = len(self._keys)
        if stop is not None:
            hi = int(np.searchsorted(self._keys, _as_key(stop), side="left"))
        return lo, max(lo, hi)

    def existing_mask(self, rowkeys: Sequence[RowKey]) -> np.ndarray:
        """Bool per input key: is it already stored?  (The duplicate rule
        ``upload`` applies — shared so callers never re-derive it.)"""
        keys = np.array([_as_key(k) for k in rowkeys], dtype="S64")
        exists = np.zeros(len(keys), dtype=bool)
        pos = np.searchsorted(self._keys, keys, side="left")
        in_range = pos < len(self._keys)
        if in_range.any():
            exists[in_range] = self._keys[pos[in_range]] == keys[in_range]
        return exists

    def _select_positions(
        self,
        rowkey: Optional[RowKey] = None,
        start: Optional[RowKey] = None,
        stop: Optional[RowKey] = None,
        skip: Optional[Sequence[RowKey]] = None,
    ) -> np.ndarray:
        """Resolve the Table-1 selector set to positional row indices.

        ``rowkey`` selects one row; otherwise ``[start, stop)`` selects a
        range (whole table when both empty); ``skip`` removes listed keys —
        mirroring the Retrieve interface's skip-file.
        """
        if rowkey is not None:
            k = _as_key(rowkey)
            pos = int(np.searchsorted(self._keys, k, side="left"))
            if pos >= len(self._keys) or self._keys[pos] != k:
                return np.empty((0,), dtype=np.int64)
            idx = np.array([pos], dtype=np.int64)
        else:
            lo, hi = self.row_range(start, stop)
            idx = np.arange(lo, hi, dtype=np.int64)
        if skip:
            skip_keys = np.array(sorted({_as_key(k) for k in skip}), dtype=self._keys.dtype)
            mask = ~np.isin(self._keys[idx], skip_keys)
            idx = idx[mask]
        return idx

    # ------------------------------------------------------------------
    # Upload / Retrieve / Delete (Table 1 interface)
    # ------------------------------------------------------------------

    def upload(
        self,
        rowkeys: Sequence[RowKey],
        data: Mapping[str, Mapping[str, np.ndarray]],
        overwrite: bool = False,
        on_duplicate: Optional[str] = None,
    ) -> int:
        """Insert (or update) a batch of rows.

        ``data[family][qualifier]`` is an array of shape ``(len(rowkeys),
        *spec.shape)``.  Every declared column must be provided — the store is
        columnar and dense.  Returns the number of rows written.

        Duplicate handling is uniform per row and independent of batch order
        or rowkey sort order.  A rowkey that appears twice *within* one batch
        always raises.  A rowkey already present in the table (uploaded by an
        earlier call) is governed by ``on_duplicate``:

        - ``"skip"`` (default): keep the stored row, don't write it — the
          interface's "avoid uploading duplicate data"; skipped rows do not
          count toward the return value;
        - ``"overwrite"``: replace the stored row with this batch's values;
        - ``"error"``: raise ``KeyError`` naming the duplicates, writing
          nothing.

        ``overwrite=True`` is the legacy spelling of
        ``on_duplicate="overwrite"``.
        """
        if on_duplicate is None:
            on_duplicate = "overwrite" if overwrite else "skip"
        if on_duplicate not in ("skip", "overwrite", "error"):
            raise ValueError(f"unknown on_duplicate mode {on_duplicate!r}")
        if not len(rowkeys):
            return 0
        new_keys = np.array([_as_key(k) for k in rowkeys], dtype="S64")
        if len(np.unique(new_keys)) != len(new_keys):
            raise ValueError("duplicate rowkeys within one upload batch")

        # validate payloads against the schema
        arrays: Dict[Tuple[str, str], np.ndarray] = {}
        for fam in self.families.values():
            fam_data = data.get(fam.name)
            if fam_data is None:
                raise ValueError(f"missing column family {fam.name!r} in upload")
            for col in fam.columns:
                if col.qualifier not in fam_data:
                    raise ValueError(f"missing column {fam.name}:{col.qualifier}")
                arr = np.asarray(fam_data[col.qualifier], dtype=col.dtype)
                want = (len(new_keys),) + col.shape
                if arr.shape != want:
                    raise ValueError(
                        f"{fam.name}:{col.qualifier} shape {arr.shape} != {want}"
                    )
                arrays[(fam.name, col.qualifier)] = arr

        # split batch into updates (existing keys) and inserts
        pos = np.searchsorted(self._keys, new_keys, side="left")
        exists = self.existing_mask(rowkeys)

        written = 0
        if exists.any():
            if on_duplicate == "error":
                dups = [k.decode(errors="replace") for k in new_keys[exists]]
                raise KeyError(f"rowkeys already uploaded: {dups}")
            if on_duplicate == "overwrite":
                upd = np.nonzero(exists)[0]
                tgt = pos[upd]
                for kq, arr in arrays.items():
                    self._data[kq][tgt] = arr[upd]
                written += len(upd)
            # else "skip": keep the stored rows (interface semantics)

        ins = np.nonzero(~exists)[0]
        if len(ins):
            ins_keys = new_keys[ins]
            order = np.argsort(ins_keys, kind="stable")
            ins_keys = ins_keys[order]
            ins_pos = np.searchsorted(self._keys, ins_keys, side="left")
            self._keys = np.insert(self._keys, ins_pos, ins_keys)
            for kq, arr in arrays.items():
                self._data[kq] = np.insert(
                    self._data[kq], ins_pos, arr[ins][order], axis=0
                )
            written += len(ins)

        events = self.regions.maybe_split(self._keys, self.row_bytes())
        self.split_log.extend(events)
        if written:
            self.mutation_count += 1
        return written

    def select_keys(
        self,
        rowkey: Optional[RowKey] = None,
        start: Optional[RowKey] = None,
        stop: Optional[RowKey] = None,
        skip: Optional[Sequence[RowKey]] = None,
    ) -> np.ndarray:
        """Rowkeys matching the Table-1 selector (copy, sorted order)."""
        return self._keys[self._select_positions(rowkey, start, stop, skip)].copy()

    def retrieve(
        self,
        family: str,
        qualifier: str,
        rowkey: Optional[RowKey] = None,
        start: Optional[RowKey] = None,
        stop: Optional[RowKey] = None,
        skip: Optional[Sequence[RowKey]] = None,
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Return ``(rowkeys, values)`` for the selector (§Table 1 Retrieve)."""
        idx = self._select_positions(rowkey, start, stop, skip)
        col = self._data[(family, qualifier)]
        return self._keys[idx].copy(), col[idx].copy()

    def delete(
        self,
        rowkey: Optional[RowKey] = None,
        start: Optional[RowKey] = None,
        stop: Optional[RowKey] = None,
        skip: Optional[Sequence[RowKey]] = None,
    ) -> int:
        """Delete whole rows matching the selector; returns rows removed.

        (HBase deletes cells; ColoGrid's columns are dense fixed-shape
        tensors, so row granularity is the faithful unit here.)
        """
        idx = self._select_positions(rowkey, start, stop, skip)
        if not len(idx):
            return 0
        keep = np.ones(self.num_rows, dtype=bool)
        keep[idx] = False
        self._keys = self._keys[keep]
        for kq in self._data:
            self._data[kq] = self._data[kq][keep]
        self.mutation_count += 1
        return int((~keep).sum())

    # ------------------------------------------------------------------
    # region helpers
    # ------------------------------------------------------------------

    def region_rows(self, region: Region) -> slice:
        return region.row_slice(self._keys)

    def region_positions(self, region: Region) -> np.ndarray:
        """Current positional row indices of a region (ascending)."""
        s = region.row_slice(self._keys)
        return np.arange(s.start, s.stop, dtype=np.int64)

    def region_column(self, region: Region, family: str,
                      qualifier: str) -> np.ndarray:
        """A private copy of one region's rows of one column — the BlockStore
        gather primitive.  A copy (not a view) because block content must
        survive later mutations that shift the backing arrays; any mutation
        to *this* region's rows invalidates the block by version instead."""
        s = region.row_slice(self._keys)
        return self._data[(family, qualifier)][s.start:s.stop].copy()

    def region_bytes(self) -> Dict[int, int]:
        rb = self.row_bytes()
        return {r.rid: r.num_bytes(self._keys, rb) for r in self.regions}

    def region_row_counts(self) -> Dict[int, int]:
        return {r.rid: r.num_rows(self._keys) for r in self.regions}

    def check_invariants(self) -> None:
        assert np.all(self._keys[:-1] < self._keys[1:]), "rowkeys must be strictly sorted"
        for kq, arr in self._data.items():
            assert arr.shape[0] == self.num_rows, f"column {kq} row count mismatch"
        self.regions.check_invariants()
        # regions must tile all rows exactly
        total = sum(r.num_rows(self._keys) for r in self.regions)
        assert total == self.num_rows


def make_mip_table(
    name: str = "mip",
    payload_shape: Tuple[int, ...] = (32, 32, 32),
    payload_dtype: np.dtype = np.float32,
    extra_index_columns: Sequence[ColumnSpec] = (),
    split_policy: Optional[SplitPolicy] = None,
    presplit_keys: Optional[Sequence[RowKey]] = None,
) -> TensorTable:
    """The paper's proposed scheme: ``img:data`` + separate ``idx`` family.

    ``idx`` always carries the ``size`` column (bytes; drives the hierarchical
    split policy) plus any study covariates (age, sex, ...).
    """
    idx_cols = [ColumnSpec(SIZE_QUALIFIER, (), np.int64)] + list(extra_index_columns)
    fams = [
        ColumnFamily(DATA_FAMILY, (ColumnSpec("data", payload_shape, payload_dtype),)),
        ColumnFamily(INDEX_FAMILY, tuple(idx_cols)),
    ]
    return TensorTable(name, fams, split_policy=split_policy, presplit_keys=presplit_keys)


def make_naive_table(
    name: str = "mip_naive",
    payload_shape: Tuple[int, ...] = (32, 32, 32),
    payload_dtype: np.dtype = np.float32,
    extra_index_columns: Sequence[ColumnSpec] = (),
    split_policy: Optional[SplitPolicy] = None,
) -> TensorTable:
    """The naïve scheme of §2.4.4: everything in ONE column family.

    Index qualifiers live next to the payload, so any index scan drags the
    image bytes through the read path (see :func:`repro.core.query.naive_query`).
    """
    cols = [
        ColumnSpec("data", payload_shape, payload_dtype),
        ColumnSpec(SIZE_QUALIFIER, (), np.int64),
    ] + list(extra_index_columns)
    fams = [ColumnFamily(DATA_FAMILY, tuple(cols))]
    return TensorTable(name, fams, split_policy=split_policy)
