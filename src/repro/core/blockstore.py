"""BlockStore — content-addressed, copy-on-write device blocks per region.

The paper's core claim is data *colocation*: computation moves to where the
image blocks already live, so mutations and repeated queries must not re-ship
or re-pad data that did not change.  Before this module, the session's caches
worked at two coarser granularities and paid for it twice:

- whole-table layouts were re-``device_put`` monolithically after every
  mutation (clean devices' payload re-crossed the host↔device boundary), and
- pruned-scan plans each gathered their own private copy of the selected
  regions, so two overlapping scans shipped the shared regions twice.

The missing abstraction is a **block**: one region's rows of one column,
materialized once on the device that owns the region.  Blocks are

- **content-addressed** — keyed by ``(region signature, column, version)``
  where the *version* is the mutation epoch that last touched the region
  (its epoch-lineage).  A key never maps to two different payloads;
- **copy-on-write** — a mutation never edits a block in place.  It bumps the
  touched regions' versions (:meth:`BlockStore.touch`), so the next request
  under the new key gathers a fresh block while live consumers (cached scan
  plans, assembled layouts) keep their references to the old object;
- **shared** — every consumer (whole-table layouts across epochs, pruned
  scans across overlapping plans) asks the store first, so a block crosses
  the host→device boundary once per (content, owner device), not once per
  plan or per epoch.

Stacked on the payload blocks is the **partial cache**: each block's
MapReduce fold result (one tiny accumulator pytree), keyed ``(block
lineage, program, row-mask signature, η)``.  Content addressing carries
over — a mutation's version bump invalidates a block's partials with it,
while every other partial survives to be *merged* instead of re-folded.
This is what makes a repeat query fold zero payload rows.

The store is storage + versioning only: *gathering* a block from the table,
choosing its owner device, and *folding* partials stay with
:class:`~repro.core.grid.GridSession` / the engine, which own placement and
compute.  Capacity is bounded by :class:`LRUCache` instances; an evicted
block is simply re-gathered — and an evicted partial re-folded — on next
use (regression tests assert re-materialization is loss-free).

Since the :class:`~repro.core.frontend.GridFrontend` serves queries from a
thread pool, the store is safe under **concurrent readers with serialized
mutators**: every cache is a locked :class:`LRUCache` whose iterating
helpers return point-in-time lists, compound operations (fetch, partial
index maintenance, touch/drop) run under one store-level re-entrant lock,
and the cumulative counters are an :class:`AtomicStats` whose ``inc`` is
lock-protected and whose ``snapshot()`` gives a consistent point-in-time
copy for benches and tests.
"""

from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.core.regions import Region

#: (region signature, family, qualifier, version) — the content address.
BlockKey = Tuple[Tuple[int, bytes, Optional[bytes]], str, str, int]


class AtomicStats:
    """Lock-protected counter mixin for the cumulative stats dataclasses.

    Bare ``+=`` on a shared dataclass field is a read-modify-write race
    under concurrent readers (two threads both load N, both store N+1, one
    update is lost); every writer goes through :meth:`inc` instead, and
    readers that need a *consistent* multi-field view (benches summing
    hits+misses, tests asserting exact fold counts) take :meth:`snapshot`.
    Direct attribute reads stay valid for single-counter checks.
    """

    def __post_init__(self):
        object.__setattr__(self, "_lock", threading.Lock())

    def inc(self, **deltas: int) -> None:
        """Atomically add each ``field=delta`` (a single lock for the whole
        batch, so multi-counter updates can't be observed half-applied)."""
        with self._lock:
            for name, d in deltas.items():
                setattr(self, name, getattr(self, name) + d)

    def imax(self, **values: int) -> None:
        """Atomically raise each ``field`` to ``max(current, value)`` —
        the monotone update behind high-water marks (peak queue depth)."""
        with self._lock:
            for name, v in values.items():
                if v > getattr(self, name):
                    setattr(self, name, v)

    def snapshot(self) -> "AtomicStats":
        """A point-in-time copy (its own lock, detached from the live
        counters) — the consistent read side of :meth:`inc`."""
        with self._lock:
            fields = {f.name: getattr(self, f.name)
                      for f in dataclasses.fields(self)}
        return type(self)(**fields)


class LRUCache:
    """A small bounded mapping with least-recently-used eviction.

    Shared by every cache this backend keeps per session — device blocks,
    bound scan plans, compiled executables — so long-lived mutating sessions
    stay memory-bounded.  ``get`` refreshes recency; ``put`` evicts the
    coldest entries beyond ``cap`` and reports them to ``on_evict`` (used to
    count evictions and, for blocks, to observe re-materialization in tests).

    Thread-safe: every operation holds an internal re-entrant lock (``get``
    mutates recency order, so even reads are writes here), and the iterating
    helpers ``keys``/``values``/``items`` return **point-in-time lists** — a
    reader walking entries while another thread inserts must never trip
    ``RuntimeError: dict changed size during iteration``.
    """

    def __init__(self, cap: int,
                 on_evict: Optional[Callable[[Any, Any], None]] = None):
        if cap <= 0:
            raise ValueError(f"LRU cap must be positive, got {cap}")
        self.cap = int(cap)
        self._d: "OrderedDict[Any, Any]" = OrderedDict()
        self._on_evict = on_evict
        self._lock = threading.RLock()
        self.evictions = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._d)

    def __contains__(self, key) -> bool:
        with self._lock:
            return key in self._d

    def get(self, key, default=None):
        with self._lock:
            if key not in self._d:
                return default
            self._d.move_to_end(key)
            return self._d[key]

    def peek(self, key, default=None):
        """Read without refreshing recency (diagnostics / identity tests)."""
        with self._lock:
            return self._d.get(key, default)

    def put(self, key, value) -> None:
        with self._lock:
            self._d[key] = value
            self._d.move_to_end(key)
            while len(self._d) > self.cap:
                k, v = self._d.popitem(last=False)
                self.evictions += 1
                if self._on_evict is not None:
                    self._on_evict(k, v)

    def pop(self, key, default=None):
        with self._lock:
            return self._d.pop(key, default)

    def clear(self) -> None:
        with self._lock:
            self._d.clear()

    def keys(self) -> List[Any]:
        with self._lock:
            return list(self._d.keys())

    def values(self) -> List[Any]:
        with self._lock:
            return list(self._d.values())

    def items(self) -> List[Tuple[Any, Any]]:
        with self._lock:
            return list(self._d.items())


@dataclasses.dataclass
class DeviceBlock:
    """One region's rows of one column, resident on the owning device.

    ``host`` is a private copy of the region's column rows (positions inside
    the table may shift under unrelated mutations; content cannot — any
    mutation to *this* region bumps its version and a new block is born).
    ``device`` is the committed on-device copy (``None`` while host-only,
    e.g. on meshes where per-shard placement is unavailable);
    ``device_index`` records which mesh shard it was committed to, so a
    rebalance that moves the region re-ships the block without re-reading
    the table.
    """

    rid: int
    family: str
    qualifier: str
    version: int
    rows: int                      # logical rows (the region's real rows)
    nbytes: int                    # logical host bytes (unpadded)
    host: np.ndarray
    device: Any = None             # jax.Array committed to the owner shard
    device_index: Optional[int] = None
    # physical bytes of the committed device copy (0 while host-only) —
    # larger than ``nbytes`` when the session commits blocks pre-padded to
    # the fold bucket; transfer/residency oracles report THIS, not the
    # logical size
    device_nbytes: int = 0


@dataclasses.dataclass
class BlockStoreStats(AtomicStats):
    """Cumulative store counters (session lifetime).  Evictions are not
    duplicated here — the LRU already counts them; read
    :attr:`BlockStore.evictions`.

    Updates go through :meth:`AtomicStats.inc` (concurrent queries bump
    these from many threads); consistent multi-counter reads through
    :meth:`AtomicStats.snapshot`."""

    gathers: int = 0        # host payloads read from the table (store misses)
    transfers: int = 0      # host→device block transfers (device_put calls)
    hits: int = 0           # requests served by a resident current block
    touches: int = 0        # region versions bumped by mutations
    host_reads: int = 0     # host-only fetches that re-read the table
    partial_hits: int = 0   # per-block fold partials served from the cache
    folds: int = 0          # per-block fold partials computed and stored
    gid_hits: int = 0       # per-region gid blocks served from the cache
    gid_builds: int = 0     # gid blocks densified (searchsorted) and stored


class BlockStore:
    """Versioned LRU of :class:`DeviceBlock`, the substrate under layouts.

    One instance per :class:`~repro.core.grid.GridSession`.  The session
    funnels every block request through :meth:`fetch`, which classifies the
    outcome for the ``QueryStats`` oracles:

    - *reused*      — current version resident on the current owner device;
    - *transferred* — host payload was shipped to a device (either because
      the block was freshly gathered, or because a rebalance moved the
      region so the cached host copy re-commits to its new owner);
    - *gathered*    — the host payload itself had to be (re-)read from the
      table (a store miss for this content version).

    Every fetched block satisfies ``reused or transferred`` — which is the
    testable invariant ``blocks_reused + blocks_transferred == blocks_total``
    carried on ``QueryStats``.
    """

    def __init__(self, cap: int = 256, partial_cap: int = 1024):
        self.stats = BlockStoreStats()
        # one re-entrant lock serializes every compound cache operation
        # (fetch's get-then-put, the partial index maintenance, touch/drop
        # sweeps); individual LRUCache ops are locked on their own, but the
        # invariants here span several of them
        self._lock = threading.RLock()
        self._blocks: LRUCache = LRUCache(cap)
        # per-block fold partials, keyed (BlockKey, program, mask sig, eta):
        # the compute-side cache that lets a repeat query fold zero rows.
        # Partials are tiny (one accumulator pytree per block), so their cap
        # is several times the block cap; an evicted partial just re-folds.
        self._partials: LRUCache = LRUCache(
            partial_cap, on_evict=lambda k, v: self._unindex_partial(k))
        # (rid, version) -> live partial count: keeps has_partials O(1)
        # (it runs once per surviving region on every cold selective scan)
        self._partial_index: Dict[Tuple[int, int], int] = {}
        # densified per-region gid blocks keyed (key-column block lineage,
        # mapping signature): a dirty-region re-fold touches OTHER regions'
        # partials but still needs THIS region's gids — caching them skips
        # the np.searchsorted re-densification on every such fold.  Tiny
        # (int32 per row), so a few hundred entries cost ~nothing.
        self._gids: LRUCache = LRUCache(512)
        # region id -> mutation epoch that last changed its content
        self._versions: Dict[int, int] = {}

    @property
    def evictions(self) -> int:
        """Blocks dropped by the LRU cap (counted once, by the LRU)."""
        return self._blocks.evictions

    # ------------------------------------------------------------------
    # epoch lineage
    # ------------------------------------------------------------------

    def version_of(self, rid: int) -> int:
        """The region's content version: the epoch of its last mutation
        (0 for regions never touched since the session opened)."""
        return self._versions.get(rid, 0)

    def touch(self, rids: Iterable[int], epoch: int) -> None:
        """Copy-on-write bump: mutated regions move to version ``epoch``.

        Superseded cache entries are dropped eagerly (they can never hit
        again); block objects stay alive wherever consumers still hold them.
        """
        with self._lock:
            touched = {int(rid) for rid in rids}
            for rid in touched:
                self._versions[rid] = int(epoch)
            self.stats.inc(touches=len(touched))
            doomed = [k for k in self._blocks.keys()
                      if k[0][0] in touched
                      and k[3] != self._versions[k[0][0]]]
            for k in doomed:
                self._blocks.pop(k)
            # superseded fold partials are as dead as their blocks: the
            # partial key embeds the block version, so they can never hit
            # again
            doomed_p = [k for k in self._partials.keys()
                        if k[0][0][0] in touched
                        and k[0][3] != self._versions[k[0][0][0]]]
            for k in doomed_p:
                self._pop_partial(k)
            # superseded gid blocks die with their key-column block lineage
            doomed_g = [k for k in self._gids.keys()
                        if k[0][0][0] in touched
                        and k[0][3] != self._versions[k[0][0][0]]]
            for k in doomed_g:
                self._gids.pop(k)

    def drop_regions(self, rids: Iterable[int]) -> None:
        """Forget regions that no longer exist (split parents): their rids
        never reappear in the region set, so their blocks could otherwise
        pin host+device payload until cap pressure that may never come."""
        doomed_rids = {int(rid) for rid in rids}
        if not doomed_rids:
            return
        with self._lock:
            for k in [k for k in self._blocks.keys()
                      if k[0][0] in doomed_rids]:
                self._blocks.pop(k)
            for k in [k for k in self._partials.keys()
                      if k[0][0][0] in doomed_rids]:
                self._pop_partial(k)
            for k in [k for k in self._gids.keys()
                      if k[0][0][0] in doomed_rids]:
                self._gids.pop(k)
            for rid in doomed_rids:
                self._versions.pop(rid, None)

    def lineage(self, regions: Iterable[Region]) -> Tuple[Tuple[int, int], ...]:
        """``((rid, version), ...)`` — the epoch-lineage signature of a
        region set.  Two plans over the same regions at the same versions may
        share everything; any difference forces a re-bind."""
        return tuple((r.rid, self.version_of(r.rid)) for r in regions)

    # ------------------------------------------------------------------
    # block access
    # ------------------------------------------------------------------

    def key_of(self, region: Region, family: str, qualifier: str) -> BlockKey:
        return (region.signature, family, qualifier,
                self.version_of(region.rid))

    def peek(self, region: Region, family: str,
             qualifier: str) -> Optional[DeviceBlock]:
        """Current-version block without touching recency (identity tests)."""
        return self._blocks.peek(self.key_of(region, family, qualifier))

    def fetch(
        self,
        region: Region,
        family: str,
        qualifier: str,
        owner_index: Optional[int],
        gather_host: Callable[[], np.ndarray],
        to_device: Optional[Callable[[np.ndarray, Optional[int]], Any]],
    ) -> Tuple[DeviceBlock, bool, bool]:
        """Return ``(block, reused, gathered)`` for the current version.

        ``gather_host`` reads the region's column rows from the table (called
        only on a content miss).  ``to_device`` commits a host payload to the
        shard ``owner_index`` (``None`` disables device residency — the
        host-assembly fallback for meshes without per-shard placement).
        ``reused`` means no host→device transfer happened; ``gathered`` means
        the table was re-read.  ``not reused`` implies a transfer, so every
        fetch is exactly one of reused / transferred.
        """
        with self._lock:
            key = self.key_of(region, family, qualifier)
            blk = self._blocks.get(key)
            gathered = False
            if blk is None:
                host = np.ascontiguousarray(gather_host())
                host.flags.writeable = False
                blk = DeviceBlock(
                    rid=region.rid, family=family, qualifier=qualifier,
                    version=key[3], rows=int(host.shape[0]),
                    nbytes=int(host.nbytes), host=host,
                )
                gathered = True
                self.stats.inc(gathers=1)
            if to_device is None:
                # host-only fallback: every layout build re-ships the whole
                # assembled array, so no block is ever device-"reused" — a
                # content hit only avoids the table re-read.  Classifying
                # each fetch as transferred keeps payload_bytes_transferred
                # honest about what actually crosses host→device here.
                if gathered:
                    self._blocks.put(key, blk)
                else:
                    self.stats.inc(hits=1)
                self.stats.inc(transfers=1)
                return blk, False, gathered

            if blk.device is not None and blk.device_index == owner_index:
                self.stats.inc(hits=1)
                return blk, True, False
            # fresh gather, or a rebalance moved the region: (re-)commit the
            # host copy to its current owner.  COW: a re-homed cached block
            # is replaced, not mutated — older consumers keep the old one.
            if blk.device is not None:
                blk = dataclasses.replace(blk)
            blk.device = to_device(blk.host, owner_index)
            blk.device_index = owner_index
            blk.device_nbytes = int(getattr(blk.device, "nbytes", blk.nbytes))
            self.stats.inc(transfers=1)
            self._blocks.put(key, blk)
            return blk, False, gathered

    def fetch_host(
        self,
        region: Region,
        family: str,
        qualifier: str,
        gather_host: Callable[[], np.ndarray],
    ) -> Tuple[DeviceBlock, bool]:
        """Current-version host payload WITHOUT device commitment — the
        retrieve path.  Returns ``(block, gathered)``; a later :meth:`fetch`
        for the fold path commits the same block to its owner device, so
        retrieve-heavy workloads and folds share one gather per content.
        """
        with self._lock:
            key = self.key_of(region, family, qualifier)
            blk = self._blocks.get(key)
            if blk is not None:
                self.stats.inc(hits=1)
                return blk, False
            host = np.ascontiguousarray(gather_host())
            host.flags.writeable = False
            blk = DeviceBlock(
                rid=region.rid, family=family, qualifier=qualifier,
                version=key[3], rows=int(host.shape[0]),
                nbytes=int(host.nbytes), host=host,
            )
            self.stats.inc(gathers=1, host_reads=1)
            self._blocks.put(key, blk)
            return blk, True

    # ------------------------------------------------------------------
    # fold partials (the compute-side cache of the block-granular engine)
    # ------------------------------------------------------------------

    def partial_key(self, region: Region, family: str, qualifier: str,
                    program_key: Tuple, mask_sig: str, eta: int,
                    group_sig: str = "", impl: str = "") -> Tuple:
        """The content address of one block's fold partial: block lineage
        (signature + version) × program × row-mask signature × η × group-key
        signature × fold implementation.  Any mutation to the region bumps
        the embedded version; any change to the selected-row subset changes
        ``mask_sig`` — either way the key becomes unmatchable and the
        partial re-folds.

        ``group_sig`` (grouped plans only) signs the group column AND the
        global value→group-id mapping: a block's group-keyed partial is
        only valid under the exact mapping it was folded with, since gid
        assignment depends on which key values the whole selection
        contains.  Ungrouped partials keep ``""``.

        ``impl`` distinguishes fold implementations whose partials agree
        only up to float accumulation order (the fused Pallas kernel vs
        the XLA scan): flipping ``engine.fold_impl`` mid-session must not
        merge partials folded under different orders.  The XLA path keeps
        ``""``, so existing keys are unchanged.
        """
        return (self.key_of(region, family, qualifier),
                program_key, mask_sig, int(eta), group_sig, impl)

    @staticmethod
    def _partial_rid_version(key: Tuple) -> Tuple[int, int]:
        return key[0][0][0], key[0][3]

    def _unindex_partial(self, key: Tuple) -> None:
        with self._lock:
            k = self._partial_rid_version(key)
            n = self._partial_index.get(k, 0) - 1
            if n <= 0:
                self._partial_index.pop(k, None)
            else:
                self._partial_index[k] = n

    def _pop_partial(self, key: Tuple) -> None:
        with self._lock:
            if self._partials.pop(key) is not None:
                self._unindex_partial(key)

    def get_partial(self, key: Tuple):
        p = self._partials.get(key)
        if p is not None:
            self.stats.inc(partial_hits=1)
        return p

    def put_partial(self, key: Tuple, value) -> None:
        with self._lock:
            self.stats.inc(folds=1)
            if key not in self._partials:
                k = self._partial_rid_version(key)
                self._partial_index[k] = self._partial_index.get(k, 0) + 1
            self._partials.put(key, value)

    def has_partials(self, rid: int) -> bool:
        """Any cached partial for the region's current content (a reuse
        signal the adaptive gather consults before going compact)."""
        return (rid, self.version_of(rid)) in self._partial_index

    # ------------------------------------------------------------------
    # gid blocks (densified group ids per region × mapping)
    # ------------------------------------------------------------------

    def gid_key(self, region: Region, family: str, qualifier: str,
                group_sig: str) -> Tuple:
        """Content address of one region's densified gid block: the KEY
        column's block lineage × the global value→gid mapping signature.
        A mutation to the region bumps the embedded version; a selection
        whose value universe differs carries another ``group_sig`` —
        either way the stale gids can never be served again."""
        return (self.key_of(region, family, qualifier), group_sig)

    def get_gids(self, region: Region, family: str, qualifier: str,
                 group_sig: str) -> Optional[np.ndarray]:
        g = self._gids.get(self.gid_key(region, family, qualifier,
                                        group_sig))
        if g is not None:
            self.stats.inc(gid_hits=1)
        return g

    def put_gids(self, region: Region, family: str, qualifier: str,
                 group_sig: str, gids: np.ndarray) -> None:
        self.stats.inc(gid_builds=1)
        g = np.ascontiguousarray(gids, dtype=np.int32)
        g.flags.writeable = False
        self._gids.put(self.gid_key(region, family, qualifier, group_sig), g)

    @property
    def gid_count(self) -> int:
        return len(self._gids)

    def clear_partials(self) -> None:
        with self._lock:
            self._partials.clear()
            self._partial_index.clear()
            self._gids.clear()

    def clear(self) -> None:
        """Drop every cached block AND partial (versions survive, so
        content addressing stays monotonic); consumers re-gather and
        re-fold losslessly on next use.  Benchmarks use this to time the
        cold-data regime without rebuilding sessions."""
        with self._lock:
            self._blocks.clear()
            self.clear_partials()

    @property
    def partial_count(self) -> int:
        return len(self._partials)

    # ------------------------------------------------------------------
    # diagnostics
    # ------------------------------------------------------------------

    @property
    def cap(self) -> int:
        return self._blocks.cap

    def __len__(self) -> int:
        return len(self._blocks)

    def resident_nbytes(self) -> int:
        """Physical bytes the store pins: host copies plus committed device
        copies (which may be fold-bucket padded beyond the logical size)."""
        return sum(b.nbytes + b.device_nbytes for b in self._blocks.values())

    def describe(self) -> str:
        s = self.stats
        return (f"BlockStore({len(self)}/{self.cap} blocks, "
                f"{self.resident_nbytes()} bytes; {s.hits} hits, "
                f"{s.gathers} gathers, {s.transfers} transfers, "
                f"{self.evictions} evictions; "
                f"{self.partial_count} partials, {s.partial_hits} partial "
                f"hits, {s.folds} folds)")
