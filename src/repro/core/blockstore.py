"""BlockStore — content-addressed, copy-on-write device blocks per region.

The paper's core claim is data *colocation*: computation moves to where the
image blocks already live, so mutations and repeated queries must not re-ship
or re-pad data that did not change.  Before this module, the session's caches
worked at two coarser granularities and paid for it twice:

- whole-table layouts were re-``device_put`` monolithically after every
  mutation (clean devices' payload re-crossed the host↔device boundary), and
- pruned-scan plans each gathered their own private copy of the selected
  regions, so two overlapping scans shipped the shared regions twice.

The missing abstraction is a **block**: one region's rows of one column,
materialized once on the device that owns the region.  Blocks are

- **content-addressed** — keyed by ``(region signature, column, version)``
  where the *version* is the mutation epoch that last touched the region
  (its epoch-lineage).  A key never maps to two different payloads;
- **copy-on-write** — a mutation never edits a block in place.  It bumps the
  touched regions' versions (:meth:`BlockStore.touch`), so the next request
  under the new key gathers a fresh block while live consumers (cached scan
  plans, assembled layouts) keep their references to the old object;
- **shared** — every consumer (whole-table layouts across epochs, pruned
  scans across overlapping plans) asks the store first, so a block crosses
  the host→device boundary once per (content, owner device), not once per
  plan or per epoch.

Stacked on the payload blocks is the **partial cache**: each block's
MapReduce fold result (one tiny accumulator pytree), keyed ``(block
lineage, program, row-mask signature, η)``.  Content addressing carries
over — a mutation's version bump invalidates a block's partials with it,
while every other partial survives to be *merged* instead of re-folded.
This is what makes a repeat query fold zero payload rows.

Capacity is a **tier chain**, not a flat cap: device HBM → host RAM → disk
(mmap'd ``.npy`` files under a session spill dir), each tier bounded by a
byte budget (``None`` = unbounded, the pre-tiering behavior).  Under
pressure the coldest payload *demotes* one tier instead of vanishing —

- device over budget: the device copy is dropped (the host copy, pulled
  back from the device first if it was the only one, stays);
- host over budget: the host copy spills to an ``.npy`` file when the
  :class:`~repro.core.chunk_model.TierCostModel` oracle says a local disk
  read beats re-fetching from the backing table, else it is dropped;
- disk over budget: the coldest spill file is deleted (the table remains
  the source of truth, so every demotion is loss-free);

and reads *promote* transparently: a fetch finds the highest tier holding
the content, re-materializing host views from spill files via
``np.load(mmap_mode="r")`` (the mmap is charged to the disk tier — it pins
no RAM).  Evicted **partials demote too**: instead of silently re-folding
on next use, an evicted partial is flattened to host leaves and written
beside the blocks when the oracle prefers a disk round-trip to a re-fold.
A background **prefetcher** overlaps ``device_put`` of next-needed
lower-tier blocks with in-flight folds; its fetch classification is
recorded and *claimed* by the next query's own fetch, so per-query
transfer/gather oracles stay exact.

The store is storage + versioning only: *gathering* a block from the table,
choosing its owner device, and *folding* partials stay with
:class:`~repro.core.grid.GridSession` / the engine, which own placement and
compute.  An entry evicted out of every tier is simply re-gathered — and a
lost partial re-folded — on next use (regression tests assert
re-materialization is loss-free).

Since the :class:`~repro.core.frontend.GridFrontend` serves queries from a
thread pool, the store is safe under **concurrent readers with serialized
mutators**: every cache is a locked :class:`LRUCache` whose iterating
helpers return point-in-time lists, compound operations (fetch, partial
index maintenance, touch/drop, tier enforcement) run under one store-level
re-entrant lock, and the cumulative counters are an :class:`AtomicStats`
whose ``inc`` is lock-protected and whose ``snapshot()`` gives a
consistent point-in-time copy for benches and tests.
"""

from __future__ import annotations

import atexit
import dataclasses
import os
import shutil
import threading
import zlib
from collections import OrderedDict
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable, Dict, Iterable, List, Optional, Set, Tuple

import numpy as np

from repro.core.chunk_model import TierCostModel
from repro.core.faults import (FaultInjector, RetryPolicy,
                               SpillCorruptionError)
from repro.core.regions import Region

#: (region signature, family, qualifier, version) — the content address.
BlockKey = Tuple[Tuple[int, bytes, Optional[bytes]], str, str, int]

_MISSING = object()


def _unlink(path: Optional[str]) -> None:
    if not path:
        return
    try:
        os.unlink(path)
    except OSError:
        pass


def _sidecar(path: str) -> str:
    """The CRC manifest that travels with every spill file."""
    return path + ".crc"


def _unlink_spill(path: Optional[str]) -> None:
    """Delete a spill payload together with its CRC sidecar."""
    if not path:
        return
    _unlink(path)
    _unlink(_sidecar(path))


def _crc_file(path: str) -> int:
    """CRC-32 of a file's bytes, streamed (spill files can be large)."""
    crc = 0
    with open(path, "rb") as f:
        for buf in iter(lambda: f.read(1 << 20), b""):
            crc = zlib.crc32(buf, crc)
    return crc & 0xFFFFFFFF


def _payload_nbytes(value: Any) -> int:
    """Total array bytes in a (possibly nested) accumulator pytree — the
    weigher behind the partial cache's byte budget.  Works on numpy and
    jax leaves (both expose ``nbytes``) without importing jax."""
    nb = getattr(value, "nbytes", None)
    if nb is not None:
        return int(nb)
    if isinstance(value, dict):
        return sum(_payload_nbytes(v) for v in value.values())
    if isinstance(value, (tuple, list)):
        return sum(_payload_nbytes(v) for v in value)
    return 0


class AtomicStats:
    """Lock-protected counter mixin for the cumulative stats dataclasses.

    Bare ``+=`` on a shared dataclass field is a read-modify-write race
    under concurrent readers (two threads both load N, both store N+1, one
    update is lost); every writer goes through :meth:`inc` instead, and
    readers that need a *consistent* multi-field view (benches summing
    hits+misses, tests asserting exact fold counts) take :meth:`snapshot`.
    Direct attribute reads stay valid for single-counter checks.
    """

    def __post_init__(self):
        object.__setattr__(self, "_lock", threading.Lock())

    def inc(self, **deltas: int) -> None:
        """Atomically add each ``field=delta`` (a single lock for the whole
        batch, so multi-counter updates can't be observed half-applied)."""
        with self._lock:
            for name, d in deltas.items():
                setattr(self, name, getattr(self, name) + d)

    def imax(self, **values: int) -> None:
        """Atomically raise each ``field`` to ``max(current, value)`` —
        the monotone update behind high-water marks (peak queue depth)."""
        with self._lock:
            for name, v in values.items():
                if v > getattr(self, name):
                    setattr(self, name, v)

    def snapshot(self) -> "AtomicStats":
        """A point-in-time copy (its own lock, detached from the live
        counters) — the consistent read side of :meth:`inc`."""
        with self._lock:
            fields = {f.name: getattr(self, f.name)
                      for f in dataclasses.fields(self)}
        return type(self)(**fields)


class LRUCache:
    """A bounded mapping with least-recently-used eviction.

    Shared by every cache this backend keeps per session — device blocks,
    bound scan plans, compiled executables — so long-lived mutating sessions
    stay memory-bounded.  ``get`` refreshes recency; ``put`` evicts the
    coldest entries and reports them to ``on_evict`` (used to count
    evictions, to spill partials, and to observe re-materialization in
    tests).

    Capacity is expressed two ways, independently optional:

    - ``cap`` — maximum entry COUNT.  ``None`` means unbounded; ``0``
      means disabled (nothing is ever admitted).
    - ``max_bytes`` — maximum total WEIGHT, where each entry weighs
      ``weigher(value)`` (default: the value's ``nbytes``, 0 if absent).
      ``None`` unbounded, ``0`` disabled.

    Eviction happens **before** insert: victims are chosen only while a
    budget is actually exceeded, so the incoming entry never forces the
    cache over budget even transiently.  An entry whose own weight exceeds
    ``max_bytes`` is never admitted at all — admitting it and then purging
    colder victims would empty the cache for an entry that cannot fit; it
    is reported to ``on_evict`` like an immediate eviction and ``put``
    returns ``False``.

    Thread-safe: every operation holds an internal re-entrant lock (``get``
    mutates recency order, so even reads are writes here), and the iterating
    helpers ``keys``/``values``/``items`` return **point-in-time lists** — a
    reader walking entries while another thread inserts must never trip
    ``RuntimeError: dict changed size during iteration``.
    """

    def __init__(self, cap: Optional[int], *,
                 max_bytes: Optional[int] = None,
                 weigher: Optional[Callable[[Any], int]] = None,
                 on_evict: Optional[Callable[[Any, Any], None]] = None):
        if cap is not None and cap < 0:
            raise ValueError(f"LRU cap must be >= 0 or None, got {cap}")
        if max_bytes is not None and max_bytes < 0:
            raise ValueError(
                f"LRU max_bytes must be >= 0 or None, got {max_bytes}")
        self.cap = None if cap is None else int(cap)
        self.max_bytes = None if max_bytes is None else int(max_bytes)
        self._weigher = weigher or _payload_nbytes
        self._d: "OrderedDict[Any, Any]" = OrderedDict()
        self._w: Dict[Any, int] = {}
        self.nbytes = 0
        self._on_evict = on_evict
        self._lock = threading.RLock()
        self.evictions = 0
        self.evict_errors = 0

    def _notify_evict(self, key, value) -> None:
        """Fire ``on_evict`` without letting a raising hook corrupt the
        sweep: the entry's own accounting (``nbytes``/``_w``/count) is
        settled by the caller *before* the callback, so a hook failure is
        counted and swallowed — the byte gauge stays exact and remaining
        victims still evict instead of aborting the sweep mid-way."""
        if self._on_evict is None:
            return
        try:
            self._on_evict(key, value)
        except Exception:
            self.evict_errors += 1

    def __len__(self) -> int:
        with self._lock:
            return len(self._d)

    def __contains__(self, key) -> bool:
        with self._lock:
            return key in self._d

    def get(self, key, default=None):
        with self._lock:
            if key not in self._d:
                return default
            self._d.move_to_end(key)
            return self._d[key]

    def peek(self, key, default=None):
        """Read without refreshing recency (diagnostics / identity tests)."""
        with self._lock:
            return self._d.get(key, default)

    def put(self, key, value) -> bool:
        """Insert ``value`` under ``key``; returns whether it was admitted.

        ``False`` means the cache is disabled (``cap==0`` / ``max_bytes==0``)
        or the entry alone exceeds ``max_bytes`` — either way the value is
        reported to ``on_evict``, so demotion/unindex hooks observe every
        entry that leaves (or never enters) the cache exactly once."""
        with self._lock:
            w = self._weigher(value) if self.max_bytes is not None else 0
            disabled = self.cap == 0 or self.max_bytes == 0
            if disabled or (self.max_bytes is not None
                            and w > self.max_bytes):
                # reject up front: no set of colder victims could make this
                # entry fit.  A previous value under the same key is stale
                # now — drop it silently (one on_evict per key, not two).
                prev = self._d.pop(key, _MISSING)
                if prev is not _MISSING:
                    self.nbytes -= self._w.pop(key, 0)
                self.evictions += 1
                self._notify_evict(key, value)
                return False
            prev = self._d.pop(key, _MISSING)
            if prev is not _MISSING:
                self.nbytes -= self._w.pop(key, 0)
            # evict BEFORE insert: victims leave only while a budget is
            # actually exceeded
            while self._d and (
                    (self.cap is not None and len(self._d) >= self.cap)
                    or (self.max_bytes is not None
                        and self.nbytes + w > self.max_bytes)):
                k, v = self._d.popitem(last=False)
                self.nbytes -= self._w.pop(k, 0)
                self.evictions += 1
                self._notify_evict(k, v)
            self._d[key] = value
            if self.max_bytes is not None:
                self._w[key] = w
                self.nbytes += w
            return True

    def replace(self, key, value) -> bool:
        """Swap the value under an existing ``key`` IN PLACE — recency is
        preserved, so a tier demotion can downgrade a cold block without
        promoting it to hottest (which would make the next victim scan
        pick a different, warmer block and cycle).  No budget enforcement:
        callers replace with equal-or-lighter values."""
        with self._lock:
            if key not in self._d:
                return False
            self._d[key] = value
            if self.max_bytes is not None:
                self.nbytes -= self._w.get(key, 0)
                w = self._weigher(value)
                self._w[key] = w
                self.nbytes += w
            return True

    def pop(self, key, default=None):
        with self._lock:
            if key in self._d:
                self.nbytes -= self._w.pop(key, 0)
            return self._d.pop(key, default)

    def clear(self) -> None:
        with self._lock:
            self._d.clear()
            self._w.clear()
            self.nbytes = 0

    def keys(self) -> List[Any]:
        with self._lock:
            return list(self._d.keys())

    def values(self) -> List[Any]:
        with self._lock:
            return list(self._d.values())

    def items(self) -> List[Tuple[Any, Any]]:
        with self._lock:
            return list(self._d.items())


@dataclasses.dataclass
class DeviceBlock:
    """One region's rows of one column, resident on the owning device.

    ``host`` is a private copy of the region's column rows (positions inside
    the table may shift under unrelated mutations; content cannot — any
    mutation to *this* region bumps its version and a new block is born).
    ``None`` after a host-tier demotion: the content then lives in the
    spill file and/or the device copy, and a tier-aware fetch
    re-materializes it.  ``device`` is the committed on-device copy
    (``None`` while host-only, e.g. on meshes where per-shard placement is
    unavailable, or after a device-tier demotion); ``device_index`` records
    which mesh shard it was committed to, so a rebalance that moves the
    region re-ships the block without re-reading the table.
    """

    rid: int
    family: str
    qualifier: str
    version: int
    rows: int                      # logical rows (the region's real rows)
    nbytes: int                    # logical host bytes (unpadded)
    host: Optional[np.ndarray]
    device: Any = None             # jax.Array committed to the owner shard
    device_index: Optional[int] = None
    # physical bytes of the committed device copy (0 while host-only) —
    # larger than ``nbytes`` when the session commits blocks pre-padded to
    # the fold bucket; transfer/residency oracles report THIS, not the
    # logical size
    device_nbytes: int = 0
    # disk-tier state: the spilled ``.npy`` file (None while not spilled)
    # and its on-disk size.  ``host_mmap`` marks a ``host`` that is an
    # mmap-backed view of the spill file: charged to the disk tier, not
    # host RAM
    spill_path: Optional[str] = None
    spill_nbytes: int = 0
    host_mmap: bool = False


@dataclasses.dataclass
class BlockStoreStats(AtomicStats):
    """Cumulative store counters (session lifetime).  Evictions are not
    duplicated here — the LRU already counts them; read
    :attr:`BlockStore.evictions`.

    ``device_bytes`` / ``host_bytes`` / ``disk_bytes`` are per-tier
    resident-byte GAUGES (inc'd with signed deltas under the store lock),
    not monotone counters — they track exactly what each tier currently
    holds: committed device payload, real (non-mmap) host copies, and
    spill files (blocks + partials).

    Updates go through :meth:`AtomicStats.inc` (concurrent queries bump
    these from many threads); consistent multi-counter reads through
    :meth:`AtomicStats.snapshot`."""

    gathers: int = 0        # host payloads read from the table (store misses)
    transfers: int = 0      # host→device block transfers (device_put calls)
    hits: int = 0           # requests served by a resident current block
    touches: int = 0        # region versions bumped by mutations
    host_reads: int = 0     # host-only fetches that re-read the table
    partial_hits: int = 0   # per-block fold partials served from the cache
    folds: int = 0          # per-block fold partials computed and stored
    gid_hits: int = 0       # per-region gid blocks served from the cache
    gid_builds: int = 0     # gid blocks densified (searchsorted) and stored
    # --- tier chain ---------------------------------------------------
    host_serves: int = 0    # fold fetches served host-side (payload larger
    #                         than the whole device budget: never committed)
    demotions: int = 0      # device payloads dropped under the device budget
    spills: int = 0         # host payloads written to the disk tier
    spill_reads: int = 0    # spill files re-opened (mmap) to serve a block
    spill_drops: int = 0    # payloads dropped entirely (no tier below)
    partial_spills: int = 0       # evicted partials demoted to disk
    partial_spill_reads: int = 0  # spilled partials promoted back to RAM
    prefetches: int = 0     # background tier promotions completed
    prefetch_hits: int = 0  # fetches served by claiming a prefetch record
    device_bytes: int = 0   # gauge: committed device payload bytes
    host_bytes: int = 0     # gauge: real (non-mmap) host copies
    disk_bytes: int = 0     # gauge: spill files on disk (blocks + partials)
    # --- fault tolerance ----------------------------------------------
    spill_corruptions: int = 0  # spill reads that failed CRC / vanished
    spill_recoveries: int = 0   # lost spills re-derived (device or table)
    retries: int = 0        # retry attempts consumed, all sites
    faults_injected: int = 0    # FaultInjector fires observed via on_fire
    quarantines: int = 0    # owner devices permanently quarantined


def _never_gather() -> np.ndarray:   # pragma: no cover - guarded by callers
    raise RuntimeError("prefetch must not gather from the table")


class BlockStore:
    """Versioned tiered cache of :class:`DeviceBlock`, the substrate under
    layouts.

    One instance per :class:`~repro.core.grid.GridSession`.  The session
    funnels every block request through :meth:`fetch`, which classifies the
    outcome for the ``QueryStats`` oracles:

    - *reused*      — current version resident on the current owner device;
    - *transferred* — host payload was shipped to a device (either because
      the block was freshly gathered, or because a rebalance moved the
      region so the cached host copy re-commits to its new owner);
    - *gathered*    — the host payload itself had to be (re-)read from the
      table (a store miss for this content version).

    Every fetched block satisfies ``reused or transferred`` — which is the
    testable invariant ``blocks_reused + blocks_transferred == blocks_total``
    carried on ``QueryStats``.

    Tier budgets (all optional, bytes): ``device_budget`` bounds committed
    device payload, ``host_budget`` bounds real host copies,
    ``disk_budget`` bounds spill files under ``spill_dir``.  ``None``
    leaves a tier unbounded (the pre-tiering behavior); without a
    ``spill_dir`` the disk tier is disabled and host-tier pressure drops
    payloads (loss-free — the table is the source of truth).  Placement
    decisions consult ``cost_model``
    (:class:`~repro.core.chunk_model.TierCostModel`).
    """

    #: completed-but-unclaimed prefetch records kept around (bounded; a
    #: mutation clears them wholesale)
    PREFETCH_RECORDS = 64

    def __init__(self, cap: Optional[int] = 256,
                 partial_cap: Optional[int] = 1024,
                 *,
                 device_budget: Optional[int] = None,
                 host_budget: Optional[int] = None,
                 disk_budget: Optional[int] = None,
                 partial_budget: Optional[int] = None,
                 spill_dir: Optional[str] = None,
                 cost_model: Optional[TierCostModel] = None,
                 prefetch_workers: int = 1,
                 fault_injector: Optional[FaultInjector] = None,
                 retry_policy: Optional[RetryPolicy] = None):
        self._closed = False
        self.stats = BlockStoreStats()
        self.device_budget = device_budget
        self.host_budget = host_budget
        self.disk_budget = disk_budget
        self.cost_model = cost_model if cost_model is not None \
            else TierCostModel()
        self._faults = fault_injector
        self._retry = retry_policy
        self.spill_dir = spill_dir
        self._owns_spill_dir = False
        self.orphans_swept = 0
        if spill_dir is not None:
            self._owns_spill_dir = not os.path.isdir(spill_dir)
            os.makedirs(spill_dir, exist_ok=True)
            self.orphans_swept = self._sweep_orphans()
            if self._owns_spill_dir:
                # belt under close(): even on abnormal exit (exception,
                # SIGTERM-handled shutdown) the dir leaves with the
                # process.  Harmless double-removal after a clean close.
                atexit.register(shutil.rmtree, spill_dir,
                                ignore_errors=True)
        self._spill_seq = 0
        # one re-entrant lock serializes every compound cache operation
        # (fetch's get-then-put, the partial index maintenance, touch/drop
        # sweeps, tier enforcement); individual LRUCache ops are locked on
        # their own, but the invariants here span several of them
        self._lock = threading.RLock()
        self._blocks: LRUCache = LRUCache(
            cap, on_evict=self._on_block_evict)
        # per-block fold partials, keyed (BlockKey, program, mask sig, eta):
        # the compute-side cache that lets a repeat query fold zero rows.
        # Partials are tiny (one accumulator pytree per block), so their cap
        # is several times the block cap; an evicted partial demotes to the
        # disk tier when spill is enabled, else it just re-folds.
        self._partials: LRUCache = LRUCache(
            partial_cap, max_bytes=partial_budget,
            on_evict=self._on_partial_evict)
        # (rid, version) -> live partial count: keeps has_partials O(1)
        # (it runs once per surviving region on every cold selective scan).
        # Spilled partials stay indexed — they are still servable.
        self._partial_index: Dict[Tuple[int, int], int] = {}
        # spilled partials: partial key -> (path, charged bytes, treedef)
        self._spilled_partials: "OrderedDict[Tuple, Tuple[str, int, Any]]" \
            = OrderedDict()
        # densified per-region gid blocks keyed (key-column block lineage,
        # mapping signature): a dirty-region re-fold touches OTHER regions'
        # partials but still needs THIS region's gids — caching them skips
        # the np.searchsorted re-densification on every such fold.  Tiny
        # (int32 per row), so a few hundred entries cost ~nothing.
        self._gids: LRUCache = LRUCache(512)
        # region id -> mutation epoch that last changed its content
        self._versions: Dict[int, int] = {}
        # background promotion: in-flight keys (single-flight) and
        # completed-but-unclaimed (block, reused, gathered) records the
        # next fetch of the key claims, preserving per-query accounting
        self._prefetch_workers = max(0, int(prefetch_workers))
        self._prefetch_pool: Optional[ThreadPoolExecutor] = None
        self._prefetch_inflight: Set[BlockKey] = set()
        self._prefetched: "OrderedDict[BlockKey, Tuple[DeviceBlock, bool, bool]]" = OrderedDict()  # noqa: E501

    @property
    def evictions(self) -> int:
        """Blocks dropped by the LRU cap (counted once, by the LRU)."""
        return self._blocks.evictions

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------

    def close(self) -> None:
        """Release tier resources: stop the prefetcher, delete every spill
        file, and remove the spill dir if this store created it.  The
        store stays usable afterwards as a pure in-memory cache."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            pool, self._prefetch_pool = self._prefetch_pool, None
        if pool is not None:
            pool.shutdown(wait=True)
        with self._lock:
            for k, blk in self._blocks.items():
                if blk.spill_path:
                    self._drop_spill_file(k, blk)
            for key in list(self._spilled_partials):
                self._drop_spilled_partial(key)
        if self._owns_spill_dir and self.spill_dir:
            shutil.rmtree(self.spill_dir, ignore_errors=True)

    def __del__(self):   # pragma: no cover - interpreter-shutdown best effort
        try:
            self.close()
        except Exception:
            pass

    # ------------------------------------------------------------------
    # epoch lineage
    # ------------------------------------------------------------------

    def version_of(self, rid: int) -> int:
        """The region's content version: the epoch of its last mutation
        (0 for regions never touched since the session opened)."""
        return self._versions.get(rid, 0)

    def touch(self, rids: Iterable[int], epoch: int) -> None:
        """Copy-on-write bump: mutated regions move to version ``epoch``.

        Superseded cache entries are dropped eagerly (they can never hit
        again); block objects stay alive wherever consumers still hold them.
        """
        with self._lock:
            touched = {int(rid) for rid in rids}
            for rid in touched:
                self._versions[rid] = int(epoch)
            self.stats.inc(touches=len(touched))
            doomed = [k for k in self._blocks.keys()
                      if k[0][0] in touched
                      and k[3] != self._versions[k[0][0]]]
            for k in doomed:
                self._drop_block(k)
            # superseded fold partials are as dead as their blocks: the
            # partial key embeds the block version, so they can never hit
            # again
            doomed_p = [k for k in self._partials.keys()
                        if k[0][0][0] in touched
                        and k[0][3] != self._versions[k[0][0][0]]]
            for k in doomed_p:
                self._pop_partial(k)
            doomed_sp = [k for k in self._spilled_partials
                         if k[0][0][0] in touched
                         and k[0][3] != self._versions[k[0][0][0]]]
            for k in doomed_sp:
                self._drop_spilled_partial(k)
            # superseded gid blocks die with their key-column block lineage
            doomed_g = [k for k in self._gids.keys()
                        if k[0][0][0] in touched
                        and k[0][3] != self._versions[k[0][0][0]]]
            for k in doomed_g:
                self._gids.pop(k)
            # unclaimed prefetch records may reference superseded content
            self._prefetched.clear()

    def drop_regions(self, rids: Iterable[int]) -> None:
        """Forget regions that no longer exist (split parents): their rids
        never reappear in the region set, so their blocks could otherwise
        pin host+device payload until cap pressure that may never come."""
        doomed_rids = {int(rid) for rid in rids}
        if not doomed_rids:
            return
        with self._lock:
            for k in [k for k in self._blocks.keys()
                      if k[0][0] in doomed_rids]:
                self._drop_block(k)
            for k in [k for k in self._partials.keys()
                      if k[0][0][0] in doomed_rids]:
                self._pop_partial(k)
            for k in [k for k in self._spilled_partials
                      if k[0][0][0] in doomed_rids]:
                self._drop_spilled_partial(k)
            for k in [k for k in self._gids.keys()
                      if k[0][0][0] in doomed_rids]:
                self._gids.pop(k)
            for rid in doomed_rids:
                self._versions.pop(rid, None)
            self._prefetched.clear()

    def lineage(self, regions: Iterable[Region]) -> Tuple[Tuple[int, int], ...]:
        """``((rid, version), ...)`` — the epoch-lineage signature of a
        region set.  Two plans over the same regions at the same versions may
        share everything; any difference forces a re-bind."""
        return tuple((r.rid, self.version_of(r.rid)) for r in regions)

    # ------------------------------------------------------------------
    # tier accounting
    # ------------------------------------------------------------------

    def _charge(self, device: int = 0, host: int = 0, disk: int = 0) -> None:
        """Apply signed deltas to the per-tier resident-byte gauges."""
        if device or host or disk:
            self.stats.inc(device_bytes=device, host_bytes=host,
                           disk_bytes=disk)

    @staticmethod
    def _block_charges(blk: DeviceBlock) -> Tuple[int, int, int]:
        """What this block currently contributes to each tier gauge."""
        dev = blk.device_nbytes if blk.device is not None else 0
        host = (blk.nbytes
                if blk.host is not None and not blk.host_mmap else 0)
        disk = blk.spill_nbytes if blk.spill_path is not None else 0
        return dev, host, disk

    def _on_block_evict(self, key, blk: DeviceBlock) -> None:
        """LRU-cap eviction hook: release every tier charge and the spill
        file (always fired under the store lock — every ``_blocks``
        mutation happens inside a compound store operation)."""
        d, h, k = self._block_charges(blk)
        self._charge(device=-d, host=-h, disk=-k)
        _unlink_spill(blk.spill_path)

    def _drop_block(self, key) -> None:
        """Pop one block and settle its tier charges (the non-LRU removal
        path: touch / drop_regions / clear / disk-tier drops)."""
        blk = self._blocks.pop(key)
        if blk is not None:
            self._on_block_evict(key, blk)

    def _put_and_charge(self, key, blk: DeviceBlock,
                        prev: Tuple[int, int, int] = (0, 0, 0)) -> None:
        """Insert/replace a block AND settle the tier gauges in one step.

        ``prev`` is what the superseded entry under the same key (if any)
        was charging.  Charging happens BEFORE the put: if the cache
        rejects the entry (``cap == 0``), its ``on_evict`` negates the new
        charges and the net effect is exactly ``-prev`` — the old entry
        left, the new one never became resident.  If the put is admitted,
        the delta stands and any *victims* it evicts settle through their
        own ``on_evict``."""
        d, h, k = self._block_charges(blk)
        self._charge(device=d - prev[0], host=h - prev[1], disk=k - prev[2])
        self._blocks.put(key, blk)

    def _new_spill_path(self, kind: str, suffix: str) -> str:
        self._spill_seq += 1
        return os.path.join(self.spill_dir,
                            f"{kind}-{self._spill_seq:06d}{suffix}")

    # ------------------------------------------------------------------
    # checksummed, crash-consistent spill I/O
    # ------------------------------------------------------------------

    def _sweep_orphans(self) -> int:
        """Startup crash-consistency sweep of the spill dir: delete
        half-written ``*.tmp`` files (a crash mid-write; ``os.replace``
        guarantees the final name is never half-written) and CRC sidecars
        whose payload is gone (a crash between payload unlink and sidecar
        unlink).  Returns the number of orphans removed."""
        try:
            names = os.listdir(self.spill_dir)
        except OSError:
            return 0
        present = set(names)
        removed = 0
        for name in names:
            full = os.path.join(self.spill_dir, name)
            if name.endswith(".tmp"):
                _unlink(full)
                removed += 1
            elif name.endswith(".crc") and name[:-4] not in present:
                _unlink(full)
                removed += 1
        return removed

    def _write_spill(self, path: str,
                     writer: Callable[[Any], None]) -> int:
        """Crash-consistent spill write: ``writer(file)`` fills a ``.tmp``
        sibling (an open file object, so numpy does not append its own
        extension), the CRC manifest is computed from the temp bytes, and
        ``os.replace`` publishes payload then sidecar atomically — a crash
        at any point leaves either nothing under the final name or a
        complete, verifiable pair (plus temps the startup sweep removes).
        Returns the payload's on-disk size.  Transient injected faults are
        retried under the store's policy; the final failure propagates so
        callers fall back to their lossy path."""
        def attempt() -> int:
            tmp = path + ".tmp"
            try:
                with open(tmp, "wb") as f:
                    writer(f)
                crc = _crc_file(tmp)
                sz = int(os.path.getsize(tmp))
                os.replace(tmp, path)
            except BaseException:
                _unlink(tmp)
                raise
            side = _sidecar(path)
            stmp = side + ".tmp"
            try:
                with open(stmp, "w") as f:
                    f.write(f"{crc:08x} {sz}\n")
                os.replace(stmp, side)
            except BaseException:
                _unlink(stmp)
                raise
            if self._faults is not None:
                # fired after publication so file-mangling fault kinds hit
                # the real spill file; the CRC check catches them on read
                self._faults.fire("spill_write", path=path)
            return sz

        if self._retry is not None:
            return self._retry.call(
                attempt, key=path,
                on_retry=lambda e, a: self.stats.inc(retries=1))
        return attempt()

    def _verify_spill(self, path: str) -> None:
        """Check a spill file against its CRC sidecar; raises
        :class:`SpillCorruptionError` on any mismatch, truncation, or a
        missing/unreadable file or sidecar."""
        side = _sidecar(path)
        try:
            with open(side, "r") as f:
                tok = f.read().split()
            want_crc, want_sz = int(tok[0], 16), int(tok[1])
        except (OSError, ValueError, IndexError):
            raise SpillCorruptionError(path, "missing/unreadable sidecar")
        try:
            have_sz = os.path.getsize(path)
        except OSError:
            raise SpillCorruptionError(path, "spill file missing")
        if have_sz != want_sz:
            raise SpillCorruptionError(
                path, f"size {have_sz} != {want_sz} (truncated?)")
        if _crc_file(path) != want_crc:
            raise SpillCorruptionError(path)

    def _read_spill_block(self, path: str) -> Optional[np.ndarray]:
        """Open one block spill file as a verified read-only mmap.
        ``None`` means the file is corrupt, truncated, or gone (or
        transient read faults exhausted their retries) — callers treat
        that as the tier being empty and recover from the next one."""
        def attempt():
            if self._faults is not None:
                self._faults.fire("spill_read", path=path)
            self._verify_spill(path)
            return np.load(path, mmap_mode="r")
        try:
            if self._retry is not None:
                return self._retry.call(
                    attempt, key=path,
                    on_retry=lambda e, a: self.stats.inc(retries=1))
            return attempt()
        except Exception:
            return None

    # ------------------------------------------------------------------
    # tier enforcement (demotions)
    # ------------------------------------------------------------------

    def _coldest(self, pred: Callable[[DeviceBlock], bool]
                 ) -> Optional[Tuple[Any, DeviceBlock]]:
        for k, b in self._blocks.items():    # coldest-first, point-in-time
            if pred(b):
                return k, b
        return None

    def _enforce_tiers(self) -> None:
        """Demote coldest payloads until every tier fits its byte budget.

        Runs under the store lock after any insertion/promotion, so
        *between* public store operations no tier gauge ever exceeds its
        budget.  Demotions cascade downward (device → host → disk → gone);
        every step is loss-free because the table remains authoritative."""
        if self.device_budget is not None:
            while self.stats.device_bytes > self.device_budget:
                victim = self._coldest(lambda b: b.device is not None)
                if victim is None:
                    break
                self._demote_device(*victim)
        if self.host_budget is not None:
            while self.stats.host_bytes > self.host_budget:
                victim = self._coldest(
                    lambda b: b.host is not None and not b.host_mmap)
                if victim is None:
                    break
                self._demote_host(*victim)
        if self.disk_budget is not None:
            self._enforce_disk()

    def _demote_device(self, key, blk: DeviceBlock) -> None:
        """Drop one block's device payload; the content survives one tier
        down.  COW: the cache entry is replaced in place (recency kept),
        never mutated — in-flight folds keep their device arrays alive."""
        if blk.host is None and blk.spill_path is None:
            # the device copy is the only one: pull it back to host first,
            # else the content would silently become a table re-read
            got = self._ensure_host(key, blk)
            assert got is not None   # the device copy guarantees a tier
            blk = got
        new = dataclasses.replace(blk, device=None, device_index=None,
                                  device_nbytes=0)
        self._blocks.replace(key, new)
        self._charge(device=-(blk.device_nbytes))
        self.stats.inc(demotions=1)

    def _demote_host(self, key, blk: DeviceBlock) -> None:
        """Demote one block's real host copy: spill to disk when the cost
        oracle prefers a local disk read to a table re-fetch, else drop."""
        if blk.spill_path is not None:
            # already on disk: just release the RAM copy
            new = dataclasses.replace(blk, host=None, host_mmap=False)
            self._blocks.replace(key, new)
            self._charge(host=-blk.nbytes)
            return
        if (self.spill_dir is not None and not self._closed
                and self.cost_model.should_spill_block(blk.nbytes)):
            path = self._new_spill_path("blk", ".npy")
            try:
                sz = self._write_spill(
                    path, lambda f: np.save(f, np.asarray(blk.host)))
            except Exception:
                # spill write failed outright (retries exhausted / disk
                # error): fall through to the lossy drop path below —
                # the table stays authoritative either way
                _unlink_spill(path)
                sz = None
            if sz is not None:
                new = dataclasses.replace(blk, host=None, host_mmap=False,
                                          spill_path=path, spill_nbytes=sz)
                self._blocks.replace(key, new)
                self._charge(host=-blk.nbytes, disk=sz)
                self.stats.inc(spills=1)
                self._enforce_disk_if_bounded()
                return
        # no disk tier below (or the oracle prefers re-gathering): drop the
        # payload; a block left with no payload at all leaves entirely and
        # re-gathers losslessly on next use
        if blk.device is not None:
            new = dataclasses.replace(blk, host=None, host_mmap=False)
            self._blocks.replace(key, new)
            self._charge(host=-blk.nbytes)
        else:
            self._drop_block(key)
        self.stats.inc(spill_drops=1)

    def _enforce_disk_if_bounded(self) -> None:
        if self.disk_budget is not None:
            self._enforce_disk()

    def _enforce_disk(self) -> None:
        while self.stats.disk_bytes > self.disk_budget:
            victim = self._coldest(lambda b: b.spill_path is not None)
            if victim is not None:
                key, blk = victim
                self._drop_spill_file(key, blk)
                self.stats.inc(spill_drops=1)
                continue
            if self._spilled_partials:
                # spilled partials go after block files: losing one costs a
                # re-fold, losing a block file only a table re-read
                k = next(iter(self._spilled_partials))
                self._drop_spilled_partial(k)
                continue
            break

    def _drop_spill_file(self, key, blk: DeviceBlock) -> None:
        """Delete one block's spill file (and any mmap view of it); the
        block survives only if another tier still holds the content."""
        _unlink_spill(blk.spill_path)
        self._charge(disk=-blk.spill_nbytes)
        keep_host = blk.host is not None and not blk.host_mmap
        new = dataclasses.replace(
            blk, spill_path=None, spill_nbytes=0,
            host=blk.host if keep_host else None, host_mmap=False)
        self._blocks.replace(key, new)
        if new.host is None and new.device is None:
            self._drop_block(key)   # remaining charges are zero by now

    # ------------------------------------------------------------------
    # tier promotion (reads walk down the chain)
    # ------------------------------------------------------------------

    def _ensure_host(self, key, blk: DeviceBlock) -> Optional[DeviceBlock]:
        """Re-materialize ``blk.host`` from the highest tier holding the
        content: spill file (as a verified read-only mmap, charged to
        disk) first, else the device copy (a real RAM copy, charged to
        host).  Returns the possibly-replaced cache entry — or ``None``
        when the only tier was a spill file that failed its CRC check (or
        vanished): the record is dropped and the caller re-derives the
        content losslessly from the table."""
        if blk.host is not None:
            return blk
        recovering = False
        if blk.spill_path is not None:
            host = self._read_spill_block(blk.spill_path)
            if host is not None:
                new = dataclasses.replace(blk, host=host, host_mmap=True)
                self._blocks.replace(key, new)
                self.stats.inc(spill_reads=1)
                return new
            # corrupt / truncated / deleted spill: detach it and fall
            # back to the next tier down
            self.stats.inc(spill_corruptions=1)
            _unlink_spill(blk.spill_path)
            self._charge(disk=-blk.spill_nbytes)
            blk = dataclasses.replace(blk, spill_path=None, spill_nbytes=0)
            self._blocks.replace(key, blk)
            recovering = True
        if blk.device is not None:
            host = np.ascontiguousarray(np.asarray(blk.device)[:blk.rows])
            host.flags.writeable = False
            new = dataclasses.replace(blk, host=host, host_mmap=False)
            if self._blocks.replace(key, new):
                self._charge(host=new.nbytes)
            if recovering:
                self.stats.inc(spill_recoveries=1)
            return new
        if recovering:
            # no tier left holding the content: drop the record (its
            # charges are zero by now) and let the caller re-gather
            self._drop_block(key)
            return None
        raise AssertionError(    # pragma: no cover - payload-less blocks
            "block with no payload in any tier")  # are dropped eagerly

    # ------------------------------------------------------------------
    # block access
    # ------------------------------------------------------------------

    def key_of(self, region: Region, family: str, qualifier: str) -> BlockKey:
        return (region.signature, family, qualifier,
                self.version_of(region.rid))

    def _gather_block(self, key: BlockKey, region: Region, family: str,
                      qualifier: str,
                      gather_host: Callable[[], np.ndarray]) -> DeviceBlock:
        """Gather one region column from the table into a fresh host block
        (the content-miss path, shared with spill-corruption recovery)."""
        host = np.ascontiguousarray(gather_host())
        host.flags.writeable = False
        blk = DeviceBlock(
            rid=region.rid, family=family, qualifier=qualifier,
            version=key[3], rows=int(host.shape[0]),
            nbytes=int(host.nbytes), host=host,
        )
        self.stats.inc(gathers=1)
        self._put_and_charge(key, blk)
        return blk

    def peek(self, region: Region, family: str,
             qualifier: str) -> Optional[DeviceBlock]:
        """Current-version block without touching recency (identity tests)."""
        return self._blocks.peek(self.key_of(region, family, qualifier))

    def _claim_prefetch(self, key: BlockKey, owner_index: Optional[int]
                        ) -> Optional[Tuple[DeviceBlock, bool, bool]]:
        """Pop a completed prefetch record for ``key`` so THIS fetch
        reports the classification the background promotion earned —
        per-query transfer/gather oracles attribute the work to the query
        that consumed it, exactly as if it had fetched synchronously."""
        rec = self._prefetched.pop(key, None)
        if rec is None:
            return None
        blk = rec[0]
        if blk.device is None or blk.device_index != owner_index:
            return None              # stale (e.g. rebalanced since): discard
        self._blocks.get(key)        # the claim is a use: refresh recency
        return rec

    def fetch(
        self,
        region: Region,
        family: str,
        qualifier: str,
        owner_index: Optional[int],
        gather_host: Callable[[], np.ndarray],
        to_device: Optional[Callable[[np.ndarray, Optional[int]], Any]],
    ) -> Tuple[DeviceBlock, bool, bool]:
        """Return ``(block, reused, gathered)`` for the current version.

        ``gather_host`` reads the region's column rows from the table (called
        only on a content miss).  ``to_device`` commits a host payload to the
        shard ``owner_index`` (``None`` disables device residency — the
        host-assembly fallback for meshes without per-shard placement).
        ``reused`` means no host→device transfer happened; ``gathered`` means
        the table was re-read.  ``not reused`` implies a transfer, so every
        fetch is exactly one of reused / transferred.

        Reads walk the tier chain transparently: a block whose host copy
        was demoted re-materializes from its spill file (mmap) or device
        copy before use, and a completed background prefetch of the key is
        claimed here with its original classification.
        """
        with self._lock:
            key = self.key_of(region, family, qualifier)
            if to_device is not None:
                rec = self._claim_prefetch(key, owner_index)
                if rec is not None:
                    self.stats.inc(prefetch_hits=1)
                    return rec
            blk = self._blocks.get(key)
            gathered = False
            if blk is None:
                blk = self._gather_block(key, region, family, qualifier,
                                         gather_host)
                gathered = True
            if to_device is None:
                # host-only fallback: every layout build re-ships the whole
                # assembled array, so no block is ever device-"reused" — a
                # content hit only avoids the table re-read.  Classifying
                # each fetch as transferred keeps payload_bytes_transferred
                # honest about what actually crosses host→device here.
                if not gathered:
                    self.stats.inc(hits=1)
                got = self._ensure_host(key, blk)
                if got is None:
                    # spill lost every copy: re-derive from the table
                    got = self._gather_block(key, region, family,
                                             qualifier, gather_host)
                    gathered = True
                    self.stats.inc(spill_recoveries=1)
                blk = got
                self.stats.inc(transfers=1)
                self._enforce_tiers()
                return blk, False, gathered

            if blk.device is not None and blk.device_index == owner_index:
                self.stats.inc(hits=1)
                return blk, True, False
            got = self._ensure_host(key, blk)
            if got is None:
                got = self._gather_block(key, region, family, qualifier,
                                         gather_host)
                gathered = True
                self.stats.inc(spill_recoveries=1)
            blk = got
            if (self.device_budget is not None
                    and blk.nbytes > self.device_budget):
                # larger than the whole device tier: committing would only
                # demote it straight back, so serve the fold host-side.
                # Classified as transferred (the payload still moves into
                # the fold), keeping gather ⟹ transfer intact.
                self.stats.inc(host_serves=1)
                self._enforce_tiers()
                return blk, False, gathered
            # fresh gather, a rebalance moved the region, or the device
            # payload was demoted: (re-)commit the host copy to its current
            # owner.  COW: a re-homed cached block is replaced, not mutated
            # — older consumers keep the old one.
            cached = self._blocks.peek(key)
            prev = self._block_charges(cached) if cached is not None \
                else (0, 0, 0)
            if blk.device is not None:
                blk = dataclasses.replace(blk)
            blk.device = to_device(blk.host, owner_index)
            blk.device_index = owner_index
            blk.device_nbytes = int(getattr(blk.device, "nbytes", blk.nbytes))
            self.stats.inc(transfers=1)
            self._put_and_charge(key, blk, prev)
            self._enforce_tiers()
            return blk, False, gathered

    def fetch_host(
        self,
        region: Region,
        family: str,
        qualifier: str,
        gather_host: Callable[[], np.ndarray],
    ) -> Tuple[DeviceBlock, bool]:
        """Current-version host payload WITHOUT device commitment — the
        retrieve path.  Returns ``(block, gathered)``; a later :meth:`fetch`
        for the fold path commits the same block to its owner device, so
        retrieve-heavy workloads and folds share one gather per content.

        Tier-aware: a host copy demoted to disk is served back as a
        read-only mmap view of its spill file; one demoted all the way out
        re-gathers from the table (loss-free)."""
        with self._lock:
            key = self.key_of(region, family, qualifier)
            blk = self._blocks.get(key)
            if blk is not None:
                self.stats.inc(hits=1)
                got = self._ensure_host(key, blk)
                if got is None:
                    # spill lost every copy: re-derive from the table
                    got = self._gather_block(key, region, family,
                                             qualifier, gather_host)
                    self.stats.inc(host_reads=1, spill_recoveries=1)
                    self._enforce_tiers()
                    return got, True
                self._enforce_tiers()
                return got, False
            blk = self._gather_block(key, region, family, qualifier,
                                     gather_host)
            self.stats.inc(host_reads=1)
            self._enforce_tiers()
            return blk, True

    # ------------------------------------------------------------------
    # background prefetch (tier promotion overlapped with folds)
    # ------------------------------------------------------------------

    @property
    def prefetch_enabled(self) -> bool:
        return self._prefetch_workers > 0 and not self._closed

    def _ensure_prefetch_pool(self) -> ThreadPoolExecutor:
        if self._prefetch_pool is None:
            self._prefetch_pool = ThreadPoolExecutor(
                max_workers=self._prefetch_workers,
                thread_name_prefix="blockstore-prefetch")
        return self._prefetch_pool

    def prefetch(self, region: Region, family: str, qualifier: str,
                 owner_index: Optional[int],
                 to_device: Optional[Callable[[np.ndarray, Optional[int]],
                                              Any]]) -> bool:
        """Schedule background promotion of a lower-tier-resident block to
        its owner device; returns whether a job was enqueued.

        Promotion only: a block the store has never gathered is left to the
        query's own fetch (so table-read accounting stays with the epoch's
        first reader, and no table access ever races a mutation).  The
        completed ``(block, reused, gathered)`` record is claimed by the
        next :meth:`fetch` of the key — concurrent coalesced queries share
        one promotion through the in-flight single-flight set."""
        if not self.prefetch_enabled or to_device is None:
            return False
        with self._lock:
            key = self.key_of(region, family, qualifier)
            blk = self._blocks.peek(key)
            if blk is None or (blk.device is not None
                               and blk.device_index == owner_index):
                return False
            if (self.device_budget is not None
                    and blk.nbytes > self.device_budget):
                return False         # can never be device-resident
            if key in self._prefetch_inflight or key in self._prefetched:
                return False
            self._prefetch_inflight.add(key)
            pool = self._ensure_prefetch_pool()
        pool.submit(self._prefetch_job, key, region, family, qualifier,
                    owner_index, to_device)
        return True

    def _prefetch_job(self, key: BlockKey, region: Region, family: str,
                      qualifier: str, owner_index: Optional[int],
                      to_device) -> None:
        try:
            with self._lock:
                if self._closed:
                    return
                if self.key_of(region, family, qualifier) != key:
                    return           # superseded by a mutation meanwhile
                if self._blocks.peek(key) is None:
                    return           # evicted meanwhile: nothing to promote
                rec = self.fetch(region, family, qualifier, owner_index,
                                 gather_host=_never_gather,
                                 to_device=to_device)
                if not rec[1]:       # a transfer actually happened
                    self._prefetched[key] = rec
                    while len(self._prefetched) > self.PREFETCH_RECORDS:
                        self._prefetched.popitem(last=False)
                    self.stats.inc(prefetches=1)
        except Exception:            # pragma: no cover - promotion is
            pass                     # best-effort; the query path recovers
        finally:
            with self._lock:
                self._prefetch_inflight.discard(key)

    # ------------------------------------------------------------------
    # fold partials (the compute-side cache of the block-granular engine)
    # ------------------------------------------------------------------

    def partial_key(self, region: Region, family: str, qualifier: str,
                    program_key: Tuple, mask_sig: str, eta: int,
                    group_sig: str = "", impl: str = "") -> Tuple:
        """The content address of one block's fold partial: block lineage
        (signature + version) × program × row-mask signature × η × group-key
        signature × fold implementation.  Any mutation to the region bumps
        the embedded version; any change to the selected-row subset changes
        ``mask_sig`` — either way the key becomes unmatchable and the
        partial re-folds.

        ``group_sig`` (grouped plans only) signs the group column AND the
        global value→group-id mapping: a block's group-keyed partial is
        only valid under the exact mapping it was folded with, since gid
        assignment depends on which key values the whole selection
        contains.  Ungrouped partials keep ``""``.

        ``impl`` distinguishes fold implementations whose partials agree
        only up to float accumulation order (the fused Pallas kernel vs
        the XLA scan): flipping ``engine.fold_impl`` mid-session must not
        merge partials folded under different orders.  The XLA path keeps
        ``""``, so existing keys are unchanged.
        """
        return (self.key_of(region, family, qualifier),
                program_key, mask_sig, int(eta), group_sig, impl)

    @staticmethod
    def _partial_rid_version(key: Tuple) -> Tuple[int, int]:
        return key[0][0][0], key[0][3]

    def _unindex_partial(self, key: Tuple) -> None:
        with self._lock:
            k = self._partial_rid_version(key)
            n = self._partial_index.get(k, 0) - 1
            if n <= 0:
                self._partial_index.pop(k, None)
            else:
                self._partial_index[k] = n

    def _pop_partial(self, key: Tuple) -> None:
        with self._lock:
            if self._partials.pop(key) is not None:
                self._unindex_partial(key)

    def _on_partial_evict(self, key: Tuple, value) -> None:
        """Partial-cache eviction hook (fires under both the LRU and —
        because every ``_partials`` insert runs inside a store compound op
        — the store lock): demote to the disk tier when spill is enabled
        and the oracle prefers a disk round-trip to a re-fold, else
        unindex (the partial is gone and will re-fold)."""
        if self.spill_dir is not None and not self._closed:
            src = self._blocks.peek(key[0])
            block_nbytes = src.nbytes if src is not None else 0
            if self.cost_model.should_spill_partial(
                    _payload_nbytes(value), block_nbytes):
                try:
                    self._spill_partial(key, value)
                    return
                except Exception:    # pragma: no cover - fall through to
                    pass             # the lossy path on any I/O failure
        self._unindex_partial(key)

    def _spill_partial(self, key: Tuple, value) -> None:
        # lazy import: mapreduce imports this module at load time, and the
        # flatten helper needs jax (which blockstore otherwise avoids)
        from repro.core.mapreduce import partial_to_host
        leaves, treedef = partial_to_host(value)
        path = self._new_spill_path("part", ".npz")
        try:
            sz = self._write_spill(path, lambda f: np.savez(f, *leaves))
        except BaseException:
            _unlink_spill(path)
            raise
        old = self._spilled_partials.pop(key, None)
        if old is not None:          # re-spill: replace the stale file
            _unlink_spill(old[0])
            self._charge(disk=-old[1])
        self._spilled_partials[key] = (path, sz, treedef)
        self._charge(disk=sz)
        self.stats.inc(partial_spills=1)
        self._enforce_disk_if_bounded()

    def _discard_spilled_record(self, key: Tuple) -> bool:
        """Remove a spilled-partial file WITHOUT unindexing — for callers
        that keep the key servable (fresh re-fold, RAM promotion)."""
        rec = self._spilled_partials.pop(key, None)
        if rec is None:
            return False
        _unlink_spill(rec[0])
        self._charge(disk=-rec[1])
        return True

    def _drop_spilled_partial(self, key: Tuple) -> None:
        if self._discard_spilled_record(key):
            self._unindex_partial(key)

    def get_partial(self, key: Tuple):
        p = self._partials.get(key)
        if p is not None:
            self.stats.inc(partial_hits=1)
            return p
        with self._lock:
            rec = self._spilled_partials.pop(key, None)
            if rec is None:
                return None
            path, sz, treedef = rec
            from repro.core.mapreduce import partial_from_host

            def read_npz():
                if self._faults is not None:
                    self._faults.fire("spill_read", path=path)
                self._verify_spill(path)
                with np.load(path) as z:
                    leaves = [z[f"arr_{i}"] for i in range(len(z.files))]
                return partial_from_host(leaves, treedef)

            try:
                if self._retry is not None:
                    value = self._retry.call(
                        read_npz, key=path,
                        on_retry=lambda e, a: self.stats.inc(retries=1))
                else:
                    value = read_npz()
            except Exception:
                # corrupt/lost spilled partial: drop it and report a plain
                # miss — the caller re-folds losslessly from the payload
                self.stats.inc(spill_corruptions=1)
                self._charge(disk=-sz)
                self._unindex_partial(key)
                _unlink_spill(path)
                return None
            self._charge(disk=-sz)
            _unlink_spill(path)
            self.stats.inc(partial_hits=1, partial_spill_reads=1)
            # promote back into the RAM cache WITHOUT re-counting a fold or
            # re-indexing (the spilled entry stayed indexed); byte pressure
            # may demote something else — or re-spill this one — via the
            # eviction hook
            self._partials.put(key, value)
            return value

    def put_partial(self, key: Tuple, value) -> None:
        with self._lock:
            self.stats.inc(folds=1)
            if key in self._spilled_partials:
                # a fresh fold supersedes the spilled copy; discard the
                # file but KEEP the index entry (the key stays counted
                # once, now by the RAM copy)
                self._discard_spilled_record(key)
            elif key not in self._partials:
                k = self._partial_rid_version(key)
                self._partial_index[k] = self._partial_index.get(k, 0) + 1
            self._partials.put(key, value)

    def peek_partial(self, key: Tuple) -> bool:
        """Whether a partial is servable (RAM or spilled) without touching
        recency or stats — the prefetch planner's probe."""
        if self._partials.peek(key) is not None:
            return True
        with self._lock:
            return key in self._spilled_partials

    def has_partials(self, rid: int) -> bool:
        """Any cached partial for the region's current content (a reuse
        signal the adaptive gather consults before going compact)."""
        return (rid, self.version_of(rid)) in self._partial_index

    # ------------------------------------------------------------------
    # gid blocks (densified group ids per region × mapping)
    # ------------------------------------------------------------------

    def gid_key(self, region: Region, family: str, qualifier: str,
                group_sig: str) -> Tuple:
        """Content address of one region's densified gid block: the KEY
        column's block lineage × the global value→gid mapping signature.
        A mutation to the region bumps the embedded version; a selection
        whose value universe differs carries another ``group_sig`` —
        either way the stale gids can never be served again."""
        return (self.key_of(region, family, qualifier), group_sig)

    def get_gids(self, region: Region, family: str, qualifier: str,
                 group_sig: str) -> Optional[np.ndarray]:
        g = self._gids.get(self.gid_key(region, family, qualifier,
                                        group_sig))
        if g is not None:
            self.stats.inc(gid_hits=1)
        return g

    def put_gids(self, region: Region, family: str, qualifier: str,
                 group_sig: str, gids: np.ndarray) -> None:
        self.stats.inc(gid_builds=1)
        g = np.ascontiguousarray(gids, dtype=np.int32)
        g.flags.writeable = False
        self._gids.put(self.gid_key(region, family, qualifier, group_sig), g)

    @property
    def gid_count(self) -> int:
        return len(self._gids)

    def clear_partials(self) -> None:
        with self._lock:
            # a wholesale clear DISCARDS — detach the spill records first
            # so the LRU clear (which fires no on_evict) matches them
            for key in list(self._spilled_partials):
                self._drop_spilled_partial(key)
            self._partials.clear()
            self._partial_index.clear()
            self._gids.clear()

    def clear(self) -> None:
        """Drop every cached block AND partial (versions survive, so
        content addressing stays monotonic); consumers re-gather and
        re-fold losslessly on next use.  Benchmarks use this to time the
        cold-data regime without rebuilding sessions."""
        with self._lock:
            for k in self._blocks.keys():
                self._drop_block(k)
            self.clear_partials()
            self._prefetched.clear()

    @property
    def partial_count(self) -> int:
        return len(self._partials)

    @property
    def spilled_partial_count(self) -> int:
        with self._lock:
            return len(self._spilled_partials)

    # ------------------------------------------------------------------
    # diagnostics
    # ------------------------------------------------------------------

    @property
    def cap(self) -> Optional[int]:
        return self._blocks.cap

    def __len__(self) -> int:
        return len(self._blocks)

    def resident_nbytes(self) -> int:
        """Physical bytes the store pins in RAM/HBM, summed **per payload
        actually held**: the host copy counts iff present (and a real copy,
        not an mmap view of a spill file), the device copy iff committed.
        Pre-tiering this summed ``nbytes + device_nbytes`` unconditionally,
        over-reporting every single-payload block (host-only after a device
        demotion, or device-only commits whose host side was demoted).
        Disk-tier bytes pin no memory — read ``tier_bytes()['disk']``."""
        total = 0
        for b in self._blocks.values():
            if b.host is not None and not b.host_mmap:
                total += b.nbytes
            if b.device is not None:
                total += b.device_nbytes
        return total

    def tier_bytes(self) -> Dict[str, int]:
        """Point-in-time per-tier resident-byte gauges."""
        s = self.stats.snapshot()
        return {"device": s.device_bytes, "host": s.host_bytes,
                "disk": s.disk_bytes}

    def describe(self) -> str:
        s = self.stats
        t = self.tier_bytes()
        return (f"BlockStore({len(self)}/{self.cap} blocks, "
                f"dev={t['device']}B host={t['host']}B disk={t['disk']}B; "
                f"{s.hits} hits, {s.gathers} gathers, {s.transfers} "
                f"transfers, {self.evictions} evictions; "
                f"{s.demotions} demotions, {s.spills} spills, "
                f"{s.spill_reads} spill reads; "
                f"{self.partial_count} partials, {s.partial_hits} partial "
                f"hits, {s.folds} folds)")
