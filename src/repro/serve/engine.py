"""Serving: batched prefill + decode with fixed-capacity caches.

``make_serve_step`` builds the one-token ``serve_step`` that the decode
dry-run cells lower (one new token against a seq_len cache).  ``ServeEngine``
is the host-side driver: batch requests, prefill once, decode greedily /
with temperature, with per-slot stop handling (continuous-batching lite:
finished slots are re-fillable because the cache is position-indexed)."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.model import build_model, pad_caches

PyTree = Any


def make_serve_step(cfg: ModelConfig, model=None) -> Callable:
    """-> pure ``serve_step(params, caches, token[B], pos[B]) ->
    (next_token[B], logits[B,V], caches)`` (greedy argmax inside so the
    lowered step is self-contained for the dry-run)."""
    model = model or build_model(cfg)

    def serve_step(params, caches, token, pos):
        logits, caches = model.decode_step(params, token, pos, caches)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_token, logits, caches

    return serve_step


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray            # [B, steps]
    steps: int


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params: PyTree, capacity: int,
                 batch_size: int):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = params
        self.capacity = capacity
        self.batch_size = batch_size
        self._decode = jax.jit(make_serve_step(cfg, self.model))

    def generate(
        self,
        prompts: np.ndarray,          # [B, S] int32 (right-aligned, padded)
        max_new_tokens: int,
        temperature: float = 0.0,
        seed: int = 0,
    ) -> GenerationResult:
        B, S = prompts.shape
        assert B == self.batch_size
        logits, caches = self.model.prefill(self.params, jnp.asarray(prompts))
        caches = pad_caches(self.cfg, caches, self.capacity)
        pos = jnp.full((B,), S, jnp.int32)

        if temperature > 0:
            key = jax.random.key(seed)
            tok = jax.random.categorical(key, logits / temperature, axis=-1)
        else:
            tok = jnp.argmax(logits, axis=-1)
        tok = tok.astype(jnp.int32)

        out = [np.asarray(tok)]
        for i in range(max_new_tokens - 1):
            tok, logits, caches = self._decode(self.params, caches, tok, pos)
            if temperature > 0:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(
                    sub, logits / temperature, axis=-1).astype(jnp.int32)
            pos = pos + 1
            out.append(np.asarray(tok))
        return GenerationResult(tokens=np.stack(out, axis=1),
                                steps=max_new_tokens)
