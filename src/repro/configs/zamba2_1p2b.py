"""zamba2-1.2b [hybrid] — 38L d_model=2048, Mamba2 backbone (ssm_state=64)
with a SHARED full-attention+MLP block every 6th layer (32H MHA kv=32,
d_ff=8192), vocab=32000.  [arXiv:2411.15242]

Simplification noted in DESIGN.md: the shared block is reused verbatim
(Zamba2's per-invocation LoRA deltas on the shared weights are omitted)."""

import jax.numpy as jnp

from repro.models.config import ModelConfig, SSMConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b", family="hybrid",
        n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
        d_ff=8192, vocab=32000, head_dim=64,
        ssm=SSMConfig(d_state=64, head_dim=64, expand=2, conv_width=4,
                      chunk=128),
        block_pattern=("ssm", "ssm", "ssm", "ssm", "ssm", "attn_shared"),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b-smoke", family="hybrid",
        n_layers=7, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=512, head_dim=16,
        ssm=SSMConfig(d_state=16, head_dim=16, expand=2, conv_width=4,
                      chunk=16),
        block_pattern=("ssm", "ssm", "ssm", "attn_shared"),
        remat_policy="none", dtype=jnp.float32, param_dtype=jnp.float32,
    )
