"""llama3.2-1b [dense] — 16L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=128256, tied embeddings.  [hf:meta-llama/Llama-3.2-1B]"""

import jax.numpy as jnp

from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-1b", family="dense",
        n_layers=16, d_model=2048, n_heads=32, n_kv_heads=8,
        d_ff=8192, vocab=128256, head_dim=64,
        rope_theta=500_000.0, tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-1b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=512, head_dim=16,
        rope_theta=500_000.0, tie_embeddings=True, remat_policy="none",
        dtype=jnp.float32, param_dtype=jnp.float32,
    )
