"""qwen2.5-14b [dense] — 48L d_model=5120 40H (GQA kv=8) d_ff=13824
vocab=152064, QKV bias.  [hf:Qwen/Qwen2.5-14B]"""

import jax.numpy as jnp

from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-14b", family="dense",
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
        d_ff=13824, vocab=152064, head_dim=128,
        rope_theta=1_000_000.0, qkv_bias=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-14b-smoke", family="dense",
        n_layers=2, d_model=80, n_heads=5, n_kv_heads=1,
        d_ff=160, vocab=512, head_dim=16,
        rope_theta=1_000_000.0, qkv_bias=True, remat_policy="none",
        dtype=jnp.float32, param_dtype=jnp.float32,
    )
