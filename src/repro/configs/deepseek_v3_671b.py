"""deepseek-v3-671b [moe] — 61L d_model=7168 128H, MLA, MoE 256 routed
top-8 + 1 shared (expert d_ff=2048), first 3 layers dense (d_ff=18432),
MTP depth 1, vocab=129280.  [arXiv:2412.19437]"""

import jax.numpy as jnp

from repro.models.config import MLAConfig, ModelConfig, MoEConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b", family="moe",
        n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
        d_ff=18432,                       # dense layers (first 3)
        vocab=129280, head_dim=128,
        rope_theta=10_000.0,
        moe=MoEConfig(n_experts=256, top_k=8, d_ff_expert=2048,
                      n_shared_experts=1, first_k_dense=3,
                      capacity_factor=1.25),
        mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                      qk_nope_head_dim=128, qk_rope_head_dim=64,
                      v_head_dim=128),
        mtp_depth=1,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b-smoke", family="moe",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=512, head_dim=16,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32,
                      n_shared_experts=1, first_k_dense=1,
                      capacity_factor=2.0),
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                      qk_nope_head_dim=16, qk_rope_head_dim=8,
                      v_head_dim=16),
        mtp_depth=1,
        remat_policy="none", dtype=jnp.float32, param_dtype=jnp.float32,
    )
