"""whisper-large-v3 [audio] — enc-dec, 32L each side, d_model=1280 20H
d_ff=5120 vocab=51866; conv/mel frontend is a STUB (input_specs provides
1500 frame embeddings).  [arXiv:2212.04356]"""

import jax.numpy as jnp

from repro.models.config import EncoderConfig, ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3", family="audio",
        n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20,
        d_ff=5120, vocab=51866, head_dim=64,
        encoder=EncoderConfig(n_layers=32, n_frames=1500, d_model=1280,
                              n_heads=20, d_ff=5120),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3-smoke", family="audio",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=512, head_dim=16,
        encoder=EncoderConfig(n_layers=2, n_frames=16, d_model=64,
                              n_heads=4, d_ff=128),
        remat_policy="none", dtype=jnp.float32, param_dtype=jnp.float32,
    )
