"""rwkv6-3b [ssm] — Finch: 32L d_model=2560 attention-free, d_ff=8960
vocab=65536, data-dependent decay.  [arXiv:2404.05892]"""

import jax.numpy as jnp

from repro.models.config import ModelConfig, RWKVConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b", family="ssm",
        n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40,
        d_ff=8960, vocab=65536, head_dim=64,
        rwkv=RWKVConfig(head_dim=64, decay_lora=64, gate_lora=32),
        block_pattern=("rwkv",),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b-smoke", family="ssm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=224, vocab=512, head_dim=16,
        rwkv=RWKVConfig(head_dim=16, decay_lora=8, gate_lora=8),
        block_pattern=("rwkv",),
        remat_policy="none", dtype=jnp.float32, param_dtype=jnp.float32,
    )
