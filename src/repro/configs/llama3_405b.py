"""llama3-405b [dense] — 126L d_model=16384 128H (GQA kv=8) d_ff=53248
vocab=128256.  [arXiv:2407.21783]"""

import jax.numpy as jnp

from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="llama3-405b", family="dense",
        n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8,
        d_ff=53248, vocab=128256, head_dim=128,
        rope_theta=500_000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llama3-405b-smoke", family="dense",
        n_layers=3, d_model=128, n_heads=8, n_kv_heads=2,
        d_ff=256, vocab=512, head_dim=16,
        rope_theta=500_000.0, remat_policy="none",
        dtype=jnp.float32, param_dtype=jnp.float32,
    )
