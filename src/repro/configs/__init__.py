"""Assigned-architecture registry: ``get_config(name, reduced=...)``.

One module per architecture; each defines ``full()`` (the exact assigned
config, sources cited in-module) and ``smoke()`` (a reduced config of the
same family for CPU tests — same structural flags, tiny dims).
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

ARCH_IDS: List[str] = [
    "zamba2_1p2b",
    "llama3_405b",
    "llama3p2_1b",
    "qwen2p5_14b",
    "qwen3_8b",
    "qwen2_vl_7b",
    "mixtral_8x7b",
    "deepseek_v3_671b",
    "whisper_large_v3",
    "rwkv6_3b",
]

#: assignment-sheet ids -> module names
ALIASES: Dict[str, str] = {
    "zamba2-1.2b": "zamba2_1p2b",
    "llama3-405b": "llama3_405b",
    "llama3.2-1b": "llama3p2_1b",
    "qwen2.5-14b": "qwen2p5_14b",
    "qwen3-8b": "qwen3_8b",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "mixtral-8x7b": "mixtral_8x7b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "whisper-large-v3": "whisper_large_v3",
    "rwkv6-3b": "rwkv6_3b",
}


def canonical(name: str) -> str:
    return ALIASES.get(name, name)


def get_config(name: str, reduced: bool = False) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.smoke() if reduced else mod.full()


def all_configs(reduced: bool = False) -> Dict[str, ModelConfig]:
    return {a: get_config(a, reduced) for a in ARCH_IDS}
