"""mixtral-8x7b [moe] — 32L d_model=4096 32H (GQA kv=8) vocab=32000,
8 experts top-2 (expert d_ff=14336), sliding-window attention 4096.
[arXiv:2401.04088]"""

import jax.numpy as jnp

from repro.models.config import ModelConfig, MoEConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b", family="moe",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab=32000, head_dim=128,
        rope_theta=1_000_000.0, sliding_window=4096,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=14336,
                      capacity_factor=1.25),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=512, head_dim=16,
        rope_theta=1_000_000.0, sliding_window=8,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=96,
                      capacity_factor=2.0),
        remat_policy="none", dtype=jnp.float32, param_dtype=jnp.float32,
    )
