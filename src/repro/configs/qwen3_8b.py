"""qwen3-8b [dense] — 36L d_model=4096 32H (GQA kv=8) d_ff=12288
vocab=151936, qk-norm.  [hf:Qwen/Qwen3-8B]"""

import jax.numpy as jnp

from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen3-8b", family="dense",
        n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=12288, vocab=151936, head_dim=128,
        rope_theta=1_000_000.0, qk_norm=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen3-8b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=512, head_dim=16,
        rope_theta=1_000_000.0, qk_norm=True, remat_policy="none",
        dtype=jnp.float32, param_dtype=jnp.float32,
    )
