"""qwen2-vl-7b [vlm] — 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064, M-RoPE; vision frontend is a STUB (input_specs provides patch
embeddings).  [arXiv:2409.12191]"""

import jax.numpy as jnp

from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-7b", family="vlm",
        n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
        d_ff=18944, vocab=152064, head_dim=128,
        rope_theta=1_000_000.0, qkv_bias=True, mrope=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-7b-smoke", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=512, head_dim=16,
        rope_theta=1_000_000.0, qkv_bias=True, mrope=True,
        remat_policy="none", dtype=jnp.float32, param_dtype=jnp.float32,
    )
