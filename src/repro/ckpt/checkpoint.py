"""Checkpointing — fault tolerance for 1000+-node runs, built from scratch.

Design (orbax-shaped, dependency-free):

- a checkpoint is a directory ``step_<N>/`` holding one ``.npz`` per
  top-level tree key plus a ``manifest.json`` (tree structure, shapes,
  dtypes, user metadata);
- writes go to ``step_<N>.tmp`` and are atomically renamed — a crash
  mid-save can never corrupt the latest restorable step (the restart
  contract at scale);
- ``async_save`` snapshots to host memory synchronously (so training can
  donate/overwrite device buffers) and writes on a background thread —
  the checkpoint wall-time cost on the step is the device->host copy only;
- restore is **elastic**: arrays come back as host numpy and are re-placed
  by the caller's current shardings (``jax.device_put`` against a possibly
  different mesh/device count) — combined with the balancer re-run on the
  table side, this is the rescale path;
- retention keeps the last K steps (plus every ``keep_every``-th for
  rollback-to-known-good).

Multi-host note: this container is single-process; at real scale each host
writes its address-local shards under ``step_<N>/host_<i>/`` with the same
manifest/rename protocol (process 0 writes the manifest last) — the layout
here is that protocol restricted to one host.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

import jax

PyTree = Any

_SEP = "/"


def _flatten(tree: PyTree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _treedef_of(tree: PyTree):
    return jax.tree.structure(tree)


def save_checkpoint(
    directory: str,
    step: int,
    tree: PyTree,
    metadata: Optional[Dict] = None,
) -> str:
    """Atomic synchronous save.  Returns the final step directory."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:09d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    flat = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"),
             **{k: v for k, v in flat.items()})
    manifest = {
        "step": step,
        "keys": sorted(flat.keys()),
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
        "metadata": metadata or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def restore_checkpoint(
    directory: str,
    template: PyTree,
    step: Optional[int] = None,
    shardings: Optional[PyTree] = None,
) -> Tuple[PyTree, Dict]:
    """Restore into ``template``'s structure; optional re-placement.

    ``shardings`` (same structure, NamedSharding leaves) re-places arrays on
    the *current* mesh — the elastic-restore path; shape/dtype mismatches
    against the template raise (a config/topology error, not a silent cast).
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    d = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, "arrays.npz"))

    flat_template = jax.tree_util.tree_flatten_with_path(template)
    leaves_out: List = []
    flat_shard = (jax.tree.leaves(shardings, is_leaf=lambda x: x is None or hasattr(x, "mesh"))
                  if shardings is not None else None)
    for i, (path, leaf) in enumerate(flat_template[0]):
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        if key not in data:
            raise KeyError(f"checkpoint missing {key!r}")
        arr = data[key]
        want_shape = tuple(leaf.shape)
        want_dtype = leaf.dtype
        if arr.shape != want_shape:
            raise ValueError(
                f"{key}: checkpoint shape {arr.shape} != template {want_shape}")
        arr = arr.astype(want_dtype)
        if flat_shard is not None and flat_shard[i] is not None:
            leaves_out.append(jax.device_put(arr, flat_shard[i]))
        else:
            leaves_out.append(arr)
    tree = jax.tree.unflatten(flat_template[1], leaves_out)
    return tree, manifest["metadata"]


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m:
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


class CheckpointManager:
    """Retention + async writes.

    ``save(step, tree)``: snapshot to host now, write in background.
    ``wait()``: join outstanding writes (call before process exit).
    """

    def __init__(
        self,
        directory: str,
        keep_last: int = 3,
        keep_every: Optional[int] = None,
    ):
        self.directory = directory
        self.keep_last = keep_last
        self.keep_every = keep_every
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree: PyTree, metadata: Optional[Dict] = None,
             async_: bool = True) -> None:
        self.wait()  # one outstanding write at a time
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree, metadata)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        if async_:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()
            self._raise_if_failed()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_if_failed()

    def restore(self, template: PyTree, step: Optional[int] = None,
                shardings: Optional[PyTree] = None):
        self.wait()
        return restore_checkpoint(self.directory, template, step, shardings)

    def latest_step(self) -> Optional[int]:
        self.wait()
        return latest_step(self.directory)

    # ------------------------------------------------------------------

    def _raise_if_failed(self):
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError("async checkpoint write failed") from err

    def _gc(self):
        steps = sorted(
            int(m.group(1))
            for name in os.listdir(self.directory)
            if (m := re.fullmatch(r"step_(\d+)", name))
        )
        keep = set(steps[-self.keep_last:])
        if self.keep_every:
            keep |= {s for s in steps if s % self.keep_every == 0}
        for s in steps:
            if s not in keep:
                shutil.rmtree(os.path.join(self.directory, f"step_{s:09d}"),
                              ignore_errors=True)
