"""Step builders + sharding assembly for the dry-run and launchers.

One place decides, per (arch × shape-kind), WHAT function lowers and HOW
its inputs/outputs shard.  Training shards batch over (pod, data) and
parameters per the FSDP+TP rules; decode additionally shards the KV-cache
*sequence* dim over ``model`` (32k×128 caches don't fit otherwise, and the
partitioned softmax XLA emits is exactly the flash-decode pattern).
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import model as model_lib
from repro.models.config import ModelConfig
from repro.models.model import build_model
from repro.models.params import resolve_spec, resolve_tree, sharding_rules
from repro.models.sharding import ShardingPolicy
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.serve.engine import make_serve_step
from repro.train.loss import cross_entropy, encdec_loss, lm_loss
from repro.train.step import TrainStepConfig, make_train_step


def rules_for(kind: str, fsdp: bool = True) -> Dict:
    rules = sharding_rules(fsdp=fsdp)
    if kind == "decode":
        # shard cache sequence over the model axis (flash-decode layout)
        rules = dict(rules)
        rules["seq"] = ("model",)
    return rules


def _is_axes_leaf(x):
    return x is None or (isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x))


def tree_specs(shapes_tree, axes_tree, rules, mesh) -> Any:
    mesh_shape = dict(mesh.shape)
    return jax.tree.map(
        lambda s, a: resolve_spec(s.shape, a, rules, mesh_shape),
        shapes_tree, axes_tree, is_leaf=lambda x: _is_axes_leaf(x),
    )


def _shardify(spec_tree, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def batch_spec(mesh: Mesh) -> P:
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    return P(axes if len(axes) > 1 else axes[0])


class CellBuilder:
    """Builds (fn, in_shardings, kwargs-specs, donate) for one dry-run cell."""

    def __init__(self, cfg: ModelConfig, mesh: Mesh, kind: str):
        self.cfg = cfg
        self.mesh = mesh
        self.kind = kind
        self.model = build_model(cfg)
        self.rules = rules_for(kind)
        self.policy = ShardingPolicy(mesh, self.rules)

        param_shapes = jax.eval_shape(self.model.init, jax.random.key(0))
        self.param_specs = tree_specs(
            param_shapes, self.model.logical_axes(), self.rules, mesh)
        self.param_sh = _shardify(self.param_specs, mesh)
        self.param_shapes = param_shapes

    # ------------------------------------------------------------------

    def opt_shardings(self):
        opt_shapes = jax.eval_shape(adamw_init, self.param_shapes)
        specs = {"m": self.param_specs, "v": self.param_specs, "step": P()}
        return _shardify(specs, self.mesh), opt_shapes

    def cache_shardings(self, cache_shapes):
        if self.cfg.is_encdec:
            self_axes = model_lib._attn_cache_axes(self.cfg, stacked=True)
            kv_axes = {"k": ("layers", "batch", None, "kv_heads", None),
                       "v": ("layers", "batch", None, "kv_heads", None)}
            axes = (self_axes, kv_axes)
        else:
            axes = self.model.cache_axes()
        specs = tree_specs(cache_shapes, axes, self.rules, self.mesh)
        return _shardify(specs, self.mesh)

    # ------------------------------------------------------------------

    def input_sh(self, shape_struct, axes):
        """Divisibility-aware sharding for one input (batch=1 stays
        replicated instead of tripping pjit)."""
        spec = resolve_spec(shape_struct.shape, axes, self.rules,
                            dict(self.mesh.shape))
        return NamedSharding(self.mesh, spec)

    def build(self, specs: Dict[str, Any]):
        """-> (fn, arg_specs tuple, in_shardings tuple, donate_argnums)."""
        cfg, model, mesh = self.cfg, self.model, self.mesh
        rep = NamedSharding(mesh, P())
        policy = self.policy

        if self.kind == "train":
            opt_sh, opt_shapes = self.opt_shardings()
            if cfg.is_encdec:
                def loss_fn_builder(frames):
                    def loss_fn(p, toks):
                        return encdec_loss(cfg, model, p, frames, toks)
                    return loss_fn

                def step(params, opt_state, frames, tokens, step_idx):
                    from repro.models.sharding import use_policy
                    with use_policy(policy):
                        inner = make_train_step(
                            cfg, model, AdamWConfig(),
                            TrainStepConfig(
                                num_microbatches=cfg.train_microbatches,
                                unroll_microbatches=cfg.microbatch_unroll),
                            loss_fn=loss_fn_builder(frames))
                        return inner(params, opt_state, tokens, step_idx)

                args = (self.param_shapes, opt_shapes, specs["frames"],
                        specs["tokens"], jax.ShapeDtypeStruct((), jnp.int32))
                shardings = (self.param_sh, opt_sh,
                             self.input_sh(specs["frames"],
                                           ("batch", None, None)),
                             self.input_sh(specs["tokens"], ("batch", "seq")),
                             rep)
                return step, args, shardings, (0, 1)

            if cfg.family == "vlm":
                def loss_fn(p, batch):
                    embeds, positions, targets = batch
                    logits, aux = model.forward_train(
                        p, embeds=embeds, positions=positions)
                    return cross_entropy(logits, targets) + 0.0 * aux, \
                        {"aux": aux}

                def step(params, opt_state, embeds, positions, targets,
                         step_idx):
                    from repro.models.sharding import use_policy
                    with use_policy(policy):
                        inner = make_train_step(
                            cfg, model, AdamWConfig(),
                            TrainStepConfig(
                                num_microbatches=cfg.train_microbatches,
                                unroll_microbatches=cfg.microbatch_unroll),
                            loss_fn=loss_fn)
                        return inner(params, opt_state,
                                     (embeds, positions, targets), step_idx)

                args = (self.param_shapes, opt_shapes, specs["embeds"],
                        specs["positions"], specs["targets"],
                        jax.ShapeDtypeStruct((), jnp.int32))
                shardings = (self.param_sh, opt_sh,
                             self.input_sh(specs["embeds"],
                                           ("batch", "seq", "embed_act")),
                             self.input_sh(specs["positions"],
                                           ("batch", None, "seq")),
                             self.input_sh(specs["targets"],
                                           ("batch", "seq")),
                             rep)
                return step, args, shardings, (0, 1)

            def step(params, opt_state, tokens, step_idx):
                from repro.models.sharding import use_policy
                with use_policy(policy):
                    inner = make_train_step(
                        cfg, model, AdamWConfig(),
                        TrainStepConfig(
                            num_microbatches=cfg.train_microbatches,
                            unroll_microbatches=cfg.microbatch_unroll))
                    return inner(params, opt_state, tokens, step_idx)

            args = (self.param_shapes, opt_shapes, specs["tokens"],
                    jax.ShapeDtypeStruct((), jnp.int32))
            shardings = (self.param_sh, opt_sh,
                         self.input_sh(specs["tokens"], ("batch", "seq")),
                         rep)
            return step, args, shardings, (0, 1)

        if self.kind == "prefill":
            if cfg.is_encdec:
                def step(params, frames, tokens):
                    from repro.models.sharding import use_policy
                    with use_policy(policy):
                        return model.prefill(params, frames, tokens)
                args = (self.param_shapes, specs["frames"], specs["tokens"])
                return step, args, (
                    self.param_sh,
                    self.input_sh(specs["frames"], ("batch", None, None)),
                    self.input_sh(specs["tokens"], ("batch", "seq"))), ()
            if cfg.family == "vlm":
                def step(params, embeds):
                    from repro.models.sharding import use_policy
                    with use_policy(policy):
                        return model.prefill(params, embeds=embeds)
                args = (self.param_shapes, specs["embeds"])
                return step, args, (
                    self.param_sh,
                    self.input_sh(specs["embeds"],
                                  ("batch", "seq", "embed_act"))), ()

            def step(params, tokens):
                from repro.models.sharding import use_policy
                with use_policy(policy):
                    return model.prefill(params, tokens)
            args = (self.param_shapes, specs["tokens"])
            return step, args, (
                self.param_sh,
                self.input_sh(specs["tokens"], ("batch", "seq"))), ()

        # decode
        cache_sh = self.cache_shardings(specs["caches"])

        def step(params, caches, token, pos):
            from repro.models.sharding import use_policy
            with use_policy(policy):
                logits, new_caches = model.decode_step(
                    params, token, pos, caches)
                next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                return next_tok, new_caches

        args = (self.param_shapes, specs["caches"], specs["token"],
                specs["pos"])
        shardings = (self.param_sh, cache_sh,
                     self.input_sh(specs["token"], ("batch",)),
                     self.input_sh(specs["pos"], ("batch",)))
        return step, args, shardings, (1,)
