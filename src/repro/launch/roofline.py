"""Roofline extraction from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds (v5e constants):

    compute    = HLO_FLOPs_per_device / peak_FLOPs
    memory     = HLO_bytes_per_device / HBM_bw
    collective = wire_bytes_per_device / ICI_bw

``cost_analysis()`` supplies flops/bytes of the per-partition module;
collective bytes are parsed from the post-SPMD HLO text (cost_analysis does
not count them): per-device wire bytes ≈ Σ op_output_bytes × factor, with
the ring factors {all-reduce: 2, all-gather/reduce-scatter/all-to-all/
collective-permute: 1}.  Cross-pod (DCN) collectives are split out by
replica-group size when detectable.
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, Optional, Tuple

# TPU v5e hardware constants (per chip)
PEAK_FLOPS_BF16 = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
DCN_BW = 6.25e9          # ~50 Gb/s/host effective for cross-pod

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "e4m3": 1, "e5m2": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVE_FACTOR = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

# e.g. "  %x = f32[8,128]{1,0} all-reduce(...)" or tuple-typed ops
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, float]:
    """-> {'wire_bytes': per-device Σ bytes×factor, 'by_op': {...},
    'count': N}."""
    by_op: Dict[str, float] = {}
    count = 0
    for m in _OP_RE.finditer(hlo_text):
        type_str, op = m.group(1), m.group(2)
        b = _shape_bytes(type_str) * _COLLECTIVE_FACTOR[op]
        by_op[op] = by_op.get(op, 0.0) + b
        count += 1
    return {
        "wire_bytes": float(sum(by_op.values())),
        "by_op": by_op,
        "count": count,
    }


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_device: float
    bytes_per_device: float
    wire_bytes_per_device: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def compute_fraction(self) -> float:
        """Fraction of roofline: useful-compute time over the binding term."""
        return self.compute_s / max(self.bound_s, 1e-30)


def derive_terms(
    flops: float,
    bytes_accessed: float,
    wire_bytes: float,
) -> RooflineTerms:
    return RooflineTerms(
        compute_s=flops / PEAK_FLOPS_BF16,
        memory_s=bytes_accessed / HBM_BW,
        collective_s=wire_bytes / ICI_BW,
        flops_per_device=flops,
        bytes_per_device=bytes_accessed,
        wire_bytes_per_device=wire_bytes,
    )


def model_flops(cfg, shape_spec, n_tokens: Optional[int] = None) -> float:
    """6·N·D (training) / 2·N·D (inference fwd) with N = active params."""
    n_active = cfg.active_param_count()
    if n_tokens is None:
        n_tokens = shape_spec.global_batch * (
            1 if shape_spec.kind == "decode" else shape_spec.seq_len)
    mult = 6.0 if shape_spec.kind == "train" else 2.0
    return mult * n_active * n_tokens
