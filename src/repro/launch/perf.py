import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Perf-iteration driver for the hillclimb cells (§Perf methodology).

Measures one (arch × shape) cell on the single-pod mesh under config /
sharding-rule overrides, with the same probe-corrected accounting as the
dry-run.  Results cached to artifacts/perf/<arch>__<shape>__<tag>.json.

    PYTHONPATH=src python -m repro.launch.perf --arch llama3_405b \
        --shape train_4k --tag chunked_attn \
        --set attention_impl=chunked
"""

import argparse
import dataclasses
import json
import time
from typing import Dict, Optional

import jax

from repro.configs import get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.probes import corrected, make_probe_plan
from repro.launch.roofline import derive_terms, model_flops
from repro.launch.shapes import SHAPES, input_specs
from repro.launch import steps as steps_mod
from repro.launch.dryrun import compile_cell


def apply_overrides(cfg, overrides: Dict[str, str]):
    moe_fields = {f.name for f in dataclasses.fields(type(cfg.moe))} \
        if cfg.moe else set()
    kw = {}
    for key, val in overrides.items():
        if key in moe_fields:
            cfg = dataclasses.replace(
                cfg, moe=dataclasses.replace(cfg.moe, **{key: _conv(val)}))
        else:
            kw[key] = _conv(val)
    return dataclasses.replace(cfg, **kw) if kw else cfg


def _conv(v: str):
    for cast in (int, float):
        try:
            return cast(v)
        except ValueError:
            pass
    if v in ("true", "false"):
        return v == "true"
    return v


def measure(arch: str, shape: str, tag: str,
            overrides: Optional[Dict[str, str]] = None,
            rules_overrides: Optional[Dict[str, tuple]] = None,
            out_dir: str = "artifacts/perf", force: bool = False) -> Dict:
    path = os.path.join(out_dir, f"{arch}__{shape}__{tag}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    cfg = apply_overrides(get_config(arch), overrides or {})
    mesh = make_production_mesh()
    spec = SHAPES[shape]

    # rule overrides hook into the single resolution point
    orig_rules_for = steps_mod.rules_for
    if rules_overrides:
        def patched(kind, fsdp=True):
            r = dict(orig_rules_for(kind, fsdp))
            r.update(rules_overrides)
            return r
        steps_mod.rules_for = patched
    try:
        t0 = time.perf_counter()
        main = compile_cell(cfg, shape, mesh, spec.kind)
        probe_a, probe_bs = make_probe_plan(cfg)
        a = compile_cell(probe_a, shape, mesh, spec.kind)
        bs = [(pb, compile_cell(pb.cfg, shape, mesh, spec.kind))
              for pb in probe_bs]
        corr = corrected(a, bs)
    finally:
        steps_mod.rules_for = orig_rules_for

    terms = derive_terms(corr["flops"], corr["bytes"], corr["wire_bytes"])
    mf = model_flops(cfg, spec)
    mem = main["memory"]
    per_dev = (mem.get("argument_size_in_bytes", 0)
               + mem.get("temp_size_in_bytes", 0)
               - mem.get("alias_size_in_bytes", 0))
    record = {
        "arch": arch, "shape": shape, "tag": tag,
        "overrides": overrides or {},
        "rules_overrides": {k: list(v) for k, v in
                            (rules_overrides or {}).items()},
        "per_device_bytes": per_dev,
        "fits_v5e": bool(per_dev < 16e9),
        "corrected": {k: corr[k] for k in ("flops", "bytes", "wire_bytes")},
        "roofline": {
            "compute_s": terms.compute_s,
            "memory_s": terms.memory_s,
            "collective_s": terms.collective_s,
            "dominant": terms.dominant,
            "bound_s": terms.bound_s,
            "compute_fraction": terms.compute_fraction(),
            "useful_flops_ratio": (mf / mesh.size) / max(corr["flops"], 1e-30),
        },
        "wall_s": round(time.perf_counter() - t0, 1),
    }
    os.makedirs(out_dir, exist_ok=True)
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    return record


def show(rec: Dict):
    r = rec["roofline"]
    print(f"{rec['arch']} {rec['shape']} [{rec['tag']}]: "
          f"dom={r['dominant']} comp={r['compute_s']:.3g}s "
          f"mem={r['memory_s']:.3g}s coll={r['collective_s']:.3g}s "
          f"frac={r['compute_fraction']:.3f} "
          f"temp={rec['per_device_bytes']/1e9:.1f}GB fits={rec['fits_v5e']}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--tag", required=True)
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override key=value (moe fields auto-nested)")
    ap.add_argument("--rule", action="append", default=[],
                    help="rules override name=axis1+axis2 (or empty)")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    overrides = dict(kv.split("=", 1) for kv in args.set)
    rules = {}
    for kv in args.rule:
        name, axes = kv.split("=", 1)
        rules[name] = tuple(a for a in axes.split("+") if a)
    rec = measure(args.arch, args.shape, args.tag, overrides, rules,
                  force=args.force)
    show(rec)


if __name__ == "__main__":
    main()
