"""Production serving launcher (decode path of the dry-run, executable).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3_8b --reduced \
        --batch 4 --prompt-len 12 --new-tokens 16
"""

from __future__ import annotations

import argparse
import time

import numpy as np

import jax

from repro.configs import ARCH_IDS, get_config
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.serve.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    if cfg.is_encdec:
        raise SystemExit("use the whisper decode dry-run cells for enc-dec")
    from repro.models.model import build_model
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M")

    prompts = np.asarray(
        jax.random.randint(jax.random.key(1),
                           (args.batch, args.prompt_len), 0, cfg.vocab),
        np.int32)
    engine = ServeEngine(cfg, params,
                         capacity=args.prompt_len + args.new_tokens + 1,
                         batch_size=args.batch)
    t0 = time.perf_counter()
    out = engine.generate(prompts, args.new_tokens,
                          temperature=args.temperature)
    dt = time.perf_counter() - t0
    print(f"{args.batch} requests x {args.new_tokens} tokens in {dt:.2f}s "
          f"({args.batch*args.new_tokens/dt:.0f} tok/s)")
    for b in range(min(args.batch, 4)):
        print(f"  req {b}: ...{prompts[b, -4:].tolist()} -> "
              f"{out.tokens[b, :12].tolist()}...")


if __name__ == "__main__":
    main()
