"""Scan-correction probes for truthful roofline accounting.

XLA's ``cost_analysis`` (and the static HLO text) counts a ``scan``/while
body ONCE, not ×trip-count, so the scanned (deployed) program under-reports
FLOPs/bytes/collectives.  Unrolling everything is exact but blows up compile
time (126-layer cells).  Instead, per cell we compile tiny *probe* variants
with layer scans unrolled:

    probe A     — exactly one layer of every distinct block kind
    probe B_k   — one extra layer of kind k

Since all layers of a kind are structurally identical, the per-layer body
cost is exactly ``C(B_k) − C(A)``, and the corrected total is

    C_corrected = C(A) + Σ_k (n_k − n_k^A) · (C(B_k) − C(A))

— every number still comes from an XLA compile of the true shapes/mesh.
Validated against a fully-unrolled compile in tests/test_dryrun.py.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

from repro.models.config import EncoderConfig, ModelConfig


@dataclasses.dataclass(frozen=True)
class ProbeB:
    label: str
    cfg: ModelConfig
    n_full: int      # layers of this kind in the full config
    n_in_a: int      # layers of this kind in probe A


def make_probe_plan(cfg: ModelConfig) -> Tuple[ModelConfig, List[ProbeB]]:
    """-> (probe_A_cfg, [ProbeB...]); all probes have scan_layers=False."""
    base = dataclasses.replace(cfg, scan_layers=False)

    if cfg.is_encdec:
        a = dataclasses.replace(
            base, n_layers=1,
            encoder=dataclasses.replace(cfg.encoder, n_layers=1))
        b_enc = ProbeB(
            "enc", dataclasses.replace(
                base, n_layers=1,
                encoder=dataclasses.replace(cfg.encoder, n_layers=2)),
            cfg.encoder.n_layers, 1)
        b_dec = ProbeB(
            "dec", dataclasses.replace(
                base, n_layers=2,
                encoder=dataclasses.replace(cfg.encoder, n_layers=1)),
            cfg.n_layers, 1)
        return a, [b_enc, b_dec]

    kinds = cfg.layer_kinds()

    if "attn_shared" in kinds:  # zamba-style hybrid
        n_shared = sum(1 for k in kinds if k == "attn_shared")
        n_ssm = len(kinds) - n_shared
        a = dataclasses.replace(
            base, n_layers=2, block_pattern=("ssm", "attn_shared"))
        b_ssm = ProbeB(
            "ssm", dataclasses.replace(
                base, n_layers=3,
                block_pattern=("ssm", "ssm", "attn_shared")),
            n_ssm, 1)
        b_sh = ProbeB(
            "attn_shared", dataclasses.replace(
                base, n_layers=3,
                block_pattern=("ssm", "attn_shared", "attn_shared")),
            n_shared, 1)
        return a, [b_ssm, b_sh]

    if cfg.moe is not None and cfg.moe.first_k_dense > 0:  # deepseek
        k = cfg.moe.first_k_dense
        a = dataclasses.replace(
            base, n_layers=2,
            moe=dataclasses.replace(cfg.moe, first_k_dense=1))
        b_dense = ProbeB(
            "dense", dataclasses.replace(
                base, n_layers=3,
                moe=dataclasses.replace(cfg.moe, first_k_dense=2)),
            k, 1)
        b_moe = ProbeB(
            "moe", dataclasses.replace(
                base, n_layers=3,
                moe=dataclasses.replace(cfg.moe, first_k_dense=1)),
            cfg.n_layers - k, 1)
        return a, [b_dense, b_moe]

    # uniform stacks (dense GQA, uniform MoE, rwkv)
    a = dataclasses.replace(base, n_layers=1)
    b = ProbeB("layer", dataclasses.replace(base, n_layers=2),
               cfg.n_layers, 1)
    return a, [b]


def corrected(
    a: Dict[str, float],
    bs: List[Tuple[ProbeB, Dict[str, float]]],
    keys: Tuple[str, ...] = ("flops", "bytes", "wire_bytes"),
) -> Dict[str, float]:
    out = dict(a)
    for key in keys:
        val = a.get(key, 0.0)
        for probe, m in bs:
            body = m.get(key, 0.0) - a.get(key, 0.0)
            val += (probe.n_full - probe.n_in_a) * body
        out[key] = val
    return out
