"""Assigned input shapes and their ShapeDtypeStruct stand-ins.

Four cells per LM architecture:

    train_4k      seq_len=4096    global_batch=256   lowers train_step
    prefill_32k   seq_len=32768   global_batch=32    lowers prefill
    decode_32k    seq_len=32768   global_batch=128   lowers serve_step
    long_500k     seq_len=524288  global_batch=1     lowers serve_step
                                  (SSM/hybrid/windowed archs only)

``input_specs`` returns weak-type-correct ShapeDtypeStructs — no device
allocation ever happens for the full configs.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.model import build_model, init_cache


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str               # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def cell_applicable(cfg: ModelConfig, shape: str) -> Tuple[bool, str]:
    """-> (runs?, reason-if-skipped).  See DESIGN.md §Arch-applicability."""
    spec = SHAPES[shape]
    if spec.name == "long_500k" and not cfg.runs_long_context:
        return False, ("pure full-attention arch: 500k decode cache is "
                       "eligible only for SSM/hybrid/windowed archs")
    return True, ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def input_specs(cfg: ModelConfig, shape: str) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every input of the lowered step."""
    spec = SHAPES[shape]
    B, S = spec.global_batch, spec.seq_len

    if spec.kind == "train":
        if cfg.is_encdec:
            return {
                "frames": _sds((B, cfg.encoder.n_frames, cfg.d_model), cfg.dtype),
                "tokens": _sds((B, S), jnp.int32),
            }
        if cfg.family == "vlm":
            # frontend stub: precomputed patch/text embeddings + M-RoPE ids
            return {
                "embeds": _sds((B, S, cfg.d_model), cfg.dtype),
                "positions": _sds((B, 3, S), jnp.int32),
                "targets": _sds((B, S), jnp.int32),
            }
        return {"tokens": _sds((B, S), jnp.int32)}

    if spec.kind == "prefill":
        if cfg.is_encdec:
            return {
                "frames": _sds((B, cfg.encoder.n_frames, cfg.d_model), cfg.dtype),
                "tokens": _sds((B, S), jnp.int32),
            }
        if cfg.family == "vlm":
            return {
                "embeds": _sds((B, S, cfg.d_model), cfg.dtype),
            }
        return {"tokens": _sds((B, S), jnp.int32)}

    # decode: one new token against a seq_len cache
    model = build_model(cfg)
    cache_shapes = jax.eval_shape(lambda: model.init_cache(B, S))
    return {
        "token": _sds((B,), jnp.int32),
        "pos": _sds((B,), jnp.int32),
        "caches": cache_shapes,
    }
