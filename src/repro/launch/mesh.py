"""Production mesh construction.

Functions (never module-level constants) so importing this module never
touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any import.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16×16 = 256 chips per pod; 2 pods = 512 chips with a ``pod`` axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(model: int = 1) -> Mesh:
    """Whatever this host has (CPU smoke/bench runs)."""
    n = jax.device_count()
    assert n % model == 0
    return jax.make_mesh(
        (n // model, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto, jax.sharding.AxisType.Auto))
