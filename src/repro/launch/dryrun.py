import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware: for the
production meshes (16×16 single-pod, 2×16×16 multi-pod) each cell's step
function must lower, SPMD-partition and compile; we record
``memory_analysis()`` (fits?), ``cost_analysis()`` (FLOPs/bytes) and the
collective schedule parsed from the optimized HLO.

Accounting: XLA counts scan bodies once, so the scanned (deployed) program
under-reports flops/bytes/collectives.  Single-pod cells therefore also
compile the tiny unrolled *probe* variants (see launch/probes.py) and
report scan-corrected totals — these feed EXPERIMENTS.md §Roofline.

Results are cached as JSON under ``artifacts/dryrun/`` (one file per cell);
reruns are incremental.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun \
        [--arch all|<id,...>] [--shape all|<name,...>] \
        [--mesh single,multi] [--force] [--no-probes] [--out DIR]
"""

import argparse
import json
import time
import traceback
from typing import Dict, Optional

import jax

from repro.configs import ARCH_IDS, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.probes import corrected, make_probe_plan
from repro.launch.roofline import (
    collective_bytes_from_hlo,
    derive_terms,
    model_flops,
)
from repro.launch.shapes import SHAPES, cell_applicable, input_specs
from repro.launch.steps import CellBuilder


def compile_cell(cfg, shape: str, mesh, kind: str) -> Dict:
    """Lower+compile one configuration; return raw measurements."""
    t0 = time.perf_counter()
    builder = CellBuilder(cfg, mesh, kind)
    specs = input_specs(cfg, shape)
    fn, args, shardings, donate = builder.build(specs)
    jitted = jax.jit(fn, in_shardings=shardings, donate_argnums=donate)
    lowered = jitted.lower(*args)
    t_lower = time.perf_counter() - t0
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0 - t_lower

    mem = compiled.memory_analysis()
    mem_rec = {
        k: int(getattr(mem, k))
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes")
        if hasattr(mem, k)
    }
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # JAX 0.4.x wraps it in a list
        cost = cost[0] if cost else {}
    coll = collective_bytes_from_hlo(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "wire_bytes": coll["wire_bytes"],
        "coll_by_op": coll["by_op"],
        "coll_count": coll["count"],
        "memory": mem_rec,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
    }


def run_cell(arch: str, shape: str, mesh_name: str, out_dir: str,
             force: bool = False, probes: bool = True) -> Dict:
    path = os.path.join(out_dir, f"{arch}__{shape}__{mesh_name}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    cfg = get_config(arch)
    ok, reason = cell_applicable(cfg, shape)
    record: Dict = {
        "arch": arch, "shape": shape, "mesh": mesh_name,
        "timestamp": time.strftime("%Y-%m-%d %H:%M:%S"),
    }
    if not ok:
        record.update(status="skipped", reason=reason)
        _write(path, record)
        return record

    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    spec = SHAPES[shape]
    try:
        main = compile_cell(cfg, shape, mesh, spec.kind)
        mem_rec = main["memory"]
        per_dev_bytes = (
            mem_rec.get("argument_size_in_bytes", 0)
            + mem_rec.get("temp_size_in_bytes", 0)
            - mem_rec.get("alias_size_in_bytes", 0)
        )
        record.update(
            status="ok",
            devices=mesh.size,
            raw=main,
            per_device_bytes=per_dev_bytes,
            fits_v5e=bool(per_dev_bytes < 16e9),
        )

        if probes and mesh_name == "single":
            probe_a, probe_bs = make_probe_plan(cfg)
            a = compile_cell(probe_a, shape, mesh, spec.kind)
            bs = [(pb, compile_cell(pb.cfg, shape, mesh, spec.kind))
                  for pb in probe_bs]
            corr = corrected(a, bs)
            terms = derive_terms(corr["flops"], corr["bytes"],
                                 corr["wire_bytes"])
            mf = model_flops(cfg, spec)
            record.update(
                probes={
                    "a": {k: a[k] for k in ("flops", "bytes", "wire_bytes",
                                            "compile_s")},
                    "bodies": {
                        pb.label: {
                            "flops": m["flops"] - a["flops"],
                            "bytes": m["bytes"] - a["bytes"],
                            "wire_bytes": m["wire_bytes"] - a["wire_bytes"],
                            "n_full": pb.n_full,
                        } for pb, m in bs
                    },
                },
                corrected={k: corr[k] for k in ("flops", "bytes",
                                                "wire_bytes")},
                roofline={
                    "compute_s": terms.compute_s,
                    "memory_s": terms.memory_s,
                    "collective_s": terms.collective_s,
                    "dominant": terms.dominant,
                    "bound_s": terms.bound_s,
                    "compute_fraction": terms.compute_fraction(),
                    "model_flops_total": mf,
                    "model_flops_per_device": mf / mesh.size,
                    "useful_flops_ratio":
                        (mf / mesh.size) / max(corr["flops"], 1e-30),
                },
            )
    except Exception as e:  # a failing cell is a bug — record it loudly
        record.update(status="error", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-4000:])
    _write(path, record)
    return record


def _write(path, record):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(record, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single,multi")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-probes", action="store_true")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = args.mesh.split(",")

    n_ok = n_skip = n_err = 0
    for arch in archs:
        for shape in shapes:
            for mesh_name in meshes:
                t0 = time.perf_counter()
                rec = run_cell(arch, shape, mesh_name, args.out,
                               force=args.force, probes=not args.no_probes)
                dt = time.perf_counter() - t0
                status = rec["status"]
                extra = ""
                if status == "ok":
                    n_ok += 1
                    if "roofline" in rec:
                        r = rec["roofline"]
                        extra = (f"dom={r['dominant']:10s} "
                                 f"frac={r['compute_fraction']:.3f} "
                                 f"mem={rec['per_device_bytes']/1e9:6.2f}GB")
                    else:
                        extra = f"mem={rec['per_device_bytes']/1e9:6.2f}GB/dev"
                elif status == "skipped":
                    n_skip += 1
                    extra = rec["reason"][:60]
                else:
                    n_err += 1
                    extra = rec["error"][:140]
                print(f"[{status:7s}] {arch:18s} {shape:12s} {mesh_name:6s} "
                      f"({dt:6.1f}s) {extra}", flush=True)
    print(f"\nDRYRUN SUMMARY: ok={n_ok} skipped={n_skip} errors={n_err}")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
