"""Production training launcher.

On a TPU pod this script is what every host runs (jax.distributed handles
process grouping); on this CPU container pass ``--reduced`` to run the same
code path end-to-end with the arch's smoke config on the host mesh.

    PYTHONPATH=src python -m repro.launch.train --arch llama3p2_1b \
        --reduced --steps 30 --global-batch 8 --seq 64
"""

from __future__ import annotations

import argparse

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.data.pipeline import ColocatedTokenDataset, synthetic_token_table
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.steps import CellBuilder, tree_specs
from repro.models.model import build_model
from repro.models.sharding import ShardingPolicy, use_policy
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.optim.schedule import linear_warmup_cosine
from repro.train.step import TrainStepConfig, make_train_step
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke config on the host mesh (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    if cfg.is_encdec or cfg.family == "vlm":
        raise SystemExit(
            "this token-corpus launcher drives decoder-only LMs; whisper/vlm "
            "train via their dry-run cells and tests (stub frontends)")
    model = build_model(cfg)
    mesh = (make_host_mesh() if args.reduced
            else make_production_mesh(multi_pod=args.multi_pod))
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"mesh={dict(mesh.shape)}")

    builder = CellBuilder(cfg, mesh, "train")
    policy = builder.policy
    with use_policy(policy):
        params = jax.jit(
            model.init, out_shardings=builder.param_sh)(jax.random.key(0))
    opt_sh, _ = builder.opt_shardings()
    opt_state = jax.jit(adamw_init, out_shardings=opt_sh)(params)

    table = synthetic_token_table(
        n_rows=max(args.global_batch * 16, 256),
        seq_len=args.seq + 1, vocab=cfg.vocab)
    ds = ColocatedTokenDataset(table, mesh, global_batch=args.global_batch)

    schedule = lambda s: linear_warmup_cosine(s, 10, args.steps)
    raw_step = make_train_step(
        cfg, model, AdamWConfig(lr=3e-4),
        TrainStepConfig(num_microbatches=args.microbatches,
                        schedule=schedule))

    def step_with_policy(p, o, b, i):
        with use_policy(policy):
            return raw_step(p, o, b, i)

    step = jax.jit(step_with_policy, donate_argnums=(0, 1))
    trainer = Trainer(step, ds, TrainerConfig(
        total_steps=args.steps, log_every=5,
        checkpoint_every=max(args.steps // 2, 1),
        checkpoint_dir=args.ckpt_dir))
    params, opt_state, history = trainer.run(params, opt_state)
    print(f"done: loss {history[0]['loss']:.3f} -> {history[-1]['loss']:.3f}")


if __name__ == "__main__":
    main()
