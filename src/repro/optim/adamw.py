"""AdamW from scratch (no optax): pure init/update over pytrees.

Moments are stored in fp32 regardless of parameter dtype; the update runs in
fp32 and casts back.  State is parameter-shaped, so it inherits the
parameters' PartitionSpecs (FSDP shards optimizer state for free).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: Optional[float] = 1.0
    # names whose params skip weight decay (norms, biases, scalar gains)
    no_decay_substrings: Tuple[str, ...] = (
        "scale", "bias", "norm", "a_log", "dt_bias", "d_skip", "mu",
        "w0", "u", "ln_",
    )


def adamw_init(params: PyTree) -> PyTree:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: PyTree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def _decay_mask(params: PyTree, cfg: AdamWConfig) -> PyTree:
    paths = jax.tree_util.tree_flatten_with_path(params)[0]
    flags = []
    for path, _ in paths:
        name = "/".join(str(getattr(k, "key", k)) for k in path).lower()
        flags.append(not any(s in name for s in cfg.no_decay_substrings))
    treedef = jax.tree.structure(params)
    return jax.tree.unflatten(treedef, flags)


def adamw_update(
    cfg: AdamWConfig,
    params: PyTree,
    grads: PyTree,
    state: PyTree,
    lr_scale: jax.Array | float = 1.0,
) -> Tuple[PyTree, PyTree, jax.Array]:
    """-> (new_params, new_state, pre-clip grad norm)."""
    gnorm = global_norm(grads)
    if cfg.grad_clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.grad_clip_norm / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    step = state["step"] + 1
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)
    decay = _decay_mask(params, cfg)

    def upd(p, g, m, v, do_decay):
        g32 = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g32
        v = cfg.b2 * v + (1 - cfg.b2) * (g32 * g32)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if do_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - cfg.lr * lr_scale * delta
        return new_p.astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    flat_d = jax.tree.leaves(decay)
    outs = [upd(p, g, m, v, d)
            for p, g, m, v, d in zip(flat_p, flat_g, flat_m, flat_v, flat_d)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in outs])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in outs])
    return new_params, {"m": new_m, "v": new_v, "step": step}, gnorm
