"""Gradient compression for the DCN (pod) axis.

At 2+ pods the gradient all-reduce crosses data-center network, ~25× slower
per byte than ICI.  Per-tensor symmetric int8 quantization cuts those bytes
4× (vs fp32 master grads) at <0.5% relative error — applied ONLY to the
pod-axis reduction; the in-pod ICI reduction stays full precision.

Usage inside a pjit'd train step (see train/step.py):

    g8, scale = int8_compress(g_pod_partial)
    g8_sum   = lax.psum(g8.astype(f32), "pod")     # wire bytes ~int8*
    g        = int8_decompress(g8_sum, psum(scale)) / n_pods

*XLA transports the int8 operand; the fp32 cast happens post-transfer on
TPU. The error model (stochastic rounding off) is validated in tests.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def int8_compress(tree: PyTree) -> Tuple[PyTree, PyTree]:
    """-> (int8 tree, per-tensor fp32 scales).  scale = max|x| / 127."""
    def one(x):
        xf = x.astype(jnp.float32)
        scale = jnp.max(jnp.abs(xf)) / 127.0 + 1e-12
        q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
        return q, scale

    qs = jax.tree.map(lambda x: one(x)[0], tree)
    scales = jax.tree.map(lambda x: one(x)[1], tree)
    return qs, scales


def int8_decompress(q_tree: PyTree, scale_tree: PyTree) -> PyTree:
    return jax.tree.map(
        lambda q, s: q.astype(jnp.float32) * s, q_tree, scale_tree)
