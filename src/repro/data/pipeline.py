"""Data pipeline — training batches served from the colocation grid.

The same ``TensorTable``/``Placement``/balancer machinery that serves the
paper's imaging workload doubles as the LM training data layer: token
sequences are rows (one row = one fixed-length sample), regions are the unit
of placement, and each data-parallel device group draws its per-step
microbatch from *its own* shard — the colocation guarantee means a training
step's input pipeline does zero cross-device traffic, and re-balancing (e.g.
after elastic rescale) is a region move, not a dataset reshuffle.

Synthetic generators provide the two dataset families the repo needs:
token corpora (LM workloads) and the paper's 5,153-image T1 population with
the Table-3 age/sex strata.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.balancer import NodeSpec
from repro.core.placement import Placement
from repro.core.regions import HierarchicalSplitPolicy
from repro.core.table import ColumnFamily, ColumnSpec, TensorTable


# ----------------------------------------------------------------------
# synthetic datasets
# ----------------------------------------------------------------------

def synthetic_token_table(
    n_rows: int,
    seq_len: int,
    vocab: int,
    seed: int = 0,
    region_bytes: int = 1 << 22,
) -> TensorTable:
    """A token corpus as a TensorTable: ``tok:ids`` + ``idx:size``."""
    rng = np.random.default_rng(seed)
    table = TensorTable(
        "tokens",
        [
            ColumnFamily("tok", (ColumnSpec("ids", (seq_len,), np.int32),)),
            ColumnFamily("idx", (ColumnSpec("size", (), np.int64),)),
        ],
        split_policy=HierarchicalSplitPolicy(max_region_bytes=region_bytes),
    )
    # mixture of zipf-ish unigram draws — enough structure for loss to move
    probs = 1.0 / np.arange(1, vocab + 1) ** 1.1
    probs /= probs.sum()
    ids = rng.choice(vocab, size=(n_rows, seq_len), p=probs).astype(np.int32)
    sizes = np.full(n_rows, seq_len * 4, np.int64)
    table.upload(
        [f"doc{i:08d}" for i in range(n_rows)],
        {"tok": {"ids": ids}, "idx": {"size": sizes}},
    )
    return table


#: Table 3 of the paper: (age_lo, age_hi, female_count, male_count)
PAPER_STRATA = (
    (4.0, 20.0, 1157, 698),
    (20.0, 40.0, 651, 648),
    (40.0, 60.0, 230, 280),
    (60.0, 98.0, 332, 494),
)


def synthetic_image_population(
    payload_shape: Tuple[int, ...] = (16, 16, 16),
    scale: float = 1.0,
    seed: int = 0,
) -> TensorTable:
    """The paper's study population per Table 3 strata (4,490 subjects;
    the paper's 5,153 figure counts *images* — some subjects have repeat
    scans), with logical sizes drawn from [SizeSmall, SizeBig] = [6, 20] MB.
    ``scale`` < 1 shrinks each stratum proportionally for CI-speed runs."""
    rng = np.random.default_rng(seed)
    rows = []
    for lo, hi, f_cnt, m_cnt in PAPER_STRATA:
        for sex, cnt in ((1, f_cnt), (0, m_cnt)):
            n = max(int(round(cnt * scale)), 1)
            ages = rng.uniform(lo, hi, n).astype(np.float32)
            rows.extend((a, sex) for a in ages)
    n = len(rows)
    ages = np.array([r[0] for r in rows], np.float32)
    sexes = np.array([r[1] for r in rows], np.int8)
    order = rng.permutation(n)
    ages, sexes = ages[order], sexes[order]

    table = TensorTable(
        "t1_population",
        [
            ColumnFamily("img", (ColumnSpec("data", payload_shape, np.float32),)),
            ColumnFamily("idx", (
                ColumnSpec("size", (), np.int64),
                ColumnSpec("age", (), np.float32),
                ColumnSpec("sex", (), np.int8),
            )),
        ],
        split_policy=HierarchicalSplitPolicy(max_region_bytes=1 << 31),
    )
    data = rng.normal(0.0, 1.0, (n,) + payload_shape).astype(np.float32)
    # age covariate leaks into the volumes so subset averages differ measurably
    data += ages[:, None, None, None] / 100.0
    sizes = rng.integers(6_000_000, 20_000_001, n)
    table.upload(
        [f"sub{i:06d}" for i in range(n)],
        {"img": {"data": data},
         "idx": {"size": sizes, "age": ages, "sex": sexes}},
    )
    return table


# ----------------------------------------------------------------------
# colocated loader
# ----------------------------------------------------------------------

class ColocatedTokenDataset:
    """Serves ``[global_batch, seq]`` batches, each device group reading only
    its local shard (device-local gather indices, no cross-shard traffic)."""

    def __init__(
        self,
        table: TensorTable,
        mesh: Mesh,
        global_batch: int,
        data_axis: str = "data",
        batch_axes: Sequence[str] = ("data",),
        strategy: str = "greedy",
        nodes: Optional[Sequence[NodeSpec]] = None,
        seed: int = 0,
        placement: Optional[Placement] = None,
    ):
        self.table = table
        self.mesh = mesh
        self.global_batch = global_batch
        self.data_axis = data_axis
        self.batch_axes = tuple(a for a in batch_axes if a in mesh.shape)
        D = int(np.prod([mesh.shape[a] for a in self.batch_axes]))
        if global_batch % D != 0:
            raise ValueError(f"global_batch {global_batch} % {D} != 0")
        self.per_shard = global_batch // D
        self.D = D
        if placement is not None:
            # ride an existing region→device map (e.g. a GridSession's)
            if len(placement.nodes) != D:
                raise ValueError(
                    f"placement has {len(placement.nodes)} nodes, need {D}")
            self.placement = placement
        else:
            if nodes is None:
                nodes = [NodeSpec(i, cores=1, mips=1.0) for i in range(D)]
            self.placement = Placement.from_strategy(table, nodes, strategy)
        self._rng = np.random.default_rng(seed)
        self._pools_version = None
        self._compute_pools()
        self.seq_len = table.column_spec("tok", "ids").shape[0]

    def _compute_pools(self) -> None:
        """Per-shard row pools (positions into the table's row order).

        Cached by the (table mutations, placement version) pair: under a
        shared (GridSession) placement the table mutates between steps and
        positional indices shift; for an immutable table this is free.
        """
        version = (self.table.mutation_count, self.placement.version)
        if version == self._pools_version:
            return
        self._pools = [self.placement.rows_for_node(n.node_id)
                       for n in self.placement.nodes]
        for i, pool in enumerate(self._pools):
            if len(pool) == 0:
                raise ValueError(f"node {i} received no rows; "
                                 "table too small for this mesh")
        self._pools_version = version

    def batch_sharding(self) -> NamedSharding:
        axes = self.batch_axes
        spec = axes[0] if len(axes) == 1 else tuple(axes)
        return NamedSharding(self.mesh, P(spec))

    def next_batch(self, step: int) -> jax.Array:
        """Deterministic per-step batch: shard d draws from pool d."""
        self._compute_pools()
        ids = np.empty((self.D, self.per_shard, self.seq_len), np.int32)
        col = self.table.column("tok", "ids")
        for d, pool in enumerate(self._pools):
            rng = np.random.default_rng((hash(("batch", step, d)) & 0x7FFFFFFF))
            take = rng.choice(pool, size=self.per_shard, replace=True)
            ids[d] = col[take]
        flat = ids.reshape(self.global_batch, self.seq_len)
        return jax.device_put(flat, self.batch_sharding())

    def __iter__(self) -> Iterator[jax.Array]:
        step = 0
        while True:
            yield self.next_batch(step)
            step += 1
