from repro.data.pipeline import (
    ColocatedTokenDataset,
    synthetic_token_table,
    synthetic_image_population,
)

__all__ = [
    "ColocatedTokenDataset",
    "synthetic_token_table",
    "synthetic_image_population",
]
