"""Pallas kernel: masked streaming sum / sum-of-squares over image rows.

This is the map-task hot loop of the paper's §2.2 workload (ANTS
AverageImages): fold η images of F features into ``(Σx, Σx², count)``.
The op is memory-bound (1 FLOP per 2 bytes read), so the kernel's job is
pure bandwidth: stream HBM→VMEM tiles once, accumulate in fp32 VMEM.

Tiling: grid ``(F // BF, R // BR)`` — feature tiles outer, row blocks inner
(the innermost grid dim is sequential on TPU), so each feature tile's fp32
accumulator lives in the *output* VMEM block across the row sweep and is
initialized at row-block 0.  ``BF = 512`` lanes (4 × 128-lane vregs),
``BR = 256`` rows keeps the input tile at 512 KiB (bf16) — well under VMEM
while long enough to amortize the HBM latency.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 256
DEFAULT_BLOCK_FEATURES = 512


def _stats_kernel(x_ref, mask_ref, sum_ref, sq_ref, cnt_ref):
    """One (feature-tile, row-block) cell.

    x_ref    [BR, BF]  input tile (any float dtype)
    mask_ref [BR, 1]   row validity (float 0/1)
    sum_ref  [1, BF]   fp32 accumulator (revisited across row blocks)
    sq_ref   [1, BF]   fp32 accumulator
    cnt_ref  [1, 1]    fp32 accumulator
    """
    j = pl.program_id(1)  # row-block index (innermost, sequential)

    @pl.when(j == 0)
    def _init():
        sum_ref[...] = jnp.zeros_like(sum_ref)
        sq_ref[...] = jnp.zeros_like(sq_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    x = x_ref[...].astype(jnp.float32)
    m = mask_ref[...].astype(jnp.float32)          # [BR, 1]
    xm = x * m
    sum_ref[...] += jnp.sum(xm, axis=0, keepdims=True)
    sq_ref[...] += jnp.sum(xm * x, axis=0, keepdims=True)
    cnt_ref[...] += jnp.sum(m, keepdims=True)


def streaming_stats_pallas(
    x: jax.Array,          # [R, F]
    mask: jax.Array,       # [R] bool/float
    block_rows: int = DEFAULT_BLOCK_ROWS,
    block_features: int = DEFAULT_BLOCK_FEATURES,
    interpret: bool = False,
):
    """-> (sum [F] fp32, sumsq [F] fp32, count [] fp32).

    R and F are padded to block multiples by the ops wrapper.
    """
    R, F = x.shape
    br = min(block_rows, R)
    bf = min(block_features, F)
    assert R % br == 0 and F % bf == 0, (R, F, br, bf)
    grid = (F // bf, R // br)

    m2 = mask.reshape(R, 1).astype(jnp.float32)

    sums, sqs, cnt = pl.pallas_call(
        _stats_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, bf), lambda i, j: (j, i)),
            pl.BlockSpec((br, 1), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bf), lambda i, j: (0, i)),
            pl.BlockSpec((1, bf), lambda i, j: (0, i)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, F), jnp.float32),
            jax.ShapeDtypeStruct((1, F), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x, m2)
    # cnt block is shared across feature tiles: each tile's j==0 resets it
    # and its row sweep re-accumulates, so the final value is exact.
    return sums[0], sqs[0], cnt[0, 0]
