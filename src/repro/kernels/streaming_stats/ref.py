"""Pure-jnp oracle for the streaming-stats kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def streaming_stats_ref(x: jax.Array, mask: jax.Array):
    """x [R, F], mask [R] -> (sum [F], sumsq [F], count []) in fp32."""
    xf = x.astype(jnp.float32)
    m = mask.astype(jnp.float32)[:, None]
    xm = xf * m
    return xm.sum(0), (xm * xf).sum(0), m.sum()
