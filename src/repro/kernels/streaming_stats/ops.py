"""Public op: masked streaming stats over a chunk of rows.

Handles arbitrary row shapes (flattens features), dispatches to the fused
fold Pallas kernel (or the jnp reference when ``impl='ref'``), and exposes
a MapReduce program so the engine's map phase can run on the kernel.

Since the fused fold kernel landed (``repro.kernels.fused_fold``), the
pallas path here is a facade: ``streaming_stats`` is exactly the
``(count, s1, s2)`` subset of the fused kernel's grouped accumulator pool
at ``G=1``.  The dedicated streaming-stats kernel is gone — one tiling,
one accumulation discipline, one equivalence suite for every power sum.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.mapreduce import MapReduceProgram
from repro.kernels.fused_fold.ops import fused_fold
from repro.kernels.streaming_stats.ref import streaming_stats_ref


@functools.partial(jax.jit, static_argnames=("impl", "interpret"))
def streaming_stats(
    rows: jax.Array,       # [R, *feature_shape]
    mask: jax.Array,       # [R]
    impl: str = "pallas",
    interpret: bool = True,   # CPU container: interpret by default
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """-> (sum, sumsq, count); sum/sumsq have the row's feature shape."""
    if impl == "ref":
        R = rows.shape[0]
        fshape = rows.shape[1:]
        s, sq, c = streaming_stats_ref(rows.reshape(R, -1), mask)
        return s.reshape(fshape), sq.reshape(fshape), c
    acc = fused_fold(rows, mask, names=("count", "s1", "s2"),
                     interpret=interpret)
    return acc["s1"][0], acc["s2"][0], acc["count"][0]


@dataclasses.dataclass(frozen=True)
class KernelMeanProgram(MapReduceProgram):
    """MeanProgram with the Pallas kernel as the map-phase fold."""

    interpret: bool = True
    additive = True

    def zero(self, row_shape, dtype):
        return {"sum": jnp.zeros(row_shape, jnp.float32),
                "count": jnp.zeros((), jnp.float32)}

    def map_chunk(self, rows, valid):
        s, _, c = streaming_stats(rows, valid, interpret=self.interpret)
        return {"sum": s, "count": c}

    def merge(self, a, b):
        return jax.tree.map(jnp.add, a, b)

    def finalize(self, p):
        return p["sum"] / jnp.maximum(p["count"], 1)


@dataclasses.dataclass(frozen=True)
class KernelSecondMomentProgram(MapReduceProgram):
    """Mean/variance/count from the kernel's ``(Σx, Σx², n)`` — the
    Pallas-backed analogue of ``VarianceProgram``'s finalize contract
    (raw-sums form instead of the Chan merge; equal up to float
    associativity, and additive so the reduce stays one ``psum``)."""

    interpret: bool = True
    additive = True

    def zero(self, row_shape, dtype):
        z = jnp.zeros(row_shape, jnp.float32)
        return {"s1": z, "s2": z, "count": jnp.zeros((), jnp.float32)}

    def map_chunk(self, rows, valid):
        s, sq, c = streaming_stats(rows, valid, interpret=self.interpret)
        return {"s1": s, "s2": sq, "count": c}

    def merge(self, a, b):
        return jax.tree.map(jnp.add, a, b)

    def finalize(self, p):
        n = jnp.maximum(p["count"], 1)
        mean = p["s1"] / n
        var = jnp.maximum(p["s2"] / n - mean * mean, 0)
        return {"mean": mean, "var": var, "count": p["count"]}


def kernel_map_program(program: MapReduceProgram, impl: str = "pallas",
                       interpret: bool = True) -> MapReduceProgram:
    """The Pallas map-phase twin of a sum/count-family program.

    ``GridSession.run(..., impl="pallas")`` routes through here: the
    returned program folds each chunk with :func:`streaming_stats` (one
    HBM→VMEM streaming pass producing Σx/Σx²/count on the fused fold
    kernel) and finalizes to the same result contract as the jnp
    reference program.  Kernel programs accumulate fp32 (the kernel's
    VMEM accumulator dtype).  Programs whose statistic is not a
    projection of (Σx, Σx², n) have no kernel twin — ask for them with
    the default reference impl.
    """
    from repro.core.stats import MeanProgram, VarianceProgram

    if impl != "pallas":
        raise ValueError(f"unknown map-phase impl {impl!r}; "
                         "use impl='pallas' or the default reference path")
    if isinstance(program, MeanProgram):
        return KernelMeanProgram(interpret=interpret)
    if isinstance(program, VarianceProgram):
        return KernelSecondMomentProgram(interpret=interpret)
    raise ValueError(
        f"no pallas map phase for {type(program).__name__}: the "
        "streaming_stats kernel covers the sum/count family "
        "(MeanProgram, VarianceProgram)")
