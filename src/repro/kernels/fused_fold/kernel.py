"""Pallas kernel: fused grouped power-sum fold — one HBM pass per block.

This is the fold hot path of the block-granular engine collapsed into a
single streaming kernel.  The XLA lowering of ``shared_map_chunk`` /
``grouped_shared_map_chunk`` (``repro.core.stats``) materializes the masked
cast, each power raise, and a per-power segment-sum as separate passes over
the chunk; the fold is memory-bound (a handful of FLOPs per byte), so every
extra pass is wall-clock.  Here the block's ``[R, F]`` payload crosses
HBM→VMEM exactly once and the full grouped shared-accumulator pool
``(count, Σx, Σx², Σx³, Σx⁴)`` comes out the other side:

- row validity and gid segment assignment are applied IN-KERNEL: the
  ``[BR, G]`` one-hot group weights are built from the gid/mask tiles, and
  rows no group claims are zeroed BEFORE the power raises — preserving the
  engine's NaN/Inf-poisoning guarantee (a poisoned masked-off row must not
  reach the weighted contraction, since ``0 × NaN = NaN``);
- each power of ``x`` is materialized once in VMEM and contracted against
  the group weights with one MXU ``dot_general`` — the grouped CSE, now
  with zero extra HBM traffic;
- accumulators are ``[G, BF]`` fp32 VMEM blocks revisited across the row
  sweep (grid: feature tiles outer, row blocks inner/sequential, init at
  row-block 0) — the same tiling story as the subsumed streaming_stats
  kernel, widened by the group axis.

Ungrouped folds are the ``G = 1`` degenerate case: every valid row lands in
group 0 and the one-hot weights collapse to the row mask.

CPU container note: targeted at TPU (G padded to sublane multiples, BF in
128-lane units), validated with ``interpret=True``.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 256
DEFAULT_BLOCK_FEATURES = 512

#: canonical accumulator order (mirrors stats.SHARED_ACCUMULATORS — kept
#: literal here so the kernel package does not import the engine)
ACC_ORDER: Tuple[str, ...] = ("count", "s1", "s2", "s3", "s4")


def _fused_fold_kernel(x_ref, g_ref, m_ref, *out_refs,
                       names: Tuple[str, ...], n_groups: int):
    """One (feature-tile, row-block) grid cell.

    x_ref    [BR, BF]   payload tile (any real dtype; cast to fp32)
    g_ref    [BR, 1]    int32 group ids
    m_ref    [BR, 1]    row validity (float 0/1)
    out_refs             fp32 accumulators in ``names`` order:
                         count [G, 1]; s1..s4 [G, BF] — revisited across the
                         row sweep, initialized at row-block 0
    """
    j = pl.program_id(1)  # row-block index (innermost, sequential)

    @pl.when(j == 0)
    def _init():
        for ref in out_refs:
            ref[...] = jnp.zeros_like(ref)

    x = x_ref[...].astype(jnp.float32)             # [BR, BF]
    m = m_ref[...].astype(jnp.float32)             # [BR, 1]
    g = g_ref[...]                                 # [BR, 1] int32
    br = x.shape[0]

    # one-hot group weights: w[r, g] = 1 iff row r is valid AND gid(r) == g
    gid_iota = jax.lax.broadcasted_iota(jnp.int32, (br, n_groups), 1)
    w = jnp.where(g == gid_iota, m, 0.0)           # [BR, G]

    # mask-zero BEFORE the power raises: a NaN/Inf payload in a masked-off
    # row must not poison the contraction (0-weight × NaN is NaN)
    x = jnp.where(m > 0.0, x, 0.0)

    def seg(v):                                    # [BR, X] -> [G, X]
        return jax.lax.dot_general(
            w, v, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    refs = iter(out_refs)
    if "count" in names:
        next(refs)[...] += seg(jnp.ones((br, 1), jnp.float32))
    if "s1" in names:
        next(refs)[...] += seg(x)
    if any(n in names for n in ("s2", "s3", "s4")):
        x2 = x * x
        if "s2" in names:
            next(refs)[...] += seg(x2)
        if "s3" in names:
            next(refs)[...] += seg(x2 * x)
        if "s4" in names:
            next(refs)[...] += seg(x2 * x2)


@functools.partial(
    jax.jit,
    static_argnames=("names", "n_groups", "block_rows", "block_features",
                     "interpret"))
def fused_fold_pallas(
    x: jax.Array,            # [R, F] — R, F already block multiples
    gids: jax.Array,         # [R] int32
    mask: jax.Array,         # [R] float 0/1
    names: Tuple[str, ...],
    n_groups: int,           # already sublane-padded by the ops wrapper
    block_rows: int = DEFAULT_BLOCK_ROWS,
    block_features: int = DEFAULT_BLOCK_FEATURES,
    interpret: bool = False,
):
    """-> accumulators in ``names`` order: count [G, 1], s_k [G, F] (fp32).

    The ``count`` block is shared across feature tiles: each tile's row
    sweep re-initializes and re-accumulates it, so the final value is exact
    (same trick as the streaming_stats kernel this one subsumes).
    """
    R, F = x.shape
    br = min(block_rows, R)
    bf = min(block_features, F)
    assert R % br == 0 and F % bf == 0, (R, F, br, bf)
    grid = (F // bf, R // br)

    g2 = gids.reshape(R, 1).astype(jnp.int32)
    m2 = mask.reshape(R, 1).astype(jnp.float32)

    out_specs = []
    out_shape = []
    for n in names:
        if n == "count":
            out_specs.append(pl.BlockSpec((n_groups, 1), lambda i, j: (0, 0)))
            out_shape.append(
                jax.ShapeDtypeStruct((n_groups, 1), jnp.float32))
        else:
            out_specs.append(
                pl.BlockSpec((n_groups, bf), lambda i, j: (0, i)))
            out_shape.append(
                jax.ShapeDtypeStruct((n_groups, F), jnp.float32))

    return pl.pallas_call(
        functools.partial(_fused_fold_kernel, names=names,
                          n_groups=n_groups),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, bf), lambda i, j: (j, i)),
            pl.BlockSpec((br, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((br, 1), lambda i, j: (j, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(x, g2, m2)
