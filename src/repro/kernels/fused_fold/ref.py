"""NumPy oracle for the fused grouped fold — the property-test ground truth.

Accumulates in float64 by default (reference-grade), independent of JAX:
the Hypothesis sweeps compare the kernel's fp32 one-pass result against
this under accumulation tolerance.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np

from repro.kernels.fused_fold.kernel import ACC_ORDER


def fused_fold_numpy(
    rows: np.ndarray,                  # [R, *feature_shape]
    mask: Optional[np.ndarray] = None,    # [R] bool
    gids: Optional[np.ndarray] = None,    # [R] int
    num_groups: int = 1,
    names: Tuple[str, ...] = ACC_ORDER,
    acc_dtype=np.float64,
) -> Dict[str, np.ndarray]:
    """-> ``{name: acc}``: count ``[G]``, s_k ``[G, *feature_shape]``.

    Masked-off rows are zeroed BEFORE the power raises (the kernel's
    NaN/Inf-poisoning contract); rows keep their gid but contribute nothing.
    """
    G = max(1, int(num_groups))
    R = rows.shape[0]
    fshape = rows.shape[1:]
    m = (np.ones(R, bool) if mask is None else np.asarray(mask, bool))
    g = (np.zeros(R, np.int64) if gids is None
         else np.asarray(gids, np.int64))

    x = np.where(m.reshape((R,) + (1,) * len(fshape)),
                 np.asarray(rows, acc_dtype), 0).reshape(R, -1)
    out: Dict[str, np.ndarray] = {}
    powers = {"s1": x, "s2": x * x, "s3": x ** 3, "s4": x ** 4}
    for n in names:
        if n == "count":
            acc = np.zeros(G, acc_dtype)
            np.add.at(acc, g[m], 1)
            out[n] = acc
        else:
            acc = np.zeros((G, x.shape[1]), acc_dtype)
            np.add.at(acc, g[m], powers[n][m])
            out[n] = acc.reshape((G,) + fshape)
    return out
