"""Public op: fused grouped power-sum fold over a block of rows.

Handles arbitrary row shapes (flattens features), pads rows/features/groups
to tile multiples (padded rows carry zero mask, padded groups receive no
rows), dispatches to the Pallas kernel, and exposes the analytic cost and
VMEM-budget helpers the engine's ``fold_path`` dispatch and the roofline
probe consult.

The op's contract is the CSE shared-accumulator pool of
``repro.core.stats``: ``{name: array}`` with ``count`` of shape ``[G]`` and
``s1..s4`` of shape ``[G, *feature_shape]``, all fp32 — exactly what
``FusedProgram``/``GroupedProgram`` partials hold, so the engine can wrap a
kernel result into a cacheable partial without reshuffling.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.chunk_model import VMEM_BYTES
from repro.kernels.fused_fold.kernel import (
    ACC_ORDER,
    DEFAULT_BLOCK_FEATURES,
    DEFAULT_BLOCK_ROWS,
    fused_fold_pallas,
)

#: fraction of per-core VMEM the grouped accumulator pool may claim (the
#: other half stays for double-buffered input tiles and the one-hot
#: weights), mirroring the chunk model's "stats may only claim half" rule
VMEM_FRACTION = 0.5


def canonical_names(names: Tuple[str, ...]) -> Tuple[str, ...]:
    """Validate and order accumulator names along ``ACC_ORDER``."""
    bad = set(names) - set(ACC_ORDER)
    if bad:
        raise ValueError(f"unknown shared accumulators {sorted(bad)}; "
                         f"supported: {ACC_ORDER}")
    if not names:
        raise ValueError("fused_fold needs at least one accumulator name")
    return tuple(n for n in ACC_ORDER if n in set(names))


def _pad_groups(num_groups: int) -> int:
    """Groups padded to an fp32 sublane multiple (min tile is 8 rows)."""
    return max(8, -(-int(num_groups) // 8) * 8)


@functools.partial(
    jax.jit,
    static_argnames=("num_groups", "names", "block_rows", "block_features",
                     "interpret"))
def fused_fold(
    rows: jax.Array,                 # [R, *feature_shape]
    mask: Optional[jax.Array] = None,   # [R] bool/float; None = all valid
    gids: Optional[jax.Array] = None,   # [R] int32; None = all group 0
    num_groups: int = 1,
    names: Tuple[str, ...] = ACC_ORDER,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    block_features: int = DEFAULT_BLOCK_FEATURES,
    interpret: bool = True,          # CPU container: interpret by default
) -> Dict[str, jax.Array]:
    """-> ``{name: acc}``: count ``[G]``, s_k ``[G, *feature_shape]`` fp32.

    One streaming pass over the block, whatever ``G`` or how many
    accumulators were asked for.  Rows are cast to fp32 in VMEM (bf16/int32
    payloads welcome); accumulation is fp32 throughout.
    """
    names = canonical_names(names)
    G = max(1, int(num_groups))
    R = rows.shape[0]
    fshape = rows.shape[1:]
    x = rows.reshape(R, -1)
    F = x.shape[1]

    m = (jnp.ones((R,), jnp.float32) if mask is None
         else mask.astype(jnp.float32))
    g = (jnp.zeros((R,), jnp.int32) if gids is None
         else gids.astype(jnp.int32))

    br = min(block_rows, max(8, R))
    bf = min(block_features, max(128, F))
    pr = -R % br
    pf = -F % bf
    if pr or pf:
        x = jnp.pad(x, ((0, pr), (0, pf)))
        m = jnp.pad(m, ((0, pr),))     # pad rows are masked off
        g = jnp.pad(g, ((0, pr),))
    Gp = _pad_groups(G)

    outs = fused_fold_pallas(x, g, m, names, Gp, br, bf,
                             interpret=interpret)
    result: Dict[str, jax.Array] = {}
    for n, o in zip(names, outs):
        if n == "count":
            result[n] = o[:G, 0]
        else:
            result[n] = o[:G, :F].reshape((G,) + fshape)
    return result


# ----------------------------------------------------------------------
# analytic cost model (roofline probe + engine dispatch)
# ----------------------------------------------------------------------

def kernel_hbm_bytes(rows: int, features: int, itemsize: int,
                     names: Tuple[str, ...], num_groups: int = 1) -> int:
    """HBM bytes one kernel launch moves: the payload ONCE, the per-row
    mask/gid sidecars, and the accumulator write-back.  This is the
    one-pass contract the bench checks XLA's measured fold bytes against."""
    names = canonical_names(names)
    G = _pad_groups(max(1, num_groups))
    out = sum(G * 4 if n == "count" else G * features * 4 for n in names)
    return rows * features * itemsize + rows * (4 + 4) + out


def kernel_flops(rows: int, features: int,
                 names: Tuple[str, ...], num_groups: int = 1) -> int:
    """FLOPs per launch: one [BR,G]×[BR,X] contraction per accumulator
    (2·R·X·G each) plus the elementwise power raises and weight build."""
    names = canonical_names(names)
    G = _pad_groups(max(1, num_groups))
    f = 0
    for n in names:
        f += 2 * rows * G * (1 if n == "count" else features)
    n_pows = sum(1 for n in names if n != "count")
    # x², x³, x⁴ elementwise products + mask/where + one-hot compare
    f += rows * features * max(0, n_pows - 1)
    f += rows * features + rows * G
    return f


def max_groups_for_vmem(
    names: Tuple[str, ...] = ACC_ORDER,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    block_features: int = DEFAULT_BLOCK_FEATURES,
    vmem_bytes: float = VMEM_BYTES * VMEM_FRACTION,
) -> int:
    """Largest G whose fp32 accumulator pool (plus the input tile and the
    one-hot weights) fits the kernel's VMEM budget — the engine falls back
    to the XLA fold above this.  Derived from the chunk model's per-core
    VMEM constant, halved like its HBM "stats may only claim half" rule."""
    names = canonical_names(names)
    n_wide = sum(1 for n in names if n != "count")
    fixed = block_rows * block_features * 4        # input tile, fp32 worst
    per_group = (n_wide * block_features + 1) * 4  # accumulator rows
    per_group += block_rows * 4                    # one-hot weight column
    budget = vmem_bytes - fixed
    if budget <= 0:
        return 0
    return max(0, int(budget // per_group))
