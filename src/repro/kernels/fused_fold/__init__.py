"""Fused Pallas fold kernel: one HBM pass per block for the grouped CSE
shared-accumulator pool.  See ``kernel.py`` for the tiling story,
``ops.py`` for the public op + cost/VMEM helpers, ``ref.py`` for the
NumPy oracle."""

from repro.kernels.fused_fold.ops import (   # noqa: F401
    fused_fold,
    kernel_flops,
    kernel_hbm_bytes,
    max_groups_for_vmem,
)
from repro.kernels.fused_fold.ref import fused_fold_numpy  # noqa: F401
