"""Pallas TPU kernels for ColoGrid's compute hot-spots.

Each kernel package ships ``kernel.py`` (pl.pallas_call + BlockSpec VMEM
tiling), ``ops.py`` (jit'd public wrapper, shape plumbing, interpret-mode
switch) and ``ref.py`` (pure oracle used by the allclose sweeps):

- ``fused_fold``       — the fold-phase workhorse: one HBM pass per block
  emitting the grouped CSE shared-accumulator pool
  ``(count, Σx, Σx², Σx³, Σx⁴)`` per group, fp32 in VMEM;
- ``streaming_stats``  — the paper's map-task hot loop: masked streaming
  sum/count (+ second moment) over a chunk of image rows (ANTS
  AverageImages analogue, HBM-bandwidth-bound).  Since the fused fold
  kernel landed it is a thin facade over ``fused_fold`` with the
  ``(Σx, Σx², n)`` accumulator subset;
- ``flash_attention``  — blockwise softmax attention forward (training /
  prefill path of the LM workloads);
- ``ssm_scan``         — chunked SSD recurrence (mamba2 / zamba2 / long
  context decode).

CPU container note: kernels are TARGETED at TPU (tile sizes chosen for
VMEM and the 128×128 MXU) and VALIDATED here with ``interpret=True``.
"""
