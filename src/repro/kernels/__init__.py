"""Pallas TPU kernels for ColoGrid's compute hot-spots.

Three kernels, each with ``kernel.py`` (pl.pallas_call + BlockSpec VMEM
tiling), ``ops.py`` (jit'd public wrapper, shape plumbing, interpret-mode
switch) and ``ref.py`` (pure-jnp oracle used by the allclose sweeps):

- ``streaming_stats``  — the paper's map-task hot loop: masked streaming
  sum/count (+ second moment) over a chunk of image rows (ANTS
  AverageImages analogue, HBM-bandwidth-bound);
- ``flash_attention``  — blockwise softmax attention forward (training /
  prefill path of the LM workloads);
- ``ssm_scan``         — chunked SSD recurrence (mamba2 / zamba2 / long
  context decode).

CPU container note: kernels are TARGETED at TPU (tile sizes chosen for
VMEM and the 128×128 MXU) and VALIDATED here with ``interpret=True``.
"""
