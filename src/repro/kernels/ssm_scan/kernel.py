"""Pallas kernel: chunked SSD (mamba2) scan with VMEM-resident state.

One grid stream per (batch·head); the chunk index is the innermost
(sequential) grid dim, so the ``[P, N]`` recurrent state lives in fp32 VMEM
scratch across the whole sequence — HBM sees each input tile exactly once
and never sees the state.  Per chunk the kernel does the SSD decomposition:

    y_intra = (C·Bᵀ ⊙ L) x          (masked decay matmul, MXU)
    y_inter = decay_in ⊙ (C · Sᵀ)   (carried state)
    S      ← chunk_decay · S + (x · decay_out)ᵀ B

Chunk Q=128 and P=64/N=64 (mamba2's dims) give MXU-aligned [128,128]·[128,64]
products and a 16 KiB state — the working set per step is ~200 KiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 128
NEG_INF = -1e30


def _ssd_kernel(x_ref, a_ref, b_ref, c_ref, y_ref, s_out_ref, state_ref, *,
                chunk: int):
    """x [1,Q,P], a [1,Q,1], b/c [1,Q,N]; y [1,Q,P]; s_out [1,P,N];
    scratch state [P,N] fp32."""
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)          # [Q, P]
    a = a_ref[0].astype(jnp.float32)          # [Q, 1]
    Bm = b_ref[0].astype(jnp.float32)         # [Q, N]
    Cm = c_ref[0].astype(jnp.float32)         # [Q, N]

    la = jnp.log(jnp.maximum(a, 1e-20))       # [Q, 1]
    cum = jnp.cumsum(la, axis=0)              # [Q, 1] inclusive
    # intra-chunk decay L[i,j] = exp(cum_i - cum_j), i >= j (mask pre-exp)
    seg = cum - cum.reshape(1, chunk)         # [Q, Q]
    i_ge_j = (
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
        >= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    )
    Lmat = jnp.exp(jnp.where(i_ge_j, seg, NEG_INF))

    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [Q,Q]
    y = jax.lax.dot_general(cb * Lmat, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # [Q,P]

    # carried state contribution: decay_in[i] * C_i · S  (S [P,N])
    decay_in = jnp.exp(cum)                   # [Q, 1]
    s_t = state_ref[...]
    y += decay_in * jax.lax.dot_general(
        Cm, s_t, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)   # [Q,N]x[P,N] -> [Q,P]

    # state update: S = chunk_decay * S + (x * decay_out)^T B
    decay_out = jnp.exp(cum[chunk - 1] - cum) # [Q, 1]
    s_in = jax.lax.dot_general(
        x * decay_out, Bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)   # [P, N]
    chunk_decay = jnp.exp(cum[chunk - 1])     # [1]
    state_ref[...] = s_t * chunk_decay + s_in

    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(ci == pl.num_programs(1) - 1)
    def _emit_state():
        s_out_ref[0] = state_ref[...].astype(s_out_ref.dtype)


def ssd_scan_pallas(
    x: jax.Array,          # [BH, L, P]   (dt folded in)
    a: jax.Array,          # [BH, L]      per-step decay
    Bm: jax.Array,         # [BH, L, N]   (already broadcast per head-stream)
    Cm: jax.Array,         # [BH, L, N]
    chunk: int = DEFAULT_CHUNK,
    interpret: bool = False,
):
    """-> (y [BH,L,P], final_state [BH,P,N]) — zero initial state."""
    BH, L, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, L)
    assert L % Q == 0, (L, Q)
    grid = (BH, L // Q)
    a3 = a.reshape(BH, L, 1)

    y, s = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=Q),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Q, P), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, Q, 1), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, Q, N), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, Q, N), lambda bh, c: (bh, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, P), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, P, N), lambda bh, c: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, L, P), jnp.float32),
            jax.ShapeDtypeStruct((BH, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, a3, Bm, Cm)
    return y, s
