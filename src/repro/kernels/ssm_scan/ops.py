"""Public SSD-scan op: model-layout plumbing + impl switch."""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.ssm_scan.kernel import DEFAULT_CHUNK, ssd_scan_pallas
from repro.kernels.ssm_scan.ref import ssd_scan_sequential


@functools.partial(jax.jit, static_argnames=("chunk", "impl", "interpret"))
def ssd_scan(
    x: jax.Array,          # [B, L, H, P]  (dt folded in, model layout)
    a: jax.Array,          # [B, L, H]
    Bm: jax.Array,         # [B, L, N]     (shared across heads)
    Cm: jax.Array,         # [B, L, N]
    chunk: int = DEFAULT_CHUNK,
    impl: str = "pallas",
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """-> (y [B,L,H,P], final_state [B,H,P,N]); zero initial state."""
    B, L, H, P = x.shape
    N = Bm.shape[-1]
    # head-major streams: [B*H, L, *]
    xs = jnp.moveaxis(x, 2, 1).reshape(B * H, L, P)
    as_ = jnp.moveaxis(a, 2, 1).reshape(B * H, L)
    Bs = jnp.broadcast_to(Bm[:, None], (B, H, L, N)).reshape(B * H, L, N)
    Cs = jnp.broadcast_to(Cm[:, None], (B, H, L, N)).reshape(B * H, L, N)

    if impl == "ref":
        y, s = ssd_scan_sequential(xs, as_, Bs, Cs)
    else:
        Q = min(chunk, L)
        pad = -L % Q
        if pad:
            xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0)))
            as_ = jnp.pad(as_, ((0, 0), (0, pad)), constant_values=1.0)
            Bs = jnp.pad(Bs, ((0, 0), (0, pad), (0, 0)))
            Cs = jnp.pad(Cs, ((0, 0), (0, pad), (0, 0)))
        y, s = ssd_scan_pallas(xs, as_, Bs, Cs, chunk=Q, interpret=interpret)
        y = y[:, :L]
    y = jnp.moveaxis(y.reshape(B, H, L, P), 1, 2)
    return y, s.reshape(B, H, P, N)
