"""Oracle for the SSD scan kernel: the sequential recurrence, plus a
re-export of the model's chunked-jnp implementation (itself scan-verified)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.ssm import ssd_chunked_ref  # noqa: F401  (second oracle)


def ssd_scan_sequential(x, a, Bm, Cm):
    """Literal per-step recurrence: x [BH,L,P], a [BH,L], B/C [BH,L,N]
    -> (y [BH,L,P], final_state [BH,P,N])."""
    BH, L, P = x.shape
    N = Bm.shape[-1]

    def step(s, inp):
        x_t, a_t, b_t, c_t = inp                     # [BH,P],[BH],[BH,N],[BH,N]
        s = s * a_t[:, None, None] + jnp.einsum("bp,bn->bpn", x_t, b_t)
        y = jnp.einsum("bn,bpn->bp", c_t, s)
        return s, y

    s0 = jnp.zeros((BH, P, N), jnp.float32)
    inputs = (
        jnp.moveaxis(x.astype(jnp.float32), 1, 0),
        jnp.moveaxis(a.astype(jnp.float32), 1, 0),
        jnp.moveaxis(Bm.astype(jnp.float32), 1, 0),
        jnp.moveaxis(Cm.astype(jnp.float32), 1, 0),
    )
    s_fin, ys = jax.lax.scan(step, s0, inputs)
    return jnp.moveaxis(ys, 0, 1), s_fin
