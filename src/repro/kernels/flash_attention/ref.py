"""Pure-jnp oracle for flash attention (naive softmax attention)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(
    q: jax.Array,          # [B, H, Sq, D]
    k: jax.Array,          # [B, Hkv, Skv, D]
    v: jax.Array,          # [B, Hkv, Skv, D]
    scale: float,
    causal: bool = True,
    window: int = 0,
) -> jax.Array:
    B, H, Sq, D = q.shape
    Hkv = k.shape[1]
    group = H // Hkv
    qg = q.reshape(B, Hkv, group, Sq, D).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kf) * scale
    if causal:
        q_pos = jnp.arange(Sq)[:, None]
        k_pos = jnp.arange(k.shape[2])[None, :]
        ok = k_pos <= q_pos
        if window > 0:
            ok = ok & (k_pos > q_pos - window)
        s = jnp.where(ok, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", w, vf)
    return out.reshape(B, H, Sq, D).astype(q.dtype)
