"""Pallas kernel: blockwise (flash) attention forward with causal / SWA mask.

Online-softmax over KV blocks: for each (batch·head, q-block) the kernel
sweeps KV blocks (innermost sequential grid dim), keeping the running max
``m``, normalizer ``l`` and the unnormalized accumulator in fp32 VMEM
scratch.  GQA is folded in through the K/V BlockSpec index maps (query head
h reads KV head ``h // group``) so grouped heads never materialize
broadcast K/V in HBM.

Tile sizes: 128×128 q/kv blocks match the MXU; with head_dim 128 the live
VMEM per step is q(64KB) + k(64KB) + v(64KB) + acc(64KB fp32) + O(16KB)
softmax state — comfortably inside the ~16MB/core VMEM with double
buffering.  Causal masking skips fully-masked KV blocks via the grid's
upper bound only in the XLA wrapper; inside the kernel, partially-masked
blocks apply the position mask.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int, block_q: int,
                  block_k: int, seq_len: int):
    """One (bh, q_block, kv_block) cell.

    q_ref [1, BQ, D]; k_ref/v_ref [1, BK, D]; o_ref [1, BQ, D];
    scratch: m/l [BQ, 1] fp32, acc [BQ, D] fp32.
    """
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)

    def compute():
        q = q_ref[0].astype(jnp.float32)                 # [BQ, D]
        k = k_ref[0].astype(jnp.float32)                 # [BK, D]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # [BQ, BK]

        ok = k_pos < seq_len
        if causal:
            ok &= k_pos <= q_pos
            if window > 0:
                ok &= k_pos > q_pos - window
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_ref[...]                              # [BQ, 1]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                           # [BQ, BK]
        correction = jnp.exp(m_prev - m_new)             # [BQ, 1]
        l_ref[...] = l_ref[...] * correction + p.sum(axis=1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)                 # [BK, D]
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)          # [BQ, D]
        acc_ref[...] = acc_ref[...] * correction + pv
        m_ref[...] = m_new

    if causal:
        # skip blocks entirely above the diagonal (no valid k <= q there)
        first_q = qi * block_q
        first_k = kj * block_k
        pl.when(first_k <= first_q + block_q - 1)(compute)
    else:
        compute()

    @pl.when(kj == pl.num_programs(2) - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,          # [BH, Sq, D]   (batch*heads flattened)
    k: jax.Array,          # [BHkv, Skv, D]
    v: jax.Array,          # [BHkv, Skv, D]
    group: int,            # q heads per kv head
    n_heads: int,
    scale: float,
    causal: bool = True,
    window: int = 0,       # 0 = no sliding window
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    kv_len: int = 0,       # true (unpadded) kv length; 0 = full
    interpret: bool = False,
) -> jax.Array:
    BH, Sq, D = q.shape
    Skv = k.shape[1]
    true_kv = kv_len if kv_len > 0 else Skv
    bq = min(block_q, Sq)
    bk = min(block_k, Skv)
    assert Sq % bq == 0 and Skv % bk == 0
    grid = (BH, Sq // bq, Skv // bk)

    def kv_index(bh, qi, kj):
        # query stream bh = b * n_heads + h reads kv stream b * n_kv + h//group
        b = bh // n_heads
        h = bh % n_heads
        n_kv = n_heads // group
        return (b * n_kv + h // group, kj, 0)

    kern = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=bq, block_k=bk, seq_len=true_kv,
    )
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda bh, qi, kj: (bh, qi, 0)),
            pl.BlockSpec((1, bk, D), kv_index),
            pl.BlockSpec((1, bk, D), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda bh, qi, kj: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max m
            pltpu.VMEM((bq, 1), jnp.float32),   # normalizer l
            pltpu.VMEM((bq, D), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
