"""Public flash-attention op: [B,H,S,D] layout, GQA, padding, impl switch."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref


@functools.partial(
    jax.jit, static_argnames=("scale", "causal", "window", "impl", "interpret"))
def flash_attention(
    q: jax.Array,          # [B, H, Sq, D]
    k: jax.Array,          # [B, Hkv, Skv, D]
    v: jax.Array,          # [B, Hkv, Skv, D]
    scale: float,
    causal: bool = True,
    window: int = 0,
    impl: str = "pallas",
    interpret: bool = True,
) -> jax.Array:
    if impl == "ref":
        return attention_ref(q, k, v, scale, causal, window)
    B, H, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    group = H // Hkv

    # pad sequence dims to 128-multiples; padded KV is masked by seq_len,
    # padded Q rows are sliced away
    pq = -Sq % min(128, max(Sq, 8))
    pk = -Skv % min(128, max(Skv, 8))
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))

    out = flash_attention_pallas(
        qp.reshape(B * H, Sq + pq, D),
        kp.reshape(B * Hkv, Skv + pk, D),
        vp.reshape(B * Hkv, Skv + pk, D),
        group=group,
        n_heads=H,
        scale=scale,
        causal=causal,
        window=window,
        block_q=min(128, Sq + pq),
        block_k=min(128, Skv + pk),
        kv_len=Skv,
        interpret=interpret,
    )
    out = out.reshape(B, H, Sq + pq, D)
    return out[:, :, :Sq] if pq else out
