"""Discrete-event simulator tests, incl. reproduction of paper orderings."""

import numpy as np
import pytest

from repro.core.balancer import (
    NodeSpec,
    balanced_allocation,
    greedy_allocation,
)
from repro.core.simulator import (
    ClusterSim,
    SimTask,
    mapreduce_job_tasks,
    paper_cluster,
)


class TestMechanics:
    def test_single_task_timing(self):
        node = NodeSpec(0, cores=1, mips=1.0,
                        disk_read_bps=100e6, disk_write_bps=50e6)
        sim = ClusterSim([node], bandwidth=70e6)
        t = SimTask(0, input_bytes=100e6, output_bytes=50e6, work=5.0,
                    home_node=0)
        res = sim.run([t], "hadoop")
        # 1s read + 5s compute + 1s write
        assert res.wall_time == pytest.approx(7.0, rel=1e-6)
        assert res.resource_time == pytest.approx(7.0, rel=1e-6)

    def test_remote_read_uses_network(self):
        nodes = [NodeSpec(0, cores=1), NodeSpec(1, cores=1)]
        sim = ClusterSim(nodes, bandwidth=70e6, allow_steal=True)
        # node 0 backlogged beyond one wave; node 1 steals, paying the network
        tasks = [
            SimTask(0, 0, 0, work=100.0, home_node=0),
            SimTask(1, input_bytes=70e6, output_bytes=0, work=1.0, home_node=0),
            SimTask(2, input_bytes=70e6, output_bytes=0, work=1.0, home_node=0),
        ]
        res = sim.run(tasks, "hadoop")
        stolen = [t for t in res.tasks if t.exec_node == 1]
        assert stolen and all(t.read_remote for t in stolen)
        assert stolen[0].end - stolen[0].start == pytest.approx(2.0, rel=1e-6)

    def test_no_steal_when_pinned(self):
        nodes = [NodeSpec(0, cores=1), NodeSpec(1, cores=1)]
        sim = ClusterSim(nodes, bandwidth=70e6)  # allow_steal defaults False
        tasks = [SimTask(i, 0, 0, work=1.0, home_node=0) for i in range(4)]
        res = sim.run(tasks, "hadoop")
        assert all(t.exec_node == 0 for t in res.tasks)
        assert res.wall_time == pytest.approx(4.0, rel=1e-6)

    def test_network_fair_sharing(self):
        nodes = [NodeSpec(i, cores=1) for i in range(4)]
        sim = ClusterSim(nodes, bandwidth=100e6)
        # 4 concurrent remote reads of 100MB share 100MB/s -> 4s each
        tasks = [SimTask(i, 100e6, 0, 0.01, home_node=None) for i in range(4)]
        res = sim.run(tasks, "sge")
        assert res.wall_time == pytest.approx(4.0, rel=0.02)

    def test_mips_scales_compute(self):
        fast = NodeSpec(0, cores=1, mips=2.0)
        sim = ClusterSim([fast], bandwidth=70e6)
        res = sim.run([SimTask(0, 0, 0, work=10.0, home_node=0)], "hadoop")
        assert res.wall_time == pytest.approx(5.0, rel=1e-6)

    def test_core_slots_limit_concurrency(self):
        node = NodeSpec(0, cores=2, mips=1.0)
        sim = ClusterSim([node], bandwidth=70e6)
        tasks = [SimTask(i, 0, 0, work=1.0, home_node=0) for i in range(4)]
        res = sim.run(tasks, "hadoop")
        assert res.wall_time == pytest.approx(2.0, rel=1e-6)
        assert res.resource_time == pytest.approx(4.0, rel=1e-6)


class TestPaperOrderings:
    """Qualitative reproduction of Fig. 3: the orderings the paper reports."""

    def _compression_tasks(self, alloc, region_of_task, extra_work):
        # use case 1: 5153 single-image .gz jobs (15MB in, 9MB out), scaled 1/8
        n = 644
        return [
            SimTask(
                i,
                input_bytes=15e6,
                output_bytes=8.9e6,
                work=3.0 + extra_work,
                home_node=alloc[region_of_task(i)],
            )
            for i in range(n)
        ]

    @pytest.mark.parametrize("extra_work", [40.0, 100.0])
    def test_balancer_beats_default_on_hetero(self, extra_work):
        nodes = paper_cluster()
        rng = np.random.default_rng(0)
        n_regions = 96
        region_bytes = {i: int(b) for i, b in
                        enumerate(rng.integers(50e6, 150e6, n_regions))}
        region_of_task = lambda i: i % n_regions
        sim = ClusterSim(nodes, bandwidth=70e6)

        t_bal = sim.run(self._compression_tasks(
            balanced_allocation(region_bytes, nodes), region_of_task,
            extra_work), "hadoop")
        t_gre = sim.run(self._compression_tasks(
            greedy_allocation(region_bytes, nodes), region_of_task,
            extra_work), "hadoop")
        # the paper reports ~1.5x; require a solid improvement
        assert t_gre.wall_time < t_bal.wall_time
        assert t_bal.wall_time / t_gre.wall_time > 1.2

    def test_hadoop_beats_sge_on_read_intensive(self):
        # use case 2 flavour: read-heavy, short compute -> SGE saturates net
        nodes = paper_cluster()
        rng = np.random.default_rng(1)
        region_bytes = {i: int(b) for i, b in
                        enumerate(rng.integers(50e6, 150e6, 96))}
        alloc = greedy_allocation(region_bytes, nodes)
        tasks = [
            SimTask(i, input_bytes=13e6 * 55, output_bytes=21e6,
                    work=0.4 * 55 + 5, home_node=alloc[i % 96])
            for i in range(93)  # 5153/55 map tasks
        ]
        sim = ClusterSim(nodes, bandwidth=70e6)
        h = sim.run(tasks, "hadoop")
        s = sim.run(tasks, "sge")
        assert s.wall_time > 2 * h.wall_time
        assert s.resource_time > 2 * h.resource_time


class TestMapReduceJobBuilder:
    def test_task_count_and_sizes(self):
        maps, red = mapreduce_job_tasks(
            n_img=5153, eta=55, size_in=13e6, size_gen=21e6,
            avg_fn=lambda e: 0.4 * e + 5, placement_of_chunk=lambda i: None,
        )
        assert len(maps) == 5153 // 55 + 1  # remainder chunk
        assert maps[0].input_bytes == pytest.approx(55 * 13e6)
        assert red.input_bytes == pytest.approx(len(maps) * 21e6)
