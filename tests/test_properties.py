"""Hypothesis property tests on ColoGrid's invariants."""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import jax

from repro.core.balancer import (
    NodeSpec,
    allocation_imbalance,
    balanced_allocation,
    greedy_allocation,
    node_loads,
    rebalance,
)
from repro.core.chunk_model import ChunkModel, PAPER_PARAMS
from repro.core.mapreduce import MapReduceEngine
from repro.core.query import indexed_query, naive_query
from repro.core.regions import ConstantSizeSplitPolicy, HierarchicalSplitPolicy, RegionSet
from repro.core.stats import MeanProgram, VarianceProgram
from repro.core.table import ColumnSpec, make_mip_table, make_naive_table
from repro.utils import make_mesh

# ----------------------------------------------------------------------
# strategies
# ----------------------------------------------------------------------

region_bytes_st = st.dictionaries(
    st.integers(0, 500),
    st.integers(1, 20_000_000),
    min_size=1,
    max_size=60,
)

nodes_st = st.lists(
    st.tuples(st.integers(1, 32), st.floats(0.25, 4.0)),
    min_size=1,
    max_size=12,
).map(
    lambda specs: [
        NodeSpec(i, cores=c, mips=m) for i, (c, m) in enumerate(specs)
    ]
)


# ----------------------------------------------------------------------
# balancer invariants
# ----------------------------------------------------------------------

class TestBalancerProperties:
    @given(rb=region_bytes_st, nodes=nodes_st)
    @settings(max_examples=60, deadline=None)
    def test_greedy_total_preserved_and_bounded(self, rb, nodes):
        alloc = greedy_allocation(rb, nodes)
        assert set(alloc) == set(rb)
        loads = node_loads(alloc, rb, nodes)
        assert sum(loads.values()) == sum(rb.values())
        # greedy deviation from proportional is bounded by one region
        total_p = sum(n.power for n in nodes)
        for n in nodes:
            target = sum(rb.values()) * n.power / total_p
            assert loads[n.node_id] <= target + max(rb.values()) + 1e-6

    @given(rb=region_bytes_st, nodes=nodes_st)
    @settings(max_examples=60, deadline=None)
    def test_rebalance_never_worse(self, rb, nodes):
        start = balanced_allocation(rb, nodes)
        out, _ = rebalance(start, rb, nodes)
        assert allocation_imbalance(out, rb, nodes) <= (
            allocation_imbalance(start, rb, nodes) + 1e-9
        )

    @given(rb=region_bytes_st, nodes=nodes_st, data=st.data())
    @settings(max_examples=40, deadline=None)
    def test_rebalance_adopts_all_orphans(self, rb, nodes, data):
        alloc = greedy_allocation(rb, nodes)
        if len(nodes) < 2:
            return
        dead = data.draw(st.sampled_from([n.node_id for n in nodes]))
        survivors = [n for n in nodes if n.node_id != dead]
        out, _ = rebalance(alloc, rb, survivors)
        assert set(out) == set(rb)
        assert dead not in set(out.values())


# ----------------------------------------------------------------------
# region split invariants
# ----------------------------------------------------------------------

class TestRegionProperties:
    @given(
        sizes=st.lists(st.integers(1, 100), min_size=1, max_size=200),
        threshold=st.integers(10, 400),
        hierarchical=st.booleans(),
    )
    @settings(max_examples=60, deadline=None)
    def test_splits_tile_keyspace(self, sizes, threshold, hierarchical):
        keys = np.array([f"k{i:05d}".encode() for i in range(len(sizes))],
                        dtype="S64")
        row_bytes = np.array(sizes, dtype=np.int64)
        policy_cls = (HierarchicalSplitPolicy if hierarchical
                      else ConstantSizeSplitPolicy)
        rs = RegionSet(policy_cls(max_region_bytes=threshold))
        rs.maybe_split(keys, row_bytes)
        rs.check_invariants()
        # rows are covered exactly once
        covered = sum(r.num_rows(keys) for r in rs)
        assert covered == len(sizes)
        # every multi-row region is within threshold OR indivisible
        for r in rs:
            if r.num_rows(keys) >= 2:
                assert r.num_bytes(keys, row_bytes) <= max(
                    threshold, int(row_bytes.max()) * 2
                )


# ----------------------------------------------------------------------
# chunk model invariants
# ----------------------------------------------------------------------

class TestChunkModelProperties:
    @given(eta=st.integers(24, 160))
    @settings(max_examples=60, deadline=None)
    def test_wall_le_resource_at_scale(self, eta):
        cm = ChunkModel(PAPER_PARAMS)
        # resource time counts every node's busy time; with 224 cores it
        # must dominate the single-critical-path wall time
        assert cm.resource_time(eta)["total"] >= cm.wall_time(eta)["map"]

    @given(eta=st.integers(24, 159))
    @settings(max_examples=40, deadline=None)
    def test_map_wall_monotone_in_eta(self, eta):
        cm = ChunkModel(PAPER_PARAMS)
        assert cm.wall_time(eta + 1)["map"] >= cm.wall_time(eta)["map"]


# ----------------------------------------------------------------------
# mapreduce: chunk-size invariance of results (the paper's key free param)
# ----------------------------------------------------------------------

class TestMapReduceProperties:
    @given(
        n=st.integers(3, 80),
        eta=st.integers(1, 32),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(max_examples=25, deadline=None)
    def test_mean_invariant_under_chunking(self, n, eta, seed):
        rng = np.random.default_rng(seed)
        data = rng.normal(size=(n, 3)).astype(np.float32)
        mesh = make_mesh((jax.device_count(),), ("data",))
        D = mesh.shape["data"]
        cap = -(-n // D)
        cap = -(-cap // eta) * eta
        vals = np.zeros((D, cap, 3), np.float32)
        valid = np.zeros((D, cap), bool)
        flat = 0
        for d in range(D):
            take = min(cap, n - flat)
            if take > 0:
                vals[d, :take] = data[flat:flat + take]
                valid[d, :take] = True
                flat += take
        assert flat == n
        res, _ = MapReduceEngine(mesh).run(MeanProgram(), vals, valid, eta)
        np.testing.assert_allclose(np.asarray(res), data.mean(0), atol=2e-4)

    @given(seed=st.integers(0, 2**31 - 1), eta=st.integers(1, 16))
    @settings(max_examples=15, deadline=None)
    def test_variance_merge_associative(self, seed, eta):
        rng = np.random.default_rng(seed)
        data = rng.normal(size=(37, 2)).astype(np.float32) * 3 + 1
        mesh = make_mesh((jax.device_count(),), ("data",))
        D = mesh.shape["data"]
        cap = -(-(-(-37 // D)) // eta) * eta
        vals = np.zeros((D, cap, 2), np.float32)
        valid = np.zeros((D, cap), bool)
        flat = 0
        for d in range(D):
            take = min(cap, 37 - flat)
            if take > 0:
                vals[d, :take] = data[flat:flat + take]
                valid[d, :take] = True
                flat += take
        res, _ = MapReduceEngine(mesh).run(VarianceProgram(), vals, valid, eta)
        np.testing.assert_allclose(np.asarray(res["var"]), data.var(0),
                                   rtol=1e-3, atol=1e-4)


# ----------------------------------------------------------------------
# query equivalence: proposed and naive schemes agree on the answer
# ----------------------------------------------------------------------

class TestQueryProperties:
    @given(
        seed=st.integers(0, 2**31 - 1),
        lo=st.floats(0, 60),
        width=st.floats(1, 40),
        sex=st.sampled_from([None, 0, 1]),
    )
    @settings(max_examples=25, deadline=None)
    def test_schemes_agree(self, seed, lo, width, sex):
        rng = np.random.default_rng(seed)
        n = 64
        data = rng.normal(size=(n, 2)).astype(np.float32)
        ages = rng.uniform(0, 90, n).astype(np.float32)
        sexes = rng.integers(0, 2, n).astype(np.int8)
        sizes = rng.integers(6e6, 20e6, n)
        keys = [f"i{j:04d}" for j in range(n)]
        idx_cols = [ColumnSpec("age", (), np.float32),
                    ColumnSpec("sex", (), np.int8)]
        prop = make_mip_table(payload_shape=(2,), extra_index_columns=idx_cols)
        prop.upload(keys, {"img": {"data": data},
                           "idx": {"size": sizes, "age": ages, "sex": sexes}})
        naive = make_naive_table(payload_shape=(2,), extra_index_columns=idx_cols)
        naive.upload(keys, {"img": {"data": data, "size": sizes,
                                    "age": ages, "sex": sexes}})

        from repro.core.query import age_sex_predicate
        pred = age_sex_predicate(lo, lo + width, sex)
        m1, s1 = indexed_query(prop, pred, ["age", "sex"])
        m2, s2 = naive_query(naive, pred, ["age", "sex"])
        np.testing.assert_array_equal(m1, m2)
        assert s1.payload_bytes_traversed == 0
        assert s2.payload_bytes_traversed == int(naive.row_bytes().sum())
