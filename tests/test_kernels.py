"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret mode).

Shapes and dtypes swept per kernel; hypothesis drives randomized shapes for
streaming_stats (the cheapest kernel) — for the heavier kernels fixed
parameterized sweeps keep CI time sane on one CPU core.
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssm_scan.ops import ssd_scan
from repro.kernels.streaming_stats.ops import KernelMeanProgram, streaming_stats
from repro.kernels.streaming_stats.ref import streaming_stats_ref

rng = np.random.default_rng(1234)


class TestStreamingStats:
    @pytest.mark.parametrize("R,shape", [
        (1, (8,)), (16, (64,)), (256, (512,)), (300, (12, 11)),
        (64, (32, 32, 4)),
    ])
    @pytest.mark.parametrize("dtype", [np.float32, np.float16])
    def test_matches_ref(self, R, shape, dtype):
        x = rng.normal(size=(R,) + shape).astype(dtype)
        m = rng.random(R) > 0.25
        s, sq, c = streaming_stats(jnp.asarray(x), jnp.asarray(m))
        rs, rsq, rc = streaming_stats_ref(
            jnp.asarray(x.reshape(R, -1)), jnp.asarray(m))
        tol = 1e-5 if dtype == np.float32 else 5e-3
        np.testing.assert_allclose(np.asarray(s).reshape(-1), rs,
                                   rtol=tol, atol=tol)
        np.testing.assert_allclose(np.asarray(sq).reshape(-1), rsq,
                                   rtol=tol, atol=tol)
        assert float(c) == m.sum()

    @given(
        R=st.integers(1, 200),
        F=st.integers(1, 300),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(max_examples=20, deadline=None)
    def test_property_random_shapes(self, R, F, seed):
        r = np.random.default_rng(seed)
        x = r.normal(size=(R, F)).astype(np.float32)
        m = r.random(R) > 0.5
        s, _, c = streaming_stats(jnp.asarray(x), jnp.asarray(m))
        np.testing.assert_allclose(
            np.asarray(s), (x * m[:, None]).sum(0), rtol=1e-4, atol=1e-4)
        assert float(c) == m.sum()

    def test_all_masked(self):
        x = rng.normal(size=(32, 16)).astype(np.float32)
        m = np.zeros(32, bool)
        s, sq, c = streaming_stats(jnp.asarray(x), jnp.asarray(m))
        assert float(c) == 0
        np.testing.assert_array_equal(np.asarray(s), 0)

    def test_mapreduce_program_agrees_with_jnp_mean(self):
        from repro.core.mapreduce import MapReduceEngine
        from repro.utils import make_mesh
        x = rng.normal(size=(60, 24)).astype(np.float32)
        mesh = make_mesh((jax.device_count(),), ("data",))
        D = mesh.shape["data"]
        vals = x.reshape(D, 60 // D, 24)
        valid = np.ones((D, 60 // D), bool)
        res, _ = MapReduceEngine(mesh).run(
            KernelMeanProgram(), jnp.asarray(vals), jnp.asarray(valid), 10)
        np.testing.assert_allclose(np.asarray(res), x.mean(0), atol=1e-5)


class TestFlashAttention:
    @pytest.mark.parametrize("B,H,Hkv,Sq,Skv,D", [
        (1, 2, 2, 128, 128, 64),
        (2, 4, 2, 128, 128, 64),
        (1, 8, 1, 256, 256, 32),   # MQA
        (1, 4, 2, 96, 96, 64),     # non-multiple of block
        (2, 4, 4, 64, 256, 128),   # cross/long kv
    ])
    def test_matches_ref_causal(self, B, H, Hkv, Sq, Skv, D):
        if Sq != Skv:
            pytest.skip("causal requires square") if False else None
        q = jnp.asarray(rng.normal(size=(B, H, Sq, D)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(B, Hkv, Skv, D)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(B, Hkv, Skv, D)).astype(np.float32))
        causal = Sq == Skv
        out = flash_attention(q, k, v, scale=D ** -0.5, causal=causal)
        ref = attention_ref(q, k, v, scale=D ** -0.5, causal=causal)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("window", [32, 64, 127])
    def test_sliding_window(self, window):
        B, H, S, D = 1, 2, 256, 64
        q = jnp.asarray(rng.normal(size=(B, H, S, D)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(B, H, S, D)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(B, H, S, D)).astype(np.float32))
        out = flash_attention(q, k, v, scale=D ** -0.5, window=window)
        ref = attention_ref(q, k, v, scale=D ** -0.5, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    def test_bf16_inputs(self):
        B, H, S, D = 1, 2, 128, 64
        q = jnp.asarray(rng.normal(size=(B, H, S, D))).astype(jnp.bfloat16)
        k = jnp.asarray(rng.normal(size=(B, H, S, D))).astype(jnp.bfloat16)
        v = jnp.asarray(rng.normal(size=(B, H, S, D))).astype(jnp.bfloat16)
        out = flash_attention(q, k, v, scale=D ** -0.5)
        ref = attention_ref(q, k, v, scale=D ** -0.5)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            rtol=2e-2, atol=2e-2)


class TestSSDScan:
    @pytest.mark.parametrize("B,L,H,P,N,chunk", [
        (1, 64, 1, 16, 16, 16),
        (2, 128, 2, 32, 16, 64),
        (1, 128, 4, 64, 64, 128),  # mamba2-native dims
        (1, 100, 2, 32, 32, 32),   # padding path
    ])
    def test_matches_sequential(self, B, L, H, P, N, chunk):
        x = jnp.asarray(rng.normal(size=(B, L, H, P)).astype(np.float32)) * 0.5
        a = jnp.asarray(rng.uniform(0.7, 0.999, (B, L, H)).astype(np.float32))
        Bm = jnp.asarray(rng.normal(size=(B, L, N)).astype(np.float32)) * 0.3
        Cm = jnp.asarray(rng.normal(size=(B, L, N)).astype(np.float32)) * 0.3
        y, s = ssd_scan(x, a, Bm, Cm, chunk=chunk)
        y_ref, s_ref = ssd_scan(x, a, Bm, Cm, impl="ref")
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                                   rtol=1e-4, atol=1e-4)

    def test_chunk_invariance(self):
        B, L, H, P, N = 1, 128, 2, 16, 16
        x = jnp.asarray(rng.normal(size=(B, L, H, P)).astype(np.float32))
        a = jnp.asarray(rng.uniform(0.8, 0.999, (B, L, H)).astype(np.float32))
        Bm = jnp.asarray(rng.normal(size=(B, L, N)).astype(np.float32))
        Cm = jnp.asarray(rng.normal(size=(B, L, N)).astype(np.float32))
        outs = [np.asarray(ssd_scan(x, a, Bm, Cm, chunk=c)[0])
                for c in (16, 32, 64, 128)]
        for o in outs[1:]:
            np.testing.assert_allclose(outs[0], o, rtol=1e-4, atol=1e-4)

    def test_long_decay_stability(self):
        """Strong decay over a long sequence: state must not blow up."""
        B, L, H, P, N = 1, 256, 1, 16, 16
        x = jnp.ones((B, L, H, P), jnp.float32)
        a = jnp.full((B, L, H), 0.5, jnp.float32)
        Bm = jnp.ones((B, L, N), jnp.float32) * 0.1
        Cm = jnp.ones((B, L, N), jnp.float32) * 0.1
        y, s = ssd_scan(x, a, Bm, Cm, chunk=64)
        assert bool(jnp.isfinite(y).all()) and bool(jnp.isfinite(s).all())
        # geometric series bound: |state| <= inp/(1-a)
        assert float(jnp.abs(np.asarray(s)).max()) < 2 * 0.1 * 1.0 / 0.5
