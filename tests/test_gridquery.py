"""GridQuery job plans: region pruning, projection pushdown, program fusion,
plan caching, and the auto-rebalance observation loop.

Covers the PR-2 acceptance criteria directly: a prefix scan selecting 1 of k
regions gathers payload for — and compiles a plan over — only the pruned
region set (``QueryStats.regions_pruned`` / ``payload_bytes_moved``), and a
fused mean+variance job costs exactly one ``engine.compile_count`` increment
and one payload gather pass.
"""

import numpy as np
import pytest

from repro.core.grid import GridSession
from repro.core.plan import prefix_range
from repro.core.query import age_sex_predicate, indexed_query
from repro.core.regions import (
    KEY_MIN,
    ConstantSizeSplitPolicy,
    HierarchicalSplitPolicy,
    RegionSet,
)
from repro.core.stats import (
    FusedProgram,
    HistogramProgram,
    MeanProgram,
    VarianceProgram,
)
from repro.core.table import ColumnSpec, make_mip_table

PAYLOAD = (3, 4)
ROW_NBYTES = int(np.prod(PAYLOAD)) * 4  # float32


def make_table(groups=("a", "b", "c", "d"), per=12, presplit=True, seed=0,
               split_bytes=10**18):
    """``len(groups)`` rowkey prefixes; presplit -> one region per prefix."""
    rng = np.random.default_rng(seed)
    t = make_mip_table(
        payload_shape=PAYLOAD,
        extra_index_columns=[ColumnSpec("age", (), np.float32),
                             ColumnSpec("sex", (), np.int8)],
        split_policy=HierarchicalSplitPolicy(max_region_bytes=split_bytes),
        presplit_keys=list(groups)[1:] if presplit else None,
    )
    keys = [f"{g}{i:04d}" for g in groups for i in range(per)]
    n = len(keys)
    t.upload(keys, {
        "img": {"data": rng.normal(size=(n,) + PAYLOAD).astype(np.float32)},
        "idx": {"size": rng.integers(6_000_000, 20_000_001, n),
                "age": rng.uniform(4, 80, n).astype(np.float32),
                "sex": rng.integers(0, 2, n).astype(np.int8)}})
    return t


# ----------------------------------------------------------------------
# prefix_range / RegionSet.prune primitives
# ----------------------------------------------------------------------

class TestPrefixRange:
    def test_plain_prefix(self):
        assert prefix_range(b"img0") == (b"img0", b"img1")

    def test_trailing_ff_rolls_over(self):
        assert prefix_range(b"a\xff") == (b"a\xff", b"b")
        assert prefix_range(b"a\xff\xff") == (b"a\xff\xff", b"b")

    def test_unbounded_prefixes(self):
        assert prefix_range(b"") == (b"", None)
        assert prefix_range(b"\xff") == (b"\xff", None)
        assert prefix_range(b"\xff\xff") == (b"\xff\xff", None)

    def test_str_prefix(self):
        assert prefix_range("ab") == (b"ab", b"ac")


class TestRegionPrune:
    def make(self, splits):
        rs = RegionSet(ConstantSizeSplitPolicy(1 << 62))
        rs.pre_split(splits)
        rs.check_invariants()
        return rs

    def test_prune_matches_interval_overlap(self):
        rs = self.make([b"b", b"c", b"d"])
        assert [r.start for r in rs.prune(b"b", b"c")] == [b"b"]
        assert [r.start for r in rs.prune(b"b0", b"b9")] == [b"b"]
        # stop at a region boundary excludes the boundary region
        assert [r.start for r in rs.prune(KEY_MIN, b"b")] == [KEY_MIN]
        # straddles two regions
        assert [r.start for r in rs.prune(b"bz", b"cz")] == [b"b", b"c"]

    def test_open_ends_cover_all(self):
        rs = self.make([b"b", b"c"])
        assert rs.prune(None, None) == rs.regions
        assert rs.prune(b"c", None) == rs.regions[2:]
        assert rs.prune(None, b"c") == rs.regions[:2]

    def test_empty_and_inverted_ranges(self):
        rs = self.make([b"b", b"c"])
        assert rs.prune(b"x", b"b") == ()
        assert rs.prune(b"b", b"b") == ()

    def test_prune_consistent_with_regions_containing(self):
        rs = self.make([b"b", b"c", b"d", b"e"])
        for key in [b"a", b"b", b"b5", b"dzz", b"zz"]:
            pruned = rs.prune(key, key + b"\x00")
            assert {r.rid for r in pruned} == rs.regions_containing([key])

    def test_containing_after_organic_splits(self):
        rs = RegionSet(ConstantSizeSplitPolicy(1))
        keys = np.array([f"k{i:03d}".encode() for i in range(32)], dtype="S8")
        rs.maybe_split(keys, np.full(32, 10, dtype=np.int64))
        rs.check_invariants()
        assert len(rs) > 1
        for k in keys:
            (rid,) = rs.regions_containing([bytes(k)])
            assert rs.region_for(bytes(k)).rid == rid


# ----------------------------------------------------------------------
# the acceptance criteria
# ----------------------------------------------------------------------

class TestPruningAcceptance:
    def test_prefix_scan_gathers_only_pruned_region_set(self):
        t = make_table(per=10)
        s = GridSession(t, default_eta=4)
        res, rep = s.scan(prefix="b").map(MeanProgram()).collect()

        q = rep.query
        assert q.regions_scanned == 1
        assert q.regions_pruned == len(t.regions) - 1 == 3
        # payload moved covers exactly the pruned region's rows
        assert q.rows_selected == 10
        assert q.payload_bytes_moved == 10 * ROW_NBYTES
        assert s.metrics.pushdown_rows_gathered == 10
        # and the fold read only those rows
        assert rep.mapreduce.local_rows_read == 10
        np.testing.assert_allclose(
            np.asarray(res), t.column("img", "data")[10:20].mean(0),
            atol=1e-5)

    def test_fused_mean_variance_compiles_like_one_program(self):
        t = make_table(per=10)
        # fusion means N statistics cost the SAME executable set as one
        # program (one per-block fold + one merge), not N of each
        s1 = GridSession(t, default_eta=4)
        s1.run(MeanProgram())
        single = s1.engine.compile_count
        s2 = GridSession(t, default_eta=4)
        g0 = s2.metrics.payload_gathers
        (mean, var), rep = (s2.scan().map(MeanProgram())
                            .map(VarianceProgram()).reduce().collect())
        assert s2.engine.compile_count == single
        assert s2.metrics.payload_gathers - g0 == 1
        data = t.column("img", "data")
        np.testing.assert_allclose(np.asarray(mean), data.mean(0), atol=1e-5)
        np.testing.assert_allclose(np.asarray(var["var"]), data.var(0),
                                   atol=1e-4)
        assert rep.query.regions_pruned == 0

    def test_fused_three_statistics_single_pass(self):
        t = make_table(per=8)
        s1 = GridSession(t, default_eta=4)
        s1.scan(prefix="c").map(MeanProgram()).collect()
        single = s1.engine.compile_count
        s = GridSession(t, default_eta=4)
        (mean, var, hist), _ = (
            s.scan(prefix="c")
            .map(MeanProgram())
            .map(VarianceProgram())
            .map(HistogramProgram(lo=-4.0, hi=4.0, bins=16))
            .collect())
        assert s.engine.compile_count == single
        assert s.metrics.programs_fused == 3
        sub = t.column("img", "data")[16:24]
        np.testing.assert_allclose(np.asarray(mean), sub.mean(0), atol=1e-5)
        np.testing.assert_allclose(np.asarray(var["var"]), sub.var(0),
                                   atol=1e-4)
        ref, _ = np.histogram(sub, bins=16, range=(-4.0, 4.0))
        np.testing.assert_allclose(np.asarray(hist)[1:-1],
                                   ref.astype(np.float32)[1:-1], atol=0.5)


class TestFusedProgram:
    def test_additivity_follows_members(self):
        assert FusedProgram((MeanProgram(), HistogramProgram())).additive
        # CSE pools variance's raw sums (count, Σx, Σx²), which merge by
        # sum — the fusion keeps the single-psum reduce
        assert FusedProgram((MeanProgram(), VarianceProgram())).additive
        # the naive product follows the weakest member
        assert not FusedProgram((MeanProgram(), VarianceProgram()),
                                cse=False).additive

    def test_needs_programs(self):
        with pytest.raises(ValueError):
            FusedProgram(())


# ----------------------------------------------------------------------
# edge cases: split-straddling prefixes, empty scans
# ----------------------------------------------------------------------

class TestScanEdges:
    def test_prefix_straddling_region_split_boundary(self):
        # presplit INSIDE the "b" prefix: b-rows live in two regions
        t = make_table(presplit=False)
        t2 = make_mip_table(
            payload_shape=PAYLOAD,
            extra_index_columns=[ColumnSpec("age", (), np.float32),
                                 ColumnSpec("sex", (), np.int8)],
            presplit_keys=["b0006", "c"])
        keys = t.keys
        t2.upload([k.decode() for k in keys],
                  {"img": {"data": t.column("img", "data")},
                   "idx": {"size": t.column("idx", "size"),
                           "age": t.column("idx", "age"),
                           "sex": t.column("idx", "sex")}})
        s = GridSession(t2, default_eta=4)
        res, rep = s.scan(prefix="b").map(MeanProgram()).collect()
        assert rep.query.regions_scanned == 2     # both halves of the prefix
        assert rep.query.regions_pruned == 1      # the [c, +inf) region
        assert rep.query.rows_selected == 12
        lo, hi = t2.row_range(b"b", b"c")
        np.testing.assert_allclose(
            np.asarray(res), t2.column("img", "data")[lo:hi].mean(0),
            atol=1e-5)

    def test_empty_result_scan_compute_and_retrieve(self):
        s = GridSession(make_table(per=6), default_eta=4)
        res, rep = s.scan(prefix="zz").map(MeanProgram()).collect()
        assert rep.query.rows_selected == 0
        assert rep.query.payload_bytes_moved == 0
        assert np.all(np.isfinite(np.asarray(res)))
        (keys, cols), rep2 = s.scan(prefix="zz").select("img:data").collect()
        assert len(keys) == 0 and cols["img:data"].shape[0] == 0

    def test_predicate_composes_with_range(self):
        t = make_table(per=16, seed=3)
        s = GridSession(t, default_eta=4)
        pred = age_sex_predicate(20, 40, 1)
        res, rep = (s.scan(prefix="c").where(pred, ["age", "sex"])
                    .map(MeanProgram()).collect())
        mask, _ = indexed_query(t, pred, ["age", "sex"],
                                start=b"c", stop=b"d")
        assert rep.query.rows_selected == int(mask.sum())
        assert rep.query.payload_bytes_moved == int(mask.sum()) * ROW_NBYTES
        # index scan charged only for the range, not the table
        per_row = (t.column_spec("idx", "age").row_nbytes
                   + t.column_spec("idx", "sex").row_nbytes)
        assert rep.query.index_bytes_scanned == 16 * per_row
        if mask.any():
            np.testing.assert_allclose(
                np.asarray(res), t.column("img", "data")[mask].mean(0),
                atol=1e-5)

    def test_prefix_exclusive_with_range(self):
        s = GridSession(make_table(per=4))
        with pytest.raises(ValueError):
            s.scan(prefix="b", start="a")

    def test_reduce_requires_map(self):
        s = GridSession(make_table(per=4))
        with pytest.raises(ValueError):
            s.scan().reduce()

    def test_multi_column_compute_runs_per_column(self):
        # the PR-2 single-column restriction is lifted: every mapped
        # program folds over EACH selected column in one pass
        t = make_table(per=4)
        s = GridSession(t)
        q = s.scan().select("img:data", "idx:age").map(MeanProgram())
        res, rep = q.collect()
        assert set(res) == {"img:data", "idx:age"}
        np.testing.assert_allclose(np.asarray(res["img:data"]),
                                   t.column("img", "data").mean(0), atol=1e-5)
        np.testing.assert_allclose(np.asarray(res["idx:age"]),
                                   t.column("idx", "age").mean(0), atol=1e-3)
        rep.query.check_block_invariant()
        rep.query.check_partial_invariant()

    def test_duplicate_compute_columns_rejected(self):
        s = GridSession(make_table(per=4))
        q = s.scan().select("img:data", "img:data").map(MeanProgram())
        with pytest.raises(ValueError):
            q.collect()


# ----------------------------------------------------------------------
# plan cache + laziness
# ----------------------------------------------------------------------

class TestPlanCache:
    def test_equivalent_fresh_plan_hits_cache(self):
        s = GridSession(make_table(per=10), default_eta=4)
        _, r1 = s.scan(prefix="b").map(MeanProgram()).collect()
        assert not r1.plan_cache_hit
        g = s.metrics.payload_gathers
        _, r2 = s.scan(prefix="b").map(MeanProgram()).collect()
        assert r2.plan_cache_hit
        assert s.metrics.payload_gathers == g    # no re-gather

    def test_collect_memoizes_on_plan_object(self):
        s = GridSession(make_table(per=10), default_eta=4)
        q = s.scan(prefix="b").map(MeanProgram())
        res1, _ = q.collect()
        scans = s.metrics.scans
        res2, _ = q.collect()
        assert s.metrics.scans == scans          # executor not re-entered
        assert res1 is res2

    def test_mutation_invalidates_scan_plans(self):
        t = make_table(per=10)
        s = GridSession(t, default_eta=4)
        q = s.scan(prefix="b").map(MeanProgram())
        res1, _ = q.collect()
        # overwrite a b-row: same shapes, new content
        rng = np.random.default_rng(9)
        s.upload(["b0001"], {
            "img": {"data": rng.normal(size=(1,) + PAYLOAD).astype(np.float32)},
            "idx": {"size": np.array([7_000_000]),
                    "age": np.array([30.0], np.float32),
                    "sex": np.array([1], np.int8)}}, on_duplicate="overwrite")
        res2, r2 = q.collect()
        assert not r2.plan_cache_hit
        np.testing.assert_allclose(
            np.asarray(res2), t.column("img", "data")[10:20].mean(0),
            atol=1e-5)
        assert not np.allclose(np.asarray(res1), np.asarray(res2))

    def test_builders_are_pure(self):
        s = GridSession(make_table(per=4))
        base = s.scan(prefix="b")
        q1 = base.map(MeanProgram())
        q2 = base.map(VarianceProgram())
        assert base.programs == ()
        assert len(q1.programs) == 1 and len(q2.programs) == 1

    def test_explain_moves_no_bytes(self):
        s = GridSession(make_table(per=10), default_eta=4)
        text = (s.scan(prefix="b").map(MeanProgram())
                .map(VarianceProgram()).explain())
        assert "1/4" in text and "3 pruned" in text
        assert s.metrics.payload_gathers == 0
        assert s.engine.compile_count == 0


# ----------------------------------------------------------------------
# property: pruned scan == unpruned full-table scan on matching rows
# ----------------------------------------------------------------------

class TestPrunedEqualsUnpruned:
    def test_property_pruned_scan_equals_full_scan_filter(self):
        pytest.importorskip("hypothesis")
        from hypothesis import given, settings, strategies as st

        t = make_table(groups=("a", "b", "c", "d", "e"), per=9, seed=11)
        s = GridSession(t, default_eta=4)
        data = t.column("img", "data")
        keys = t.keys

        @settings(max_examples=25, deadline=None)
        @given(prefix=st.text(alphabet="abcdef", min_size=0, max_size=3))
        def check_prefix(prefix):
            res, rep = (s.scan(prefix=prefix).select("img:data").collect())
            sel_keys, _ = res
            lo, hi = prefix_range(prefix)
            want = [bytes(k) for k in keys
                    if bytes(k).startswith(lo)]
            assert [bytes(k) for k in sel_keys] == want
            # pruned + scanned always tiles the table
            assert (rep.query.regions_scanned + rep.query.regions_pruned
                    == len(t.regions))
            # and the compute path agrees with numpy on the same subset
            if want:
                got, _ = s.scan(prefix=prefix).map(MeanProgram()).collect()
                mask = np.array([bytes(k).startswith(lo) for k in keys])
                np.testing.assert_allclose(
                    np.asarray(got), data[mask].mean(0), atol=1e-5)

        check_prefix()


# ----------------------------------------------------------------------
# auto-rebalance wiring
# ----------------------------------------------------------------------

class TestAutoRebalance:
    def test_auto_rejects_explicit_nodes(self):
        from repro.core.balancer import NodeSpec
        s = GridSession(make_table(per=4))
        with pytest.raises(ValueError):
            s.rebalance(auto=True, nodes=[NodeSpec(0)])

    def test_auto_without_observations_is_plain_rebalance(self):
        s = GridSession(make_table(per=4))
        assert s.rebalance(auto=True) == []

    def test_observe_round_feeds_scheduler_and_history(self):
        s = GridSession(make_table(per=4))
        s.observe_round({0: 2.0})
        s.observe_round({0: 2.5})
        assert s._round_history[0] == [2.0, 2.5]
        assert s.scheduler.round_index == 2
        assert s.scheduler.makespan_estimate() > 0
        # the scheduler's own refreshed specs reflect the slow rounds and
        # are valid input for an explicit rebalance(nodes=...)
        (spec,) = s.scheduler.effective_nodes()
        assert spec.node_id == 0 and spec.power < 1.0
        assert s.rebalance(nodes=s.scheduler.effective_nodes()) == []

    def test_round_history_is_bounded(self):
        s = GridSession(make_table(per=4))
        for i in range(GridSession.ROUND_HISTORY_CAP + 40):
            s.observe_round({0: 1.0 + i})
        assert len(s._round_history[0]) == GridSession.ROUND_HISTORY_CAP
        # oldest entries dropped, newest kept
        assert s._round_history[0][-1] == 1.0 + GridSession.ROUND_HISTORY_CAP + 39

    def test_session_scheduler_cannot_mutate_membership(self):
        # fail/join would rebind the shared placement behind the session's
        # epoch machinery; the session-owned scheduler refuses
        from repro.core.balancer import NodeSpec
        s = GridSession(make_table(per=4))
        with pytest.raises(NotImplementedError):
            s.scheduler.handle_failure([0])
        with pytest.raises(NotImplementedError):
            s.scheduler.handle_join([NodeSpec(9)])

    def test_auto_rebalance_deweights_straggler_multinode(self):
        # needs >1 device to host >1 node; run in a subprocess like
        # test_multidevice does
        import os
        import subprocess
        import sys
        import textwrap
        repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        env["PYTHONPATH"] = os.path.join(repo, "src")
        env.setdefault("JAX_PLATFORMS", "cpu")
        body = """
            import numpy as np
            from repro.core.balancer import NodeSpec
            from repro.core.grid import GridSession
            from repro.core.regions import HierarchicalSplitPolicy
            from repro.core.stats import MeanProgram
            from repro.core.table import make_mip_table

            rng = np.random.default_rng(0)
            t = make_mip_table(
                payload_shape=(2,),
                extra_index_columns=[],
                split_policy=HierarchicalSplitPolicy(max_region_bytes=int(60e6)))
            n = 256
            t.upload([f"r{i:05d}" for i in range(n)],
                     {"img": {"data": rng.normal(size=(n, 2)).astype(np.float32)},
                      "idx": {"size": rng.integers(6e6, 2e7, n)}})
            s = GridSession(t, nodes=[NodeSpec(i, cores=1, mips=1.0)
                                      for i in range(4)])
            before = s.placement.node_bytes()
            # node 3 is persistently 4x slower
            for _ in range(6):
                s.observe_round({0: 1.0, 1: 1.0, 2: 1.0, 3: 4.0})
            moved = s.rebalance(auto=True, tolerance=0.05)
            after = s.placement.node_bytes()
            assert moved, "straggler must force region moves"
            assert after[3] < before[3], (before, after)
            assert s.epoch == 1      # moves advanced the mutation epoch
            res, _ = s.run(MeanProgram())
            np.testing.assert_allclose(np.asarray(res),
                                       t.column("img", "data").mean(0),
                                       atol=1e-5)

            # pruned range scan across the rebalanced multi-node placement
            res2, rep2 = (s.scan(start="r00100", stop="r00200")
                          .map(MeanProgram()).collect())
            q = rep2.query
            assert q.rows_selected == 100, q
            assert q.regions_pruned > 0, q
            assert q.regions_scanned + q.regions_pruned == len(t.regions)
            np.testing.assert_allclose(
                np.asarray(res2),
                t.column("img", "data")[100:200].mean(0), atol=1e-5)
            print("OK")
        """
        proc = subprocess.run(
            [sys.executable, "-c", textwrap.dedent(body)],
            capture_output=True, text=True, env=env, timeout=600)
        assert proc.returncode == 0, (
            f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}")
        assert "OK" in proc.stdout
