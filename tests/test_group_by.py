"""Grouped multi-column analytics: group-aware fold partials, the grouped
CSE segment-sum, bucketed power-of-two fold padding, and merge-path
accounting.

The PR acceptance oracles live here and in test_differential /
test_multidevice: ``scan().select([c1, c2]).group_by(k).stats(...)``
matches a NumPy groupby oracle in ONE pass (each (column, region) block
gathers exactly once however many groups exist); a repeat grouped
``.stats()`` on a clean epoch folds zero rows; a mutation re-folds only the
dirty regions' blocks.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.grid import GridSession
from repro.core.mapreduce import MapReduceEngine
from repro.core.query import age_sex_predicate
from repro.core.regions import HierarchicalSplitPolicy
from repro.core.stats import (
    CountProgram,
    FusedProgram,
    GroupedProgram,
    GroupedResult,
    HistogramProgram,
    MeanProgram,
    MomentsProgram,
    VarianceProgram,
)
from repro.core.table import ColumnSpec, make_mip_table
from repro.utils import make_mesh

PAYLOAD = (3, 4)
N_SITES = 5


def make_table(regions=("a", "b", "c", "d"), per=10, seed=0, sites=N_SITES):
    rng = np.random.default_rng(seed)
    t = make_mip_table(
        payload_shape=PAYLOAD,
        extra_index_columns=[ColumnSpec("age", (), np.float32),
                             ColumnSpec("sex", (), np.int8),
                             ColumnSpec("site", (), np.int32)],
        split_policy=HierarchicalSplitPolicy(max_region_bytes=10**18),
        presplit_keys=list(regions)[1:],
    )
    keys = [f"{g}{i:04d}" for g in regions for i in range(per)]
    n = len(keys)
    t.upload(keys, {
        "img": {"data": rng.normal(size=(n,) + PAYLOAD).astype(np.float32)},
        "idx": {"size": rng.integers(6_000_000, 20_000_001, n),
                "age": rng.uniform(4, 80, n).astype(np.float32),
                "sex": rng.integers(0, 2, n).astype(np.int8),
                "site": rng.integers(0, sites, n).astype(np.int32)}})
    return t


def groupby_oracle(values: np.ndarray, keys: np.ndarray):
    """{key: rows} — the plain-NumPy groupby every test compares against."""
    return {k: values[keys == k] for k in np.unique(keys)}


# ----------------------------------------------------------------------
# correctness vs the NumPy groupby oracle
# ----------------------------------------------------------------------

class TestGroupedCorrectness:
    def test_grouped_stats_match_groupby_oracle(self):
        t = make_table()
        s = GridSession(t, default_eta=4)
        res, rep = (s.scan().select("img:data").group_by("idx:site")
                    .map(MeanProgram()).map(VarianceProgram())
                    .map(CountProgram()).reduce().collect())
        data = t.column("img", "data")
        sites = t.column("idx", "site")
        oracle = groupby_oracle(data, sites)
        assert isinstance(res, GroupedResult)
        assert list(res.keys) == sorted(oracle)
        assert rep.query.num_groups == len(oracle)
        mean, var, count = res.values
        for g, k in enumerate(res.keys):
            want = oracle[k]
            np.testing.assert_allclose(np.asarray(mean)[g], want.mean(0),
                                       atol=1e-4)
            np.testing.assert_allclose(np.asarray(var["var"])[g],
                                       want.var(0), atol=1e-3)
            assert int(np.asarray(count)[g]) == len(want)
        rep.query.check_block_invariant()
        rep.query.check_partial_invariant()

    def test_one_pass_acceptance_single_region(self):
        """Acceptance: a COLD grouped multi-statistic query gathers each
        block exactly once — gather_count == 1 on a one-region table, no
        matter how many groups the key column holds."""
        t = make_table(regions=("a",), per=24)
        s = GridSession(t, default_eta=4)
        res, rep = (s.scan().select(["img:data"]).group_by("idx:site")
                    .map(MeanProgram()).map(VarianceProgram())
                    .reduce().collect())
        q = rep.query
        assert q.gather_count == 1, q          # one gather, G groups
        assert q.blocks_transferred <= 1
        assert q.num_groups == len(np.unique(t.column("idx", "site")))
        assert q.rows_folded == t.num_rows

    def test_multi_column_grouped_one_gather_per_block(self):
        """select([c1, c2]).group_by(k): every program folds over every
        column; gathers stay one per (column, region) — grouping never
        multiplies them."""
        t = make_table()
        s = GridSession(t, default_eta=4)
        res, rep = (s.scan().select(["img:data", "idx:age"])
                    .group_by("idx:sex").map(MeanProgram())
                    .map(VarianceProgram()).reduce().collect())
        n_regions = len(t.regions)
        q = rep.query
        assert q.gather_count == 2 * n_regions, q   # 2 columns × regions
        assert q.partials_total == 2 * n_regions
        sexes = t.column("idx", "sex")
        for col, ref in (("img:data", t.column("img", "data")),
                         ("idx:age", t.column("idx", "age"))):
            gr = res[col]
            oracle = groupby_oracle(ref, sexes)
            assert list(gr.keys) == sorted(oracle)
            mean, var = gr.values
            for g, k in enumerate(gr.keys):
                np.testing.assert_allclose(np.asarray(mean)[g],
                                           oracle[k].mean(0), atol=1e-3)
                np.testing.assert_allclose(np.asarray(var["var"])[g],
                                           oracle[k].var(0), rtol=2e-3,
                                           atol=1e-2)

    def test_grouped_with_predicate(self):
        t = make_table(per=16, seed=3)
        s = GridSession(t, default_eta=4, compact_gather_threshold=0.0)
        pred = age_sex_predicate(20, 60, None)
        res, rep = (s.scan().where(pred, ["age", "sex"])
                    .group_by("idx:site").map(CountProgram())
                    .reduce().collect())
        ages = t.column("idx", "age")
        mask = (ages >= 20) & (ages < 60)
        sites = t.column("idx", "site")[mask]
        oracle = groupby_oracle(sites, sites)
        assert list(res.keys) == sorted(oracle)
        for g, k in enumerate(res.keys):
            assert int(np.asarray(res.values)[g]) == len(oracle[k])

    def test_grouped_with_prefix_range(self):
        t = make_table()
        s = GridSession(t, default_eta=4)
        res, rep = (s.scan(prefix="b").group_by("idx:sex")
                    .map(MeanProgram()).reduce().collect())
        keys = t.keys
        in_b = np.array([k.startswith(b"b") for k in keys])
        data = t.column("img", "data")[in_b]
        sexes = t.column("idx", "sex")[in_b]
        oracle = groupby_oracle(data, sexes)
        for g, k in enumerate(res.keys):
            np.testing.assert_allclose(np.asarray(res.values)[g],
                                       oracle[k].mean(0), atol=1e-4)
        assert rep.query.regions_pruned > 0

    def test_single_group_and_float_keys(self):
        t = make_table(sites=1)                    # every row in one site
        s = GridSession(t, default_eta=4)
        res, rep = (s.scan().group_by("idx:site").map(MeanProgram())
                    .reduce().collect())
        assert len(res) == 1 and rep.query.num_groups == 1
        np.testing.assert_allclose(np.asarray(res.values)[0],
                                   t.column("img", "data").mean(0),
                                   atol=1e-4)
        # float-valued key column groups by exact value
        resf, _ = (s.scan().group_by("idx:age").map(CountProgram())
                   .reduce().collect())
        assert rep.query.num_groups <= len(resf) == t.num_rows

    def test_empty_selection_yields_zero_groups(self):
        t = make_table()
        s = GridSession(t, default_eta=4)
        res, rep = (s.scan(prefix=b"zzz").group_by("idx:site")
                    .map(MeanProgram()).reduce().collect())
        assert len(res) == 0 and rep.query.num_groups == 0
        rep.query.check_partial_invariant()

    def test_grouped_count_is_exact_int(self):
        t = make_table()
        s = GridSession(t, default_eta=4)
        res, _ = (s.scan().group_by("idx:sex").map(CountProgram())
                  .reduce().collect())
        counts = np.asarray(res.values)
        assert counts.dtype == np.int32
        assert counts.sum() == t.num_rows


class TestGroupedValidation:
    def test_group_by_needs_scalar_column(self):
        s = GridSession(make_table(per=4))
        with pytest.raises(ValueError):
            (s.scan().group_by("img:data").map(MeanProgram())
             .reduce().collect())

    def test_group_by_without_map_raises(self):
        s = GridSession(make_table(per=4))
        with pytest.raises(ValueError):
            s.scan().group_by("idx:site").collect()

    def test_double_group_by_raises(self):
        s = GridSession(make_table(per=4))
        with pytest.raises(ValueError):
            s.scan().group_by("idx:site").group_by("idx:sex")

    def test_explain_shows_group(self):
        s = GridSession(make_table(per=4))
        text = (s.scan().group_by("idx:site").map(MeanProgram())
                .reduce().explain())
        assert "idx:site" in text


# ----------------------------------------------------------------------
# caching: group-keyed partials ride the same content-addressed machinery
# ----------------------------------------------------------------------

class TestGroupedCaching:
    def grouped(self, s):
        return (s.scan().select("img:data").group_by("idx:site")
                .map(MeanProgram()).map(VarianceProgram()).reduce())

    def test_repeat_grouped_stats_folds_zero_rows(self):
        """Acceptance: repeat grouped .stats() on a clean epoch folds 0."""
        t = make_table()
        s = GridSession(t, default_eta=4)
        r1 = self.grouped(s).stats()
        assert r1.query.rows_folded == t.num_rows
        r2 = self.grouped(s).stats()                # fresh plan object
        q = r2.query
        assert r2.plan_cache_hit
        assert q.rows_folded == 0, q
        assert q.partials_reused == q.partials_total
        q.check_partial_invariant()

    def test_mutation_refolds_only_dirty_region(self):
        """Acceptance: a mutation that keeps the group universe stable
        re-folds exactly the dirty region's blocks."""
        t = make_table()
        s = GridSession(t, default_eta=4)
        self.grouped(s).stats()
        rng = np.random.default_rng(9)
        # overwrite one row, PRESERVING its index columns (group universe
        # and row masks unchanged -> only the region's version bumps)
        key = b"b0003"
        _, age = s.retrieve("idx", "age", rowkey=key)
        _, sex = s.retrieve("idx", "sex", rowkey=key)
        _, site = s.retrieve("idx", "site", rowkey=key)
        _, size = s.retrieve("idx", "size", rowkey=key)
        s.upload([key], {
            "img": {"data": rng.normal(size=(1,) + PAYLOAD)
                    .astype(np.float32)},
            "idx": {"size": size, "age": age, "sex": sex, "site": site}},
            on_duplicate="overwrite")
        res, rep = self.grouped(s).collect()
        q = rep.query
        dirty = t.regions.region_for(key)
        assert q.partials_reused == q.partials_total - 1, q
        assert q.rows_folded == dirty.num_rows(t.keys), q
        # and the answer is right
        data, sites = t.column("img", "data"), t.column("idx", "site")
        mean = res.values[0]
        for g, k in enumerate(res.keys):
            np.testing.assert_allclose(np.asarray(mean)[g],
                                       data[sites == k].mean(0), atol=1e-4)

    def test_group_universe_change_invalidates_but_stays_correct(self):
        """A mutation that changes the distinct key values re-signs the
        mapping (gid assignment is global), so group-keyed partials under
        the old mapping can't be misused — and results stay correct."""
        t = make_table(sites=3)
        s = GridSession(t, default_eta=4)
        self.grouped(s).stats()
        rng = np.random.default_rng(4)
        _, age = s.retrieve("idx", "age", rowkey=b"a0000")
        s.upload([b"a0000"], {
            "img": {"data": rng.normal(size=(1,) + PAYLOAD)
                    .astype(np.float32)},
            "idx": {"size": np.array([7_000_000]), "age": age,
                    "sex": np.array([0], np.int8),
                    "site": np.array([77], np.int32)}},   # NEW site value
            on_duplicate="overwrite")
        res, rep = self.grouped(s).collect()
        data, sites = t.column("img", "data"), t.column("idx", "site")
        assert 77 in res.keys
        assert rep.query.num_groups == len(np.unique(sites))
        mean = res.values[0]
        for g, k in enumerate(res.keys):
            np.testing.assert_allclose(np.asarray(mean)[g],
                                       data[sites == k].mean(0), atol=1e-4)

    def test_distinct_group_columns_keep_distinct_partials(self):
        t = make_table()
        s = GridSession(t, default_eta=4)
        (s.scan().group_by("idx:site").map(MeanProgram()).reduce().stats())
        r = (s.scan().group_by("idx:sex").map(MeanProgram()).reduce()
             .stats())
        q = r.query
        assert q.partials_reused == 0 and q.rows_folded > 0, q
        # ...but the payload BLOCKS are shared: no re-gather
        assert q.gather_count == 0 and q.blocks_reused == q.blocks_total

    def test_grouped_and_ungrouped_partials_are_distinct(self):
        t = make_table()
        s = GridSession(t, default_eta=4)
        s.run(MeanProgram())
        r = (s.scan().group_by("idx:site").map(MeanProgram()).reduce()
             .stats())
        assert r.query.partials_reused == 0 and r.query.rows_folded > 0
        assert r.query.gather_count == 0       # blocks shared

    def test_rebalance_refolds_nothing_grouped(self):
        t = make_table()
        s = GridSession(t, default_eta=4)
        self.grouped(s).stats()
        s.rebalance(tolerance=0.0)
        r = self.grouped(s).stats()
        assert r.query.rows_folded == 0, r.query

    def test_masked_out_nan_rows_do_not_poison_groups(self):
        """A NaN/Inf payload in a predicate-EXCLUDED row must not leak into
        any group's segment sums (the grouped CSE zeroes unclaimed rows
        before raising powers, like the ungrouped _masked path)."""
        t = make_table()
        bad = np.full((1,) + PAYLOAD, np.nan, np.float32)
        t.upload([b"a0000"], {
            "img": {"data": bad},
            "idx": {"size": np.array([7_000_000]),
                    "age": np.array([1.0], np.float32),   # below the window
                    "sex": np.array([0], np.int8),
                    "site": np.array([0], np.int32)}},
            on_duplicate="overwrite")
        s = GridSession(t, default_eta=4, compact_gather_threshold=0.0)
        pred = age_sex_predicate(4, None, None)           # excludes the NaN row
        res, rep = (s.scan().where(pred, ["age", "sex"])
                    .group_by("idx:site")
                    .map(MeanProgram()).map(VarianceProgram())
                    .reduce().collect())
        ages = t.column("idx", "age")
        sel = ages >= 4
        data, sites = t.column("img", "data")[sel], t.column("idx",
                                                             "site")[sel]
        mean, var = res.values
        assert np.isfinite(np.asarray(mean)).all()
        assert np.isfinite(np.asarray(var["var"])).all()
        for g, k in enumerate(res.keys):
            np.testing.assert_allclose(np.asarray(mean)[g],
                                       data[sites == k].mean(0), atol=1e-4)

    def test_group_mapping_memoized_per_lineage(self):
        """Repeat grouped queries reuse the resolved mapping (no per-repeat
        unique+hash over the selection); mutations re-resolve."""
        t = make_table()
        s = GridSession(t, default_eta=4)
        self.grouped(s).stats()
        assert len(s._groups) == 1
        self.grouped(s).stats()
        assert len(s._groups) == 1                 # memo hit, no new entry
        s.remove(rowkey=b"a0000")
        self.grouped(s).stats()
        assert len(s._groups) == 2                 # new lineage, new entry

    def test_grouped_skips_compact_path(self):
        # grouping always takes block granularity (partials are the point)
        t = make_table(per=32, seed=5)
        s = GridSession(t, default_eta=4, compact_gather_threshold=0.5)
        r = (s.scan().where(age_sex_predicate(None, 10.0, None),
                            ["age", "sex"])
             .group_by("idx:sex").map(MeanProgram()).reduce().stats())
        assert r.query.gather_path == "blocks", r.query


# ----------------------------------------------------------------------
# composite group keys: group_by(["f:a", "f:b"]) — tuple-labeled groups
# ----------------------------------------------------------------------

class TestCompositeKeys:
    def composite(self, s, cols=("idx:site", "idx:sex")):
        return (s.scan().select("img:data").group_by(list(cols))
                .map(MeanProgram()).map(CountProgram()).reduce())

    def test_composite_key_matches_oracle(self):
        t = make_table(sites=3)
        s = GridSession(t, default_eta=4)
        res, rep = self.composite(s).collect()
        data = t.column("img", "data")
        sites, sexes = t.column("idx", "site"), t.column("idx", "sex")
        combos = sorted({(int(a), int(b)) for a, b in zip(sites, sexes)})
        assert isinstance(res, GroupedResult)
        assert [tuple(int(x) for x in k) for k in res.keys] == combos
        assert rep.query.num_groups == len(combos)
        mean, count = res.values
        for g, k in enumerate(res.keys):
            sel = (sites == k[0]) & (sexes == k[1])
            np.testing.assert_allclose(np.asarray(mean)[g],
                                       data[sel].mean(0), atol=1e-4)
            assert int(np.asarray(count)[g]) == int(sel.sum())
        rep.query.check_partial_invariant()

    def test_key_order_is_a_distinct_grouping(self):
        """["idx:site", "idx:sex"] and the reverse are different groupings
        with different tuple labels AND distinct partial-cache identities
        (group_sig hashes the ordered column list)."""
        t = make_table(sites=3)
        s = GridSession(t, default_eta=4)
        r1, _ = self.composite(s, ("idx:site", "idx:sex")).collect()
        r = self.composite(s, ("idx:sex", "idx:site")).stats()
        q = r.query
        assert q.partials_reused == 0 and q.rows_folded > 0, q
        assert q.gather_count == 0          # payload blocks are shared
        sigs = {info.sig for info in s._groups.values()}
        assert len(s._groups) == len(sigs) == 2
        r2, _ = self.composite(s, ("idx:sex", "idx:site")).collect()
        assert {tuple(map(int, k)) for k in r2.keys} == \
            {(int(k[1]), int(k[0])) for k in r1.keys}

    def test_composite_mutation_refolds_only_dirty_region(self):
        t = make_table(sites=3)
        s = GridSession(t, default_eta=4)
        self.composite(s).stats()
        rng = np.random.default_rng(17)
        key = b"c0002"
        _, age = s.retrieve("idx", "age", rowkey=key)
        _, sex = s.retrieve("idx", "sex", rowkey=key)
        _, site = s.retrieve("idx", "site", rowkey=key)
        _, size = s.retrieve("idx", "size", rowkey=key)
        s.upload([key], {
            "img": {"data": rng.normal(size=(1,) + PAYLOAD)
                    .astype(np.float32)},
            "idx": {"size": size, "age": age, "sex": sex, "site": site}},
            on_duplicate="overwrite")
        res, rep = self.composite(s).collect()
        q = rep.query
        dirty = t.regions.region_for(key)
        assert q.partials_reused == q.partials_total - 1, q
        assert q.rows_folded == dirty.num_rows(t.keys), q
        data = t.column("img", "data")
        sites, sexes = t.column("idx", "site"), t.column("idx", "sex")
        mean = res.values[0]
        for g, k in enumerate(res.keys):
            sel = (sites == k[0]) & (sexes == k[1])
            np.testing.assert_allclose(np.asarray(mean)[g],
                                       data[sel].mean(0), atol=1e-4)

    def test_composite_universe_change_stays_correct(self):
        t = make_table(sites=2)
        s = GridSession(t, default_eta=4)
        self.composite(s).stats()
        rng = np.random.default_rng(21)
        s.upload([b"a0001"], {
            "img": {"data": rng.normal(size=(1,) + PAYLOAD)
                    .astype(np.float32)},
            "idx": {"size": np.array([7_000_000]),
                    "age": np.array([30.0], np.float32),
                    "sex": np.array([0], np.int8),
                    "site": np.array([55], np.int32)}},  # NEW site value
            on_duplicate="overwrite")
        res, rep = self.composite(s).collect()
        sites, sexes = t.column("idx", "site"), t.column("idx", "sex")
        combos = sorted({(int(a), int(b)) for a, b in zip(sites, sexes)})
        assert [tuple(map(int, k)) for k in res.keys] == combos
        assert any(int(k[0]) == 55 for k in res.keys)

    def test_tuple_keyed_result_api(self):
        t = make_table(sites=2)
        s = GridSession(t, default_eta=4)
        res, _ = self.composite(s).collect()
        k0 = tuple(res.keys[0])
        g = res.group(k0)
        np.testing.assert_array_equal(np.asarray(g[0]),
                                      np.asarray(res.values[0])[0])
        assert res.index_of(k0) == 0
        d = res.asdict()
        assert len(d) == len(res)
        assert all(isinstance(k, tuple) and len(k) == 2 for k in d)
        with pytest.raises(KeyError):
            res.index_of((99, 99))

    def test_composite_validation_and_explain(self):
        s = GridSession(make_table(per=4))
        with pytest.raises(ValueError):
            s.scan().group_by([])
        with pytest.raises(ValueError):
            s.scan().group_by(["idx:site", "idx:site"])
        plan = (s.scan().group_by(["idx:site", "idx:sex"])
                .map(MeanProgram()).reduce())
        assert "idx:site, idx:sex" in plan.explain()
        rev = (s.scan().group_by(["idx:sex", "idx:site"])
               .map(MeanProgram()).reduce())
        assert plan.signature() != rev.signature()


# ----------------------------------------------------------------------
# GroupedProgram / GroupedResult units
# ----------------------------------------------------------------------

class TestGroupedProgram:
    def fold_grouped(self, program, data, gids, G, eta=4):
        eng = MapReduceEngine(make_mesh((1,), ("data",)))
        gp = GroupedProgram(program, G)
        p = eng.fold_block(gp, jnp.asarray(data), None, eta, PAYLOAD,
                           np.float32, gids=jnp.asarray(gids), num_groups=G)
        return eng.merge_finalize(gp, [p], PAYLOAD, np.float32)

    @pytest.mark.parametrize("program", [
        MeanProgram(), VarianceProgram(), MomentsProgram(),
        HistogramProgram(lo=-4, hi=4, bins=8), CountProgram(),
    ])
    def test_grouped_fold_equals_per_group_fold(self, program):
        """Property: a grouped fold == the base program folded over each
        group's rows separately, for CSE'd and private members alike."""
        rng = np.random.default_rng(0)
        n, G = 22, 3
        data = rng.normal(size=(n,) + PAYLOAD).astype(np.float32)
        gids = rng.integers(0, G, n).astype(np.int32)
        got = self.fold_grouped(program, data, gids, G)
        eng = MapReduceEngine(make_mesh((1,), ("data",)))
        for g in range(G):
            sub = data[gids == g]
            p = eng.fold_block(program, jnp.asarray(sub), None, 4,
                               PAYLOAD, np.float32)
            want = eng.merge_finalize(program, [p], PAYLOAD, np.float32)
            jax.tree.map(
                lambda a, b: np.testing.assert_allclose(
                    np.asarray(a)[g], np.asarray(b), rtol=2e-4, atol=2e-3),
                got, want)

    def test_grouped_fused_additive_and_cse(self):
        fused = FusedProgram((MeanProgram(), VarianceProgram(),
                              MomentsProgram()))
        gp = GroupedProgram(fused, 4)
        assert gp.additive                      # CSE keeps the psum reduce
        zero = gp.zero(PAYLOAD, np.float32)
        assert zero["private"] == ()
        (dt, pool), = ((k, v) for k, v in zero["shared"].items())
        assert pool["count"].shape == (4,)      # per-group counts
        assert pool["s1"].shape == (4,) + PAYLOAD

    def test_cache_key_includes_group_count(self):
        a = GroupedProgram(MeanProgram(), 3).cache_key()
        b = GroupedProgram(MeanProgram(), 4).cache_key()
        assert a != b
        assert GroupedProgram(MeanProgram(), 3).cache_key() == a

    def test_grouped_result_api(self):
        vals = jnp.arange(6.0).reshape(3, 2)
        r = GroupedResult(keys=np.array([2, 5, 9]), values=vals)
        assert len(r) == 3
        np.testing.assert_array_equal(np.asarray(r.group(5)), [2.0, 3.0])
        d = r.asdict()
        assert set(d) == {2, 5, 9}
        with pytest.raises(KeyError):
            r.index_of(4)

    def test_grouped_program_validation(self):
        with pytest.raises(ValueError):
            GroupedProgram(MeanProgram(), -1)
        with pytest.raises(ValueError):
            GroupedProgram(None, 3)


# ----------------------------------------------------------------------
# bucketed power-of-two fold padding
# ----------------------------------------------------------------------

class TestBucketedPadding:
    def test_distinct_block_sizes_share_pow2_executables(self):
        eng = MapReduceEngine(make_mesh((1,), ("data",)))
        c0 = eng.compile_count
        for r in (5, 6, 7, 8, 9, 12, 13, 15, 16):
            eng.fold_block(MeanProgram(), jnp.ones((r,) + PAYLOAD), None,
                           4, PAYLOAD, np.float32)
        # buckets 8, 8, 8, 8*, 16, 16, 16, 16, 16* — *unmasked exact-pow2
        # blocks skip the mask, so 2 bucket sizes × (masked, unmasked)
        assert eng.compile_count - c0 <= 4, eng.compile_count - c0

    def test_padded_fold_matches_unpadded(self):
        rng = np.random.default_rng(1)
        data = rng.normal(size=(11,) + PAYLOAD).astype(np.float32)
        mask = rng.integers(0, 2, 11).astype(bool)
        mask[0] = True
        ref_eng = MapReduceEngine(make_mesh((1,), ("data",)),
                                  block_pad="none")
        pow2_eng = MapReduceEngine(make_mesh((1,), ("data",)))
        for m in (None, jnp.asarray(mask)):
            a = ref_eng.fold_block(MeanProgram(), jnp.asarray(data), m, 4,
                                   PAYLOAD, np.float32)
            b = pow2_eng.fold_block(MeanProgram(), jnp.asarray(data), m, 4,
                                    PAYLOAD, np.float32)
            jax.tree.map(lambda x, y: np.testing.assert_allclose(
                np.asarray(x), np.asarray(y), atol=1e-5), a, b)

    def test_grouped_padded_fold_correct(self):
        rng = np.random.default_rng(2)
        n, G = 13, 3                               # pads to 16
        data = rng.normal(size=(n,) + PAYLOAD).astype(np.float32)
        gids = rng.integers(0, G, n).astype(np.int32)
        eng = MapReduceEngine(make_mesh((1,), ("data",)))
        gp = GroupedProgram(CountProgram(), G)
        p = eng.fold_block(gp, jnp.asarray(data), None, 4, PAYLOAD,
                           np.float32, gids=jnp.asarray(gids), num_groups=G)
        got = eng.merge_finalize(gp, [p], PAYLOAD, np.float32)
        for g in range(G):
            assert int(np.asarray(got)[g]) == int((gids == g).sum())

    def test_funnel_merge_buckets_partial_count(self):
        eng = MapReduceEngine(make_mesh((1,), ("data",)))
        mk = lambda: eng.fold_block(MeanProgram(), jnp.ones((4,) + PAYLOAD),
                                    None, 4, PAYLOAD, np.float32)
        ps = [mk() for _ in range(9)]
        c0 = eng.compile_count
        for n in (3, 4, 5, 6, 7, 8):
            eng.merge_finalize(MeanProgram(), ps[:n], PAYLOAD, np.float32)
        # counts bucket to 4 and 8: two merge executables, not six
        assert eng.compile_count - c0 == 2, eng.compile_count - c0

    def test_invalid_policies_rejected(self):
        with pytest.raises(ValueError):
            MapReduceEngine(make_mesh((1,), ("data",)), block_pad="pow3")
        with pytest.raises(ValueError):
            MapReduceEngine(make_mesh((1,), ("data",)),
                            merge_strategy="ring")


# ----------------------------------------------------------------------
# merge-path accounting (the tree reduce itself needs >1 device: see
# test_multidevice.py::test_tree_reduce_merge_8dev)
# ----------------------------------------------------------------------

class TestMergePath:
    def test_single_device_funnels(self):
        t = make_table()
        s = GridSession(t, default_eta=4)
        r = s.scan().map(MeanProgram()).reduce().stats()
        if jax.device_count() == 1:
            assert r.query.merge_path == "funnel", r.query
        assert s.engine.merge_path_counts["funnel"] + \
            s.engine.merge_path_counts["tree"] >= 1

    def test_result_cache_hit_reports_no_merge(self):
        t = make_table()
        s = GridSession(t, default_eta=4)
        s.run(MeanProgram())
        _, rep = s.run(MeanProgram())
        assert rep.plan_cache_hit and rep.query.merge_path == ""
