"""The CI perf gate (benchmarks/check_regression.py): passes in-tolerance
metrics, FAILS on an injected >tolerance regression, and never passes
vacuously when a required artifact or metric is missing.

The injected-regression cases here are the same demonstration the PR
description quotes:

    python -m benchmarks.check_regression --bench-dir <dir-with-bad-json>
"""

import json

import pytest

from benchmarks.check_regression import check_metric, run_gate


def write(path, payload):
    path.write_text(json.dumps(payload))


@pytest.fixture
def baselines(tmp_path):
    p = tmp_path / "perf_baselines.json"
    write(p, {
        "default_tolerance": 0.25,
        "metrics": {
            "fake": {
                "speedup": {"baseline": 4.0, "direction": "higher"},
                "flop_ratio": {"baseline": 0.66, "direction": "lower"},
                "probe": {"baseline": 1.0, "direction": "higher",
                          "optional": True},
            },
        },
    })
    return p


def emit(tmp_path, **metrics):
    write(tmp_path / "BENCH_fake.json",
          {"bench": "fake", "elapsed_us": 1,
           "speedup": 4.1, "flop_ratio": 0.65, "probe": 1.2, **metrics})


class TestPerfGate:
    def test_passes_within_tolerance(self, tmp_path, baselines):
        emit(tmp_path)
        ok, lines = run_gate(str(tmp_path), str(baselines))
        assert ok, lines

    def test_fails_on_injected_regression(self, tmp_path, baselines):
        # >25% below the 4.0 baseline: 4.0 * 0.75 = 3.0 is the floor
        emit(tmp_path, speedup=2.9)
        ok, lines = run_gate(str(tmp_path), str(baselines))
        assert not ok
        assert any("REGRESSION" in ln and "speedup" in ln for ln in lines)

    def test_boundary_is_not_a_regression(self, tmp_path, baselines):
        emit(tmp_path, speedup=3.0)          # exactly the 25% floor
        ok, _ = run_gate(str(tmp_path), str(baselines))
        assert ok

    def test_lower_direction_gates_increases(self, tmp_path, baselines):
        # flop RATIO regresses by going UP: 0.66 * 1.25 = 0.825 ceiling
        emit(tmp_path, flop_ratio=0.9)
        ok, lines = run_gate(str(tmp_path), str(baselines))
        assert not ok
        assert any("REGRESSION" in ln and "flop_ratio" in ln
                   for ln in lines)

    def test_missing_artifact_fails(self, tmp_path, baselines):
        ok, lines = run_gate(str(tmp_path), str(baselines))
        assert not ok
        assert any("MISSING" in ln for ln in lines)

    def test_missing_metric_fails(self, tmp_path, baselines):
        write(tmp_path / "BENCH_fake.json",
              {"bench": "fake", "flop_ratio": 0.6, "probe": 1.0})
        ok, lines = run_gate(str(tmp_path), str(baselines))
        assert not ok

    def test_optional_probe_zero_is_skipped(self, tmp_path, baselines):
        # the multi-device merge probe reports 0 where the subprocess is
        # unavailable — that is "no data", not a regression
        emit(tmp_path, probe=0.0)
        ok, lines = run_gate(str(tmp_path), str(baselines))
        assert ok, lines

    def test_optional_probe_regression_still_fails(self, tmp_path,
                                                   baselines):
        emit(tmp_path, probe=0.5)            # real data, below tolerance
        ok, _ = run_gate(str(tmp_path), str(baselines))
        assert not ok

    def test_check_metric_directions(self):
        assert check_metric("m", 3.9, 4.0, "higher", 0.25)[0]
        assert not check_metric("m", 2.9, 4.0, "higher", 0.25)[0]
        assert check_metric("m", 0.8, 0.66, "lower", 0.25)[0]
        assert not check_metric("m", 0.9, 0.66, "lower", 0.25)[0]
        assert not check_metric("m", 1.0, 1.0, "sideways", 0.25)[0]

    def test_committed_baselines_parse_and_cover_group_by(self):
        from benchmarks.check_regression import DEFAULT_BASELINES
        spec = json.load(open(DEFAULT_BASELINES))
        assert "group_by" in spec["metrics"]
        assert "grouped_speedup_vs_loop" in spec["metrics"]["group_by"]
        for bench, metrics in spec["metrics"].items():
            for name, m in metrics.items():
                assert m.get("direction") in ("higher", "lower"), (bench,
                                                                   name)
                assert float(m["baseline"]) > 0
